"""OTP router training on a PMQ-compressed checkpoint (paper §3.4).

    PYTHONPATH=src python examples/otp_training.py --ckpt results/ckpt_moe100m

Loads the 100M MoE checkpoint (train it first with train_moe_100m.py, or
the script falls back to a random model), compresses with PMQ, trains the
per-layer DM routers with different sparsity weights λ and reports the
mask-ratio trajectories (paper Fig. 13).
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import pipeline
from repro.core.otp_train import OTPTrainConfig, train_otp
from repro.data.pipeline import make_calibration_tokens
from repro.models.registry import get_model
from train_moe_100m import CFG_100M


def load_params(ckpt_dir):
    bundle = get_model(CFG_100M)
    params = bundle.init(jax.random.PRNGKey(0))
    try:
        ckpt = Checkpointer(ckpt_dir)
        last = ckpt.latest_step()
        if last is not None:
            from repro.optim.adamw import AdamWConfig, adamw_init

            opt = adamw_init(params, AdamWConfig())
            st = ckpt.restore(last, {"params": params, "opt": opt})
            print(f"loaded checkpoint step {last}")
            return st["params"]
    except FileNotFoundError:
        pass
    print("WARNING: no checkpoint found — using random init")
    return params


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--ckpt", default="results/ckpt_moe100m")
    p.add_argument("--steps", type=int, default=60)
    p.add_argument("--lams", default="0.5,1.0,2.0")
    p.add_argument("--bits", type=float, default=2.25)
    args = p.parse_args()

    cfg = CFG_100M
    params = load_params(args.ckpt)
    calib_tokens = jnp.asarray(make_calibration_tokens(cfg.vocab_size, 8, 128))
    calib = pipeline.calibrate(params, calib_tokens, cfg)
    eps = pipeline.compute_eps(params, calib, cfg, eps_tokens=512)
    plan = pipeline.run_pmq(params, calib, cfg, target_avg_bits=args.bits, eps=eps)
    print(f"PMQ: avg {plan.avg_bits:.3f} bits {plan.histogram()}")
    blocks_c, top = pipeline.compress_model(
        params, calib, plan, cfg, use_gptq=False
    )
    data = make_calibration_tokens(cfg.vocab_size, 128, 64, seed=3)
    out = {}
    for lam in [float(x) for x in args.lams.split(",")]:
        tcfg = OTPTrainConfig(steps=args.steps, batch=4, lr=5e-3, lam=lam)
        _, hist = train_otp(blocks_c, top, cfg, data, tcfg)
        traj = [h["mask_ratio"] for h in hist]
        out[lam] = {"final_mask_ratio": traj[-1], "final_kl": hist[-1]["kl"]}
        print(f"λ={lam}: mask ratio {traj[0]:.3f} → {traj[-1]:.3f} "
              f"(KL {hist[-1]['kl']:.4f})")
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
