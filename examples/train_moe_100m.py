"""End-to-end driver (deliverable b): train a ~100M-param MoE LM for a few
hundred steps on the synthetic corpus, with checkpoint/restart.

    PYTHONPATH=src python examples/train_moe_100m.py --steps 300

The trained checkpoint is the subject of the paper-table benchmarks
(benchmarks/ reuse it via --ckpt). ~100M params: 6 layers x 512 d_model x
16 experts (top-2) x 1024 d_ff + 32k vocab.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import HostDataLoader
from repro.models.registry import get_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine

CFG_100M = ModelConfig(
    name="moe-100m",
    family="moe",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    head_dim=64,
    d_ff=1024,
    d_ff_expert=1024,
    vocab_size=32768,
    num_experts=16,
    top_k=2,
    num_shared_experts=1,
    dtype="float32",
    remat="none",
    logits_chunk=64,
    attn_q_chunk=128,
    attn_kv_chunk=128,
    moe_capacity_factor=1.5,
)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=6e-4)
    p.add_argument("--ckpt-dir", default="results/ckpt_moe100m")
    p.add_argument("--log-every", type=int, default=20)
    args = p.parse_args()

    cfg = CFG_100M
    bundle = get_model(cfg)
    print(f"params: {cfg.param_count()/1e6:.0f}M "
          f"(active/token {cfg.active_param_count()/1e6:.0f}M)")
    params = bundle.init(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=args.lr)
    opt_state = adamw_init(params, ocfg)
    loader = HostDataLoader(
        vocab=cfg.vocab_size, global_batch=args.batch, seq_len=args.seq
    )
    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    start = 0
    last = ckpt.latest_step()
    if last is not None:
        st = ckpt.restore(last, {"params": params, "opt": opt_state})
        params, opt_state = st["params"], st["opt"]
        start = last + 1
        print(f"resumed from step {last}")

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            loss, _ = bundle.train_loss(p, batch)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr_scale = warmup_cosine(opt_state["step"], warmup=20, total=args.steps)
        params, opt_state = adamw_update(params, grads, opt_state, ocfg, lr_scale)
        return params, opt_state, loss

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in loader.batch_at(step).items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"({time.time()-t0:.0f}s)")
        if (step + 1) % 100 == 0:
            ckpt.save(step, {"params": params, "opt": opt_state})
    ckpt.save(args.steps - 1, {"params": params, "opt": opt_state}, blocking=True)
    ckpt.wait()
    print("checkpoint:", args.ckpt_dir)


if __name__ == "__main__":
    main()
