"""Quickstart: the full MC# pipeline on a pocket-size MoE LM, on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. builds a small 8-expert MoE LM,
2. runs PMQ: calibration → significance (Eq. 6) → IP bit allocation
   (Eq. 7) → GPTQ quantization → bit-bucketed compressed model,
3. runs OTP: Gumbel-Softmax router distillation (Eq. 14),
4. compares weights bytes / activated experts / output agreement.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import pipeline
from repro.core.otp_train import OTPTrainConfig, train_otp
from repro.data.pipeline import make_calibration_tokens
from repro.models.registry import get_model
from repro.models import transformer as tf

CFG = ModelConfig(
    name="quickstart-moe",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=128,
    d_ff_expert=128,
    vocab_size=512,
    num_experts=8,
    top_k=2,
    num_shared_experts=1,
    dtype="float32",
    remat="none",
    logits_chunk=64,
    attn_q_chunk=64,
    attn_kv_chunk=64,
    moe_capacity_factor=2.0,
)


def main():
    print("=== MC# quickstart ===")
    bundle = get_model(CFG)
    params = bundle.init(jax.random.PRNGKey(0))
    fp_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    print(f"model: {CFG.num_experts} experts x {CFG.num_layers} layers, "
          f"{fp_bytes/1e6:.1f} MB fp32")

    # --- PMQ ---------------------------------------------------------
    calib_tokens = jnp.asarray(
        make_calibration_tokens(CFG.vocab_size, n=8, seq=64)
    )
    calib = pipeline.calibrate(params, calib_tokens, CFG)
    print(f"calibration: phi[0] = {np.round(calib.phi[0], 3)}")
    eps = pipeline.compute_eps(params, calib, CFG, eps_tokens=256)
    plan = pipeline.run_pmq(params, calib, CFG, target_avg_bits=2.25, eps=eps)
    print(f"PMQ plan: avg {plan.avg_bits:.3f} bits, "
          f"histogram {plan.histogram()}, per-layer budgets {plan.layer_budgets}")
    blocks_c, top = pipeline.compress_model(params, calib, plan, CFG, use_gptq=True,
                                            gptq_tokens=512)
    c_bytes = pipeline.model_weight_bytes(blocks_c, top)
    print(f"compressed: {c_bytes/1e6:.1f} MB ({fp_bytes/c_bytes:.1f}x smaller)")

    # fidelity
    test_tokens = calib_tokens[:2]
    h_fp, _, _ = tf.forward_hidden(params, test_tokens, CFG)
    h_c, _ = pipeline.compressed_forward(blocks_c, top, test_tokens, CFG)
    cos = float(
        jnp.sum(h_fp * h_c)
        / (jnp.linalg.norm(h_fp) * jnp.linalg.norm(h_c))
    )
    print(f"hidden-state cosine vs fp32: {cos:.4f}")

    # --- OTP ---------------------------------------------------------
    data = make_calibration_tokens(CFG.vocab_size, n=64, seq=32, seed=7)
    tcfg = OTPTrainConfig(steps=40, batch=4, lr=5e-3, lam=1.5)
    otp_params, hist = train_otp(blocks_c, top, CFG, data, tcfg)
    print(f"OTP: mask ratio {hist[0]['mask_ratio']:.3f} → "
          f"{hist[-1]['mask_ratio']:.3f}, final KL {hist[-1]['kl']:.4f}")
    print("=== done ===")


if __name__ == "__main__":
    main()
