"""Continuous-batching serving example over the paged-KV engine.

    PYTHONPATH=src python examples/serve_batched.py --arch moonshot-v1-16b-a3b

Drives the *reduced* config of an assigned MoE arch through
``repro.serving.PagedServingEngine`` on CPU and demonstrates the three
properties a wave batcher cannot provide:

1. requests with different ``max_new`` finish independently (a finished
   request frees its slot + KV pages immediately),
2. a queued request is admitted **mid-flight** into the running batch
   (visible as ``mid_flight_admissions`` / slot releases in metrics —
   slot turnover without a wave barrier),
3. paged decode is *exactly* the dense decode: greedy tokens and logits
   of a solo request match the dense prefill+decode reference allclose,
4. dynamic page growth + preemption: the same workload through a pool at
   ~half the worst-case demand still finishes every request with the
   same tokens — victims are swapped to host memory and resumed.
"""
import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.registry import get_model
from repro.serving import EngineConfig, PagedServingEngine, Request
from repro.serving.engine import dense_greedy_reference


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS, default="moonshot-v1-16b-a3b")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--max-new", type=int, default=12)
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    engine = PagedServingEngine(
        cfg, params,
        EngineConfig(max_slots=3, block_size=8, num_blocks=24,
                     max_blocks_per_slot=8, prefill_chunk=8),
    )
    # different max_new per request → slots free at different steps
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=16).astype(np.int32),
            max_new=max(2, args.max_new - 2 * i),
        )
        for i in range(args.requests)
    ]
    out = engine.serve(reqs)
    for rid in sorted(out):
        print(f"req {rid}: {len(out[rid])} tokens {out[rid][:8]}...")
    m = engine.metrics.summary()
    print("metrics:", engine.metrics.to_json())
    assert all(len(out[r.rid]) == r.max_new for r in reqs), \
        "requests must finish at their own max_new"
    assert m["mid_flight_admissions"] > 0, \
        "queued requests should join the batch mid-decode (slot turnover)"
    print(f"continuous batching OK: {m['mid_flight_admissions']} requests "
          f"admitted mid-flight, {m['slot_releases']} slot releases")

    # --- paged vs dense equivalence (solo request, greedy) -------------
    prompt = rng.integers(0, cfg.vocab_size, size=13).astype(np.int32)
    max_new = 6
    # the reference runs at the engine's drop-free expert capacity so the
    # comparison isolates the cache layout (see EngineConfig)
    ref_toks, _ = dense_greedy_reference(engine.model_cfg, params, prompt, max_new)
    solo = PagedServingEngine(
        cfg, params,
        EngineConfig(max_slots=2, block_size=4, num_blocks=16,
                     max_blocks_per_slot=8, prefill_chunk=4),
    )
    paged_toks = solo.serve([Request(rid=0, prompt=prompt, max_new=max_new)])[0]
    assert paged_toks == ref_toks, (paged_toks, ref_toks)
    print(f"paged == dense greedy decode: {paged_toks}")

    # --- pool pressure: growth + preemption, same outputs -------------
    def fresh():
        return [
            Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
            for r in reqs
        ]

    bs = 8  # block size shared by the page-count math and the engine
    demand = sum(-(-(len(r.prompt) + r.max_new) // bs) for r in reqs)
    biggest = max(-(-(len(r.prompt) + r.max_new) // bs) for r in reqs)
    tight = PagedServingEngine(
        cfg, params,
        EngineConfig(max_slots=len(reqs), block_size=bs,
                     num_blocks=max(biggest, demand // 2),
                     max_blocks_per_slot=8, prefill_chunk=bs,
                     preempt_mode="swap"),
    )
    out_tight = tight.serve(fresh())
    mt = tight.metrics.summary()
    assert out_tight == out, "pool pressure must never change outputs"
    print(f"half-pool serve OK: {mt['preemptions']} preemptions, "
          f"{mt['swap_bytes']} swap bytes, "
          f"page util p95 {mt['page_util_p95']:.2f} "
          f"(pool {tight.ecfg.num_blocks} of {demand} worst-case pages)")


if __name__ == "__main__":
    main()
