"""Batched serving example (deliverable b, serving flavor).

    PYTHONPATH=src python examples/serve_batched.py --arch moonshot-v1-16b-a3b

Serves a wave of synthetic requests against the *reduced* config of an
assigned MoE arch through the continuous batcher in repro.launch.serve.
"""
import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.serve import BatchedServer, Request
from repro.models.registry import get_model


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS, default="moonshot-v1-16b-a3b")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--max-new", type=int, default=12)
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, max_slots=3, prompt_len=24)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=16).astype(np.int32),
            max_new=args.max_new,
        )
        for i in range(args.requests)
    ]
    out = server.serve(reqs)
    for rid in sorted(out):
        print(f"req {rid}: {out[rid][:8]}...")
    print("stats:", server.summary())


if __name__ == "__main__":
    main()
