"""Tab. 6 reproduction: online pruning method ablation.

At a fixed PMQ budget: PMQ-only vs PMQ+random-mask vs PMQ+OTP at matched
pruning ratios. Paper claim: OTP prunes *more* experts at *less* PPL cost
than random masking (and than rule-based ODP, which the random-mask row
upper-bounds since ODP ⊂ heuristic masks).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import pipeline
from repro.core.otp import candidate_masks
from repro.core.otp_train import OTPTrainConfig, train_otp
from repro.data.pipeline import make_calibration_tokens

from .common import calibration, csv_row, eval_tokens, ppl_compressed, trained_model


class _RandomMask:
    """gate-mask oracle with a fixed expected pruning ratio."""

    def __init__(self, cfg, ratio: float, seed=0):
        self.k = cfg.top_k
        self.ratio = ratio
        self.rng = np.random.default_rng(seed)


def _ppl_with_random_mask(cfg, blocks_c, top, toks, ratio, seed=0):
    """Random per-token masks at expected ratio (keeps ≥1 expert)."""
    import jax.numpy as jnp

    key = jax.random.PRNGKey(seed)

    def make_otp_like(ratio):
        # emulate via otp_params=None + monkey gate_mask through pipeline:
        # easiest faithful route: draw candidate index uniform-biased
        return None

    # run forward with handcrafted masks by temporarily wrapping otp
    from repro.core import otp as otp_mod

    orig = otp_mod.otp_mask

    def random_mask(p, x2, idx, gates, rng=None, tau=1.0):
        t, k = gates.shape
        nonlocal key
        key, sub = jax.random.split(key)
        # choose "keep m" with E[pruned] = ratio
        keep_probs = np.zeros(k)
        m_keep = max(1, int(round(k * (1 - ratio))))
        keep_probs[k - m_keep] = 1.0  # candidate index = k - m_keep... row j keeps k-j
        choice = jnp.full((t,), k - m_keep, jnp.int32)
        cand = candidate_masks(k)[choice]
        order = jnp.argsort(-gates, axis=-1)
        inv = jnp.argsort(order, axis=-1)
        return jnp.take_along_axis(cand, inv, axis=-1)

    otp_mod.otp_mask = random_mask
    try:
        dummy = [{"fc1": jnp.zeros((cfg.d_model, cfg.top_k)),
                  "fc2": jnp.zeros((2 * cfg.top_k, cfg.top_k))}
                 for _ in range(cfg.num_layers)]
        ppl = ppl_compressed(cfg, blocks_c, top, toks, otp_params=dummy)
    finally:
        otp_mod.otp_mask = orig
    return ppl


def run(quick: bool = False):
    print("== otp_ablation (Tab. 6) ==")
    cfg, params = trained_model()
    calib = calibration(cfg, params)
    toks = eval_tokens(cfg)
    eps = pipeline.compute_eps(params, calib, cfg, eps_tokens=512)
    plan = pipeline.run_pmq(params, calib, cfg, target_avg_bits=2.0, eps=eps)
    blocks_c, top = pipeline.compress_model(params, calib, plan, cfg,
                                            use_gptq=False)
    rows = []
    t0 = time.time()
    ppl_base = ppl_compressed(cfg, blocks_c, top, toks)
    rows.append(csv_row("otp_ablation/pmq_only", (time.time() - t0) * 1e6,
                        f"ppl={ppl_base:.3f};ratio=0"))

    # OTP training
    data = make_calibration_tokens(cfg.vocab_size, 96, 64, seed=5)
    steps = 20 if quick else 80
    tcfg = OTPTrainConfig(steps=steps, batch=4, lr=5e-3, lam=1.0)
    t0 = time.time()
    otp_params, hist = train_otp(blocks_c, top, cfg, data, tcfg)
    ratio_otp = hist[-1]["mask_ratio"]
    ppl_otp = ppl_compressed(cfg, blocks_c, top, toks, otp_params=otp_params)
    rows.append(csv_row("otp_ablation/pmq+otp", (time.time() - t0) * 1e6,
                        f"ppl={ppl_otp:.3f};ratio={ratio_otp:.3f}"))

    # random mask at matched (or higher) keep rate
    t0 = time.time()
    ppl_rand = _ppl_with_random_mask(cfg, blocks_c, top, toks, ratio_otp)
    rows.append(csv_row("otp_ablation/pmq+random", (time.time() - t0) * 1e6,
                        f"ppl={ppl_rand:.3f};ratio={ratio_otp:.3f}"))
    print(f"  PPL: pmq {ppl_base:.3f} | +OTP({ratio_otp:.0%} pruned) "
          f"{ppl_otp:.3f} | +random {ppl_rand:.3f}")
    assert ppl_otp <= ppl_rand * 1.05, (ppl_otp, ppl_rand)
    return rows


if __name__ == "__main__":
    run()
