"""Expert-FFN hot path: legacy per-expert scan vs grouped GEMM dispatch.

The compressed MoE layer's compute used to be a ``lax.scan`` over
experts on the dense ``[num_slots·cap, D]`` capacity layout — every
padded capacity row was dequantized against and multiplied, routed or
not. The grouped path (:func:`repro.core.compressed_moe.
compressed_expert_ffn`, default backend) compacts each bucket's
occupied row prefixes into bm-aligned ragged groups and lets the
``moe_gmm`` kernel skip every row-block past the routed frontier, so
its useful-FLOP count scales with *traffic*, not with capacity.

This bench seeds the perf trajectory for that path: scan vs grouped
legs across bit mixes × capacity factors × batch shapes, reporting

* CPU wall-clock per call (what this host can measure — the jnp oracle
  computes skipped blocks and masks them, so treat CPU wall-clock as a
  dispatch-overhead check, not the kernel story),
* analytic MAC FLOPs actually *required* by each path per routed
  (token, choice) pair — the capacity-padding waste the grouped path's
  ``num_active`` frontier eliminates on TPU, exact by construction,

and writes every leg to ``results/BENCH_moe_ffn.json``:

    PYTHONPATH=src python -m benchmarks.moe_ffn_bench [--quick|--smoke]

``--smoke`` is the CI leg: tiny shapes, still ≥3 capacity factors, and
it asserts scan/grouped numerical equivalence on every leg it times.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from types import SimpleNamespace
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressed_moe as cm
from repro.models.moe import (
    capacity_dispatch,
    dispatch_capacity,
    slot_fill_counts,
)

from .common import csv_row, platform_meta

OUT_PATH = os.path.join("results", "BENCH_moe_ffn.json")

BIT_MIXES = {
    "uniform2": lambda e: [2] * e,
    "mixed124": lambda e: [1, 2, 4] * (e // 3) + [2] * (e % 3),
    "mixed23": lambda e: [2] * (e // 2) + [3] * (e - e // 2),
}


def _routing(ce, t: int, k: int, cap: int, seed: int, skew: float = 1.2):
    """Zipf-ish routed batch → (xp, slot_fill, routed_pairs)."""
    rng = np.random.default_rng(seed)
    d = ce.d_model
    x2 = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    p = 1.0 / np.arange(1, ce.num_slots + 1) ** skew
    p /= p.sum()
    slots = jnp.asarray(
        rng.choice(ce.num_slots, size=(t, k), p=p), jnp.int32
    )
    gates = jax.nn.softmax(jnp.asarray(rng.normal(size=(t, k)), jnp.float32))
    xp, dest, valid, _ = capacity_dispatch(
        x2, slots, gates, ce.num_slots, cap, None
    )
    fill = slot_fill_counts(dest, valid, ce.num_slots, cap)
    return xp, fill, int(np.asarray(valid).sum())


def _flops(ce, cap: int, fill: np.ndarray, path: str) -> int:
    """Exact MAC FLOPs the path must execute (2·rows·D·F per projection).

    scan: every capacity row of every bucket. grouped: only bm-aligned
    blocks carrying routed rows (``num_active`` skips the rest)."""
    total = 0
    per_row = 3 * 2 * ce.d_model * ce.d_ff  # gate + up + down
    bm = cm.gmm_block_rows(cap)
    for i, m in enumerate(ce.meta):
        if path == "scan":
            rows = m.count * cap
        else:
            f = np.minimum(fill[m.start : m.start + m.count], cap)
            rows = int((np.ceil(f / bm) * bm).sum())
        total += rows * per_row
    return total


def _time_call(fn, *args, iters: int = 5) -> float:
    y = jax.block_until_ready(fn(*args))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        y = fn(*args)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = False, smoke: bool = False) -> List[str]:
    print("== moe_ffn_bench (scan vs grouped expert dispatch) ==")
    # cf sweeps lean toward the drop-free serving regime (cf = E): that
    # is where capacity padding dominates and the ragged skip pays
    if smoke:
        e, d, f, group = 4, 64, 128, 32
        cfs = (2.0, 4.0, 8.0)
        shapes = ((32, 2),)
        mixes = ("mixed124",)
        iters = 2
    elif quick:
        e, d, f, group = 8, 128, 256, 64
        cfs = (2.0, 4.0, 8.0)
        shapes = ((64, 2),)
        mixes = ("uniform2", "mixed124")
        iters = 3
    else:
        e, d, f, group = 8, 256, 512, 128
        cfs = (1.5, 2.0, 4.0, 8.0)
        shapes = ((64, 2), (256, 2), (16, 2))
        mixes = tuple(BIT_MIXES)
        iters = 5
    rng = np.random.default_rng(0)
    rows: List[str] = []
    legs: List[Dict] = []
    for mix in mixes:
        bits = BIT_MIXES[mix](e)
        experts = {
            "w_gate": rng.normal(size=(e, d, f)).astype(np.float32),
            "w_up": rng.normal(size=(e, d, f)).astype(np.float32),
            "w_down": rng.normal(size=(e, f, d)).astype(np.float32),
        }
        ce = cm.build_compressed_experts(experts, bits, group=group, ep=1,
                                         refine=False)
        for t, k in shapes:
            for cf in cfs:
                # the exact capacity the model paths would dispatch with
                cap = dispatch_capacity(
                    SimpleNamespace(
                        moe_capacity_factor=cf, top_k=k, num_experts=e
                    ),
                    t,
                )
                xp, fill, routed = _routing(ce, t, k, cap, seed=t + int(cf * 8))
                fill_np = np.asarray(fill)
                outs = {}
                for backend, use_fill in (("scan", False), ("grouped", True)):
                    sf = fill if use_fill else None

                    def call(xp_, sf_=sf, kb_=backend):
                        return cm.compressed_expert_ffn(
                            ce, xp_, cap, backend=kb_, slot_fill=sf_
                        )

                    fn = jax.jit(call)
                    us = _time_call(fn, xp, iters=iters)
                    outs[backend] = np.asarray(fn(xp))
                    flops = _flops(ce, cap, fill_np, backend)
                    fpr = flops / max(routed, 1)
                    cap_rows = ce.num_slots * cap
                    leg = {
                        "bit_mix": mix,
                        "bits": bits,
                        "capacity_factor": cf,
                        "tokens": t,
                        "top_k": k,
                        "cap": cap,
                        "backend": backend,
                        "us_per_call": us,
                        "flops": flops,
                        "flops_per_routed_pair": fpr,
                        "routed_pairs": routed,
                        "capacity_rows": cap_rows,
                        "capacity_utilization": routed / cap_rows,
                    }
                    legs.append(leg)
                    rows.append(csv_row(
                        f"moe_ffn/{mix}_cf{cf:g}_t{t}_{backend}",
                        us,
                        f"flops_per_pair={fpr:.3g};"
                        f"routed={routed};cap_rows={cap_rows};"
                        f"util={routed / cap_rows:.2f}",
                    ))
                np.testing.assert_allclose(
                    outs["scan"], outs["grouped"], rtol=2e-4, atol=2e-4
                )
    # pair up scan/grouped legs for the headline reduction numbers
    for i in range(0, len(legs), 2):
        s, g = legs[i], legs[i + 1]
        s["flops_reduction_vs_scan"] = 1.0
        g["flops_reduction_vs_scan"] = s["flops"] / max(g["flops"], 1)
    # each leg pins its own expert-FFN implementation — stamp it as the
    # provenance ffn_backend rather than the process-wide default
    legs = [
        {**platform_meta(ffn_backend=leg.get("backend")), **leg}
        for leg in legs
    ]
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as fh:
        json.dump(
            {
                "bench": "moe_ffn",
                "d_model": d, "d_ff": f, "num_experts": e, "group": group,
                "note": (
                    "FLOPs are exact per-path MAC requirements; wall-clock "
                    "is this host (CPU oracle computes skipped blocks)"
                ),
                "legs": legs,
            },
            fh, indent=1,
        )
    red = [l["flops_reduction_vs_scan"] for l in legs
           if l["backend"] == "grouped"]
    print(f"  wrote {OUT_PATH}: {len(legs)} legs; grouped FLOP reduction "
          f"vs scan {min(red):.2f}x–{max(red):.2f}x")
    return rows


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true")
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized: tiny shapes, still 3 capacity factors, "
                        "asserts scan/grouped equivalence per leg")
    args = p.parse_args()
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
