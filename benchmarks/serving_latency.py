"""Serving latency: TTFT + per-token latency vs offered load, fp vs PMQ.

Drives the paged continuous-batching engine (repro.serving) over the
trained benchmark MoE at different offered loads (queued requests per
slot) with full-precision weights and with PMQ-compressed experts
(§3.2 bit buckets; serving is the paper's Tab. 8 deployment setting).
CPU wall-clock ratios are reported for what they are — the roofline
projection in memory_speed covers the accelerator-side speedup story.

The compressed engine serves the *stacked* compressed tree: the PMQ plan
is made layer-uniform (every layer gets layer 0's bit vector) so all
layers share one bucket structure and ride the decode scan — the same
layout the dry-run uses (repro.launch.specs.synthetic_stacked_compressed).

Emits the same CSV row shape as memory_speed: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import numpy as np

from repro.core import pipeline
from repro.models import transformer as tf
from repro.serving import EngineConfig, PagedServingEngine, Request

from .common import calibration, csv_row, trained_model

PROMPT_LEN = 32


def _stacked_compressed_params(cfg, params, calib):
    """Compress with a layer-uniform PMQ plan and restack for the scan."""
    eps = pipeline.compute_eps(params, calib, cfg, eps_tokens=128)
    plan = pipeline.run_pmq(params, calib, cfg, target_avg_bits=2.05, eps=eps)
    plan.bits = [plan.bits[0]] * cfg.num_layers  # uniform bucket structure
    blocks_c, top = pipeline.compress_model(
        params, calib, plan, cfg, use_gptq=False
    )
    out = dict(top)
    out["blocks"] = tf.restack_blocks(blocks_c)
    return out, plan.avg_bits


def _serve_once(cfg, params, *, n_requests: int, slots: int, max_new: int,
                seed: int = 0):
    mb = -(-(PROMPT_LEN + max_new) // 16) + 1
    engine = PagedServingEngine(
        cfg, params,
        EngineConfig(max_slots=slots, block_size=16,
                     num_blocks=slots * mb, max_blocks_per_slot=mb,
                     prefill_chunk=16),
    )
    rng = np.random.default_rng(seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=PROMPT_LEN).astype(np.int32),
            max_new=max_new,
        )
        for i in range(n_requests)
    ]
    engine.serve(reqs)
    return engine.metrics.summary()


def run(quick: bool = False):
    print("== serving_latency (paged engine, fp vs PMQ) ==")
    cfg, params = trained_model()
    calib = calibration(cfg, params)
    params_c, avg_bits = _stacked_compressed_params(cfg, params, calib)
    slots = 2 if quick else 4
    max_new = 8 if quick else 16
    loads = (1.0,) if quick else (0.5, 2.0)
    rows = []
    for label, prm in (("fp", params), ("pmq", params_c)):
        for load in loads:
            n = max(1, int(round(load * slots)))
            m = _serve_once(cfg, prm, n_requests=n, slots=slots,
                            max_new=max_new)
            rows.append(csv_row(
                f"serving/{label}_load{load:g}",
                m["decode_step_mean_s"] * 1e6,
                f"ttft_ms={m['ttft_mean_s']*1e3:.1f};"
                f"ttft_p95_ms={m['ttft_p95_s']*1e3:.1f};"
                f"tok_ms={m['decode_step_mean_s']*1e3:.1f};"
                f"tok_p95_ms={m['decode_step_p95_s']*1e3:.1f};"
                f"tps={m['tokens_per_s']:.1f};"
                f"midflight={m['mid_flight_admissions']};"
                f"act={m['expert_activation_mean']:.2f}",
            ))
    print(f"  pmq avg bits {avg_bits:.2f}; rows emitted: {len(rows)}")
    return rows


if __name__ == "__main__":
    run(quick=True)
