"""Serving latency: TTFT + per-token latency vs offered load, fp vs PMQ,
decode-horizon A/B legs, plus throughput-vs-pool-size pressure sweeps
for growth + preemption.

Drives the paged continuous-batching engine (repro.serving) over the
trained benchmark MoE at different offered loads (queued requests per
slot) with full-precision weights and with PMQ-compressed experts
(§3.2 bit buckets; serving is the paper's Tab. 8 deployment setting).
CPU wall-clock ratios are reported for what they are — the roofline
projection in memory_speed covers the accelerator-side speedup story.

The ``--pool-blocks`` sweep shrinks the KV page pool below the trace's
worst-case demand and serves the same mixed-length trace twice per pool
size: once with on-demand growth + preemption (victims swap to host
memory) and once with the conservative full-reservation baseline
(``reserve_full`` — admission waits until prompt+max_new pages are
free). Throughput, preemption counts and page utilization quantify how
much traffic a fixed pool serves under each policy — MC#'s compression
argument (§3.2/§3.4) applied to the KV budget:

    PYTHONPATH=src python -m benchmarks.serving_latency --pool-blocks 12 20 32

The ``--resident-experts`` sweep applies the same squeeze to the
*expert* budget: PMQ buckets are host-offloaded
(repro.serving.offload) and the same trace is served at shrinking
per-layer resident-slot budgets, reporting throughput, prefetch hit
rate, upload traffic and the device-resident expert bytes each budget
buys. The fp leg (all experts resident, no offload — the only option
for bf16 weights) anchors the comparison:

    PYTHONPATH=src python -m benchmarks.serving_latency --resident-experts 8 6 4

The ``--horizons`` sweep A/Bs the fused decode megastep: the same trace
is served at ``H = 1`` (the per-token baseline program) and larger
horizons, asserting bit-identical greedy outputs per leg and recording
steady-state decode tokens/s, TTFT, per-token latency and the
deterministic dispatch/sync amortization (``dispatches_per_step`` falls
from 1 toward 1/H). Legs land in ``results/BENCH_serving.json`` so the
serving perf trajectory accumulates per PR:

    PYTHONPATH=src python -m benchmarks.serving_latency --horizons 1 8

The ``--prefix-share`` sweep serves traces whose prompts share a
leading template (0%..100% of the prompt) with the shared-prefix KV
cache off vs on, asserting bit-identical outputs and reporting prefix
hits / prompt tokens served from shared pages / prefill dispatches.
``--kv-bits`` holds the KV pool's device bytes fixed and compares fp
pools against int8-quantized pools (uint8 codes + per-row f32 scale
tables): the int8 leg gets ``4·dh/(dh+8)`` ≈ 2.67× the KV tokens at
``dh=16`` over f32 pools:

    PYTHONPATH=src python -m benchmarks.serving_latency --prefix-share 0 0.5 1
    PYTHONPATH=src python -m benchmarks.serving_latency --kv-bits

The ``--policy`` sweep serves a bursty two-tenant trace (a batch flood
at step 0, interactive stragglers mid-flight) under each scheduling
policy (fcfs / priority / fair — docs/serving_scheduling.md), gating
that greedy outputs are bit-identical across policies while the
interactive class's p99 admission wait (in steps, deterministic)
strictly improves under ``priority`` vs ``fcfs``:

    PYTHONPATH=src python -m benchmarks.serving_latency --policy

``--smoke`` is the CI leg: a tiny random MoE (no training), H=1 vs H=8,
asserts greedy-output equivalence + dispatch amortization, plus the
shared-prefix gate (a verbatim-repeat trace dispatches ZERO prefill
programs after its first request), the int8-KV capacity gate (≥2×
KV tokens in the fp pool's bytes, batch outputs equal to the isolated
quantized oracle), and the scheduler-policy gate above, and still
writes ``results/BENCH_serving.json``.

The compressed engine serves the *stacked* compressed tree: the PMQ plan
is made layer-uniform (every layer gets layer 0's bit vector) so all
layers share one bucket structure and ride the decode scan — the same
layout the dry-run uses (repro.launch.specs.synthetic_stacked_compressed).

Emits the same CSV row shape as memory_speed: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import pipeline
from repro.serving import EngineConfig, PagedServingEngine, Request

from .common import calibration, csv_row, platform_meta, trained_model

PROMPT_LEN = 32
BLOCK_SIZE = 16
OUT_PATH = os.path.join("results", "BENCH_serving.json")


def _stacked_compressed_params(cfg, params, calib):
    """Compress with a layer-uniform PMQ plan and restack for the scan."""
    return pipeline.compress_for_serving(params, calib, cfg,
                                         target_avg_bits=2.05)


def _serve_once(cfg, params, *, n_requests: int, slots: int, max_new: int,
                seed: int = 0, ffn_backend: Optional[str] = None):
    mb = -(-(PROMPT_LEN + max_new) // BLOCK_SIZE) + 1
    # decode_horizon=1 keeps these rows comparable with the per-token
    # trajectory accumulated by earlier PRs; the horizon A/B legs carry
    # their H in the row label (serving/*_hN)
    engine = PagedServingEngine(
        cfg, params,
        EngineConfig(max_slots=slots, block_size=BLOCK_SIZE,
                     num_blocks=slots * mb, max_blocks_per_slot=mb,
                     prefill_chunk=BLOCK_SIZE, ffn_backend=ffn_backend,
                     decode_horizon=1),
    )
    rng = np.random.default_rng(seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=PROMPT_LEN).astype(np.int32),
            max_new=max_new,
        )
        for i in range(n_requests)
    ]
    engine.serve(reqs)
    return engine.metrics.summary()


# ------------------------------------------------- decode horizon sweep
def _horizon_leg_summary(h: int, m: Dict) -> Dict:
    """The slice of a leg's metrics the perf trajectory tracks."""
    return {
        "horizon": h,
        "tokens_per_s": m["tokens_per_s"],
        "ttft_mean_s": m["ttft_mean_s"],
        "ttft_p95_s": m["ttft_p95_s"],
        "per_token_latency_s": m["decode_step_mean_s"],
        "per_token_latency_p95_s": m["decode_step_p95_s"],
        "decode_compute_mean_s": m["decode_compute_mean_s"],
        "decode_offload_mean_s": m["decode_offload_mean_s"],
        "generated_tokens": m["generated_tokens"],
        "megasteps": m["megasteps"],
        "dispatches_per_step": m["dispatches_per_step"],
        "dispatches_per_token": m["dispatches_per_token"],
        "syncs_per_token": m["syncs_per_token"],
    }


def horizon_sweep(cfg, params, horizons: Sequence[int], *,
                  n_requests: int = 4, slots: int = 4, max_new: int = 49,
                  label: str = "fp", check_equal: bool = True,
                  trace_dir: Optional[str] = None):
    """Serve one decode-heavy trace per horizon; assert bit-identical
    greedy outputs across legs (the fusion invariant) and return
    ``(csv_rows, json_legs)``.

    Measures *steady-state* decode: a warmup request compiles both
    jitted programs before metrics reset, the timed trace fills every
    slot from step 0 with equal ``max_new`` (no ragged tail, no
    mid-flight churn), and ``max_new`` leans long so decode — the regime
    the megastep amortizes — dominates the timing.

    ``trace_dir`` enables full-level span tracing and writes one
    Perfetto-viewable artifact pair per leg
    (``BENCH_serving_{label}_h{H}.trace.json`` + ``.trace.jsonl``).
    """
    from repro.serving import ExpertRoutingTelemetry, ServingMetrics

    rng = np.random.default_rng(17)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=PROMPT_LEN).astype(np.int32)
        for _ in range(n_requests)
    ]
    warm = rng.integers(0, cfg.vocab_size, size=PROMPT_LEN).astype(np.int32)
    mb = -(-(PROMPT_LEN + max_new) // BLOCK_SIZE) + 1
    rows, legs, outs = [], [], {}
    for h in horizons:
        engine = PagedServingEngine(
            cfg, params,
            EngineConfig(max_slots=slots, block_size=BLOCK_SIZE,
                         num_blocks=slots * mb, max_blocks_per_slot=mb,
                         prefill_chunk=BLOCK_SIZE, decode_horizon=int(h),
                         trace_level="full" if trace_dir else "off"),
        )
        # compile prefill + the H-step megastep outside the timed window
        engine.serve([Request(rid=-1, prompt=warm, max_new=max(h + 1, 2))])
        engine.metrics = ServingMetrics()
        engine.tracer.reset()
        if engine.routing is not None:
            engine.routing = ExpertRoutingTelemetry()
        outs[h] = engine.serve([
            Request(rid=i, prompt=prompts[i], max_new=max_new)
            for i in range(n_requests)
        ])
        m = engine.metrics.summary()
        leg = dict(_horizon_leg_summary(int(h), m), label=label)
        legs.append(leg)
        if trace_dir:
            os.makedirs(trace_dir, exist_ok=True)
            base = os.path.join(trace_dir, f"BENCH_serving_{label}_h{h}")
            report = engine.routing_report()
            engine.tracer.write_chrome(
                base + ".trace.json",
                extra={"routing_report": report} if report else None,
            )
            engine.tracer.write_jsonl(base + ".trace.jsonl")
            print(f"  trace: {len(engine.tracer.events)} events → "
                  f"{base}.trace.json (+ .trace.jsonl)")
        rows.append(csv_row(
            f"serving/{label}_h{h}",
            m["decode_step_mean_s"] * 1e6,
            f"tps={m['tokens_per_s']:.1f};"
            f"ttft_p95_ms={m['ttft_p95_s']*1e3:.1f}"
            f";disp_per_step={m['dispatches_per_step']:.3f}"
            f";megasteps={m['megasteps']}",
        ))
    if check_equal:
        h0 = horizons[0]
        for h in horizons[1:]:
            assert outs[h] == outs[h0], (
                f"horizon {h} changed greedy outputs vs horizon {h0}"
            )
    return rows, legs


def _write_bench_json(legs: List[Dict], note: str) -> None:
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    meta = platform_meta()
    legs = [{**meta, **leg} for leg in legs]
    with open(OUT_PATH, "w") as fh:
        json.dump({"bench": "serving", "note": note, "legs": legs}, fh,
                  indent=1)
    print(f"  wrote {OUT_PATH}: {len(legs)} legs")


def _append_bench_json(legs: List[Dict], note_suffix: str) -> None:
    """Extend an existing BENCH_serving.json (written by a prior leg of
    the same CI run) rather than clobbering it; falls back to a fresh
    file when none exists."""
    meta = platform_meta()
    legs = [{**meta, **leg} for leg in legs]
    doc = {"bench": "serving", "note": "", "legs": []}
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as fh:
            doc = json.load(fh)
    doc["legs"] = list(doc.get("legs", [])) + legs
    note = doc.get("note") or ""
    doc["note"] = (note + "; " if note else "") + note_suffix
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as fh:
        json.dump(doc, fh, indent=1)
    print(f"  wrote {OUT_PATH}: +{len(legs)} legs "
          f"({len(doc['legs'])} total)")


def _smoke_model():
    """Tiny random MoE for the CI smoke leg — the horizon invariant and
    the dispatch amortization are model-free, so no training needed."""
    import jax

    from repro.configs.base import ModelConfig
    from repro.models.registry import get_model

    cfg = ModelConfig(
        name="smoke-serving-moe", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, d_ff_expert=64,
        vocab_size=128, num_experts=4, top_k=2, num_shared_experts=1,
        dtype="float32", remat="none", logits_chunk=32, attn_q_chunk=16,
        attn_kv_chunk=16,
    )
    return cfg, get_model(cfg).init(jax.random.PRNGKey(0))


def smoke() -> List[str]:
    """CI leg: H=1 vs H=8 on a tiny model — greedy outputs must be
    bit-identical, dispatches/step must amortize by ~H, and the JSON
    perf artifact is written."""
    print("== serving_latency --smoke (decode horizon H=1 vs H=8) ==")
    cfg, params = _smoke_model()
    for attempt in (1, 2):
        rows, legs = horizon_sweep(
            cfg, params, (1, 8), n_requests=2, slots=2, max_new=33,
            label="smoke", check_equal=True, trace_dir="results",
        )
        by_h = {l["horizon"]: l for l in legs}
        # ratio fields are None only for empty runs — these legs must
        # have generated tokens (the satellite's distinguishability fix)
        for h in (1, 8):
            assert by_h[h]["tokens_per_s"] is not None, f"H={h} leg empty"
            assert by_h[h]["dispatches_per_step"] is not None
        # deterministic amortization proof — never retried
        assert by_h[1]["dispatches_per_step"] == 1.0
        assert by_h[8]["dispatches_per_step"] <= 1 / 8 + 0.1, (
            "H=8 must amortize jitted dispatches per logical step by ~8x"
        )
        # wall-clock throughput can flake on noisy shared runners (the
        # local margin is ~5x, but CI timings are sub-second): re-measure
        # once, then warn rather than fail — the deterministic asserts
        # above are the gating proof of the amortization
        if by_h[8]["tokens_per_s"] > by_h[1]["tokens_per_s"]:
            break
        if attempt == 2:
            print(
                "  WARNING: H=8 wall-clock tps did not beat H=1 on this "
                f"host (H=8 {by_h[8]['tokens_per_s']:.1f} vs "
                f"H=1 {by_h[1]['tokens_per_s']:.1f} tok/s, twice) — "
                "dispatch amortization held; timing likely noisy"
            )

    print("== serving_latency --smoke (shared-prefix KV reuse) ==")
    max_new = 9
    mb = -(-(PROMPT_LEN + max_new) // BLOCK_SIZE) + 1
    eng = PagedServingEngine(
        cfg, params,
        EngineConfig(max_slots=2, block_size=BLOCK_SIZE,
                     num_blocks=4 * mb, max_blocks_per_slot=mb,
                     prefill_chunk=BLOCK_SIZE, decode_horizon=1,
                     prefix_cache=True),
    )
    rngp = np.random.default_rng(11)
    prompt = rngp.integers(0, cfg.vocab_size, size=PROMPT_LEN).astype(np.int32)
    first = eng.serve([Request(rid=0, prompt=prompt, max_new=max_new)])
    disp0 = eng.metrics.summary()["prefill_dispatches"]
    rest = eng.serve([
        Request(rid=i, prompt=prompt.copy(), max_new=max_new)
        for i in (1, 2, 3)
    ])
    mp = eng.metrics.summary()
    # the gating claim of the prefix cache: a 100%-shared trace runs
    # ZERO additional prefill programs after the first request
    assert mp["prefill_dispatches"] == disp0, (
        f"verbatim-repeat trace dispatched prefill: "
        f"{mp['prefill_dispatches']} vs {disp0} after the first request"
    )
    assert mp["prefix_full_hits"] == 3, mp["prefix_full_hits"]
    assert rest == {i: first[0] for i in (1, 2, 3)}, (
        "shared-prefix outputs diverged"
    )
    legs.append({
        "label": "smoke_prefix",
        "prefix_full_hits": mp["prefix_full_hits"],
        "prefix_tokens_saved": mp["prefix_tokens_saved"],
        "prefill_dispatches": mp["prefill_dispatches"],
    })
    print("  prefix OK: 3 verbatim repeats → 0 extra prefill dispatches, "
          f"{mp['prefix_tokens_saved']} prompt tokens served from cache")

    print("== serving_latency --smoke (int8 KV at fixed pool bytes) ==")
    krows, ratio, kleg = kv_bits_leg(cfg, params, label="smoke",
                                     check_oracle=True)
    rows += krows
    legs.append(kleg)
    # codes + scale tables must buy ≥2× KV tokens in the same bytes
    # (exact ratio is 4·dh/(dh+8) ≈ 2.67 at dh=16 over f32 pools)
    assert ratio >= 2.0, f"int8 capacity ratio {ratio:.2f} < 2x"
    print(f"  kv-quant OK: int8 fits {ratio:.2f}x tokens in the fp pool's "
          "bytes; batch outputs == isolated quantized oracle")

    print("== serving_latency --smoke (scheduler policy: bursty 2-tenant) ==")
    prow, pleg = policy_sweep(cfg, params, label="smoke")
    rows += prow
    legs.append(pleg)

    _write_bench_json(
        legs, "smoke legs: tiny random MoE (CI); wall-clock is this host"
    )
    print("  smoke OK: H=1 and H=8 greedy outputs bit-identical")
    return rows


# ------------------------------------------------------- chaos smoke leg
CHAOS_OUT_PATH = os.path.join("results", "BENCH_serving_chaos.json")


def _drive_chaos(engine, pending, cancel_at: Dict[int, int]):
    """The step-driven submission loop plus client cancellations:
    ``cancel_at`` maps rid → tick. Deterministic — same trace, same
    fault plan, same tick grid ⇒ same outcome."""
    pending = sorted(pending, key=lambda t: t[0])
    tick = 0
    while pending or engine.scheduler.has_work():
        while pending and pending[0][0] <= tick:
            engine.submit(pending.pop(0)[1])
        for rid, t in cancel_at.items():
            if t == tick:
                engine.cancel(rid)
        if engine.scheduler.has_work():
            engine.step()
        tick += 1
    return dict(engine.results)


def _chaos_trace(cfg, n_requests: int = 6, seed: int = 23):
    """Staggered arrivals, mixed lengths — enough churn that cold
    expert rows get routed to (upload path) while staying CI-sized."""
    rng = np.random.default_rng(seed)
    return [
        (i, Request(
            rid=i,
            prompt=rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(10, 21))
            ).astype(np.int32),
            max_new=int(rng.integers(8, 13)),
        ))
        for i in range(n_requests)
    ]


def chaos() -> List[str]:
    """CI chaos leg: the smoke MoE PMQ-compressed with offloaded experts,
    served under a seeded ``FaultPlan`` (transient expert-upload
    failures) plus one client cancellation, gating the fail-closed
    contract end-to-end (docs/serving_robustness.md):

    * every non-cancelled request's greedy tokens are **bit-identical**
      to the fault-free leg, and the cancelled request's partial output
      is a prefix of its fault-free tokens;
    * at least one upload failure was injected *and recovered by retry*
      (``fault_injected`` ≥ 1, ``upload_retries`` ≥ 1, no degradation,
      no engine errors besides the cancellation);
    * the cancellation terminated typed and clean (``cancelled`` == 1,
      pool drained to consistency);
    * the trace artifacts pass ``python -m repro.serving.trace`` schema
      validation.
    """
    from repro.serving import FaultPlan, FaultSpec, RequestCancelled
    from repro.serving.trace import main as validate_traces

    print("== serving_latency --chaos (fail-closed serving under faults) ==")
    cfg, params = _smoke_model()
    calib = calibration(cfg, params, n=4, seq=64)
    params_c, avg_bits = _stacked_compressed_params(cfg, params, calib)
    num_slots = params_c["blocks"]["moe_ce"].num_slots
    resident = max(1, num_slots - 1)  # ≥1 cold row: uploads must happen
    max_new = 12
    mb = -(-(20 + max_new) // BLOCK_SIZE) + 1
    slots = 3
    ecfg = EngineConfig(
        max_slots=slots, block_size=BLOCK_SIZE, num_blocks=slots * mb,
        max_blocks_per_slot=mb, prefill_chunk=BLOCK_SIZE, decode_horizon=4,
        preempt_mode="swap", resident_experts=resident,
    )
    cancel_rid, cancel_tick = 5, 5  # cancelled the tick it arrives

    # fault-free reference leg (no cancels — the bit-identity anchor)
    ref_engine = PagedServingEngine(cfg, params_c, ecfg)
    ref = _drive_chaos(ref_engine, _chaos_trace(cfg), {})

    # chaos leg: every expert upload fails twice, then a cancellation
    plan = FaultPlan([
        FaultSpec(site="upload", mode="fail", count=2),
        FaultSpec(site="upload", mode="corrupt", count=1, step=2),
    ])
    engine = PagedServingEngine(
        cfg, params_c,
        dataclasses.replace(ecfg, trace_level="full"),
        faults=plan,
    )
    outs = _drive_chaos(engine, _chaos_trace(cfg),
                        {cancel_rid: cancel_tick})
    ctr = engine.metrics.counters()

    # gate 1: bit-exact-or-typed-error against the fault-free leg
    assert set(engine.errors) == {cancel_rid}, (
        f"chaos leg errored unexpectedly: "
        f"{ {r: type(e).__name__ for r, e in engine.errors.items()} }"
    )
    assert isinstance(engine.errors[cancel_rid], RequestCancelled)
    for rid, toks in ref.items():
        if rid == cancel_rid:
            assert outs[rid] == toks[:len(outs[rid])], (
                "cancelled request's partial output is not a prefix of "
                "its fault-free tokens"
            )
        else:
            assert outs[rid] == toks, (
                f"request {rid} diverged from the fault-free leg under "
                "recovered upload faults"
            )
    # gate 2: faults actually fired and were recovered by retry
    assert plan.injected >= 1, "fault plan never fired"
    assert ctr["fault_injected"] == plan.injected
    assert ctr["upload_retries"] >= 1, "no upload retry was exercised"
    assert ctr.get("degraded_serves", 0) == 0, (
        "transient faults must recover at full precision"
    )
    # gate 3: the cancellation terminated typed and the pool is clean
    assert ctr["cancelled"] == 1
    assert not engine.scheduler.active and not engine.scheduler.waiting
    engine.cache.check_consistency()

    # gate 4: artifacts pass the schema validator CI also runs
    os.makedirs("results", exist_ok=True)
    base = os.path.join("results", "BENCH_serving_chaos")
    report = engine.routing_report()
    engine.tracer.write_chrome(
        base + ".trace.json",
        extra={"routing_report": report} if report else None,
    )
    engine.tracer.write_jsonl(base + ".trace.jsonl")
    rc = validate_traces([base + ".trace.json", base + ".trace.jsonl"])
    assert rc == 0, "chaos trace artifacts failed schema validation"

    leg = {
        **platform_meta(),
        "label": "chaos",
        "avg_bits": round(float(avg_bits), 3),
        "resident_experts": resident,
        "num_slots": num_slots,
        "fault_injected": ctr["fault_injected"],
        "faults_by_site": ctr.get("faults_by_site", {}),
        "upload_retries": ctr["upload_retries"],
        "cancelled": ctr["cancelled"],
        "degraded_serves": ctr.get("degraded_serves", 0),
    }
    with open(CHAOS_OUT_PATH, "w") as fh:
        json.dump({"bench": "serving_chaos",
                   "note": "chaos smoke: recovered upload faults + clean "
                           "cancellation, bit-identical to fault-free",
                   "legs": [leg]}, fh, indent=1)
    print(f"  wrote {CHAOS_OUT_PATH}")
    print(f"  chaos OK: {ctr['fault_injected']} faults injected, "
          f"{ctr['upload_retries']} upload retries recovered, "
          f"1 clean cancellation; outputs bit-identical to fault-free")
    return [csv_row(
        "serving/chaos",
        engine.metrics.summary()["decode_step_mean_s"] * 1e6,
        f"faults={ctr['fault_injected']};retries={ctr['upload_retries']};"
        f"cancelled={ctr['cancelled']};degraded={ctr.get('degraded_serves', 0)}",
    )]


# -------------------------------------------- async expert streaming leg
def _overlap_model():
    """A model shaped so the residency *planner* (not just miss replay)
    carries traffic: 8 experts in two 4-row buckets with budget 3 each,
    top_k=1 and short programs (prefill_chunk=2, H=2) so no single
    program's working set can exceed a bucket budget — the demand-driven
    ``_grow`` escape hatch never fires and the buckets stay under budget
    for the planner to converge."""
    import jax as _jax

    from repro.configs.base import ModelConfig
    from repro.core.compressed_moe import build_compressed_experts
    from repro.models import transformer as _tf
    from repro.models.registry import get_model

    cfg = ModelConfig(
        name="overlap-serving-moe", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, d_ff_expert=64,
        vocab_size=128, num_experts=8, top_k=1, num_shared_experts=1,
        dtype="float32", remat="none", logits_chunk=32, attn_q_chunk=16,
        attn_kv_chunk=16,
    )
    params = get_model(cfg).init(_jax.random.PRNGKey(0))
    bits = [1, 1, 1, 1, 2, 2, 2, 2]  # two buckets of four rows each
    blocks = _tf.unstack_blocks(params, cfg)
    blocks_c = []
    for p_l in blocks:
        experts = {k: np.asarray(p_l["moe"]["experts"][k])
                   for k in ("w_gate", "w_up", "w_down")}
        ce = build_compressed_experts(experts, bits, group=32, ep=1,
                                      refine=False)
        blocks_c.append({"ln1": p_l["ln1"], "attn": p_l["attn"],
                         "ln2": p_l["ln2"],
                         "moe": {"router": p_l["moe"]["router"],
                                 "shared": p_l["moe"]["shared"]},
                         "moe_ce": ce})
    params_c = {"embed": params["embed"], "final_norm": params["final_norm"],
                "blocks": _tf.restack_blocks(blocks_c)}
    return cfg, params_c


def _overlap_ecfg(**kw) -> EngineConfig:
    return EngineConfig(
        max_slots=1, block_size=4, num_blocks=8, max_blocks_per_slot=8,
        prefill_chunk=2, decode_horizon=2, resident_experts=6, **kw,
    )


def _overlap_requests(cfg, n: int = 4, max_new: int = 16, plen: int = 4):
    rng = np.random.default_rng(0)
    return [
        (i * 2, Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new=max_new,
        ))
        for i in range(n)
    ]


def _drive_primed(engine, trace, cold, period: int = 2,
                  weight: float = 40.0):
    """Tick loop that injects a deterministic router-stats priming
    schedule: every ``period`` ticks the EMA is pushed toward the other
    of each bucket's two *coldest* slots (cold per the unprimed warmup
    run, so flips evict rows the workload never routes — planner-driven
    churn without induced misses). Miss-driven steady state never leaves
    residency targets unmet (eviction is EMA-coldest = the exact
    complement of the desired set), so this synthetic drift is what
    keeps the planner path live; both legs see the identical schedule."""
    mgr = engine.offload
    pending, tick = sorted(trace, key=lambda t: t[0]), 0
    while True:
        while pending and pending[0][0] <= tick:
            engine.submit(pending.pop(0)[1])
        counts = np.zeros((mgr.num_layers, mgr.num_slots), np.int64)
        for i, m in enumerate(mgr.meta):
            counts[:, m.start + cold[i][(tick // period) % 2]] = weight
        mgr.update_stats(counts)
        if not engine.step() and not pending:
            break
        tick += 1
        assert tick < 10_000, "overlap trace failed to drain"
    return {rid: list(toks) for rid, toks in engine.results.items()}


def async_offload_smoke() -> List[str]:
    """CI async-offload leg: double-buffered residency vs the synchronous
    boundary upload, plus a disk-tier leg, gating the tentpole contract
    (docs/serving_offload.md):

    * greedy outputs are **bit-identical** across sync / async / disk
      legs (placement independence makes overlap invisible to tokens);
    * the async leg overlapped ≥ 1 planner upload with compute and its
      ``decode_offload_frac`` (which folds boundary upload stalls) lands
      **strictly below** the sync leg's;
    * the disk-tier leg serves from a device budget below total expert
      bytes with ≥ 1 CRC-verified disk fetch;
    * the async leg's trace artifacts pass schema validation.
    """
    import tempfile

    from repro.serving.trace import main as validate_traces

    print("== serving_latency --async-offload (double-buffered residency) ==")
    cfg, params_c = _overlap_model()

    # warmup: compiles every program shape AND learns the workload's true
    # routing heat — the two coldest slots per bucket are the safe lanes
    # for the priming schedule to churn
    warm = PagedServingEngine(cfg, params_c, _overlap_ecfg())
    pending = sorted(_overlap_requests(cfg), key=lambda t: t[0])
    tick = 0
    while True:
        while pending and pending[0][0] <= tick:
            warm.submit(pending.pop(0)[1])
        if not warm.step() and not pending:
            break
        tick += 1
    warm_out = {rid: list(t) for rid, t in warm.results.items()}
    ema = warm.offload.ema.sum(0)
    cold = {}
    for i, m in enumerate(warm.offload.meta):
        order = np.argsort(ema[m.start:m.start + m.count], kind="stable")
        cold[i] = [int(x) for x in order[:2]]

    legs, rows = [], []
    metrics = {}
    for label, kw in (("offload_sync", {}),
                      ("offload_async", {"async_offload": True,
                                         "trace_level": "full"})):
        engine = PagedServingEngine(cfg, params_c, _overlap_ecfg(**kw))
        out = _drive_primed(engine, _overlap_requests(cfg), cold)
        assert out == warm_out, f"{label} outputs diverged from warmup leg"
        assert engine.offload.grows == 0, (
            f"{label}: budget grew — the planner demo needs under-budget "
            f"buckets"
        )
        m = engine.metrics.summary()
        metrics[label] = m
        legs.append({
            "label": label,
            "async_offload": bool(kw.get("async_offload", False)),
            "resident_experts": 6,
            "num_slots": 8,
            "decode_offload_frac": round(m["decode_offload_frac"], 6),
            "upload_stall_s": round(m["upload_stall_s"], 6),
            "upload_hidden_s": round(m["upload_hidden_s"], 6),
            "uploads_overlapped": m["uploads_overlapped"],
            "uploads_committed": m["uploads_committed"],
            "uploads_dropped_stale": m["uploads_dropped_stale"],
            "expert_prefetch_uploads": m["expert_prefetch_uploads"],
            "expert_miss_uploads": m["expert_miss_uploads"],
            "tokens_per_s": round(m["tokens_per_s"], 2),
        })
        rows.append(csv_row(
            f"serving/{label}",
            m["decode_step_mean_s"] * 1e6,
            f"frac={m['decode_offload_frac']:.4f};"
            f"stall_s={m['upload_stall_s']:.4f};"
            f"hidden_s={m['upload_hidden_s']:.4f};"
            f"overlapped={m['uploads_overlapped']};"
            f"committed={m['uploads_committed']}",
        ))
        if kw.get("trace_level") == "full":
            os.makedirs("results", exist_ok=True)
            base = os.path.join("results", "BENCH_serving_async_offload")
            engine.tracer.write_chrome(base + ".trace.json")
            engine.tracer.write_jsonl(base + ".trace.jsonl")
            rc = validate_traces([base + ".trace.json",
                                  base + ".trace.jsonl"])
            assert rc == 0, "async-offload trace failed schema validation"

    ms, ma = metrics["offload_sync"], metrics["offload_async"]
    assert ma["uploads_overlapped"] >= 1, "async leg never overlapped"
    assert ma["uploads_committed"] >= 1, "async leg never committed"
    assert ms["upload_stall_s"] > 0.0, "sync leg never stalled on uploads"
    assert ma["decode_offload_frac"] < ms["decode_offload_frac"], (
        f"async frac {ma['decode_offload_frac']:.4f} not below sync "
        f"{ms['decode_offload_frac']:.4f}"
    )

    # disk-tier leg: same trace served from mmap'd packed buckets behind
    # a byte-budgeted host cache, device budget below total expert bytes
    with tempfile.TemporaryDirectory() as td:
        engine = PagedServingEngine(
            cfg, params_c,
            _overlap_ecfg(async_offload=True, offload_dir=td,
                          host_expert_bytes=65536),
        )
        assert engine.offload.resident_bytes < engine.offload.host_bytes, (
            "disk-tier leg must serve from a device budget below total "
            "expert bytes"
        )
        out = _drive_primed(engine, _overlap_requests(cfg), cold)
        assert out == warm_out, "disk-tier outputs diverged"
        ctr = engine.metrics.counters()
        assert ctr["tier_disk_hits"] >= 1, "disk tier never fetched"
        m = engine.metrics.summary()
        legs.append({
            "label": "offload_disk_tier",
            "async_offload": True,
            "host_expert_bytes": 65536,
            "tier_disk_hits": ctr["tier_disk_hits"],
            "tier_disk_bytes": ctr["tier_disk_bytes"],
            "tier_host_hits": ctr["tier_host_hits"],
            "decode_offload_frac": round(m["decode_offload_frac"], 6),
            "tokens_per_s": round(m["tokens_per_s"], 2),
        })
        rows.append(csv_row(
            "serving/offload_disk_tier",
            m["decode_step_mean_s"] * 1e6,
            f"disk_hits={ctr['tier_disk_hits']};"
            f"disk_bytes={ctr['tier_disk_bytes']};"
            f"host_hits={ctr['tier_host_hits']}",
        ))

    _append_bench_json(
        legs,
        "async-offload legs: planner uploads overlapped vs synchronous "
        "boundary stall + disk-tier dryrun; outputs bit-identical",
    )
    print(f"  async-offload OK: {ma['uploads_overlapped']} overlapped "
          f"({ma['uploads_committed']} committed, "
          f"{ma['uploads_dropped_stale']} dropped stale); frac "
          f"{ms['decode_offload_frac']:.4f} → "
          f"{ma['decode_offload_frac']:.4f}; disk tier CRC-clean")
    return rows


# --------------------------------------------------- pool pressure sweep
def _pressure_requests(cfg, n_requests: int, seed: int = 0) -> List[Request]:
    """Mixed-length trace: short prompts + long decodes, the shape that
    stresses on-demand growth hardest (cheap admission, heavy growth)."""
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(8, 25))
            ).astype(np.int32),
            max_new=int(rng.integers(12, 33)),
        )
        for i in range(n_requests)
    ]


def pool_sweep(pool_blocks: Optional[Sequence[int]] = None, *,
               quick: bool = False, n_requests: int = 8, slots: int = 6):
    """Serve one trace across pool sizes, preemption on vs off."""
    cfg, params = trained_model()
    reqs = _pressure_requests(cfg, n_requests)
    per_req = [-(-(len(r.prompt) + r.max_new) // BLOCK_SIZE) for r in reqs]
    demand, biggest = sum(per_req), max(per_req)
    if pool_blocks is None:
        fracs = (1.0, 0.6) if quick else (1.0, 0.6, 0.4)
        pool_blocks = [max(biggest, int(demand * f)) for f in fracs]
    rows = []
    for pool in pool_blocks:
        pool = max(int(pool), biggest)  # completion needs the largest req to fit
        for policy, reserve in (("preempt", False), ("reserve", True)):
            # decode_horizon=1: keep the pressure rows comparable with
            # the per-token trajectory of earlier PRs
            engine = PagedServingEngine(
                cfg, params,
                EngineConfig(
                    max_slots=slots, block_size=BLOCK_SIZE, num_blocks=pool,
                    max_blocks_per_slot=biggest, prefill_chunk=BLOCK_SIZE,
                    preempt_mode="swap", reserve_full=reserve,
                    decode_horizon=1,
                ),
            )
            engine.serve(
                [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                 for r in reqs]
            )
            m = engine.metrics.summary()
            rows.append(csv_row(
                f"serving/pool{pool}_{policy}",
                m["decode_step_mean_s"] * 1e6,
                f"pool_frac={pool/demand:.2f};"
                f"tps={m['tokens_per_s']:.1f};"
                f"preempts={m['preemptions']};"
                f"swap_mb={m['swap_bytes']/2**20:.2f};"
                f"util_p95={m['page_util_p95']:.2f};"
                f"ttft_p95_ms={m['ttft_p95_s']*1e3:.1f}",
            ))
    return rows


# ------------------------------------------------ expert residency sweep
def resident_sweep(budgets: Optional[Sequence[int]] = None, *,
                   quick: bool = False, n_requests: int = 6, slots: int = 3,
                   compressed=None):
    """Serve one trace at shrinking device expert budgets, fp vs PMQ.

    The fp leg serves bf16 experts (necessarily all-resident) once; the
    PMQ leg serves the same trace per budget with cold bucket rows
    offloaded to host memory. Budgets below a step's working set are
    honored best-effort: the manager grows the resident buffer rather
    than serving wrong tokens, and the ``grows`` field reports how often
    the configured budget was too small. ``compressed`` optionally reuses
    an already-built ``(params_c, avg_bits)`` (run() passes its own).
    """
    cfg, params = trained_model()
    if compressed is None:
        calib = calibration(cfg, params)
        compressed = _stacked_compressed_params(cfg, params, calib)
    params_c, avg_bits = compressed
    num_slots = params_c["blocks"]["moe_ce"].num_slots
    if budgets is None:
        fracs = (1.0, 0.5) if quick else (1.0, 0.75, 0.5)
        budgets = sorted(
            {max(1, int(round(num_slots * f))) for f in fracs}, reverse=True
        )
    max_new = 8 if quick else 16
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=PROMPT_LEN).astype(np.int32)
        for _ in range(n_requests)
    ]
    mb = -(-(PROMPT_LEN + max_new) // BLOCK_SIZE) + 1
    # decode_horizon=1: keep the residency rows comparable with the
    # per-token trajectory of earlier PRs
    ecfg = EngineConfig(max_slots=slots, block_size=BLOCK_SIZE,
                        num_blocks=slots * mb, max_blocks_per_slot=mb,
                        prefill_chunk=BLOCK_SIZE, decode_horizon=1)
    rows = []

    def serve(prm, label, engine_cfg):
        engine = PagedServingEngine(cfg, prm, engine_cfg)
        engine.serve([
            Request(rid=i, prompt=prompts[i], max_new=max_new)
            for i in range(n_requests)
        ])
        m = engine.metrics.summary()
        # fp serves all-resident: its hit rate is the trivial 1.0 anchor
        extra = f";hit_rate={m['expert_hit_rate']:.2f}"
        if engine.offload is not None:
            extra += (
                f";upload_mb={m['expert_upload_bytes']/2**20:.3f}"
                f";resident_b={m['expert_resident_bytes_last']}"
                f";grows={engine.offload.grows}"
            )
        rows.append(csv_row(
            f"serving/{label}",
            m["decode_step_mean_s"] * 1e6,
            f"tps={m['tokens_per_s']:.1f};"
            f"ttft_p95_ms={m['ttft_p95_s']*1e3:.1f}" + extra,
        ))

    serve(params, "resident_fp_all", ecfg)
    for budget in budgets:
        serve(
            params_c, f"resident_pmq{budget}of{num_slots}",
            dataclasses.replace(ecfg, resident_experts=int(budget)),
        )
    print(f"  pmq avg bits {avg_bits:.2f}; num_slots {num_slots}")
    return rows


# --------------------------------------------------- scheduler policy leg
def _bursty_two_tenant_trace(cfg, *, seed: int = 29):
    """Bursty two-tenant arrival trace: a **batch** tenant floods the
    queue at step 0 with long prompts + long decodes (priority 0), then
    a latency-floor **interactive** tenant's short requests trickle in
    mid-flight (priority 2). Returns ``[(submit_step, Request), ...]``
    — fresh Request objects per call, deterministic shapes."""
    rng = np.random.default_rng(seed)
    pending = []
    rid = 0
    for _ in range(6):
        pending.append((0, Request(
            rid=rid,
            prompt=rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(20, 33))
            ).astype(np.int32),
            max_new=int(rng.integers(12, 29)),
            tenant="batch", priority=0,
        )))
        rid += 1
    for _ in range(8):
        pending.append((int(rng.integers(2, 8)), Request(
            rid=rid,
            prompt=rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(2, 9))
            ).astype(np.int32),
            max_new=int(rng.integers(2, 7)),
            tenant="interactive", priority=2,
        )))
        rid += 1
    return pending


def _drive_pending(engine, pending):
    """Step-driven submission (arrivals interleave with decode), the
    sim-harness loop — ``engine.serve`` would submit everything up
    front and hide the queueing the policy leg measures."""
    pending = sorted(pending, key=lambda t: t[0])
    tick = 0
    while pending or engine.scheduler.has_work():
        while pending and pending[0][0] <= tick:
            engine.submit(pending.pop(0)[1])
        if engine.scheduler.has_work():
            engine.step()
        tick += 1
    return dict(engine.results)


def policy_sweep(cfg, params, *, slots: int = 4, label: str = "fp"):
    """Serve the bursty two-tenant trace under each scheduling policy.

    Gates (deterministic, admission-step based — no wall-clock):

    * greedy outputs are **bit-identical** across fcfs/priority/fair —
      policy moves requests in time, never in token space;
    * the interactive class's p99 admission wait (steps from submit to
      slot bind) strictly improves under ``priority`` vs ``fcfs`` —
      class-ordered admission is worth something on a bursty mix.

    The fair leg is reported (per-tenant tokens + waits) but only gated
    on output identity. Returns ``(csv_rows, json_leg)``.
    """
    mb = -(-(32 + 28) // BLOCK_SIZE) + 1
    legs = {}
    outs = {}
    rows = []
    for policy in ("fcfs", "priority", "fair"):
        engine = PagedServingEngine(
            cfg, params,
            EngineConfig(
                max_slots=slots, block_size=BLOCK_SIZE,
                num_blocks=slots * mb, max_blocks_per_slot=mb,
                prefill_chunk=BLOCK_SIZE, decode_horizon=4,
                preempt_mode="swap", policy=policy,
                tenant_weights=(
                    (("batch", 1.0), ("interactive", 4.0))
                    if policy == "fair" else None
                ),
            ),
        )
        outs[policy] = _drive_pending(engine, _bursty_two_tenant_trace(cfg))
        m = engine.metrics.summary()
        waits = sorted(
            a["wait_steps"] for a in engine.metrics.admissions
            if a["tenant"] == "interactive"
        )
        p99 = float(np.percentile(waits, 99)) if waits else 0.0
        legs[policy] = {
            "interactive_admit_wait_steps_p99": p99,
            "interactive_admit_wait_steps_mean": float(np.mean(waits)),
            "interactive_admissions": len(waits),
            "tokens_per_s": m["tokens_per_s"],
            "preemptions": m["preemptions"],
            "sheds": m["sheds"],
            "tenant_tokens": m["tenant_tokens"],
        }
        rows.append(csv_row(
            f"serving/{label}_policy_{policy}",
            m["decode_step_mean_s"] * 1e6,
            f"iwait_p99={p99:.0f};iwait_mean={np.mean(waits):.1f};"
            f"tps={m['tokens_per_s']:.1f};preempts={m['preemptions']};"
            f"plans={m['plans']}",
        ))
    assert outs["priority"] == outs["fcfs"] == outs["fair"], (
        "scheduling policy changed greedy outputs"
    )
    p99_fcfs = legs["fcfs"]["interactive_admit_wait_steps_p99"]
    p99_prio = legs["priority"]["interactive_admit_wait_steps_p99"]
    assert p99_prio < p99_fcfs, (
        f"priority policy must cut the interactive class's p99 admission "
        f"wait on a bursty mix: priority {p99_prio} vs fcfs {p99_fcfs} steps"
    )
    print(f"  policy OK: outputs identical; interactive p99 wait "
          f"{p99_fcfs:.0f} steps (fcfs) -> {p99_prio:.0f} (priority), "
          f"{legs['fair']['interactive_admit_wait_steps_p99']:.0f} (fair)")
    leg = {"label": f"{label}_policy", "policies": legs}
    return rows, leg


# ------------------------------------------- shared-prefix / KV-quant legs
def _prefix_trace(cfg, share: float, n_requests: int, seed: int = 5):
    """Prompts sharing the leading ``share`` fraction of their tokens:
    one common template + per-request random suffixes (``share=1`` is a
    verbatim-repeat trace — the full-hit regime)."""
    rng = np.random.default_rng(seed)
    t_len = int(round(PROMPT_LEN * share))
    template = rng.integers(0, cfg.vocab_size, size=t_len).astype(np.int32)
    return [
        np.concatenate([
            template,
            rng.integers(
                0, cfg.vocab_size, size=PROMPT_LEN - t_len
            ).astype(np.int32),
        ])
        for _ in range(n_requests)
    ]


def prefix_sweep(cfg, params, shares: Sequence[float], *,
                 n_requests: int = 6, slots: int = 3, max_new: int = 9,
                 label: str = "fp"):
    """Serve one trace per shared-prefix fraction, cache off vs on.

    The cache-off leg is the correctness anchor: outputs must be
    bit-identical (prefix reuse is pure page plumbing). The derived
    column reports what the cache bought — prefix hits / full hits /
    prompt tokens served from shared pages / COW copies — next to the
    prefill-dispatch counts of both legs.
    """
    mb = -(-(PROMPT_LEN + max_new) // BLOCK_SIZE) + 1
    base = EngineConfig(
        max_slots=slots, block_size=BLOCK_SIZE,
        num_blocks=slots * mb + n_requests, max_blocks_per_slot=mb,
        prefill_chunk=BLOCK_SIZE, decode_horizon=1,
    )
    rows = []
    for share in shares:
        prompts = _prefix_trace(cfg, float(share), n_requests)
        outs, mets = {}, {}
        for on in (False, True):
            engine = PagedServingEngine(
                cfg, params, dataclasses.replace(base, prefix_cache=on)
            )
            outs[on] = engine.serve([
                Request(rid=i, prompt=p, max_new=max_new)
                for i, p in enumerate(prompts)
            ])
            mets[on] = engine.metrics.summary()
        assert outs[True] == outs[False], (
            f"prefix cache changed greedy outputs at share={share}"
        )
        m = mets[True]
        rows.append(csv_row(
            f"serving/{label}_prefixshare{int(round(float(share) * 100))}",
            m["decode_step_mean_s"] * 1e6,
            f"hits={m['prefix_hits']};full={m['prefix_full_hits']};"
            f"saved_tok={m['prefix_tokens_saved']};cow={m['cow_copies']};"
            f"prefill_disp={m['prefill_dispatches']}"
            f"(off={mets[False]['prefill_dispatches']});"
            f"tps={m['tokens_per_s']:.1f};"
            f"ttft_ms={m['ttft_mean_s']*1e3:.1f}",
        ))
    return rows


def _pool_nbytes(engine) -> int:
    """Device bytes of the engine's KV pool: codes + (quant) scale
    tables — the honest denominator for the capacity comparison."""
    cache = engine.cache
    n = cache.k.nbytes + cache.v.nbytes
    if cache.quant is not None:
        n += sum(a.nbytes for a in cache.quant.values())
    return int(n)


def kv_bits_leg(cfg, params, *, n_requests: int = 4, slots: int = 2,
                max_new: int = 9, blocks_fp: Optional[int] = None,
                label: str = "fp", check_oracle: bool = False):
    """Fixed pool-byte budget: fp KV vs int8-quantized KV.

    The budget is the measured device bytes of the fp pool; the int8 leg
    gets as many pages as fit in the same budget counting codes *and*
    the four per-row f32 scale tables — ``4·dh / (dh + 8)`` tokens per
    fp token (≈2.67× at ``dh=16``, f32 pools), not a hand-wavy 4×. Both
    legs serve the same trace; the quantized leg's outputs optionally
    check against the isolated single-request quantized oracle.
    Returns ``(csv_rows, capacity_ratio, json_leg)``.
    """
    mb = -(-(PROMPT_LEN + max_new) // BLOCK_SIZE) + 1
    blocks_fp = int(blocks_fp or slots * mb)
    ecfg = EngineConfig(
        max_slots=slots, block_size=BLOCK_SIZE, num_blocks=blocks_fp,
        max_blocks_per_slot=mb, prefill_chunk=BLOCK_SIZE, decode_horizon=1,
    )
    eng_fp = PagedServingEngine(cfg, params, ecfg)
    budget = _pool_nbytes(eng_fp)
    # int8 page cost: 1-byte codes for K and V plus 4 f32 scale tables
    # (k/v × scale/zero), one entry per (token, kv-head)
    per_page_q = cfg.num_layers * BLOCK_SIZE * (
        2 * cfg.num_kv_heads * cfg.head_dim + 4 * cfg.num_kv_heads * 4
    )
    blocks_q = budget // per_page_q
    eng_q = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ecfg, num_blocks=int(blocks_q), kv_bits=8),
    )
    assert _pool_nbytes(eng_q) <= budget, "int8 leg exceeded the byte budget"
    ratio = blocks_q / blocks_fp
    rng = np.random.default_rng(13)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=PROMPT_LEN).astype(np.int32)
        for _ in range(n_requests)
    ]
    rows, tps = [], {}
    for leg_label, engine, nb in ((f"{label}_kvfp", eng_fp, blocks_fp),
                                  (f"{label}_kvint8", eng_q, blocks_q)):
        outs = engine.serve([
            Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)
        ])
        m = engine.metrics.summary()
        tps[leg_label] = m["tokens_per_s"]
        rows.append(csv_row(
            f"serving/{leg_label}",
            m["decode_step_mean_s"] * 1e6,
            f"pool_mb={_pool_nbytes(engine)/2**20:.2f};"
            f"pages={nb};cap_tok={nb * BLOCK_SIZE};"
            f"tps={m['tokens_per_s']:.1f};preempts={m['preemptions']}",
        ))
        if check_oracle and engine is eng_q:
            from repro.serving import quantized_greedy_reference

            for i, p in enumerate(prompts):
                want = quantized_greedy_reference(cfg, params, p, max_new)
                assert outs[i] == want, (
                    f"int8 batch output diverged from isolated oracle "
                    f"(request {i})"
                )
    leg = {
        "label": f"{label}_kv_budget",
        "pool_budget_bytes": budget,
        "fp_pages": blocks_fp,
        "int8_pages": int(blocks_q),
        "capacity_ratio": round(float(ratio), 3),
        "fp_tokens_per_s": tps[f"{label}_kvfp"],
        "int8_tokens_per_s": tps[f"{label}_kvint8"],
    }
    return rows, float(ratio), leg


def run(quick: bool = False, ffn_backend: Optional[str] = None):
    print("== serving_latency (paged engine, fp vs PMQ) ==")
    cfg, params = trained_model()
    calib = calibration(cfg, params)
    params_c, avg_bits = _stacked_compressed_params(cfg, params, calib)
    slots = 2 if quick else 4
    max_new = 8 if quick else 16
    loads = (1.0,) if quick else (0.5, 2.0)
    rows = []
    for label, prm in (("fp", params), ("pmq", params_c)):
        for load in loads:
            n = max(1, int(round(load * slots)))
            m = _serve_once(cfg, prm, n_requests=n, slots=slots,
                            max_new=max_new, ffn_backend=ffn_backend)
            rows.append(csv_row(
                f"serving/{label}_load{load:g}"
                + (f"_{ffn_backend}" if ffn_backend else ""),
                m["decode_step_mean_s"] * 1e6,
                f"ttft_ms={m['ttft_mean_s']*1e3:.1f};"
                f"ttft_p95_ms={m['ttft_p95_s']*1e3:.1f};"
                f"tok_ms={m['decode_step_mean_s']*1e3:.1f};"
                f"tok_p95_ms={m['decode_step_p95_s']*1e3:.1f};"
                f"tps={m['tokens_per_s']:.1f};"
                f"midflight={m['mid_flight_admissions']};"
                f"act={m['expert_activation_mean']:.2f};"
                f"cap_util={m['capacity_util_mean']:.2f}",
            ))
    print(f"  pmq avg bits {avg_bits:.2f}; rows emitted: {len(rows)}")
    print("== serving_latency (decode horizon: fused megastep A/B) ==")
    # slot-aligned trace (n == slots, budget divisible by 8) so every leg
    # measures pure steady-state decode, not admission churn/ragged tails
    hs = (1, 8) if quick else (1, 2, 4, 8)
    hrows, legs = horizon_sweep(
        cfg, params, hs, n_requests=2 if quick else 4,
        slots=2 if quick else 4, max_new=17 if quick else 49, label="fp",
        trace_dir="results",
    )
    rows += hrows
    _write_bench_json(
        legs,
        "fp legs over the trained bench MoE (decode-heavy trace); "
        "wall-clock is this host",
    )
    print("== serving_latency (pool pressure: growth+preempt vs reserve) ==")
    rows += pool_sweep(quick=quick, n_requests=4 if quick else 8,
                       slots=3 if quick else 6)
    print("== serving_latency (shared-prefix reuse: cache off vs on) ==")
    rows += prefix_sweep(cfg, params, (0.0, 0.5, 1.0),
                         n_requests=4 if quick else 6,
                         slots=2 if quick else 3)
    print("== serving_latency (int8 KV at fixed pool bytes) ==")
    krows, _, _ = kv_bits_leg(cfg, params, n_requests=2 if quick else 4)
    rows += krows
    print("== serving_latency (expert residency: offload vs all-resident) ==")
    rows += resident_sweep(quick=quick, n_requests=4 if quick else 6,
                           slots=3, compressed=(params_c, avg_bits))
    return rows


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true")
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized horizon A/B on a tiny random MoE: "
                        "asserts H=1 vs H=8 greedy-output equivalence + "
                        "dispatch amortization, writes the JSON artifact")
    p.add_argument("--chaos", action="store_true",
                   help="CI chaos leg: the smoke MoE with offloaded PMQ "
                        "experts served under a seeded FaultPlan — gates "
                        "bit-identical recovery from injected upload "
                        "faults, one clean typed cancellation, and trace-"
                        "artifact schema validation")
    p.add_argument("--async-offload", action="store_true",
                   help="CI async-offload leg: double-buffered expert "
                        "residency vs the synchronous boundary upload + "
                        "a disk-tier leg — gates bit-identical outputs, "
                        "uploads_overlapped >= 1 with decode_offload_frac "
                        "strictly below sync, and >= 1 CRC-verified disk "
                        "fetch from a device budget below total expert "
                        "bytes; appends legs to the serving JSON artifact")
    p.add_argument("--horizons", type=int, nargs="+", default=None,
                   metavar="H",
                   help="explicit decode horizons for the fused-megastep "
                        "A/B sweep over the trained bench model (always "
                        "includes the outputs-identical assertion)")
    p.add_argument("--pool-blocks", type=int, nargs="+", default=None,
                   metavar="N",
                   help="explicit pool sizes (pages) for the pressure "
                        "sweep; default derives ~3 sizes from the trace's "
                        "worst-case demand")
    p.add_argument("--resident-experts", type=int, nargs="+", default=None,
                   metavar="N",
                   help="explicit per-layer expert-slot budgets for the "
                        "residency sweep (fp + PMQ legs); default derives "
                        "~3 budgets from the compressed model's slot count")
    p.add_argument("--prefix-share", type=float, nargs="+", default=None,
                   metavar="F",
                   help="shared-prefix fractions (0..1) for the prefix-"
                        "cache sweep over the trained bench model; each "
                        "leg serves cache-off vs cache-on and asserts "
                        "bit-identical outputs")
    p.add_argument("--kv-bits", action="store_true",
                   help="fixed pool-byte-budget leg: fp KV vs int8-"
                        "quantized KV (codes + per-row scale tables) over "
                        "the trained bench model")
    p.add_argument("--policy", action="store_true",
                   help="scheduler-policy sweep (fcfs/priority/fair) on a "
                        "bursty two-tenant trace over the trained bench "
                        "model: gates identical outputs + interactive-"
                        "class p99 admission wait priority < fcfs")
    p.add_argument("--ffn-backend", choices=["grouped", "scan", "ref"],
                   default=None,
                   help="compressed expert-FFN implementation for every "
                        "engine this run builds (grouped GEMM vs legacy "
                        "per-expert scan vs forced jnp reference) — "
                        "reproducible A/B legs from the CLI")
    args = p.parse_args()
    if args.ffn_backend:
        # pressure/residency sweeps build engines through shared helpers;
        # the process default reaches all of them (trace-time static)
        os.environ["REPRO_FFN_BACKEND"] = args.ffn_backend
    if args.smoke or args.chaos or args.async_offload:
        if args.smoke:
            smoke()
        if args.chaos:
            chaos()
        if args.async_offload:
            async_offload_smoke()
        return
    if args.horizons is not None:
        cfg, params = trained_model()
        _, legs = horizon_sweep(cfg, params, args.horizons,
                                trace_dir="results")
        _write_bench_json(
            legs,
            "fp legs over the trained bench MoE (decode-heavy trace); "
            "wall-clock is this host",
        )
    if args.pool_blocks is not None:
        pool_sweep(args.pool_blocks, quick=args.quick,
                   n_requests=4 if args.quick else 8,
                   slots=3 if args.quick else 6)
    if args.resident_experts is not None:
        resident_sweep(args.resident_experts, quick=args.quick,
                       n_requests=4 if args.quick else 6, slots=3)
    if args.prefix_share is not None:
        cfg, params = trained_model()
        prefix_sweep(cfg, params, args.prefix_share)
    if args.kv_bits:
        cfg, params = trained_model()
        kv_bits_leg(cfg, params)
    if args.policy:
        cfg, params = trained_model()
        _, pleg = policy_sweep(cfg, params)
        _write_bench_json(
            [pleg],
            "policy sweep over the trained bench MoE (bursty two-tenant "
            "trace); wall-clock is this host",
        )
    if (args.pool_blocks is None and args.resident_experts is None
            and args.horizons is None and args.prefix_share is None
            and not args.kv_bits and not args.policy):
        run(quick=args.quick, ffn_backend=args.ffn_backend)


if __name__ == "__main__":
    main()
