"""Benchmark harness entrypoint — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Each bench prints ``name,us_per_call,derived`` CSV rows; roofline rows
come from the dry-run JSONs (run repro.launch.dryrun --all first for the
full 40-cell table).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="reduced sweeps (CI-sized)")
    p.add_argument("--only", default=None,
                   help="comma-separated bench names")
    args = p.parse_args()

    from . import (
        bit_allocation,
        kernel_bench,
        lambda_sweep,
        memory_speed,
        moe_ffn_bench,
        otp_ablation,
        pareto,
        roofline,
        serving_latency,
    )

    benches = {
        "kernel_bench": lambda: kernel_bench.run(args.quick),
        "moe_ffn": lambda: moe_ffn_bench.run(args.quick),
        "bit_allocation": lambda: bit_allocation.run(args.quick),
        "pareto": lambda: pareto.run(args.quick),
        "otp_ablation": lambda: otp_ablation.run(args.quick),
        "lambda_sweep": lambda: lambda_sweep.run(args.quick),
        "memory_speed": lambda: memory_speed.run(args.quick),
        "serving_latency": lambda: serving_latency.run(args.quick),
        "roofline": lambda: roofline.run(),
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    print("name,us_per_call,derived")
    failures = []
    t0 = time.time()
    for name, fn in benches.items():
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failures.append(name)
    print(f"# total {time.time()-t0:.0f}s; failures: {failures or 'none'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
