"""Tab. 5/8 reproduction: memory compression + activated params + speed.

Memory/activated-parameter numbers are exact (byte-counted on the
compressed model). Wall-clock speedups cannot be measured faithfully on a
CPU container — we report (a) measured CPU step-time ratios for what they
are, and (b) v5e roofline-projected decode speedups from weight-byte
reduction (the paper's Tab. 8 mechanism — serving is weight-bandwidth
bound; DESIGN.md §5.1).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline
from repro.data.pipeline import make_calibration_tokens

from .common import calibration, csv_row, eval_tokens, trained_model

HBM_BW = 819e9


def run(quick: bool = False):
    print("== memory_speed (Tab. 5/8) ==")
    cfg, params = trained_model()
    calib = calibration(cfg, params)
    eps = pipeline.compute_eps(params, calib, cfg, eps_tokens=256)
    plan = pipeline.run_pmq(params, calib, cfg, target_avg_bits=2.05, eps=eps)
    blocks_c, top = pipeline.compress_model(params, calib, plan, cfg,
                                            use_gptq=False)
    rows = []

    # ---- Tab. 5: bytes ------------------------------------------------
    fp_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    # fp32 here; the paper's baseline is 16-bit → halve for a fair ratio
    fp16_bytes = fp_bytes // 2
    c_bytes = pipeline.model_weight_bytes(blocks_c, top)
    ratio = fp16_bytes / c_bytes
    rows.append(csv_row("memory/weights", 0.0,
                        f"fp16_mb={fp16_bytes/1e6:.1f};mc_mb={c_bytes/1e6:.1f};"
                        f"ratio={ratio:.2f}x"))

    # activated params per token: top-k experts + shared + attn
    act_full = cfg.active_param_count()
    # OTP at ~25% pruning removes 25% of routed-expert compute
    expert_act = cfg.num_layers * cfg.top_k * 3 * cfg.d_model * cfg.d_ff_expert
    act_otp = act_full - int(0.25 * expert_act)
    rows.append(csv_row("memory/activated_params", 0.0,
                        f"full={act_full/1e6:.1f}M;otp25={act_otp/1e6:.1f}M"))

    # ---- Tab. 8: decode step times ------------------------------------
    toks = eval_tokens(cfg, n=4, seq=64)
    from repro.models import transformer as tf

    fp_step = jax.jit(lambda p, t: tf.forward_hidden(p, t, cfg)[0])
    _ = jax.block_until_ready(fp_step(params, toks))
    t0 = time.time()
    reps = 2 if quick else 5
    for _ in range(reps):
        jax.block_until_ready(fp_step(params, toks))
    t_fp = (time.time() - t0) / reps

    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(
            pipeline.compressed_forward(blocks_c, top, toks, cfg)[0]
        )
    t_c = (time.time() - t0) / reps
    rows.append(csv_row("speed/cpu_forward", t_fp * 1e6,
                        f"fp_s={t_fp:.3f};mc_s={t_c:.3f};cpu_ratio={t_fp/t_c:.2f}"))

    # v5e roofline projection: decode is weight-bandwidth bound
    t_fp16_decode = fp16_bytes / HBM_BW
    t_mc_decode = c_bytes / HBM_BW
    rows.append(csv_row("speed/v5e_decode_projection", t_fp16_decode * 1e6,
                        f"fp16_us={t_fp16_decode*1e6:.1f};"
                        f"mc_us={t_mc_decode*1e6:.1f};"
                        f"speedup={t_fp16_decode/t_mc_decode:.2f}x"))
    print(f"  weights {ratio:.2f}x smaller; projected v5e decode speedup "
          f"{t_fp16_decode/t_mc_decode:.2f}x (paper Tab. 5: 1.6–2.3x at "
          f"2.05 bits on GPU)")
    return rows


if __name__ == "__main__":
    run()
