"""Fig. 13 reproduction: sparsity-constraint λ vs learned mask ratio.

Trains the OTP router at several λ and records the mask-ratio
trajectory; the paper's claim is monotone: larger λ → higher pruning.
"""
from __future__ import annotations

import time

from repro.core import pipeline
from repro.core.otp_train import OTPTrainConfig, train_otp
from repro.data.pipeline import make_calibration_tokens

from .common import calibration, csv_row, trained_model


def run(quick: bool = False):
    print("== lambda_sweep (Fig. 13) ==")
    cfg, params = trained_model()
    calib = calibration(cfg, params)
    eps = pipeline.compute_eps(params, calib, cfg, eps_tokens=256)
    plan = pipeline.run_pmq(params, calib, cfg, target_avg_bits=2.0, eps=eps)
    blocks_c, top = pipeline.compress_model(params, calib, plan, cfg,
                                            use_gptq=False)
    data = make_calibration_tokens(cfg.vocab_size, 96, 64, seed=11)
    lams = [1.0, 2.0] if quick else [0.5, 1.0, 2.0]
    steps = 20 if quick else 60
    rows, finals = [], {}
    for lam in lams:
        t0 = time.time()
        _, hist = train_otp(
            blocks_c, top, cfg, data,
            OTPTrainConfig(steps=steps, batch=4, lr=5e-3, lam=lam, seed=1),
        )
        traj = [h["mask_ratio"] for h in hist]
        finals[lam] = sum(traj[-5:]) / 5
        rows.append(csv_row(
            f"lambda_sweep/lam{lam}", (time.time() - t0) * 1e6,
            f"final_ratio={finals[lam]:.3f};start_ratio={traj[0]:.3f}"))
    ordered = sorted(finals)
    mono = all(finals[a] <= finals[b] + 0.05
               for a, b in zip(ordered, ordered[1:]))
    print(f"  mask ratio by λ: {finals} monotone≈{mono}")
    return rows


if __name__ == "__main__":
    run()
