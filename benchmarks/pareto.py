"""Fig. 11/12 reproduction: Pareto frontier of size vs quality.

Sweeps PMQ over the paper's 1.5–2.75-bit range and scatters random
mixed-precision configurations at matched budgets; the claim is that the
PMQ curve lower-bounds (PPL) every random config at equal average bits.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import pipeline, pmq

from .common import calibration, csv_row, eval_tokens, ppl_compressed, ppl_fp, trained_model


def _random_plan(cfg, budget_avg: float, rng) -> pmq.PMQPlan:
    """A random allocation meeting the same per-layer integer budget."""
    L, E = cfg.num_layers, cfg.num_experts
    bits = []
    for _ in range(L):
        target = int(round(budget_avg * E))
        # random feasible combo via local search
        b = rng.integers(1, 4, size=E)
        while b.sum() != target:
            i = rng.integers(0, E)
            if b.sum() < target and b[i] < 3:
                b[i] += 1
            elif b.sum() > target and b[i] > 1:
                b[i] -= 1
        bits.append(b.astype(np.int32))
    return pmq.PMQPlan(bits=bits, target_avg_bits=budget_avg, objective=0.0,
                       layer_budgets=np.array([int(round(budget_avg * E))] * L))


def run(quick: bool = False):
    print("== pareto (Fig. 11/12) ==")
    cfg, params = trained_model()
    calib = calibration(cfg, params)
    toks = eval_tokens(cfg)
    base_ppl = ppl_fp(cfg, params, toks)
    eps = pipeline.compute_eps(params, calib, cfg, eps_tokens=512)
    rng = np.random.default_rng(1)
    budgets = [1.75, 2.25] if quick else [1.625, 1.875, 2.125, 2.375, 2.625]
    n_random = 1 if quick else 3
    rows = []
    pmq_curve, rand_pts = {}, []
    for b in budgets:
        t0 = time.time()
        plan = pmq.allocate_model(calib.phi, calib.w, eps, b)
        blocks_c, top = pipeline.compress_model(
            params, calib, plan, cfg, use_gptq=False
        )
        ppl = ppl_compressed(cfg, blocks_c, top, toks)
        pmq_curve[b] = ppl
        rows.append(csv_row(
            f"pareto/pmq@{b}b", (time.time() - t0) * 1e6,
            f"ppl={ppl:.3f}"))
        for r in range(n_random):
            t0 = time.time()
            rplan = _random_plan(cfg, b, rng)
            blocks_c, top = pipeline.compress_model(
                params, calib, rplan, cfg, use_gptq=False
            )
            rppl = ppl_compressed(cfg, blocks_c, top, toks)
            rand_pts.append((b, rppl))
            rows.append(csv_row(
                f"pareto/random{r}@{b}b", (time.time() - t0) * 1e6,
                f"ppl={rppl:.3f}"))
    # Pareto check: PMQ at each budget ≤ every random config at that budget
    dominated = sum(
        1 for b, rppl in rand_pts if pmq_curve[b] <= rppl * 1.02
    )
    print(f"  PMQ dominates {dominated}/{len(rand_pts)} random configs "
          f"(fp PPL {base_ppl:.3f})")
    # monotone: more bits → no worse
    bs = sorted(pmq_curve)
    mono = all(pmq_curve[bs[i]] >= pmq_curve[bs[i + 1]] * 0.98
               for i in range(len(bs) - 1))
    print(f"  curve monotone-decreasing: {mono}: "
          f"{[f'{b}b:{pmq_curve[b]:.2f}' for b in bs]}")
    return rows


if __name__ == "__main__":
    run()
