"""Fig. 9/10 reproduction: bit-allocation strategy ablation.

Compares PPL (synthetic held-out corpus) of the compressed model under
different bit-width allocation signals at equal average bits:

* PMQ   — phi^α · w^β · eps^γ  (Eq. 7, the paper's method)
* F-norm — eps only (α=β=0)
* Hessian — HAWQ-style: input second moment × weight-perturbation norm
* freq  — activation frequency only
* weights — mean routing weight only
* random — random costs
* uniform — all experts 2-bit (only defined at avg=2.0)

Paper claim (Figs. 9/10): PMQ ≤ F-norm < Hessian < freq < weights <
random/uniform, with the gap growing below 2 bits.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import pipeline, pmq
from repro.core.quantizers import quantize_to_packed

from .common import calibration, csv_row, eval_tokens, ppl_compressed, ppl_fp, trained_model


def _weight_delta(params, cfg, bits_options=(1, 2, 3)):
    """||W_i − Q(W_i, j)||_F per expert/bit (HAWQ-style signal base)."""
    import jax.numpy as jnp
    from repro.models import transformer as tf

    blocks = tf.unstack_blocks(params, cfg)
    out = np.zeros((cfg.num_layers, cfg.num_experts, len(bits_options)))
    for l, p_l in enumerate(blocks):
        ex = p_l["moe"]["experts"]
        for i in range(cfg.num_experts):
            for j, b in enumerate(bits_options):
                tot = 0.0
                for name in ("w_gate", "w_up", "w_down"):
                    w = jnp.asarray(ex[name][i])
                    pt = quantize_to_packed(w, b, group=128, refine=False)
                    tot += float(jnp.sum((w - pt.dequantize()) ** 2))
                out[l, i, j] = np.sqrt(tot)
    return out


def _hessian_scale(calib, cfg):
    """Mean input second moment per layer (diag-Hessian proxy)."""
    return np.array([float(np.mean(h**2)) for h in calib.moe_inputs])


def run(quick: bool = False):
    print("== bit_allocation (Fig. 9/10) ==")
    cfg, params = trained_model()
    calib = calibration(cfg, params)
    toks = eval_tokens(cfg)
    base_ppl = ppl_fp(cfg, params, toks)
    print(f"  16-bit baseline PPL {base_ppl:.3f}")
    eps = pipeline.compute_eps(params, calib, cfg, eps_tokens=512)
    wdelta = _weight_delta(params, cfg)
    hscale = _hessian_scale(calib, cfg)
    rng = np.random.default_rng(0)

    strategies = {
        "pmq": lambda: pmq.allocate_model(calib.phi, calib.w, eps, target),
        "fnorm": lambda: pmq.allocate_model(
            calib.phi, calib.w, eps, target, alpha=0.0, beta=0.0
        ),
        "hessian": lambda: pmq.allocate_model(
            np.ones_like(calib.phi), np.ones_like(calib.w),
            wdelta**2 * hscale[:, None, None], target, alpha=0, beta=0,
        ),
        "freq": lambda: pmq.allocate_model(
            calib.phi, np.ones_like(calib.w), wdelta, target, beta=0.0
        ),
        "weights": lambda: pmq.allocate_model(
            np.ones_like(calib.phi), calib.w, wdelta, target, alpha=0.0
        ),
        "random": lambda: pmq.allocate_model(
            np.ones_like(calib.phi), np.ones_like(calib.w),
            rng.uniform(0.1, 1.0, eps.shape), target, alpha=0, beta=0,
        ),
    }
    targets = [2.0] if quick else [1.75, 2.0, 2.375]
    rows = []
    results = {}
    for target in targets:
        for name, alloc in strategies.items():
            t0 = time.time()
            plan = alloc()
            # RTN+HQQ packing: the allocation-strategy ordering is the
            # claim under test; GPTQ's uniform gain is covered by
            # tests/test_quantizers.py and examples/quickstart.py
            blocks_c, top = pipeline.compress_model(
                params, calib, plan, cfg, use_gptq=False
            )
            ppl = ppl_compressed(cfg, blocks_c, top, toks)
            results[(name, target)] = ppl
            rows.append(csv_row(
                f"bit_allocation/{name}@{target}b",
                (time.time() - t0) * 1e6,
                f"ppl={ppl:.3f};fp_ppl={base_ppl:.3f}",
            ))
    # the paper's headline ordering at the lowest budget
    t = targets[0]
    assert results[("pmq", t)] <= results[("random", t)] * 1.02, results
    print(f"  PMQ@{t}b PPL {results[('pmq', t)]:.3f} vs "
          f"random {results[('random', t)]:.3f} "
          f"fnorm {results[('fnorm', t)]:.3f} hessian {results[('hessian', t)]:.3f}")
    return rows


if __name__ == "__main__":
    run()
