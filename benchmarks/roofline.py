"""Roofline report (assignment deliverable (g)).

Reads the per-cell dry-run JSONs (repro.launch.dryrun) and emits the
§Roofline table: three terms per (arch × shape × mesh), dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and per-device fit.

Byte model (DESIGN.md §8 / EXPERIMENTS.md):
* compute term — while-aware parsed HLO dot-FLOPs (per device);
* memory  term — max(analytic floor, XLA cost_analysis bytes). The
  analytic floor counts parameter + optimizer + KV-cache + residual-
  stash traffic (formulas below); the parsed-HLO byte model is reported
  as an upper bound (it charges flash-attention interiors that live in
  VMEM on TPU);
* collective term — parsed collective operand bytes (while-aware).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

from repro.configs.base import SHAPES
from repro.configs.registry import get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops(cfg, shape) -> float:
    """Assignment MODEL_FLOPS: 6·N·D train / 2·N·D prefill / 2·N_act·B
    decode (N_act for MoE; D = tokens processed), GLOBAL."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch  # decode: one token per seq


def _weight_bytes(cfg, precision: str) -> float:
    p_total = cfg.param_count()
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    body = p_total - emb
    if precision != "quant":
        return 2.0 * p_total  # bf16
    if cfg.is_moe:
        expert = cfg.num_layers * cfg.num_experts * 3 * cfg.d_model * cfg.d_ff_expert
        rest = body - expert
        return expert * 2.25 / 8 + rest * 4 / 8 + emb * 2.0
    return body * 4 / 8 + emb * 2.0


def _cache_bytes(cfg, shape) -> float:
    if cfg.family == "ssm":
        # recurrent state only
        return cfg.num_layers * shape.global_batch * (
            cfg.num_heads * 256 * 256 * 4 + cfg.d_model * 16
        )
    l_attn = cfg.num_layers
    if cfg.family == "hybrid":
        l_attn = cfg.num_layers // 3
    s_eff = shape.seq_len
    if cfg.local_window and cfg.local_global_ratio:
        n_glob = cfg.num_layers // (cfg.local_global_ratio + 1)
        n_loc = cfg.num_layers - n_glob
        return (
            (n_glob * s_eff + n_loc * min(cfg.local_window, s_eff))
            * shape.global_batch * cfg.num_kv_heads * cfg.head_dim * 2 * 2
        )
    if cfg.local_window:
        s_eff = min(cfg.local_window, s_eff)
    return l_attn * shape.global_batch * s_eff * cfg.num_kv_heads * cfg.head_dim * 2 * 2


def analytic_bytes(cfg, shape, meta: Dict, chips: int) -> float:
    """Per-device analytic HBM-traffic floor."""
    kind = shape.kind
    precision = meta.get("precision", "bf16")
    wb = _weight_bytes(cfg, precision)
    tokens = shape.global_batch * shape.seq_len
    act = tokens * cfg.d_model * 2  # one residual tensor, bf16
    if kind == "train":
        if meta.get("train_mode") == "otp":
            # frozen compressed weights read twice (student+teacher)
            total = 2 * wb + 6 * cfg.num_layers * act
        else:
            # fwd + bwd + update reads/writes + Adam m/v rw (f32)
            p = cfg.param_count()
            total = 3 * 2 * p + 16 * p + 4 * cfg.num_layers * act
        return total / chips
    if kind == "prefill":
        total = wb + 2 * _cache_bytes(cfg, shape) + 4 * cfg.num_layers * act
        return total / chips
    # decode: weights + cache read + tiny activations
    total = wb + _cache_bytes(cfg, shape)
    return total / chips


def load_cells(result_dir: str):
    cells = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def summarize(cell: Dict) -> Dict:
    cfg = get_config(cell["arch"])
    shape = SHAPES[cell["shape"]]
    chips = cell["chips"]
    mf = model_flops(cfg, shape) / chips  # per device
    hlo_f = cell["hlo_flops_per_dev"]
    ana_b = analytic_bytes(cfg, shape, cell.get("meta", {}), chips)
    mem_b = max(ana_b, cell.get("xla_bytes_accessed", 0.0))
    compute = hlo_f / PEAK_FLOPS
    memory = mem_b / HBM_BW
    coll = sum(cell["collective_bytes_per_dev"].values()) / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dom = max(terms, key=terms.get)
    # roofline fraction = fundamental work / total modeled time: the ideal
    # step does either peak-rate math or the analytic-floor weight/cache
    # movement; everything else (excess bytes, collectives) is overhead.
    # 1.0 = at the roofline. (compute-bound train ≈ compute/sum; decode ≈
    # weight-read floor/sum.)
    fundamental = max(min(compute, mf / PEAK_FLOPS), ana_b / HBM_BW)
    frac = fundamental / max(sum(terms.values()), 1e-12)
    return {
        **{f"{k}_s": v for k, v in terms.items()},
        "dominant": dom,
        "roofline_fraction": frac,
        "model_flops_ratio": mf / max(hlo_f, 1.0),
        "mem_upper_s": cell["hbm_bytes_per_dev"] / HBM_BW,
        "fits": cell["memory"]["fits_16gb"],
        "per_dev_gib": cell["memory"]["per_device_total"] / 2**30,
    }


def run(result_dir: str = "results/dryrun", out_md: str = "results/roofline.md"):
    print("== roofline ==")
    cells = load_cells(result_dir)
    if not cells:
        print("  (no dry-run results found — run repro.launch.dryrun first)")
        return []
    lines = [
        "| arch | shape | mesh | step | compute (ms) | memory (ms) | "
        "collective (ms) | dominant | roofline frac | MODEL/HLO flops | "
        "fits 16G | GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for cell in cells:
        s = summarize(cell)
        lines.append(
            f"| {cell['arch']} | {cell['shape']} | {cell['mesh']} | "
            f"{cell['step']} | {s['compute_s']*1e3:.2f} | {s['memory_s']*1e3:.2f} | "
            f"{s['collective_s']*1e3:.2f} | {s['dominant']} | "
            f"{s['roofline_fraction']:.3f} | {s['model_flops_ratio']:.2f} | "
            f"{'✓' if s['fits'] else '✗'} | {s['per_dev_gib']:.2f} |"
        )
        rows.append(
            f"roofline/{cell['arch']}/{cell['shape']}/{cell['mesh']},"
            f"{s['compute_s']*1e6:.1f},"
            f"dom={s['dominant']};frac={s['roofline_fraction']:.3f}"
        )
        print(rows[-1])
    os.makedirs(os.path.dirname(out_md), exist_ok=True)
    with open(out_md, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"  wrote {out_md} ({len(cells)} cells)")
    return rows


if __name__ == "__main__":
    run()
