"""Kernel microbenchmarks (correctness-scale; §4.4 support).

On CPU, Pallas interpret mode is an emulator — wall-clock there is
meaningless. This bench (a) re-validates each kernel against its oracle
on larger shapes than the unit tests, (b) times the *jnp reference path*
(what the dry-run lowers) for dense-vs-dequant overhead visibility, and
(c) reports the analytic VMEM working set per kernel tile configuration
(the quantity that governs TPU occupancy).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import quantize_to_packed
from repro.kernels import ref
from repro.kernels.quant_matmul import quant_matmul_pallas

from .common import csv_row


def _vmem_bytes(bm, bn, bk, bits, group):
    x = bm * bk * 4
    w = bk * bn * bits // 8
    deq = bk * bn * 4
    sc = 2 * (bk // group) * bn * 4
    acc = bm * bn * 4
    return x + w + deq + sc + acc


def run(quick: bool = False):
    print("== kernel_bench ==")
    rows = []
    rng = np.random.default_rng(0)
    m, k, n = (64, 512, 512) if quick else (128, 1024, 1024)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    reps = 3 if quick else 10

    dense = jax.jit(lambda a, b: a @ b)
    _ = jax.block_until_ready(dense(x, w))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(dense(x, w))
    t_dense = (time.time() - t0) / reps
    rows.append(csv_row("kernel/dense_matmul", t_dense * 1e6, f"m{m}k{k}n{n}"))

    for bits in (1, 2, 3, 4):
        pt = quantize_to_packed(w, bits, group=128, refine=False)
        f = jax.jit(lambda a, d=pt.data, s=pt.scale, z=pt.zero: ref.quant_matmul_ref(
            a, d, s, z, bits=bits, group=128))
        y = jax.block_until_ready(f(x))
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(f(x))
        t_q = (time.time() - t0) / reps
        # correctness vs pallas interpret on a sub-tile
        y_pl = quant_matmul_pallas(
            x[:32], pt.data, pt.scale, pt.zero, bits=bits, group=128,
            bm=32, bn=min(n, 256), bk=min(k, 512), interpret=True,
        )
        err = float(jnp.max(jnp.abs(y_pl - f(x[:32]))))
        vmem = _vmem_bytes(256, 256, 512, bits, 128)
        rows.append(csv_row(
            f"kernel/quant_matmul_{bits}b", t_q * 1e6,
            f"vs_dense={t_q/t_dense:.2f};pallas_maxerr={err:.2e};"
            f"vmem_tile_kb={vmem//1024}"))
        assert err < 1e-3, f"{bits}-bit kernel mismatch {err}"
    return rows


if __name__ == "__main__":
    run()
