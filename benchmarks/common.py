"""Shared benchmark infrastructure.

All paper-table benchmarks run against a *trained* small MoE LM (random
models make quality metrics meaningless — see tests). The model trains
once on the synthetic corpus and is cached under results/bench_model.
Relative claims (strategy orderings, Pareto shape, OTP-vs-random) are
scale-free, which is what the tables assert.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.core import pipeline
from repro.data.pipeline import HostDataLoader, make_calibration_tokens
from repro.models.registry import get_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine

BENCH_CFG = ModelConfig(
    name="bench-moe-16m",
    family="moe",
    num_layers=4,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=512,
    d_ff_expert=512,
    vocab_size=512,  # small vocab → the 512K-token budget actually learns the corpus
    num_experts=8,
    top_k=2,
    num_shared_experts=1,
    dtype="float32",
    remat="none",
    logits_chunk=64,
    attn_q_chunk=128,
    attn_kv_chunk=128,
    moe_capacity_factor=2.0,
)

CKPT_DIR = "results/bench_model"
_STATE: Dict = {}


def trained_model(steps: int = 250, force: bool = False):
    """Train (or load) the benchmark MoE. Returns (cfg, params)."""
    if "params" in _STATE and not force:
        return BENCH_CFG, _STATE["params"]
    cfg = BENCH_CFG
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    ckpt = Checkpointer(CKPT_DIR, keep=1)
    last = ckpt.latest_step()
    if last is not None and not force:
        params = ckpt.restore(last, {"params": params})["params"]
        _STATE["params"] = params
        return cfg, params
    ocfg = AdamWConfig(lr=2e-3)
    opt = adamw_init(params, ocfg)
    loader = HostDataLoader(vocab=cfg.vocab_size, global_batch=16, seq_len=128)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: bundle.train_loss(p, batch)[0]
        )(params)
        sc = warmup_cosine(opt["step"], warmup=20, total=steps)
        params, opt = adamw_update(params, grads, opt, ocfg, sc)
        return params, opt, loss

    t0 = time.time()
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in loader.batch_at(step).items()}
        params, opt, loss = step_fn(params, opt, batch)
        if step % 50 == 0:
            print(f"  [bench-train] step {step} loss {float(loss):.3f}")
    print(f"  [bench-train] done in {time.time()-t0:.0f}s "
          f"final loss {float(loss):.3f}")
    ckpt.save(steps - 1, {"params": params}, blocking=True)
    ckpt.wait()
    _STATE["params"] = params
    return cfg, params


def calibration(cfg, params, n: int = 16, seq: int = 128):
    key = ("calib", n, seq)
    if key not in _STATE:
        toks = jnp.asarray(make_calibration_tokens(cfg.vocab_size, n, seq))
        _STATE[key] = pipeline.calibrate(params, toks, cfg)
    return _STATE[key]


def eval_tokens(cfg, n: int = 16, seq: int = 128) -> jnp.ndarray:
    return jnp.asarray(
        make_calibration_tokens(cfg.vocab_size, n, seq, seed=999)
    )


def ppl_fp(cfg, params, tokens) -> float:
    from repro.models import transformer as tf
    from repro.models import layers as L

    hidden, _, _ = tf.forward_hidden(params, tokens[:, :-1], cfg)
    emb = params.get("unembed", params["embed"])
    nll = L.chunked_xent(hidden, emb, tokens[:, 1:], cfg.logits_chunk)
    return float(jnp.exp(nll))


def ppl_compressed(cfg, blocks_c, top, tokens, otp_params=None) -> float:
    from repro.models import layers as L

    hidden, _ = pipeline.compressed_forward(
        blocks_c, top, tokens[:, :-1], cfg, otp_params=otp_params
    )
    emb = top.get("unembed", top["embed"])
    nll = L.chunked_xent(hidden, emb, tokens[:, 1:], cfg.logits_chunk)
    return float(jnp.exp(nll))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row)
    return row


def platform_meta(ffn_backend: str | None = None) -> Dict[str, str]:
    """Provenance stamp for every bench leg: which backend produced the
    numbers. ``ffn_backend`` records the expert-FFN implementation the
    leg ran (CLI flag or REPRO_FFN_BACKEND; 'default' when unpinned)."""
    return {
        "platform": str(jax.default_backend()),
        "device_kind": str(jax.devices()[0].device_kind),
        "ffn_backend": str(
            ffn_backend or os.environ.get("REPRO_FFN_BACKEND") or "default"
        ),
    }
