"""Paged-KV decode attention Pallas TPU kernel (serving hot path).

One query token per sequence attends to a KV history scattered across
fixed-size blocks of a shared pool (``repro.serving.kvcache``): block
``j`` of sequence ``i`` lives at physical page ``block_tables[i, j]``.
The kernel streams pages HBM→VMEM via **scalar-prefetched** block tables
(the index map reads ``block_tables[i, j]`` to pick each page's DMA
source), so the gather costs exactly the bytes of the pages it visits —
no [B, S_max, Hkv, dh] contiguous copy ever exists. Online softmax
accumulates across pages (the "arbitrary" grid dim), the same recurrence
as :func:`repro.models.layers._online_attn`.

Grid ``(B, Hkv, MB)``: one program per (sequence, kv-head, page). GQA
rides the block shape — each program computes all ``G = Hq/Hkv`` query
heads of its kv head against one [BS, dh] page.

Layouts
-------
* ``q``: [B, Hkv, G, dh]
* ``k_pool`` / ``v_pool``: [NB, BS, Hkv, dh] (one layer's pool)
* ``block_tables``: [B, MB] int32 physical page ids (scalar prefetch)
* ``lengths``: [B] int32 logical kv length (newest token at length−1)
* ``window``: [1] int32 sliding-window size (≥ max length = full attn)

The jnp oracle is :func:`repro.kernels.ref.paged_attention_ref`; the CPU
serving path and tests run it (or this kernel under ``interpret=True``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

__all__ = ["paged_attention_pallas", "paged_attention_quant_pallas"]

NEG_INF = -1e30


def _kernel(
    bt_ref,  # [B, MB] scalar prefetch (consumed by index maps)
    len_ref,  # [B] scalar prefetch
    win_ref,  # [1] scalar prefetch
    q_ref,  # [1, 1, G, dh]
    k_ref,  # [1, BS, 1, dh] — page bt[i, j] of kv head h
    v_ref,  # [1, BS, 1, dh]
    o_ref,  # [1, 1, G, dh]
    acc_ref,  # VMEM [G, dh] f32
    m_ref,  # VMEM [G, 1] f32 running max
    l_ref,  # VMEM [G, 1] f32 running denominator
    *,
    bs: int,
    nj: int,
):
    i, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[i]
    win = win_ref[0]
    dh = q_ref.shape[-1]
    q = q_ref[0, 0].astype(jnp.float32) * dh**-0.5  # [G, dh]
    k = k_ref[0, :, 0].astype(jnp.float32)  # [BS, dh]
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [G, BS]
    kv_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = (kv_pos < length) & (kv_pos > (length - 1) - win)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _done():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def _kernel_quant(
    bt_ref,  # [B, MB] scalar prefetch (consumed by index maps)
    len_ref,  # [B] scalar prefetch
    win_ref,  # [1] scalar prefetch
    q_ref,  # [1, 1, G, dh]
    k_ref,  # [1, BS, 1, dh] uint8 codes — page bt[i, j] of kv head h
    v_ref,  # [1, BS, 1, dh] uint8 codes
    ks_ref,  # [1, BS, 1] f32 per-row K scale of the same page/head
    kz_ref,  # [1, BS, 1] f32 per-row K zero
    vs_ref,  # [1, BS, 1] f32
    vz_ref,  # [1, BS, 1] f32
    o_ref,  # [1, 1, G, dh]
    acc_ref,  # VMEM [G, dh] f32
    m_ref,  # VMEM [G, 1] f32 running max
    l_ref,  # VMEM [G, 1] f32 running denominator
    *,
    bs: int,
    nj: int,
):
    """int8-KV variant of :func:`_kernel`: identical online-softmax
    recurrence with a per-row affine **dequant epilogue** on the gathered
    page — ``(codes - zero) * scale`` in f32, the exact expression of
    :func:`repro.core.quantizers.dequantize_kv_rows` and of the ref
    oracle's quant mode, applied after the page lands in VMEM (the DMA
    moves 1-byte codes + one f32 pair per row, ~4× fewer HBM bytes than
    an fp32 page)."""
    i, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[i]
    win = win_ref[0]
    dh = q_ref.shape[-1]
    q = q_ref[0, 0].astype(jnp.float32) * dh**-0.5  # [G, dh]
    k = (k_ref[0, :, 0].astype(jnp.float32) - kz_ref[0, :, 0][:, None]) \
        * ks_ref[0, :, 0][:, None]  # [BS, dh] dequantized rows
    v = (v_ref[0, :, 0].astype(jnp.float32) - vz_ref[0, :, 0][:, None]) \
        * vs_ref[0, :, 0][:, None]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [G, BS]
    kv_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = (kv_pos < length) & (kv_pos > (length - 1) - win)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _done():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_quant_pallas(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    k_scale: jnp.ndarray,
    k_zero: jnp.ndarray,
    v_scale: jnp.ndarray,
    v_zero: jnp.ndarray,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    window: jnp.ndarray,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """``out[B,Hkv,G,dh]`` over int8-quantized pools: ``k_pool/v_pool``
    are ``[NB, BS, Hkv, dh]`` uint8 codes, the scale/zero tables
    ``[NB, BS, Hkv]`` f32 — one affine pair per KV row, streamed
    page-at-a-time through the same scalar-prefetched block tables as
    the codes."""
    b, hkv, g, dh = q.shape
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    mb = block_tables.shape[1]
    grid = (b, hkv, mb)

    q_spec = pl.BlockSpec((1, 1, g, dh), lambda i, h, j, bt, ln, wd: (i, h, 0, 0))
    kv_spec = pl.BlockSpec(
        (1, bs, 1, dh), lambda i, h, j, bt, ln, wd: (bt[i, j], 0, h, 0)
    )
    sc_spec = pl.BlockSpec(
        (1, bs, 1), lambda i, h, j, bt, ln, wd: (bt[i, j], 0, h)
    )
    o_spec = pl.BlockSpec((1, 1, g, dh), lambda i, h, j, bt, ln, wd: (i, h, 0, 0))
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec, sc_spec, sc_spec, sc_spec, sc_spec],
        out_specs=o_spec,
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel_quant, bs=bs, nj=mb)
    return pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        block_tables.astype(jnp.int32),
        lengths.astype(jnp.int32),
        window.astype(jnp.int32),
        q,
        k_pool,
        v_pool,
        k_scale.astype(jnp.float32),
        k_zero.astype(jnp.float32),
        v_scale.astype(jnp.float32),
        v_zero.astype(jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    window: jnp.ndarray,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """``out[B,Hkv,G,dh]`` — see module docstring for layouts."""
    b, hkv, g, dh = q.shape
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    mb = block_tables.shape[1]
    grid = (b, hkv, mb)

    q_spec = pl.BlockSpec((1, 1, g, dh), lambda i, h, j, bt, ln, wd: (i, h, 0, 0))
    kv_spec = pl.BlockSpec(
        (1, bs, 1, dh), lambda i, h, j, bt, ln, wd: (bt[i, j], 0, h, 0)
    )
    o_spec = pl.BlockSpec((1, 1, g, dh), lambda i, h, j, bt, ln, wd: (i, h, 0, 0))
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, bs=bs, nj=mb)
    return pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        block_tables.astype(jnp.int32),
        lengths.astype(jnp.int32),
        window.astype(jnp.int32),
        q,
        k_pool,
        v_pool,
    )
