"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

These are also the *model* code path used for CPU smoke tests and the
multi-pod dry-run: mathematically identical to the kernels, and XLA:TPU
fuses the dequant chain into the GEMM operand, so cost_analysis FLOPs match
the kernel path (memory terms for quantized weights are additionally
computed analytically — see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.packing import unpack_bits

__all__ = [
    "dequant_ref",
    "quant_matmul_ref",
    "binary_matmul_ref",
    "moe_gmm_ref",
    "moe_gmm_swiglu_ref",
    "paged_attention_ref",
]

NEG_INF = -1e30


def dequant_ref(
    w_packed, scale: jnp.ndarray, zero: jnp.ndarray, bits: int, k: int,
    group: int = 128, dtype=jnp.float32,
) -> jnp.ndarray:
    """Unpack + group-wise affine dequant to ``[K, N]``."""
    codes = unpack_bits(w_packed, bits, axis=0 if bits != 3 else 0)
    codes = codes[:k].astype(jnp.float32)
    n = codes.shape[1]
    ng = (k + group - 1) // group
    if k % group:
        codes = jnp.pad(codes, ((0, ng * group - k), (0, 0)))
    cg = codes.reshape(ng, group, n)
    w = (cg - zero[:, None, :]) * scale[:, None, :]
    return w.reshape(ng * group, n)[:k].astype(dtype)


def quant_matmul_ref(
    x: jnp.ndarray, w_packed, scale, zero, *, bits: int, group: int = 128,
    out_dtype=None,
) -> jnp.ndarray:
    k = x.shape[-1]
    w = dequant_ref(w_packed, scale, zero, bits, k, group,
                    dtype=jnp.float32 if x.dtype == jnp.float32 else jnp.bfloat16)
    y = jnp.dot(x.astype(w.dtype), w, preferred_element_type=jnp.float32)
    return y.astype(out_dtype or x.dtype)


def binary_matmul_ref(
    x: jnp.ndarray, b_packed: jnp.ndarray, alpha: jnp.ndarray, *, out_dtype=None
) -> jnp.ndarray:
    """Eq. 9 oracle: ``(x @ (2B~-1)) * alpha``."""
    k = x.shape[-1]
    bits01 = unpack_bits(b_packed, 1, axis=0)[:k]
    cd = jnp.float32 if x.dtype == jnp.float32 else jnp.bfloat16
    w = bits01.astype(cd) * 2 - 1
    y = jnp.dot(x.astype(cd), w, preferred_element_type=jnp.float32) * alpha
    return y.astype(out_dtype or x.dtype)


def moe_gmm_ref(
    x_padded: jnp.ndarray,
    w_packed,
    scale,
    zero,
    block_expert: jnp.ndarray,
    num_active=None,
    *,
    bits: int,
    group: int = 128,
    bm: int = 128,
    out_dtype=None,
) -> jnp.ndarray:
    """Row-block i of ``x_padded`` hits expert ``block_expert[i]``.

    ``num_active`` (scalar or [1], optional) mirrors the kernel's ragged
    skip: row-blocks at index ≥ it are zero-filled (the kernel never
    computes them; the oracle computes then masks — same values, the
    FLOP saving is the kernel's job).
    """
    m, k = x_padded.shape
    if bits == 3:
        e = w_packed[0].shape[0]
        n = w_packed[0].shape[2]
        planes = [
            (w_packed[0][i], w_packed[1][i]) for i in range(e)
        ]
    else:
        e, _, n = w_packed.shape
        planes = [w_packed[i] for i in range(e)]
    ws = jnp.stack(
        [
            dequant_ref(planes[i], scale[i], zero[i], bits, k, group)
            for i in range(e)
        ]
    )  # [E, K, N]
    nblocks = m // bm
    xb = x_padded.reshape(nblocks, bm, k)
    wb = ws[block_expert]  # [nblocks, K, N]
    cd = jnp.float32 if x_padded.dtype == jnp.float32 else jnp.bfloat16
    y = jnp.einsum(
        "bmk,bkn->bmn", xb.astype(cd), wb.astype(cd),
        preferred_element_type=jnp.float32,
    )
    if num_active is not None:
        live = jnp.arange(nblocks) < jnp.asarray(num_active).reshape(())
        y = jnp.where(live[:, None, None], y, 0.0)
    return y.reshape(m, n).astype(out_dtype or x_padded.dtype)


def moe_gmm_swiglu_ref(
    x_padded: jnp.ndarray,
    wg_packed,
    wu_packed,
    g_scale,
    g_zero,
    u_scale,
    u_zero,
    block_expert: jnp.ndarray,
    num_active=None,
    *,
    bits: int,
    group: int = 128,
    bm: int = 128,
    out_dtype=None,
) -> jnp.ndarray:
    """Oracle for the fused gate/up grouped GEMM:
    ``silu(x @ Wg) * (x @ Wu)`` per row-block's expert. Inactive blocks
    are exactly zero (``silu(0)·0``), matching the kernel's skip path."""
    f32 = jnp.float32
    g = moe_gmm_ref(
        x_padded, wg_packed, g_scale, g_zero, block_expert, num_active,
        bits=bits, group=group, bm=bm, out_dtype=f32,
    )
    u = moe_gmm_ref(
        x_padded, wu_packed, u_scale, u_zero, block_expert, num_active,
        bits=bits, group=group, bm=bm, out_dtype=f32,
    )
    h = jax.nn.silu(g) * u
    return h.astype(out_dtype or x_padded.dtype)


def paged_attention_ref(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    window=None,
    out_dtype=None,
    quant=None,
) -> jnp.ndarray:
    """Oracle for :mod:`repro.kernels.paged_attention`: gather each
    sequence's pages through its block table, then masked softmax decode
    attention in f32.

    ``q [B, Hkv, G, dh]``; ``k_pool/v_pool [NB, BS, Hkv, dh]``;
    ``block_tables [B, MB]``; ``lengths [B]`` logical kv lengths (the
    newest token sits at ``lengths - 1``). ``window`` keeps
    ``kv_pos > (lengths−1) − window`` (None = full attention).

    ``quant = (k_scale, k_zero, v_scale, v_zero)`` (each ``[NB, BS,
    Hkv]`` f32) switches the pools to int8-quantized-KV mode: the pools
    carry uint8 codes and the gathered rows pass through the per-row
    affine dequant ``(q - z) * s`` in f32 before the attention math —
    the expression of :func:`repro.core.quantizers.dequantize_kv_rows`,
    which the Pallas dequant epilogue mirrors. ``quant=None`` leaves the
    fp path byte-for-byte the historical computation.
    """
    b, hkv, g, dh = q.shape
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    mb = block_tables.shape[1]
    flat_k = k_pool.reshape(nb * bs, hkv, dh)
    flat_v = v_pool.reshape(nb * bs, hkv, dh)
    phys = (
        block_tables[:, :, None] * bs + jnp.arange(bs)[None, None, :]
    ).reshape(b, mb * bs)
    k = flat_k[phys]  # [B, S_log, Hkv, dh]
    v = flat_v[phys]
    if quant is not None:
        ks, kz, vs, vz = (a.reshape(nb * bs, hkv) for a in quant)
        k = (k.astype(jnp.float32) - kz[phys][..., None]) * ks[phys][..., None]
        v = (v.astype(jnp.float32) - vz[phys][..., None]) * vs[phys][..., None]
    kv_pos = jnp.arange(mb * bs)
    valid = kv_pos[None, :] < lengths[:, None]
    if window is not None:
        valid &= kv_pos[None, :] > (lengths[:, None] - 1) - window
    s = jnp.einsum(
        "bhgd,bshd->bhgs", q.astype(jnp.float32) * dh**-0.5,
        k.astype(jnp.float32), preferred_element_type=jnp.float32,
    )
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgs,bshd->bhgd", w, v.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return o.astype(out_dtype or q.dtype)
