"""1-bit (binary) matmul Pallas TPU kernel — paper §3.3 Eqs. 8/9.

The paper stores ``B~ = (sign(W)+1)/2 ∈ {0,1}`` packed 8/byte and computes
``s·xB`` with additions only on GPU. The MXU has no add-only mode, so the
TPU-native reading (DESIGN.md §5.1) is bandwidth: weights stream at 1/16th
of bf16 bytes; the VPU unpacks to ``±1``, applies the per-output-channel L1
scale ``alpha`` (Eq. 4), and the MXU runs a normal dot. Decode-time expert
GEMMs are memory-bound, so the 16× byte reduction is the realized speedup.

Layouts: ``x [M, K]``, ``b_packed [K/8, N] uint8``, ``alpha [1, N] f32``.
Grid (M/bm, N/bn, K/bk), K innermost, f32 scratch accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

__all__ = ["binary_matmul_pallas"]


def _kernel(x_ref, b_ref, a_ref, o_ref, acc_ref, *, nk: int, compute_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bk = x_ref.shape[1]
    bn = o_ref.shape[1]
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, :, None]
    bits01 = ((b_ref[...][:, None, :] >> shifts) & 1).reshape(bk, bn)
    w = (bits01.astype(compute_dtype) * 2 - 1)  # ±1; alpha applied at the end
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(compute_dtype), w, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * a_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def binary_matmul_pallas(
    x: jnp.ndarray,
    b_packed: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """``y = (x @ (2·unpack(b_packed)-1)) * alpha`` — Eq. 9 on the MXU."""
    m, k = x.shape
    n = b_packed.shape[1]
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % 8 == 0
    nk = k // bk
    compute_dtype = jnp.float32 if x.dtype == jnp.float32 else jnp.bfloat16
    kernel = functools.partial(_kernel, nk=nk, compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 8, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, b_packed, alpha)
