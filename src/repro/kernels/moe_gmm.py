"""Grouped (ragged) quantized expert matmul — the MoE hot loop.

MegaBlocks-style dropless expert GEMM adapted to TPU + PMQ quantization
(DESIGN.md §5.4): tokens are pre-sorted by expert id and padded so each
expert's row range is a multiple of ``bm``; a scalar-prefetch array
``block_expert [M/bm]`` tells each row-block which expert's packed weight
tile to fetch. Dequantization (group-wise affine over K) happens in VMEM
exactly as in :mod:`repro.kernels.quant_matmul`.

Because every PMQ bit-width rides the same (scale, zero) affine form
(1-bit: scale=2α, zero=0.5 — see ``quantize_to_packed``), a *bit-bucketed*
MoE layer issues one ``moe_gmm`` per bucket with experts of equal width.

**Ragged-length handling**: the compacted token-sorted layout
(:func:`repro.core.compressed_moe.compressed_expert_ffn`) packs each
expert's *routed* rows into bm-aligned groups at the front of a
static-shape buffer; ``num_active [1]`` (second scalar-prefetch operand)
tells the kernel how many leading row-blocks actually carry tokens.
Blocks past it skip the unpack/dequant/MXU work entirely and write
zeros — the dead capacity padding costs (almost) nothing, while the
grid, and therefore the jitted program, keeps its static shape.

**SwiGLU epilogue** (:func:`moe_gmm_swiglu_pallas`): the gate and up
projections share their ``x`` tile and accumulate side by side in VMEM;
the epilogue applies ``silu(acc_g) · acc_u`` before the single output
write, so the [M, F] hidden tile never round-trips HBM between the two
GEMMs and ``x`` streams from HBM once instead of twice.

Layouts
-------
* ``x_sorted``:  [Mp, K]   tokens sorted by expert, bm-padded per expert
* ``w_packed``:  [E, K/per, N] uint8 (or (hi [E,K/4,N], lo [E,K/8,N]) for 3-bit)
* ``scale/zero``:[E, K/group, N] f32
* ``block_expert``: [Mp/bm] int32 — expert id per row-block (scalar prefetch)
* ``num_active``: [1] int32 — row-blocks carrying routed tokens (scalar
  prefetch; blocks ≥ it are skipped and zero-filled)
* grid (Mp/bm, N/bn, K/bk), K innermost, f32 scratch accumulator.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

from .quant_matmul import _dequant, _unpack_tile

__all__ = [
    "moe_gmm_pallas",
    "moe_gmm_swiglu_pallas",
    "pad_groups",
    "sort_by_expert",
]


def _w_specs_and_planes(w_packed, bits: int, bk: int, bn: int):
    """BlockSpecs + flat plane list for one packed weight operand."""
    if bits == 3:
        hi, lo = w_packed
        specs = [
            pl.BlockSpec((1, bk // 4, bn), lambda i, j, kk, be, na: (be[i], kk, j)),
            pl.BlockSpec((1, bk // 8, bn), lambda i, j, kk, be, na: (be[i], kk, j)),
        ]
        return specs, [hi, lo]
    per = 8 // bits
    specs = [
        pl.BlockSpec((1, bk // per, bn), lambda i, j, kk, be, na: (be[i], kk, j))
    ]
    return specs, [w_packed]


def _take_w_tile(refs, bits: int):
    """Pop one weight operand's refs and present it to ``_unpack_tile``."""
    if bits == 3:
        (hi_ref, lo_ref), rest = refs[:2], refs[2:]
        return (_Squeezed(hi_ref), _Squeezed(lo_ref)), rest
    return _Squeezed(refs[0]), refs[1:]


def _full_blocks(m: int, bm: int) -> jnp.ndarray:
    return jnp.full((1,), m // bm, jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group", "bm", "bn", "bk", "out_dtype", "interpret"),
)
def moe_gmm_pallas(
    x_sorted: jnp.ndarray,
    w_packed,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    block_expert: jnp.ndarray,
    num_active: jnp.ndarray | None = None,
    *,
    bits: int,
    group: int = 128,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Block-diagonal grouped GEMM: row-block i uses expert block_expert[i].

    ``num_active [1]`` (optional) marks how many leading row-blocks carry
    routed tokens; blocks past it are zero-filled without touching the
    MXU (ragged capacity layouts pass the bm-padded routed-row count).
    """
    m, k = x_sorted.shape
    if bits == 3:
        hi, lo = w_packed
        e, _, n = hi.shape
    else:
        e, _, n = w_packed.shape
    out_dtype = out_dtype or x_sorted.dtype
    bn, bk = min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % group == 0
    assert block_expert.shape == (m // bm,)
    if num_active is None:
        num_active = _full_blocks(m, bm)
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, kk, be, na: (i, kk))
    s_spec = pl.BlockSpec(
        (1, bk // group, bn), lambda i, j, kk, be, na: (be[i], kk, j)
    )
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk, be, na: (i, j))
    w_specs, planes = _w_specs_and_planes(w_packed, bits, bk, bn)
    args = (block_expert, num_active, x_sorted, *planes, scale, zero)

    compute_dtype = jnp.float32 if x_sorted.dtype == jnp.float32 else jnp.bfloat16

    def kernel(be_ref, na_ref, x_ref, *rest):
        # squeeze the leading expert dim of the weight/scale tiles
        w_tile, rest = _take_w_tile(list(rest), bits)
        s_ref, z_ref, o_ref, acc_ref = rest
        s_t, z_t = s_ref[0], z_ref[0]

        @pl.when(pl.program_id(2) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # ragged skip: blocks past the routed-row frontier never unpack,
        # dequantize or touch the MXU — their accumulator stays zero
        @pl.when(pl.program_id(0) < na_ref[0])
        def _compute():
            bk_ = x_ref.shape[1]
            bn_ = o_ref.shape[1]
            codes = _unpack_tile(w_tile, bits, bk_, bn_)
            w = _dequant(codes, s_t, z_t, group, compute_dtype)
            acc_ref[...] += jnp.dot(
                x_ref[...].astype(compute_dtype),
                w,
                preferred_element_type=jnp.float32,
            )

        @pl.when(pl.program_id(2) == nk - 1)
        def _done():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[x_spec, *w_specs, s_spec, s_spec],
        out_specs=o_spec,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*args)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group", "bm", "bn", "bk", "out_dtype", "interpret"),
)
def moe_gmm_swiglu_pallas(
    x_sorted: jnp.ndarray,
    wg_packed,
    wu_packed,
    g_scale: jnp.ndarray,
    g_zero: jnp.ndarray,
    u_scale: jnp.ndarray,
    u_zero: jnp.ndarray,
    block_expert: jnp.ndarray,
    num_active: jnp.ndarray | None = None,
    *,
    bits: int,
    group: int = 128,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused gate/up grouped GEMM with the SwiGLU epilogue.

    ``y = silu(x @ dequant(Wg)) * (x @ dequant(Wu))`` per row-block's
    expert. The two projections accumulate in separate VMEM scratches
    off a single streamed ``x`` tile; the nonlinearity runs on the f32
    accumulators right before the one output write, so the [M, F] hidden
    never exists in HBM. Same ragged ``num_active`` semantics as
    :func:`moe_gmm_pallas`.
    """
    m, k = x_sorted.shape
    if bits == 3:
        e, _, n = wg_packed[0].shape
    else:
        e, _, n = wg_packed.shape
    out_dtype = out_dtype or x_sorted.dtype
    bn, bk = min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % group == 0
    assert block_expert.shape == (m // bm,)
    if num_active is None:
        num_active = _full_blocks(m, bm)
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, kk, be, na: (i, kk))
    s_spec = pl.BlockSpec(
        (1, bk // group, bn), lambda i, j, kk, be, na: (be[i], kk, j)
    )
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk, be, na: (i, j))
    g_specs, g_planes = _w_specs_and_planes(wg_packed, bits, bk, bn)
    u_specs, u_planes = _w_specs_and_planes(wu_packed, bits, bk, bn)
    args = (
        block_expert, num_active, x_sorted, *g_planes, *u_planes,
        g_scale, g_zero, u_scale, u_zero,
    )

    compute_dtype = jnp.float32 if x_sorted.dtype == jnp.float32 else jnp.bfloat16

    def kernel(be_ref, na_ref, x_ref, *rest):
        g_tile, rest = _take_w_tile(list(rest), bits)
        u_tile, rest = _take_w_tile(rest, bits)
        gs_ref, gz_ref, us_ref, uz_ref, o_ref, accg_ref, accu_ref = rest

        @pl.when(pl.program_id(2) == 0)
        def _init():
            accg_ref[...] = jnp.zeros_like(accg_ref)
            accu_ref[...] = jnp.zeros_like(accu_ref)

        @pl.when(pl.program_id(0) < na_ref[0])
        def _compute():
            bk_ = x_ref.shape[1]
            bn_ = o_ref.shape[1]
            xt = x_ref[...].astype(compute_dtype)
            wg = _dequant(
                _unpack_tile(g_tile, bits, bk_, bn_),
                gs_ref[0], gz_ref[0], group, compute_dtype,
            )
            accg_ref[...] += jnp.dot(xt, wg, preferred_element_type=jnp.float32)
            wu = _dequant(
                _unpack_tile(u_tile, bits, bk_, bn_),
                us_ref[0], uz_ref[0], group, compute_dtype,
            )
            accu_ref[...] += jnp.dot(xt, wu, preferred_element_type=jnp.float32)

        @pl.when(pl.program_id(2) == nk - 1)
        def _done():
            h = jax.nn.silu(accg_ref[...]) * accu_ref[...]
            o_ref[...] = h.astype(o_ref.dtype)

    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[x_spec, *g_specs, *u_specs, s_spec, s_spec, s_spec, s_spec],
        out_specs=o_spec,
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*args)


class _Squeezed:
    """Adapter presenting ``ref[0]`` as a 2-D ref for ``_unpack_tile``."""

    def __init__(self, ref):
        self._ref = ref

    def __getitem__(self, idx):
        return self._ref[0][idx] if idx is not Ellipsis else self._ref[0]

    @property
    def shape(self):
        return self._ref.shape[1:]


def sort_by_expert(
    tokens: jnp.ndarray, expert_ids: jnp.ndarray, num_experts: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Stable-sort rows by expert id.

    Returns ``(sorted_tokens, sort_idx, group_sizes)`` where
    ``group_sizes[e]`` counts rows routed to expert e.
    """
    order = jnp.argsort(expert_ids, stable=True)
    sorted_tokens = tokens[order]
    group_sizes = jnp.bincount(expert_ids, length=num_experts)
    return sorted_tokens, order, group_sizes


def pad_groups(
    sorted_tokens: jnp.ndarray,
    group_sizes: jnp.ndarray,
    bm: int,
    capacity: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Scatter each expert's rows into a bm-aligned, fixed-capacity layout.

    Static-shape friendly (jit-safe): every expert gets ``capacity`` rows
    (capacity % bm == 0); rows beyond capacity are dropped (standard
    capacity-factor semantics). Returns ``(x_padded [E*capacity, K],
    block_expert [E*capacity/bm], row_map [T] -> padded index or -1)``.

    The *compacted* variant of this layout — groups packed back-to-back at
    bm boundaries with a ``num_active`` block count instead of a fixed
    per-expert stride — is built by
    :func:`repro.core.compressed_moe.compressed_expert_ffn` directly on
    the capacity-dispatch layout.
    """
    e = group_sizes.shape[0]
    assert capacity % bm == 0
    t = sorted_tokens.shape[0]
    starts = jnp.concatenate([jnp.zeros(1, group_sizes.dtype), jnp.cumsum(group_sizes)[:-1]])
    row_expert = jnp.repeat(
        jnp.arange(e), group_sizes, total_repeat_length=t
    )
    rank_in_group = jnp.arange(t) - starts[row_expert]
    dest = row_expert * capacity + rank_in_group
    valid = rank_in_group < capacity
    dest = jnp.where(valid, dest, t * 0 + e * capacity)  # overflow bucket
    x_padded = jnp.zeros(
        (e * capacity + 1, sorted_tokens.shape[1]), sorted_tokens.dtype
    )
    x_padded = x_padded.at[dest].set(sorted_tokens)[: e * capacity]
    block_expert = jnp.repeat(jnp.arange(e, dtype=jnp.int32), capacity // bm)
    row_map = jnp.where(valid, dest, -1)
    return x_padded, block_expert, row_map
