"""Version shims for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in newer
jax releases; the kernels support both so the same code runs on the
container's pinned jax and on current TPU toolchains.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams"]

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
