"""Public jit'd wrappers around the Pallas kernels.

Handle platform selection (TPU → compiled kernel, CPU → interpret or
reference), padding to block multiples, and the `PackedTensor` container
from :mod:`repro.core.packing`. Models call these; they never touch
`pallas_call` directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.packing import PackedTensor
from . import ref
from .binary_matmul import binary_matmul_pallas
from .moe_gmm import moe_gmm_pallas, pad_groups, sort_by_expert
from .paged_attention import paged_attention_pallas
from .quant_matmul import quant_matmul_pallas

__all__ = [
    "quant_matmul",
    "binary_matmul",
    "moe_gmm",
    "paged_attention",
    "pad_groups",
    "sort_by_expert",
    "default_backend",
]


def default_backend() -> str:
    """'pallas' on TPU, 'ref' elsewhere (tests opt into 'interpret')."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def quant_matmul(
    x: jnp.ndarray,
    pt: PackedTensor,
    *,
    backend: str | None = None,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    """``y = x @ dequant(pt)`` for any leading x shape; K = pt.shape[0]."""
    backend = backend or default_backend()
    k, n = pt.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    if backend == "ref":
        y = ref.quant_matmul_ref(
            x2, pt.data, pt.scale, pt.zero, bits=pt.bits, group=pt.group
        )
        return y.reshape(*lead, n)
    m = x2.shape[0]
    bm_ = min(bm, _next_mult(m, 8))
    x2p = _pad_to(x2, bm_, 0)
    y = quant_matmul_pallas(
        x2p,
        pt.data,
        pt.scale,
        pt.zero,
        bits=pt.bits,
        group=pt.group,
        bm=bm_,
        bn=bn,
        bk=bk,
        interpret=(backend == "interpret"),
    )
    return y[:m].reshape(*lead, n)


def binary_matmul(
    x: jnp.ndarray,
    b_packed: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    backend: str | None = None,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    backend = backend or default_backend()
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if backend == "ref":
        return ref.binary_matmul_ref(x2, b_packed, alpha).reshape(
            *lead, b_packed.shape[1]
        )
    m = x2.shape[0]
    bm_ = min(bm, _next_mult(m, 8))
    x2p = _pad_to(x2, bm_, 0)
    y = binary_matmul_pallas(
        x2p, b_packed, alpha, bm=bm_, bn=bn, bk=bk,
        interpret=(backend == "interpret"),
    )
    return y[:m].reshape(*lead, b_packed.shape[1])


def moe_gmm(
    x_padded: jnp.ndarray,
    w_packed,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    block_expert: jnp.ndarray,
    *,
    bits: int,
    group: int = 128,
    backend: str | None = None,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    backend = backend or default_backend()
    if backend == "ref":
        return ref.moe_gmm_ref(
            x_padded, w_packed, scale, zero, block_expert,
            bits=bits, group=group, bm=bm,
        )
    return moe_gmm_pallas(
        x_padded, w_packed, scale, zero, block_expert,
        bits=bits, group=group, bm=bm, bn=bn, bk=bk,
        interpret=(backend == "interpret"),
    )


def paged_attention(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    window=None,
    backend: str | None = None,
) -> jnp.ndarray:
    """Decode attention through a paged KV pool (serving hot path).

    ``q [B, Hkv, G, dh]``; ``k_pool/v_pool [NB, BS, Hkv, dh]`` — one
    layer's pool; ``block_tables [B, MB]``; ``lengths [B]`` logical kv
    lengths. ``window`` may be a python int or traced scalar (per-layer
    sliding windows ride the decode scan). Returns ``[B, Hkv, G, dh]``.
    """
    backend = backend or default_backend()
    if backend == "ref":
        return ref.paged_attention_ref(
            q, k_pool, v_pool, block_tables, lengths, window=window
        )
    mb, bs = block_tables.shape[1], k_pool.shape[1]
    win = jnp.full((1,), mb * bs + 1, jnp.int32) if window is None else (
        jnp.asarray(window, jnp.int32).reshape(1)
    )
    return paged_attention_pallas(
        q, k_pool, v_pool, block_tables, lengths, win,
        interpret=(backend == "interpret"),
    )


def _next_mult(x: int, base: int) -> int:
    return ((x + base - 1) // base) * base
