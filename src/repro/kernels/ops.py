"""Public jit'd wrappers around the Pallas kernels.

Handle platform selection (TPU → compiled kernel, CPU → interpret or
reference), padding to block multiples, and the `PackedTensor` container
from :mod:`repro.core.packing`. Models call these; they never touch
`pallas_call` directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.packing import PackedTensor
from . import ref
from .binary_matmul import binary_matmul_pallas
from .moe_gmm import (
    moe_gmm_pallas,
    moe_gmm_swiglu_pallas,
    pad_groups,
    sort_by_expert,
)
from .paged_attention import (
    paged_attention_pallas,
    paged_attention_quant_pallas,
)
from .quant_matmul import quant_matmul_pallas

__all__ = [
    "quant_matmul",
    "quant_matmul_parts",
    "binary_matmul",
    "moe_gmm",
    "moe_gmm_swiglu",
    "paged_attention",
    "pad_groups",
    "sort_by_expert",
    "default_backend",
]


def default_backend() -> str:
    """'pallas' on TPU, 'ref' elsewhere (tests opt into 'interpret')."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def quant_matmul(
    x: jnp.ndarray,
    pt: PackedTensor,
    *,
    backend: str | None = None,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    """``y = x @ dequant(pt)`` for any leading x shape; K = pt.shape[0]."""
    backend = backend or default_backend()
    k, n = pt.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    if backend == "ref":
        y = ref.quant_matmul_ref(
            x2, pt.data, pt.scale, pt.zero, bits=pt.bits, group=pt.group
        )
        return y.reshape(*lead, n)
    m = x2.shape[0]
    bm_ = min(bm, _next_mult(m, 8))
    x2p = _pad_to(x2, bm_, 0)
    y = quant_matmul_pallas(
        x2p,
        pt.data,
        pt.scale,
        pt.zero,
        bits=pt.bits,
        group=pt.group,
        bm=bm_,
        bn=bn,
        bk=bk,
        interpret=(backend == "interpret"),
    )
    return y[:m].reshape(*lead, n)


def quant_matmul_parts(
    x: jnp.ndarray,
    w_packed,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    *,
    bits: int,
    group: int = 128,
    backend: str | None = None,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    """``y = x @ dequant(w)`` from raw packed parts (no PackedTensor).

    The backend-selection twin of :func:`quant_matmul` for call sites
    that hold per-expert stacked/sliced arrays rather than a
    :class:`PackedTensor` — the EP shard bodies and the legacy scan path
    route through here so TPU shards get the Pallas kernel and CPU tests
    keep the jnp oracle. ``w_packed`` is ``[K/per, N]`` uint8 (or the
    ``(hi, lo)`` plane pair for 3-bit).
    """
    backend = backend or default_backend()
    k = x.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    if backend == "ref":
        y = ref.quant_matmul_ref(
            x2, w_packed, scale, zero, bits=bits, group=group
        )
        return y.reshape(*lead, y.shape[-1])
    m = x2.shape[0]
    n = (w_packed[0] if bits == 3 else w_packed).shape[-1]
    bm_ = min(bm, _next_mult(m, 8))
    bn_, bk_ = _gmm_blocks(n, k, group, bn, bk)
    x2p = _pad_to(x2, bm_, 0)
    y = quant_matmul_pallas(
        x2p, w_packed, scale, zero,
        bits=bits, group=group, bm=bm_, bn=bn_, bk=bk_,
        interpret=(backend == "interpret"),
    )
    return y[:m].reshape(*lead, n)


def binary_matmul(
    x: jnp.ndarray,
    b_packed: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    backend: str | None = None,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    backend = backend or default_backend()
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if backend == "ref":
        return ref.binary_matmul_ref(x2, b_packed, alpha).reshape(
            *lead, b_packed.shape[1]
        )
    m = x2.shape[0]
    bm_ = min(bm, _next_mult(m, 8))
    x2p = _pad_to(x2, bm_, 0)
    y = binary_matmul_pallas(
        x2p, b_packed, alpha, bm=bm_, bn=bn, bk=bk,
        interpret=(backend == "interpret"),
    )
    return y[:m].reshape(*lead, b_packed.shape[1])


def _gmm_blocks(n: int, k: int, group: int, bn: int, bk: int):
    """Clamp default bn/bk to shapes the Pallas kernel's asserts accept."""
    bn_ = bn if n % min(bn, n) == 0 else n
    bk_ = bk if (k % min(bk, k) == 0 and min(bk, k) % group == 0) else k
    return bn_, bk_


def moe_gmm(
    x_padded: jnp.ndarray,
    w_packed,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    block_expert: jnp.ndarray,
    num_active: jnp.ndarray | None = None,
    *,
    bits: int,
    group: int = 128,
    backend: str | None = None,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    """Grouped expert GEMM; ``num_active`` enables the ragged skip of
    row-blocks past the routed-token frontier (see moe_gmm.py)."""
    backend = backend or default_backend()
    if backend == "ref":
        return ref.moe_gmm_ref(
            x_padded, w_packed, scale, zero, block_expert, num_active,
            bits=bits, group=group, bm=bm,
        )
    n = (w_packed[0] if bits == 3 else w_packed).shape[-1]
    bn, bk = _gmm_blocks(n, x_padded.shape[-1], group, bn, bk)
    return moe_gmm_pallas(
        x_padded, w_packed, scale, zero, block_expert, num_active,
        bits=bits, group=group, bm=bm, bn=bn, bk=bk,
        interpret=(backend == "interpret"),
    )


def moe_gmm_swiglu(
    x_padded: jnp.ndarray,
    wg_packed,
    wu_packed,
    g_scale: jnp.ndarray,
    g_zero: jnp.ndarray,
    u_scale: jnp.ndarray,
    u_zero: jnp.ndarray,
    block_expert: jnp.ndarray,
    num_active: jnp.ndarray | None = None,
    *,
    bits: int,
    group: int = 128,
    backend: str | None = None,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
) -> jnp.ndarray:
    """Fused gate/up grouped GEMM + SwiGLU epilogue (one x stream, the
    [M, F] hidden never round-trips HBM between the two projections)."""
    backend = backend or default_backend()
    if backend == "ref":
        return ref.moe_gmm_swiglu_ref(
            x_padded, wg_packed, wu_packed, g_scale, g_zero,
            u_scale, u_zero, block_expert, num_active,
            bits=bits, group=group, bm=bm,
        )
    n = (wg_packed[0] if bits == 3 else wg_packed).shape[-1]
    bn, bk = _gmm_blocks(n, x_padded.shape[-1], group, bn, bk)
    return moe_gmm_swiglu_pallas(
        x_padded, wg_packed, wu_packed, g_scale, g_zero, u_scale, u_zero,
        block_expert, num_active,
        bits=bits, group=group, bm=bm, bn=bn, bk=bk,
        interpret=(backend == "interpret"),
    )


def paged_attention(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    window=None,
    backend: str | None = None,
    quant=None,
) -> jnp.ndarray:
    """Decode attention through a paged KV pool (serving hot path).

    ``q [B, Hkv, G, dh]``; ``k_pool/v_pool [NB, BS, Hkv, dh]`` — one
    layer's pool; ``block_tables [B, MB]``; ``lengths [B]`` logical kv
    lengths. ``window`` may be a python int or traced scalar (per-layer
    sliding windows ride the decode scan). Returns ``[B, Hkv, G, dh]``.

    ``quant = (k_scale, k_zero, v_scale, v_zero)`` (each ``[NB, BS,
    Hkv]`` f32) reads the pools as int8 codes with a per-row affine
    dequant epilogue on the gathered pages (ref oracle:
    :func:`repro.kernels.ref.paged_attention_ref` quant mode; TPU:
    :func:`repro.kernels.paged_attention.paged_attention_quant_pallas`).
    ``quant=None`` is the unchanged fp path.
    """
    backend = backend or default_backend()
    if backend == "ref":
        return ref.paged_attention_ref(
            q, k_pool, v_pool, block_tables, lengths, window=window,
            quant=quant,
        )
    mb, bs = block_tables.shape[1], k_pool.shape[1]
    win = jnp.full((1,), mb * bs + 1, jnp.int32) if window is None else (
        jnp.asarray(window, jnp.int32).reshape(1)
    )
    if quant is not None:
        ks, kz, vs, vz = quant
        return paged_attention_quant_pallas(
            q, k_pool, v_pool, ks, kz, vs, vz, block_tables, lengths, win,
            interpret=(backend == "interpret"),
        )
    return paged_attention_pallas(
        q, k_pool, v_pool, block_tables, lengths, win,
        interpret=(backend == "interpret"),
    )


def _next_mult(x: int, base: int) -> int:
    return ((x + base - 1) // base) * base
