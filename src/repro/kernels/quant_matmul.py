"""Fused dequantize-matmul Pallas TPU kernel (DESIGN.md §5.2/§6).

Computes ``y = x @ dequant(W_packed)`` without ever materializing the
dequantized weights in HBM: packed uint8 tiles stream HBM→VMEM, the VPU
unpacks + applies group-wise ``(q - zero) * scale``, and the MXU consumes
the bf16/f32 tile directly. This is the TPU-native replacement for the
paper's HQQ ATEN dequant kernels — the ultra-low-bit serving path is
memory-bound, so weight bytes are the roofline term this kernel attacks
(2-bit: 8× less HBM traffic than bf16).

Layouts
-------
* ``x``: [M, K] (bf16/f32)
* ``w_packed``: [K/per, N] uint8 (pow-2 widths) or the (hi, lo) plane pair
  for 3-bit (K/4 + K/8 rows — exactly 3.0 bits/weight)
* ``scale``/``zero``: [K/group, N] f32, quantization groups along K
* grid (M/bm, N/bn, K/bk) with K innermost ("arbitrary" semantics), f32
  VMEM scratch accumulator, ``bk`` a multiple of ``group``.

MXU alignment: bm/bn multiples of 128; bk multiple of max(group, 128).
Defaults (bm=256, bn=256, bk=512) keep the VMEM working set ≈
256·512·4 + 512·256/4 + 2·256·512·4 + 256·256·4 ≈ 1.6 MiB « 16 MiB.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

__all__ = ["quant_matmul_pallas"]


def _unpack_tile(w_ref, bits: int, bk: int, bn: int) -> jnp.ndarray:
    """uint8 packed tile -> [bk, bn] uint8 codes (VPU shifts, no HBM)."""
    if bits == 3:
        hi = _unpack_pow2_tile(w_ref[0][...], 2, bk, bn)
        lo = _unpack_pow2_tile(w_ref[1][...], 1, bk, bn)
        return (hi << 1) | lo
    return _unpack_pow2_tile(w_ref[...], bits, bk, bn)


def _unpack_pow2_tile(packed: jnp.ndarray, bits: int, bk: int, bn: int):
    per = 8 // bits
    # [bk/per, bn] -> [bk/per, per, bn] -> [bk, bn]
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits)[None, :, None]
    vals = (packed[:, None, :] >> shifts) & ((1 << bits) - 1)
    return vals.reshape(bk, bn)


def _dequant(codes: jnp.ndarray, scale, zero, group: int, compute_dtype):
    bk, bn = codes.shape
    ng = bk // group
    c = codes.astype(jnp.float32).reshape(ng, group, bn)
    w = (c - zero[:, None, :]) * scale[:, None, :]
    return w.reshape(bk, bn).astype(compute_dtype)


def _kernel(
    x_ref,
    *rest,
    bits: int,
    group: int,
    nk: int,
    compute_dtype,
):
    if bits == 3:
        hi_ref, lo_ref, s_ref, z_ref, o_ref, acc_ref = rest
        w_ref = (hi_ref, lo_ref)
    else:
        w_ref, s_ref, z_ref, o_ref, acc_ref = rest

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bk = x_ref.shape[1]
    bn = o_ref.shape[1]
    codes = _unpack_tile(w_ref, bits, bk, bn)
    w = _dequant(codes, s_ref[...], z_ref[...], group, compute_dtype)
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(compute_dtype), w, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group", "bm", "bn", "bk", "out_dtype", "interpret"),
)
def quant_matmul_pallas(
    x: jnp.ndarray,
    w_packed,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    *,
    bits: int,
    group: int = 128,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """``y[M,N] = x[M,K] @ dequant(w_packed)``. See module docstring.

    The wrapper in :mod:`repro.kernels.ops` handles padding / transposes /
    platform fallback; this function requires M % bm == N % bn == K % bk ==
    0 and bk % group == 0.
    """
    m, k = x.shape
    if bits == 3:
        hi, lo = w_packed
        n = hi.shape[1]
    else:
        n = w_packed.shape[1]
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % group == 0, (bk, group)
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    s_spec = pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    if bits == 3:
        w_specs = [
            pl.BlockSpec((bk // 4, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // 8, bn), lambda i, j, kk: (kk, j)),
        ]
        args = (x, hi, lo, scale, zero)
    else:
        per = 8 // bits
        w_specs = [pl.BlockSpec((bk // per, bn), lambda i, j, kk: (kk, j))]
        args = (x, w_packed, scale, zero)

    compute_dtype = jnp.float32 if x.dtype == jnp.float32 else jnp.bfloat16
    kernel = functools.partial(
        _kernel, bits=bits, group=group, nk=nk, compute_dtype=compute_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, *w_specs, s_spec, s_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*args)
