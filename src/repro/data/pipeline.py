"""Deterministic synthetic data pipeline (offline container — no corpora).

A seeded Zipfian n-gram generator with enough structure for a ~100M LM to
make real progress (next-token entropy well below uniform): a fixed random
bigram transition table + topic drift. Deterministic per (seed, step,
host) so multi-host shards never overlap and restarts resume exactly
(fault-tolerance requirement).

``HostDataLoader`` yields per-host batch shards; with ``jax.make_array``
-style global batches assembled by the train launcher.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["SyntheticLM", "HostDataLoader", "make_calibration_tokens"]


@dataclasses.dataclass
class SyntheticLM:
    """Markov-with-topics corpus over ``vocab`` symbols."""

    vocab: int
    seed: int = 0
    branching: int = 24  # candidate next-tokens per state
    topics: int = 16

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab
        self.next_tokens = rng.integers(0, v, size=(v, self.branching))
        # Zipf over the branch choices, tilted per topic
        base = 1.0 / np.arange(1, self.branching + 1)
        tilt = rng.dirichlet(np.ones(self.branching) * 2.0, size=self.topics)
        probs = base[None] * (0.5 + tilt)
        self.branch_probs = probs / probs.sum(axis=1, keepdims=True)

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq + 1), np.int64)
        state = rng.integers(0, self.vocab, size=batch)
        topic = rng.integers(0, self.topics, size=batch)
        out[:, 0] = state
        for t in range(1, seq + 1):
            drift = rng.random(batch) < 0.01
            topic = np.where(drift, rng.integers(0, self.topics, batch), topic)
            p = self.branch_probs[topic]  # [B, branching]
            cum = np.cumsum(p, axis=1)
            u = rng.random((batch, 1))
            choice = (u > cum).sum(axis=1)
            state = self.next_tokens[state, choice]
            out[:, t] = state
        return out


@dataclasses.dataclass
class HostDataLoader:
    """Per-host deterministic shard of the global batch."""

    vocab: int
    global_batch: int
    seq_len: int
    host_id: int = 0
    num_hosts: int = 1
    seed: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        self.local_batch = self.global_batch // self.num_hosts
        self.corpus = SyntheticLM(self.vocab, seed=self.seed)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for a given step (restart-safe: pure function of step)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id
        )
        toks = self.corpus.sample(rng, self.local_batch, self.seq_len)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_calibration_tokens(
    vocab: int, n: int, seq: int, seed: int = 1234, corpus_seed: int = 0
) -> np.ndarray:
    """Calibration samples for PMQ/OTP (paper: 128×2048 C4 / 4096 samples).

    ``corpus_seed`` fixes the *language* (transition tables) — it must
    match the training corpus; ``seed`` only varies the sampling, so
    held-out eval measures the same distribution the model learned.
    """
    corpus = SyntheticLM(vocab, seed=corpus_seed)
    rng = np.random.default_rng(seed)
    return corpus.sample(rng, n, seq)[:, :-1].astype(np.int32)
