"""Three-tier expert backing store: mmap'd disk → host cache → device.

The PR-3 offload manager kept every packed PMQ bucket leaf as a full
numpy copy in host memory — a *two*-tier ladder (host → device) whose
host rung costs as much RAM as the model's expert bytes. This module
adds the bottom rung: the pristine packed buckets are spilled once to
``offload_dir`` as per-leaf ``.npy`` files and reopened **memory-mapped**
(``np.load(mmap_mode="r")``), so the OS page cache — not the process —
owns cold expert bytes, and a byte-budgeted host cache of hot rows sits
between the disk images and the device partitions:

* **Disk** (coldest): mmap'd ``[L, count, ...]`` leaves, read-only and
  pristine at each bucket's *target* PMQ bit-width. Every row's CRC32
  is recorded in a JSON manifest at spill time; every disk fetch is
  verified against it (torn writes / bit rot fail closed with
  :class:`~repro.serving.faults.ExpertUploadFailed` — silent corruption
  can never reach the device).
* **Host** (warm): an EMA-heat-aware row cache bounded by
  ``host_budget_bytes``. Placement is **bit-width-aware** through byte
  cost: at equal routing heat the cache evicts the row that frees the
  most bytes first, so wide-bit (hot-assigned) rows must *earn* their
  host residency with routing traffic while 1-bit rows are nearly free
  to keep — the hierarchical-placement idea of "Collaborative
  Compression for Large-Scale MoE Deployment on Edge" (PAPERS.md)
  composed with MC#'s mixed-precision buckets. Rows are promoted on
  fetch (a disk read installs the row at its current heat) and demoted
  purely by eviction; the EMA heat comes from the offload manager's
  routing statistics, so the ladder warms exactly as the router does.
* **Device** (hottest): the budget-shaped resident partitions owned by
  :class:`~repro.serving.offload.ExpertOffloadManager` — unchanged.

Because the disk tier always serves the pristine target-bit payload,
tiering is invisible to the bit-exactness contract: a row fetched
through any rung is bitwise-identical to the PR-3 host copy, and the
miss-replay / CRC / degrade ladder above this store behaves as before.

Fetch accounting (host hits, disk hits, disk bytes) flows through the
tracer's lifecycle stream (``tier_fetch`` events), so the counters are
deterministic per trace and replay-identical — the same contract every
other :meth:`ServingMetrics.counters` field obeys.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from .faults import ExpertUploadFailed, checksum_tree

__all__ = ["TieredExpertStore"]

_MANIFEST = "manifest.json"


def _leaf_name(bk: str, path: Tuple[str, ...]) -> str:
    return bk + "__" + "__".join(path) + ".npy"


def _tree_paths(tree: Dict, prefix: Tuple[str, ...] = ()) -> List[Tuple[Tuple[str, ...], np.ndarray]]:
    """Deterministic (path, leaf) pairs of a nested-dict tree — sorted
    key order, matching jax's dict traversal."""
    out = []
    for k in sorted(tree):
        v = tree[k]
        if isinstance(v, dict):
            out.extend(_tree_paths(v, prefix + (k,)))
        else:
            out.append((prefix + (k,), np.asarray(v)))
    return out


def _set_path(tree: Dict, path: Tuple[str, ...], leaf) -> None:
    for k in path[:-1]:
        tree = tree.setdefault(k, {})
    tree[path[-1]] = leaf


class TieredExpertStore:
    """Disk-backed expert row store with a byte-budgeted host cache.

    ``host`` maps bucket key → nested dict of ``[L, count, ...]`` numpy
    leaves (the offload manager's backing store). The constructor spills
    every leaf to ``offload_dir``, records the per-row CRC manifest, and
    reopens the files memory-mapped; callers should then drop their
    reference to ``host`` — the process no longer needs those bytes.
    ``host_budget_bytes=None`` means an unbounded host cache (two-tier
    behavior with a disk floor); ``0`` disables host caching entirely
    (every fetch reads and verifies the mmap).
    """

    def __init__(self, host: Dict[str, Dict], *, offload_dir: str,
                 host_budget_bytes: Optional[int] = None, tracer=None):
        if tracer is None:
            from .trace import NULL_TRACER

            tracer = NULL_TRACER
        self.tracer = tracer
        self.dir = str(offload_dir)
        self.host_budget_bytes = (
            None if host_budget_bytes is None else int(host_budget_bytes)
        )
        os.makedirs(self.dir, exist_ok=True)
        manifest = {"buckets": {}, "crc": {}}
        self.disk: Dict[str, Dict] = {}
        for bk in sorted(host):
            files = []
            for path, leaf in _tree_paths(host[bk]):
                fname = _leaf_name(bk, path)
                np.save(os.path.join(self.dir, fname), leaf)
                files.append({"path": list(path), "file": fname})
            manifest["buckets"][bk] = files
            # per-row CRCs from the pristine in-memory tree, *before* the
            # mmap reopen — a spill that tore is caught on first fetch
            L = int(jax.tree.leaves(host[bk])[0].shape[0])
            count = int(jax.tree.leaves(host[bk])[0].shape[1])
            for layer in range(L):
                for slot in range(count):
                    row = jax.tree.map(lambda a: a[layer, slot], host[bk])
                    manifest["crc"][f"{bk}/{layer}/{slot}"] = checksum_tree(row)
        with open(os.path.join(self.dir, _MANIFEST), "w") as f:
            json.dump(manifest, f, sort_keys=True)
        self._crc = {
            tuple(k.split("/")): v for k, v in manifest["crc"].items()
        }
        self._open_disk(manifest)
        # host cache: key -> (row tree, nbytes, heat)
        self._cache: Dict[Tuple[str, int, int], Tuple[Dict, int, float]] = {}
        self._cache_bytes = 0

    @classmethod
    def reopen(cls, offload_dir: str, tracer=None) -> "TieredExpertStore":
        """Reattach to an existing spill directory (no re-write): mmap
        every leaf listed in the manifest and start with a cold host
        cache. The CRC manifest travels with the directory, so a
        reopened store verifies rows against the *original* spill."""
        self = cls.__new__(cls)
        if tracer is None:
            from .trace import NULL_TRACER

            tracer = NULL_TRACER
        self.tracer = tracer
        self.dir = str(offload_dir)
        self.host_budget_bytes = None
        with open(os.path.join(self.dir, _MANIFEST)) as f:
            manifest = json.load(f)
        self._crc = {
            tuple(k.split("/")): v for k, v in manifest["crc"].items()
        }
        self._open_disk(manifest)
        self._cache = {}
        self._cache_bytes = 0
        return self

    def _open_disk(self, manifest: Dict) -> None:
        self.disk = {}
        for bk, files in manifest["buckets"].items():
            tree: Dict = {}
            for ent in files:
                leaf = np.load(
                    os.path.join(self.dir, ent["file"]), mmap_mode="r"
                )
                _set_path(tree, tuple(ent["path"]), leaf)
            self.disk[bk] = tree

    # ------------------------------------------------------------- sizing
    @property
    def disk_bytes(self) -> int:
        return sum(
            a.size * a.dtype.itemsize
            for bk in self.disk for a in jax.tree.leaves(self.disk[bk])
        )

    @property
    def host_cached_bytes(self) -> int:
        return self._cache_bytes

    # -------------------------------------------------------------- fetch
    def crc(self, bk: str, layer: int, slot: int) -> int:
        return self._crc[(bk, str(int(layer)), str(int(slot)))]

    def row(self, bk: str, layer: int, slot: int, heat: float = 0.0) -> Dict:
        """One ``(layer, slot)`` row tree of bucket ``bk``, served from
        the warmest tier that holds it. A host-cache hit refreshes the
        row's recorded heat; a disk fetch CRC-verifies the mmap'd bytes
        against the spill manifest (fail closed on mismatch) and
        promotes the row into the host cache at ``heat``."""
        key = (bk, int(layer), int(slot))
        hit = self._cache.get(key)
        if hit is not None:
            row, nbytes, _ = hit
            self._cache[key] = (row, nbytes, float(heat))
            self.tracer.lifecycle(
                "tier_fetch", track="experts", tier="host", nbytes=0,
            )
            return row
        # disk tier: materialize the row (np.array copies out of the
        # mmap — the device upload needs contiguous host bytes anyway)
        row = jax.tree.map(
            lambda a: np.array(a[int(layer), int(slot)]), self.disk[bk]
        )
        if checksum_tree(row) != self.crc(bk, layer, slot):
            raise ExpertUploadFailed(
                f"disk-tier row ({bk}, layer {layer}, slot {slot}) failed "
                f"CRC against the spill manifest — refusing to serve "
                f"corrupt expert bytes"
            )
        nbytes = sum(a.nbytes for a in jax.tree.leaves(row))
        self.tracer.lifecycle(
            "tier_fetch", track="experts", tier="disk", nbytes=int(nbytes),
        )
        self._promote(key, row, nbytes, float(heat))
        return row

    def _promote(self, key, row: Dict, nbytes: int, heat: float) -> None:
        budget = self.host_budget_bytes
        if budget is not None and nbytes > budget:
            return  # row alone exceeds the cache — serve disk-direct
        self._cache[key] = (row, int(nbytes), heat)
        self._cache_bytes += int(nbytes)
        if budget is None:
            return
        while self._cache_bytes > budget and len(self._cache) > 1:
            # bit-width-aware eviction: coldest heat first, widest
            # (most bytes) first on ties — wide rows must earn their
            # host residency, narrow rows are cheap to keep
            victim = min(
                (k for k in self._cache if k != key),
                key=lambda k: (self._cache[k][2], -self._cache[k][1], k),
            )
            self._cache_bytes -= self._cache[victim][1]
            del self._cache[victim]
