"""Host-offloaded PMQ expert buckets with router-stats prefetch.

MC#'s PMQ buckets (§3.2) shrink expert *storage*; this module shrinks
expert *device residency*: a device that holds only the hot slice of
each bit-bucket (plus the paged KV pool) can serve models whose full
expert set never fits. The pattern mirrors the serving swap store
(:class:`repro.serving.kvcache.SwappedKV`): cold rows live in a
host-memory backing store and move across the host↔device boundary in
whole quantized-expert rows (packed codes + scales/zeros — a fraction
of the bf16 bytes, which is exactly why PMQ makes offload cheap).

Residency is managed per ``(layer, bucket, expert slot)``:

* **Device**: per bucket, a ``[L, R_i, ...]`` resident buffer for each
  packed leaf plus a ``[L, count_i]`` int32 map from bucket slot to
  resident row. Both have *budget-determined* shapes, so changing which
  experts are resident never changes the pytree — the jitted serving
  programs compile once per budget, not per residency state.
* **Host**: full numpy copies of every bucket leaf (``[L, count_i, ...]``).
* **Prefetch**: an EMA over the per-(layer, slot) dispatch counts that
  every decode/prefill program reports (EAC-MoE-style expert-selection
  awareness, PAPERS.md) picks the top-``R_i`` slots per bucket; uploads
  happen between engine steps, alongside KV page growth.
* **Miss**: routing happens *inside* the jitted program, so the true
  working set is only known after the program ran. The engine replays
  the program after a synchronous upload of the missing experts
  (:meth:`ensure_resident`); KV writes land at position-determined
  destinations and the fused decode horizon's token sequence is
  deterministic per megastep, so a replay simply overwrites them with
  the correct values — residency is invisible to correctness for any
  budget that holds the per-program working set. Only usage up to the
  first missed row of the reported counts — layer-major within a step,
  step-major across a fused horizon — is trusted (later rows routed on
  garbage activations); authentic slots are **pinned** until the
  program is accepted, each replay extends the correct prefix, and the
  loop accepts within ``rows`` (``num_layers``, or ``H·num_layers``
  for a decode megastep) replays.
* **Overflow**: if a single step's working set exceeds a bucket's
  budget, the manager grows that bucket's resident buffer to fit (a
  one-time retrace) rather than serving wrong tokens — ``grows`` counts
  how often the configured budget was too small to be honored.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compressed_moe import CompressedExperts

__all__ = ["ExpertOffloadManager"]


class ExpertOffloadManager:
    """Residency manager for one model's layer-stacked PMQ buckets.

    ``ce`` must be the serving layout: every bucket leaf stacked to
    ``[L, count, ...]`` (see ``repro.models.transformer.restack_blocks``).
    ``resident_slots`` is the per-layer device budget in expert slots,
    split across buckets proportionally to their padded counts (every
    bucket keeps ≥ 1 resident row). The manager owns :attr:`ce` — a new
    :class:`CompressedExperts` whose arrays are the resident partitions;
    callers splice it into their parameter tree and never touch the
    original full-resident arrays again.
    """

    def __init__(self, ce: CompressedExperts, *, resident_slots: int,
                 ema_decay: float = 0.8, tracer=None):
        if ce.resident_map is not None:
            raise ValueError("CompressedExperts is already host-offloaded")
        if tracer is None:
            from .trace import NULL_TRACER

            tracer = NULL_TRACER
        self.tracer = tracer
        self.meta = ce.meta
        self.num_slots = ce.num_slots
        self.ema_decay = float(ema_decay)
        self._bkeys = [f"b{i}" for i in range(len(ce.meta))]
        # full host backing store (numpy copies of every packed leaf)
        self.host: Dict[str, Dict] = {
            bk: jax.tree.map(np.asarray, ce.arrays[bk]) for bk in self._bkeys
        }
        first = jax.tree.leaves(self.host[self._bkeys[0]])[0]
        if first.ndim < 3 or first.shape[1] != ce.meta[0].count:
            raise ValueError(
                "expert offload expects layer-stacked buckets "
                f"[L, count, ...]; got leaf shape {first.shape} for "
                f"bucket count {ce.meta[0].count}"
            )
        self.num_layers = int(first.shape[0])
        self._budgets = self._split_budget(int(resident_slots))
        # residency tables (host side): slot -> row (-1 absent), row -> slot
        self.slot_row: Dict[str, np.ndarray] = {}
        self.row_slot: Dict[str, np.ndarray] = {}
        self.ema = np.zeros((self.num_layers, self.num_slots), np.float64)
        # upload counts/bytes are returned to the caller per call and
        # aggregated by ServingMetrics — the manager only tracks what the
        # metrics cannot derive: budget growths (deterministic per trace)
        self.grows = 0
        self._pinned: List[Dict[str, set]] = []
        self.begin_step()

        dev_arrays: Dict[str, Dict] = {}
        maps: Dict[str, jnp.ndarray] = {}
        for i, bk in enumerate(self._bkeys):
            r, cnt = self._budgets[i], self.meta[i].count
            # seed residency with the first r slots of each bucket — the
            # EMA prefetcher re-ranks them after the first real traffic
            sr = np.full((self.num_layers, cnt), -1, np.int32)
            sr[:, :r] = np.arange(r, dtype=np.int32)[None, :]
            self.slot_row[bk] = sr
            rs = np.full((self.num_layers, r), -1, np.int32)
            rs[:, :] = np.arange(r, dtype=np.int32)[None, :]
            self.row_slot[bk] = rs
            dev_arrays[bk] = jax.tree.map(
                lambda a: jnp.asarray(a[:, :r]), self.host[bk]
            )
            maps[bk] = jnp.asarray(np.maximum(sr, 0))
        self.ce = dataclasses.replace(
            ce, arrays=dev_arrays, resident_map=maps,
            resident_rows=tuple(self._budgets),
        )

    # ---------------------------------------------------------- budgeting
    def _split_budget(self, resident_slots: int) -> List[int]:
        counts = [m.count for m in self.meta]
        nb = len(counts)
        total = min(self.num_slots, max(nb, resident_slots))
        if total != resident_slots:
            warnings.warn(
                f"resident_slots={resident_slots} clamped to {total} "
                f"(floor: one row per bucket = {nb}; ceiling: "
                f"num_slots = {self.num_slots})",
                RuntimeWarning, stacklevel=3,
            )
        r = [
            max(1, min(c, int(round(resident_slots * c / self.num_slots))))
            for c in counts
        ]
        while sum(r) > total:
            i = max(range(nb), key=lambda j: r[j])
            if r[i] <= 1:
                break
            r[i] -= 1
        while sum(r) < total:
            cands = [j for j in range(nb) if r[j] < counts[j]]
            if not cands:
                break
            i = max(cands, key=lambda j: counts[j] - r[j])
            r[i] += 1
        return r

    @property
    def budgets(self) -> Tuple[int, ...]:
        return tuple(self._budgets)

    @property
    def resident_bytes(self) -> int:
        tot = 0
        for bk in self._bkeys:
            for a in jax.tree.leaves(self.ce.arrays[bk]):
                tot += a.size * a.dtype.itemsize
        return tot

    @property
    def host_bytes(self) -> int:
        return sum(
            a.nbytes for bk in self._bkeys
            for a in jax.tree.leaves(self.host[bk])
        )

    def resident_slots_of(self, layer: int) -> Dict[str, set]:
        """Bucket-local resident slot sets of one layer (for tests)."""
        return {
            bk: {int(s) for s in np.nonzero(self.slot_row[bk][layer] >= 0)[0]}
            for bk in self._bkeys
        }

    # ----------------------------------------------------------- plumbing
    def _upload_batch(self, bk: str, triples) -> int:
        """Host→device copy of ``(layer, row, slot)`` placements — one
        batched scatter per packed leaf per bucket, regardless of how
        many layers the placements span (a per-layer ``.set`` would
        rebuild the whole [L, R, ...] buffer once per layer)."""
        if not triples:
            return 0
        l_idx = np.asarray([t[0] for t in triples], np.int32)
        r_idx = np.asarray([t[1] for t in triples], np.int32)
        s_idx = np.asarray([t[2] for t in triples], np.int32)
        nbytes = 0

        def up(dev, host):
            nonlocal nbytes
            src = host[l_idx, s_idx]  # [n, ...]
            nbytes += src.nbytes
            return dev.at[l_idx, r_idx].set(jnp.asarray(src))

        self.ce.arrays[bk] = jax.tree.map(up, self.ce.arrays[bk], self.host[bk])
        return nbytes

    def _refresh_map(self, bk: str) -> None:
        self.ce.resident_map[bk] = jnp.asarray(
            np.maximum(self.slot_row[bk], 0).astype(np.int32)
        )

    def _grow(self, i: int, need: int) -> None:
        """Enlarge bucket i's resident buffer to ``need`` rows (all
        layers). Changes leaf shapes — the jitted programs re-specialize
        once — and is only taken when a step's working set cannot fit the
        configured budget (correctness beats the budget)."""
        bk = self._bkeys[i]
        old = self._budgets[i]
        new_r = min(self.meta[i].count, int(need))
        if new_r <= old:
            return
        pad = new_r - old
        self.row_slot[bk] = np.concatenate(
            [self.row_slot[bk],
             np.full((self.num_layers, pad), -1, np.int32)], axis=1,
        )
        self.ce.arrays[bk] = jax.tree.map(
            lambda a: jnp.concatenate(
                [a, jnp.zeros((a.shape[0], pad) + a.shape[2:], a.dtype)],
                axis=1,
            ),
            self.ce.arrays[bk],
        )
        self._budgets[i] = new_r
        self.ce.resident_rows = tuple(self._budgets)
        self.grows += 1
        self.tracer.instant(
            "expert_budget_grow", track="experts", cat="offload",
            bucket=i, rows_before=old, rows_after=new_r,
        )

    def _place(self, i: int, layer: int, want, protected, score_fn):
        """Install bucket-local slots ``want`` into bucket ``i``'s rows of
        one layer, filling free rows first and then evicting the
        lowest-``score_fn`` rows whose slot is not in ``protected``.
        Updates the host-side tables and returns the ``(layer, row,
        slot)`` placements; the caller batch-uploads them
        (:meth:`_upload_batch`) and refreshes the device map.
        """
        bk = self._bkeys[i]
        sr = self.slot_row[bk]
        rows = self.row_slot[bk]
        r_i = self._budgets[i]
        free = [j for j in range(r_i) if rows[layer, j] < 0]
        evictable = sorted(
            (j for j in range(r_i)
             if rows[layer, j] >= 0 and int(rows[layer, j]) not in protected),
            key=lambda j: (score_fn(int(rows[layer, j])),
                           int(rows[layer, j])),
        )
        targets = (free + evictable)[: len(want)]
        placed = []
        for s, j in zip(want, targets):
            old = int(rows[layer, j])
            if old >= 0:
                sr[layer, old] = -1
            rows[layer, j] = s
            sr[layer, s] = j
            placed.append((layer, j, s))
        return placed

    # ------------------------------------------------------ step protocol
    def begin_step(self) -> None:
        """Reset the per-step pin sets. The engine calls this before each
        jitted-program replay loop; every slot reported used during the
        loop stays pinned (never evicted) until the loop accepts."""
        self._pinned = [
            {bk: set() for bk in self._bkeys} for _ in range(self.num_layers)
        ]

    def ensure_resident(self, counts: np.ndarray) -> Tuple[int, int]:
        """Make the last program run's *authentic* working set resident.

        ``counts`` is the run's ``slot_counts`` output with rows in
        **computation order**: ``[L, num_slots]`` for a single-step
        program, or ``[H·L, num_slots]`` (step-major: row ``k`` is layer
        ``k % L`` of horizon step ``k // L``) for a fused decode
        megastep — whose union over steps is the horizon working set.
        Returns ``(uploads, bytes)`` — ``uploads == 0`` means the run's
        whole working set was already resident (the run is *accepted*:
        its outputs are bit-identical to the all-resident engine).
        Otherwise the caller must replay the whole program after this
        synchronous upload (KV writes are position-addressed and the
        token sequence is deterministic per megastep key, so a megastep
        replay is idempotent).

        Usage is only trusted up to the **first row with a miss**: rows
        before it computed with correct expert rows, so their routing —
        and the missed row's own routing — is authentic; later rows
        (deeper layers, and with a horizon every subsequent fused step,
        whose input token depends on the full previous step) routed on
        garbage activations and are ignored until a replay reaches them
        with correct inputs. Every pinned slot is therefore part of the
        true working set — phantom usage can never inflate uploads or
        trigger a budget grow — and each replay extends the correct
        prefix by ≥ 1 row, so the loop accepts within ``rows`` (≤ H·L)
        replays. Evicts only unpinned rows, coldest EMA first.
        """
        rows = counts.reshape(-1, self.num_slots)
        # fast path (the common all-hit case): nothing dispatched-to is
        # non-resident, so the run is accepted without touching the pin
        # sets — pins only matter across replays, and slots pinned by an
        # earlier iteration are already resident (eviction protects them)
        resident = np.concatenate(
            [self.slot_row[bk] >= 0 for bk in self._bkeys], axis=1
        )
        layer_of = np.arange(rows.shape[0]) % self.num_layers
        if not np.any((rows > 0) & ~resident[layer_of]):
            return 0, 0
        t0 = self.tracer.now_us()
        ups = 0
        nbytes = 0
        pending = {bk: [] for bk in self._bkeys}
        for k in range(rows.shape[0]):
            l = int(layer_of[k])
            row_missed = False
            for i, bk in enumerate(self._bkeys):
                m = self.meta[i]
                used = np.nonzero(rows[k, m.start:m.start + m.count] > 0)[0]
                pin = self._pinned[l][bk]
                pin.update(int(u) for u in used)
                missing = [s for s in sorted(pin) if self.slot_row[bk][l, s] < 0]
                if not missing:
                    continue
                row_missed = True
                if len(pin) > self._budgets[i]:
                    self._grow(i, len(pin))
                # pin ≤ budget now, so every missing slot finds a row
                placed = self._place(
                    i, l, missing, pin,
                    lambda s, l=l, m=m: self.ema[l, m.start + s],
                )
                assert len(placed) == len(missing), "pin set exceeds budget"
                pending[bk].extend(placed)
                ups += len(placed)
            if row_missed:
                break  # later rows routed on garbage — replay first
        for bk in self._bkeys:  # one batched upload + map per bucket
            if pending[bk]:
                nbytes += self._upload_batch(bk, pending[bk])
                self._refresh_map(bk)
        if ups:
            self.tracer.complete(
                "expert_upload", track="experts", cat="offload", start_us=t0,
                args={"kind": "miss", "uploads": ups, "bytes": nbytes},
            )
        return ups, nbytes

    def update_stats(self, counts: np.ndarray) -> None:
        """Fold an accepted program's dispatch counts into the routing
        EMA. Accepts ``[L, num_slots]`` or a fused megastep's
        ``[H·L, num_slots]`` / ``[H, L, num_slots]`` — horizon steps are
        summed, so one EMA update per accepted megastep sees the whole
        horizon's traffic (a smoother, more predictive prefetch signal
        than per-token updates)."""
        counts = counts.reshape(-1, self.num_layers, self.num_slots).sum(0)
        d = self.ema_decay
        self.ema = d * self.ema + (1.0 - d) * counts.astype(np.float64)

    def residency_targets(self) -> Tuple[Tuple[int, int, Tuple[int, ...]], ...]:
        """Pure target-set computation: the declarative half of prefetch.

        Per (layer, bucket): the top-``R_i`` slots by EMA score are the
        *desired* resident set. Stable ranking (score desc, slot asc)
        keeps the selection deterministic and churn-free on ties.
        Returns one ``(bucket_idx, layer, desired_slots)`` triple for
        every (layer, bucket) whose desired set is not fully resident —
        an empty tuple means residency already matches the target.
        Reads routing EMA and residency maps; mutates **nothing** (the
        controller calls this at planning time; convergence happens in
        :meth:`apply_residency`).
        """
        targets = []
        for l in range(self.num_layers):
            for i, bk in enumerate(self._bkeys):
                m = self.meta[i]
                r_i = self._budgets[i]
                if r_i >= m.count:
                    continue
                scores = self.ema[l, m.start:m.start + m.count]
                desired = tuple(
                    int(s) for s in np.argsort(-scores, kind="stable")[:r_i]
                )
                if any(self.slot_row[bk][l, s] < 0 for s in desired):
                    targets.append((i, l, desired))
        return tuple(targets)

    def apply_residency(
        self, targets: Tuple[Tuple[int, int, Tuple[int, ...]], ...]
    ) -> Tuple[int, int]:
        """Converge residency toward :meth:`residency_targets` output:
        missing desired slots are uploaded over the coldest undesired
        residents (one batched upload + device-map refresh per bucket).
        Returns ``(uploads, bytes)``.
        """
        if not targets:
            return 0, 0
        t0 = self.tracer.now_us()
        ups = 0
        nbytes = 0
        pending = {bk: [] for bk in self._bkeys}
        for i, l, desired in targets:
            bk = self._bkeys[i]
            m = self.meta[i]
            scores = self.ema[l, m.start:m.start + m.count]
            want = sorted(
                s for s in desired if self.slot_row[bk][l, s] < 0
            )
            if not want:
                continue
            placed = self._place(i, l, want, set(desired),
                                 lambda s, scores=scores: scores[s])
            pending[bk].extend(placed)
            ups += len(placed)
        for bk in self._bkeys:  # one batched upload + map per bucket
            if pending[bk]:
                nbytes += self._upload_batch(bk, pending[bk])
                self._refresh_map(bk)
        if ups:
            self.tracer.complete(
                "expert_upload", track="experts", cat="offload", start_us=t0,
                args={"kind": "prefetch", "uploads": ups, "bytes": nbytes},
            )
        return ups, nbytes

    def prefetch(self) -> Tuple[int, int]:
        """Upload the EMA-hottest slots ahead of need (between steps):
        :meth:`residency_targets` (pure) followed by
        :meth:`apply_residency` (converge). Kept as the one-call form
        for direct drivers and tests; the engine goes through the
        resource controller, which folds the target set into its
        boundary plan as an ``upload_experts`` action.
        """
        return self.apply_residency(self.residency_targets())
