"""Host-offloaded PMQ expert buckets with router-stats prefetch.

MC#'s PMQ buckets (§3.2) shrink expert *storage*; this module shrinks
expert *device residency*: a device that holds only the hot slice of
each bit-bucket (plus the paged KV pool) can serve models whose full
expert set never fits. The pattern mirrors the serving swap store
(:class:`repro.serving.kvcache.SwappedKV`): cold rows live in a
host-memory backing store and move across the host↔device boundary in
whole quantized-expert rows (packed codes + scales/zeros — a fraction
of the bf16 bytes, which is exactly why PMQ makes offload cheap).

Residency is managed per ``(layer, bucket, expert slot)``:

* **Device**: per bucket, a ``[L, R_i, ...]`` resident buffer for each
  packed leaf plus a ``[L, count_i]`` int32 map from bucket slot to
  resident row. Both have *budget-determined* shapes, so changing which
  experts are resident never changes the pytree — the jitted serving
  programs compile once per budget, not per residency state.
* **Host**: full numpy copies of every bucket leaf (``[L, count_i, ...]``).
* **Prefetch**: an EMA over the per-(layer, slot) dispatch counts that
  every decode/prefill program reports (EAC-MoE-style expert-selection
  awareness, PAPERS.md) picks the top-``R_i`` slots per bucket; uploads
  happen between engine steps, alongside KV page growth.
* **Miss**: routing happens *inside* the jitted program, so the true
  working set is only known after the program ran. The engine replays
  the program after a synchronous upload of the missing experts
  (:meth:`ensure_resident`); KV writes land at position-determined
  destinations and the fused decode horizon's token sequence is
  deterministic per megastep, so a replay simply overwrites them with
  the correct values — residency is invisible to correctness for any
  budget that holds the per-program working set. Only usage up to the
  first missed row of the reported counts — layer-major within a step,
  step-major across a fused horizon — is trusted (later rows routed on
  garbage activations); authentic slots are **pinned** until the
  program is accepted, each replay extends the correct prefix, and the
  loop accepts within ``rows`` (``num_layers``, or ``H·num_layers``
  for a decode megastep) replays.
* **Overflow**: if a single step's working set exceeds a bucket's
  budget, the manager grows that bucket's resident buffer to fit (a
  one-time retrace) rather than serving wrong tokens — ``grows`` counts
  how often the configured budget was too small to be honored.
* **Async overlap** (:meth:`issue_async` / :meth:`commit_async`): with
  ``EngineConfig(async_offload=True)`` the controller's prefetch plan is
  *issued* right after the megastep's program dispatch — the post-upload
  device buffers are built against immutable jax arrays while the
  megastep computes on the live ones — and *committed* (buffers, tables
  and device maps flipped together) at the next megastep boundary.
  Content versions invalidate stale batches: any miss upload or budget
  grow between issue and commit bumps the touched bucket's version and
  the commit drops the batch instead of installing stale buffers.
  Placement is output-invariant and the miss backstop is untouched, so
  outputs stay bit-identical with overlap on or off.
* **Tiers** (:mod:`repro.serving.tierstore`): with ``offload_dir`` set
  the backing store generalizes to disk → host → device — packed
  buckets spilled once to mmap'd ``.npy`` images (CRC manifest, verified
  on every read) with a byte-budgeted EMA-heat host row cache between
  them, so host RAM no longer scales with total expert bytes.
* **Faults** (:mod:`repro.serving.faults`): with a :class:`FaultPlan`
  attached, every upload runs the recovery ladder of
  docs/serving_robustness.md — each staged payload is CRC-checked
  against the host row's checksum and re-fetched on mismatch; transient
  I/O failures retry (immediately and bounded on the miss path, with
  deterministic logical-step backoff on the prefetch path); a row whose
  target-bit upload persistently fails is **degraded**: its codes are
  snapped to the next lower rung of the PMQ precision ladder
  (:func:`degrade_expert_row` — same packed container, strictly fewer
  levels, scale/zero kept) and served from there permanently, emitting
  a ``degrade`` lifecycle event, or the manager fails closed with
  :class:`~repro.serving.faults.ExpertUploadFailed` when degradation is
  disabled or impossible (1-bit floor).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compressed_moe import CompressedExperts
from .faults import (
    ExpertUploadFailed,
    FaultPlan,
    checksum_tree,
    corrupt_tree,
)
from .tierstore import TieredExpertStore

__all__ = ["ExpertOffloadManager", "degrade_expert_row"]


def degrade_expert_row(row: Dict, bits: int, to_bits: int) -> Dict:
    """Snap one packed expert row's codes onto the ``2^to_bits`` grid,
    re-encoded in the same ``bits``-wide container (shapes unchanged, so
    the degraded payload drops into the resident buffer like any other
    upload). Scale/zero tables are kept — the row keeps its calibrated
    dynamic range but only ``2^to_bits`` distinct levels survive, i.e.
    the next rung down the PMQ precision ladder. ``row`` is the
    ``{w_gate/w_up/w_down: {data|hi+lo, scale, zero}}`` sub-tree of one
    ``(layer, slot)`` host row (packed axis 0)."""
    from ..core.packing import pack_bits, unpack_bits

    if not 1 <= to_bits < bits:
        raise ValueError(f"cannot degrade {bits}-bit codes to {to_bits}")
    maxq = (1 << bits) - 1
    maxt = (1 << to_bits) - 1

    def snap(q):
        q = np.asarray(q, np.float64)
        q2 = np.rint(q * maxt / maxq)
        return np.rint(q2 * maxq / maxt).astype(np.uint8)

    out: Dict = {}
    for wname, parts in row.items():
        new = dict(parts)
        if bits == 3:
            q = np.asarray(unpack_bits(
                (jnp.asarray(parts["hi"]), jnp.asarray(parts["lo"])),
                3, axis=0,
            ))
            hi, lo = pack_bits(jnp.asarray(snap(q)), 3, axis=0)
            new["hi"], new["lo"] = np.asarray(hi), np.asarray(lo)
        elif bits == 8:
            new["data"] = snap(parts["data"])
        else:
            q = np.asarray(unpack_bits(jnp.asarray(parts["data"]), bits,
                                       axis=0))
            new["data"] = np.asarray(
                pack_bits(jnp.asarray(snap(q)), bits, axis=0)
            )
        out[wname] = new
    return out


class ExpertOffloadManager:
    """Residency manager for one model's layer-stacked PMQ buckets.

    ``ce`` must be the serving layout: every bucket leaf stacked to
    ``[L, count, ...]`` (see ``repro.models.transformer.restack_blocks``).
    ``resident_slots`` is the per-layer device budget in expert slots,
    split across buckets proportionally to their padded counts (every
    bucket keeps ≥ 1 resident row). The manager owns :attr:`ce` — a new
    :class:`CompressedExperts` whose arrays are the resident partitions;
    callers splice it into their parameter tree and never touch the
    original full-resident arrays again.
    """

    def __init__(self, ce: CompressedExperts, *, resident_slots: int,
                 ema_decay: float = 0.8, tracer=None,
                 faults: Optional[FaultPlan] = None, degrade: bool = False,
                 max_retries: int = 3, offload_dir: Optional[str] = None,
                 host_budget_bytes: Optional[int] = None):
        if ce.resident_map is not None:
            raise ValueError("CompressedExperts is already host-offloaded")
        if tracer is None:
            from .trace import NULL_TRACER

            tracer = NULL_TRACER
        self.tracer = tracer
        # fault plane (docs/serving_robustness.md): with a FaultPlan
        # attached every upload is checksum-verified and runs the
        # retry -> re-fetch -> degrade -> fail-closed recovery ladder
        self.faults = faults
        self.degrade_enabled = bool(degrade)
        self.max_retries = int(max_retries)
        self._host_crc: Dict[Tuple[str, int, int], int] = {}
        self._degraded_rows: Dict[Tuple[str, int, int], Dict] = {}
        # (layer, global slot) -> (from_bits, to_bits), engine-lifetime
        self.degraded: Dict[Tuple[int, int], Tuple[int, int]] = {}
        self._attempts: Dict[Tuple[str, int, int], int] = {}
        # prefetch backoff: key -> logical step before which no re-attempt
        self._retry_after: Dict[Tuple[str, int, int], int] = {}
        self.meta = ce.meta
        self.num_slots = ce.num_slots
        self.ema_decay = float(ema_decay)
        self._bkeys = [f"b{i}" for i in range(len(ce.meta))]
        # full host backing store (numpy copies of every packed leaf)
        self.host: Dict[str, Dict] = {
            bk: jax.tree.map(np.asarray, ce.arrays[bk]) for bk in self._bkeys
        }
        first = jax.tree.leaves(self.host[self._bkeys[0]])[0]
        if first.ndim < 3 or first.shape[1] != ce.meta[0].count:
            raise ValueError(
                "expert offload expects layer-stacked buckets "
                f"[L, count, ...]; got leaf shape {first.shape} for "
                f"bucket count {ce.meta[0].count}"
            )
        self.num_layers = int(first.shape[0])
        self._budgets = self._split_budget(int(resident_slots))
        # residency tables (host side): slot -> row (-1 absent), row -> slot
        self.slot_row: Dict[str, np.ndarray] = {}
        self.row_slot: Dict[str, np.ndarray] = {}
        self.ema = np.zeros((self.num_layers, self.num_slots), np.float64)
        # upload counts/bytes are returned to the caller per call and
        # aggregated by ServingMetrics — the manager only tracks what the
        # metrics cannot derive: budget growths (deterministic per trace)
        self.grows = 0
        self._pinned: List[Dict[str, set]] = []
        # double-buffered async prefetch (issue_async/commit_async): the
        # one staged upload batch in flight, validated against these
        # per-bucket content versions at commit time — any mutation of a
        # bucket's device buffer between issue and commit (miss upload,
        # budget grow) bumps its version and invalidates the batch
        self._bucket_version: Dict[str, int] = {}
        self._inflight: Optional[Dict] = None
        self.begin_step()

        dev_arrays: Dict[str, Dict] = {}
        maps: Dict[str, jnp.ndarray] = {}
        for i, bk in enumerate(self._bkeys):
            r, cnt = self._budgets[i], self.meta[i].count
            # seed residency with the first r slots of each bucket — the
            # EMA prefetcher re-ranks them after the first real traffic
            sr = np.full((self.num_layers, cnt), -1, np.int32)
            sr[:, :r] = np.arange(r, dtype=np.int32)[None, :]
            self.slot_row[bk] = sr
            rs = np.full((self.num_layers, r), -1, np.int32)
            rs[:, :] = np.arange(r, dtype=np.int32)[None, :]
            self.row_slot[bk] = rs
            dev_arrays[bk] = jax.tree.map(
                lambda a: jnp.asarray(a[:, :r]), self.host[bk]
            )
            maps[bk] = jnp.asarray(np.maximum(sr, 0))
            self._bucket_version[bk] = 0
        self.ce = dataclasses.replace(
            ce, arrays=dev_arrays, resident_map=maps,
            resident_rows=tuple(self._budgets),
        )
        # three-tier mode (docs/serving_offload.md): spill the packed
        # buckets to mmap'd disk images and drop the full host copies —
        # cold rows are then served disk → byte-budgeted host cache →
        # device, and the process stops paying RAM for the whole model
        self.store: Optional[TieredExpertStore] = None
        if offload_dir is not None:
            self.store = TieredExpertStore(
                self.host, offload_dir=offload_dir,
                host_budget_bytes=host_budget_bytes, tracer=tracer,
            )
            self.host = None

    # ---------------------------------------------------------- budgeting
    def _split_budget(self, resident_slots: int) -> List[int]:
        counts = [m.count for m in self.meta]
        nb = len(counts)
        total = min(self.num_slots, max(nb, resident_slots))
        if total != resident_slots:
            warnings.warn(
                f"resident_slots={resident_slots} clamped to {total} "
                f"(floor: one row per bucket = {nb}; ceiling: "
                f"num_slots = {self.num_slots})",
                RuntimeWarning, stacklevel=3,
            )
        r = [
            max(1, min(c, int(round(resident_slots * c / self.num_slots))))
            for c in counts
        ]
        while sum(r) > total:
            i = max(range(nb), key=lambda j: r[j])
            if r[i] <= 1:
                break
            r[i] -= 1
        while sum(r) < total:
            cands = [j for j in range(nb) if r[j] < counts[j]]
            if not cands:
                break
            i = max(cands, key=lambda j: counts[j] - r[j])
            r[i] += 1
        return r

    @property
    def budgets(self) -> Tuple[int, ...]:
        return tuple(self._budgets)

    @property
    def resident_bytes(self) -> int:
        tot = 0
        for bk in self._bkeys:
            for a in jax.tree.leaves(self.ce.arrays[bk]):
                tot += a.size * a.dtype.itemsize
        return tot

    @property
    def host_bytes(self) -> int:
        """Bytes of the full backing store — the in-memory host copies,
        or the mmap'd disk images when tiered (the host then holds only
        the byte-budgeted warm cache)."""
        if self.store is not None:
            return self.store.disk_bytes
        return sum(
            a.nbytes for bk in self._bkeys
            for a in jax.tree.leaves(self.host[bk])
        )

    def resident_slots_of(self, layer: int) -> Dict[str, set]:
        """Bucket-local resident slot sets of one layer (for tests)."""
        return {
            bk: {int(s) for s in np.nonzero(self.slot_row[bk][layer] >= 0)[0]}
            for bk in self._bkeys
        }

    # ----------------------------------------------------------- plumbing
    def _row_tree(self, bk: str, layer: int, slot: int) -> Dict:
        """The pristine host payload of one (layer, bucket-local slot)
        row: the ``{w_gate/w_up/w_down: {...}}`` sub-tree sliced from the
        ``[L, count, ...]`` backing-store leaves (numpy views), or — in
        three-tier mode — fetched through the disk → host-cache ladder
        at the row's current routing heat (disk reads CRC-verify and
        promote; see :mod:`repro.serving.tierstore`)."""
        if self.store is not None:
            i = self._bkeys.index(bk)
            gslot = self.meta[i].start + int(slot)
            return self.store.row(
                bk, layer, slot, heat=float(self.ema[int(layer), gslot])
            )
        return jax.tree.map(lambda a: a[layer, slot], self.host[bk])

    def _row_crc(self, bk: str, layer: int, slot: int) -> int:
        """Lazily computed/cached checksum of the pristine host row —
        what every staged upload payload is verified against. Tiered
        stores carry the spill-time CRC manifest instead."""
        if self.store is not None:
            return self.store.crc(bk, layer, slot)
        key = (bk, int(layer), int(slot))
        crc = self._host_crc.get(key)
        if crc is None:
            crc = checksum_tree(self._row_tree(bk, layer, slot))
            self._host_crc[key] = crc
        return crc

    def _degrade_target_bits(self, i: int) -> Optional[int]:
        """to_bits for bucket ``i``: the next lower rung of the mixed-
        precision ladder (the largest smaller bucket width, else half
        this bucket's width). ``None`` means no rung below (1-bit floor)."""
        bits = self.meta[i].bits
        lower = [m.bits for m in self.meta if m.bits < bits]
        if lower:
            return max(lower)
        return bits // 2 if bits // 2 >= 1 else None

    def _degrade_or_raise(self, i: int, layer: int, slot: int) -> Dict:
        """A row's target-bit upload failed past the retry budget: build
        (and permanently cache) its precision-degraded payload, or fail
        closed with :class:`ExpertUploadFailed` when degradation is
        disabled or the row is already at the 1-bit floor."""
        bk = self._bkeys[i]
        m = self.meta[i]
        gslot = int(m.start + slot)
        to_bits = self._degrade_target_bits(i) if self.degrade_enabled else None
        if to_bits is None:
            raise ExpertUploadFailed(
                f"expert row (layer {layer}, slot {gslot}) upload failed "
                f"past {self.max_retries} retries and degradation is "
                + ("impossible at the 1-bit floor" if self.degrade_enabled
                   else "disabled")
            )
        key = (bk, int(layer), int(slot))
        if key not in self._degraded_rows:
            self._degraded_rows[key] = degrade_expert_row(
                self._row_tree(bk, layer, slot), m.bits, to_bits
            )
            self.degraded[(int(layer), gslot)] = (int(m.bits), int(to_bits))
        return self._degraded_rows[key]

    def _clear_for_upload(self, i: int, layer: int, slots, kind: str):
        """Run the recovery ladder over bucket-local ``slots`` of one
        layer before placement. Returns ``(cleared, payloads)`` —
        ``payloads`` is ``None`` on the fault-free fast path (the caller
        batch-gathers from the backing store), else one verified host
        row per cleared slot. On the ``miss`` path every slot is cleared
        (bounded immediate retries, then degrade-or-raise: the megastep
        cannot proceed without the row); on the ``prefetch`` path a
        transiently failing slot is deferred with deterministic
        logical-step backoff and simply dropped from this boundary's
        placement (a later boundary, or a miss, re-attempts)."""
        bk = self._bkeys[i]
        m = self.meta[i]
        if self.faults is None and not self._degraded_rows \
                and self.store is None:
            # fast path: the caller batch-gathers straight from the
            # in-memory backing store (tiered stores always hand back
            # per-row payloads — the gather goes through the ladder)
            return list(slots), None
        cleared: List[int] = []
        payloads: List[Dict] = []
        for s in slots:
            s = int(s)
            key = (bk, int(layer), s)
            gslot = int(m.start + s)
            degraded = self._degraded_rows.get(key)
            if degraded is not None:
                # permanently degraded: serve the lower-bit copy. The
                # fault models the *target-bit* payload's transport; the
                # degraded substitute is a different payload and bypasses
                # injection.
                fb, tb = self.degraded[(int(layer), gslot)]
                self.tracer.lifecycle(
                    "degrade", track="experts", layer=int(layer),
                    slot=gslot, from_bits=fb, to_bits=tb,
                )
                cleared.append(s)
                payloads.append(degraded)
                continue
            if self.faults is None:
                cleared.append(s)
                payloads.append(self._row_tree(bk, layer, s))
                continue
            was_deferred = key in self._retry_after
            if kind == "prefetch" and was_deferred:
                if self.faults.step < self._retry_after[key]:
                    continue  # still backing off — skip this boundary
                del self._retry_after[key]
                self.tracer.lifecycle(
                    "retry", track="experts", path="prefetch",
                    layer=int(layer), slot=gslot,
                    attempt=int(self._attempts.get(key, 0)),
                )
            attempts = int(self._attempts.get(key, 0))
            while True:
                spec = self.faults.fire("upload", (int(layer), gslot))
                if spec is not None:
                    self.tracer.lifecycle(
                        "fault", track="experts", site="upload",
                        mode=spec.mode, layer=int(layer), slot=gslot,
                        path=kind,
                    )
                if spec is None or spec.mode == "corrupt":
                    row = self._row_tree(bk, layer, s)
                    if spec is not None:
                        row = corrupt_tree(row)
                    if checksum_tree(row) != self._row_crc(bk, layer, s):
                        # integrity check caught the damage: re-fetch the
                        # pristine host payload (one recovered retry)
                        self.tracer.lifecycle(
                            "retry", track="experts", path="refetch",
                            layer=int(layer), slot=gslot,
                            attempt=attempts + 1,
                        )
                        row = self._row_tree(bk, layer, s)
                    cleared.append(s)
                    payloads.append(row)
                    self._attempts.pop(key, None)
                    break
                # mode == "fail": transient/persistent I/O error
                attempts += 1
                self._attempts[key] = attempts
                if attempts > self.max_retries:
                    # persistent: degrade to the next ladder rung (or
                    # fail closed). The degraded payload bypasses
                    # injection — see above.
                    row = self._degrade_or_raise(i, layer, s)
                    fb, tb = self.degraded[(int(layer), gslot)]
                    self.tracer.lifecycle(
                        "degrade", track="experts", layer=int(layer),
                        slot=gslot, from_bits=fb, to_bits=tb,
                    )
                    cleared.append(s)
                    payloads.append(row)
                    break
                if kind == "prefetch":
                    # deterministic backoff in logical steps, never
                    # seconds — replay-identical across runs
                    self._retry_after[key] = self.faults.step + (1 << attempts)
                    break  # deferred; a later boundary re-attempts
                # miss path: bounded immediate retries
                self.tracer.lifecycle(
                    "retry", track="experts", path="miss",
                    layer=int(layer), slot=gslot, attempt=attempts,
                )
        return cleared, payloads

    def _build_upload(self, bk: str, triples, payloads=None):
        """Build the post-upload device buffers for ``(layer, row,
        slot)`` placements — one batched scatter per packed leaf per
        bucket, regardless of how many layers the placements span (a
        per-layer ``.set`` would rebuild the whole [L, R, ...] buffer
        once per layer). Pure with respect to the manager: jax arrays
        are immutable, so ``.at[].set`` returns *new* buffers and the
        live ones keep serving until the caller swaps them in — exactly
        the double-buffering :meth:`issue_async` rides on. ``payloads``
        (one verified host-row tree per triple, from
        :meth:`_clear_for_upload`) replaces the backing-store gather on
        the fault/tiered paths. Returns ``(new_arrays, nbytes)``."""
        l_idx = np.asarray([t[0] for t in triples], np.int32)
        r_idx = np.asarray([t[1] for t in triples], np.int32)
        s_idx = np.asarray([t[2] for t in triples], np.int32)
        nbytes = 0

        if payloads is None:
            def up(dev, host):
                nonlocal nbytes
                src = host[l_idx, s_idx]  # [n, ...]
                nbytes += src.nbytes
                return dev.at[l_idx, r_idx].set(jnp.asarray(src))

            return jax.tree.map(
                up, self.ce.arrays[bk], self.host[bk]
            ), nbytes

        stacked = jax.tree.map(lambda *rows: np.stack(rows), *payloads)

        def up_rows(dev, src):
            nonlocal nbytes
            nbytes += src.nbytes
            return dev.at[l_idx, r_idx].set(jnp.asarray(src))

        return jax.tree.map(
            up_rows, self.ce.arrays[bk], stacked
        ), nbytes

    def _upload_batch(self, bk: str, triples, payloads=None) -> int:
        """Synchronous host→device copy: build the new buffers and swap
        them in immediately, invalidating any in-flight async batch for
        this bucket (its staged buffers no longer contain these rows)."""
        if not triples:
            return 0
        new_arrays, nbytes = self._build_upload(bk, triples, payloads)
        self.ce.arrays[bk] = new_arrays
        self._bucket_version[bk] += 1
        return nbytes

    def _refresh_map(self, bk: str) -> None:
        self.ce.resident_map[bk] = jnp.asarray(
            np.maximum(self.slot_row[bk], 0).astype(np.int32)
        )

    def _grow(self, i: int, need: int) -> None:
        """Enlarge bucket i's resident buffer to ``need`` rows (all
        layers). Changes leaf shapes — the jitted programs re-specialize
        once — and is only taken when a step's working set cannot fit the
        configured budget (correctness beats the budget)."""
        bk = self._bkeys[i]
        old = self._budgets[i]
        new_r = min(self.meta[i].count, int(need))
        if new_r <= old:
            return
        pad = new_r - old
        self.row_slot[bk] = np.concatenate(
            [self.row_slot[bk],
             np.full((self.num_layers, pad), -1, np.int32)], axis=1,
        )
        self.ce.arrays[bk] = jax.tree.map(
            lambda a: jnp.concatenate(
                [a, jnp.zeros((a.shape[0], pad) + a.shape[2:], a.dtype)],
                axis=1,
            ),
            self.ce.arrays[bk],
        )
        self._budgets[i] = new_r
        self.ce.resident_rows = tuple(self._budgets)
        self._bucket_version[bk] += 1  # staged async buffers now stale
        self.grows += 1
        self.tracer.instant(
            "expert_budget_grow", track="experts", cat="offload",
            bucket=i, rows_before=old, rows_after=new_r,
        )

    def _place(self, i: int, layer: int, want, protected, score_fn):
        """Install bucket-local slots ``want`` into bucket ``i``'s rows of
        one layer, filling free rows first and then evicting the
        lowest-``score_fn`` rows whose slot is not in ``protected``.
        Updates the host-side tables and returns the ``(layer, row,
        slot)`` placements; the caller batch-uploads them
        (:meth:`_upload_batch`) and refreshes the device map.
        """
        bk = self._bkeys[i]
        sr = self.slot_row[bk]
        rows = self.row_slot[bk]
        r_i = self._budgets[i]
        free = [j for j in range(r_i) if rows[layer, j] < 0]
        evictable = sorted(
            (j for j in range(r_i)
             if rows[layer, j] >= 0 and int(rows[layer, j]) not in protected),
            key=lambda j: (score_fn(int(rows[layer, j])),
                           int(rows[layer, j])),
        )
        targets = (free + evictable)[: len(want)]
        placed = []
        for s, j in zip(want, targets):
            old = int(rows[layer, j])
            if old >= 0:
                sr[layer, old] = -1
            rows[layer, j] = s
            sr[layer, s] = j
            placed.append((layer, j, s))
        return placed

    # ------------------------------------------------------ step protocol
    def begin_step(self) -> None:
        """Reset the per-step pin sets. The engine calls this before each
        jitted-program replay loop; every slot reported used during the
        loop stays pinned (never evicted) until the loop accepts."""
        self._pinned = [
            {bk: set() for bk in self._bkeys} for _ in range(self.num_layers)
        ]

    def ensure_resident(self, counts: np.ndarray) -> Tuple[int, int]:
        """Make the last program run's *authentic* working set resident.

        ``counts`` is the run's ``slot_counts`` output with rows in
        **computation order**: ``[L, num_slots]`` for a single-step
        program, or ``[H·L, num_slots]`` (step-major: row ``k`` is layer
        ``k % L`` of horizon step ``k // L``) for a fused decode
        megastep — whose union over steps is the horizon working set.
        Returns ``(uploads, bytes)`` — ``uploads == 0`` means the run's
        whole working set was already resident (the run is *accepted*:
        its outputs are bit-identical to the all-resident engine).
        Otherwise the caller must replay the whole program after this
        synchronous upload (KV writes are position-addressed and the
        token sequence is deterministic per megastep key, so a megastep
        replay is idempotent).

        Usage is only trusted up to the **first row with a miss**: rows
        before it computed with correct expert rows, so their routing —
        and the missed row's own routing — is authentic; later rows
        (deeper layers, and with a horizon every subsequent fused step,
        whose input token depends on the full previous step) routed on
        garbage activations and are ignored until a replay reaches them
        with correct inputs. Every pinned slot is therefore part of the
        true working set — phantom usage can never inflate uploads or
        trigger a budget grow — and each replay extends the correct
        prefix by ≥ 1 row, so the loop accepts within ``rows`` (≤ H·L)
        replays. Evicts only unpinned rows, coldest EMA first.
        """
        rows = counts.reshape(-1, self.num_slots)
        # fast path (the common all-hit case): nothing dispatched-to is
        # non-resident, so the run is accepted without touching the pin
        # sets — pins only matter across replays, and slots pinned by an
        # earlier iteration are already resident (eviction protects them)
        resident = np.concatenate(
            [self.slot_row[bk] >= 0 for bk in self._bkeys], axis=1
        )
        layer_of = np.arange(rows.shape[0]) % self.num_layers
        if not np.any((rows > 0) & ~resident[layer_of]):
            return 0, 0
        t0 = self.tracer.now_us()
        ups = 0
        nbytes = 0
        pending = {bk: [] for bk in self._bkeys}
        pend_rows = {bk: [] for bk in self._bkeys}
        for k in range(rows.shape[0]):
            l = int(layer_of[k])
            row_missed = False
            for i, bk in enumerate(self._bkeys):
                m = self.meta[i]
                used = np.nonzero(rows[k, m.start:m.start + m.count] > 0)[0]
                pin = self._pinned[l][bk]
                pin.update(int(u) for u in used)
                missing = [s for s in sorted(pin) if self.slot_row[bk][l, s] < 0]
                if not missing:
                    continue
                row_missed = True
                if len(pin) > self._budgets[i]:
                    self._grow(i, len(pin))
                # recovery ladder first: on the miss path every slot is
                # cleared (retried, degraded) or a typed fault is raised
                missing, rows_pay = self._clear_for_upload(
                    i, l, missing, "miss"
                )
                # pin ≤ budget now, so every missing slot finds a row
                placed = self._place(
                    i, l, missing, pin,
                    lambda s, l=l, m=m: self.ema[l, m.start + s],
                )
                assert len(placed) == len(missing), "pin set exceeds budget"
                pending[bk].extend(placed)
                if rows_pay is not None:
                    pend_rows[bk].extend(rows_pay)
                ups += len(placed)
            if row_missed:
                break  # later rows routed on garbage — replay first
        for bk in self._bkeys:  # one batched upload + map per bucket
            if pending[bk]:
                nbytes += self._upload_batch(
                    bk, pending[bk], pend_rows[bk] or None
                )
                self._refresh_map(bk)
        if ups:
            self.tracer.complete(
                "expert_upload", track="experts", cat="offload", start_us=t0,
                args={"kind": "miss", "uploads": ups, "bytes": nbytes},
            )
        return ups, nbytes

    def update_stats(self, counts: np.ndarray) -> None:
        """Fold an accepted program's dispatch counts into the routing
        EMA. Accepts ``[L, num_slots]`` or a fused megastep's
        ``[H·L, num_slots]`` / ``[H, L, num_slots]`` — horizon steps are
        summed, so one EMA update per accepted megastep sees the whole
        horizon's traffic (a smoother, more predictive prefetch signal
        than per-token updates)."""
        counts = counts.reshape(-1, self.num_layers, self.num_slots).sum(0)
        d = self.ema_decay
        self.ema = d * self.ema + (1.0 - d) * counts.astype(np.float64)

    def residency_targets(self) -> Tuple[Tuple[int, int, Tuple[int, ...]], ...]:
        """Pure target-set computation: the declarative half of prefetch.

        Per (layer, bucket): the top-``R_i`` slots by EMA score are the
        *desired* resident set. Stable ranking (score desc, slot asc)
        keeps the selection deterministic and churn-free on ties.
        Returns one ``(bucket_idx, layer, desired_slots)`` triple for
        every (layer, bucket) whose desired set is not fully resident —
        an empty tuple means residency already matches the target.
        Reads routing EMA and residency maps; mutates **nothing** (the
        controller calls this at planning time; convergence happens in
        :meth:`apply_residency`).
        """
        targets = []
        for l in range(self.num_layers):
            for i, bk in enumerate(self._bkeys):
                m = self.meta[i]
                r_i = self._budgets[i]
                if r_i >= m.count:
                    continue
                scores = self.ema[l, m.start:m.start + m.count]
                desired = tuple(
                    int(s) for s in np.argsort(-scores, kind="stable")[:r_i]
                )
                if any(self.slot_row[bk][l, s] < 0 for s in desired):
                    targets.append((i, l, desired))
        return tuple(targets)

    def apply_residency(
        self, targets: Tuple[Tuple[int, int, Tuple[int, ...]], ...]
    ) -> Tuple[int, int]:
        """Converge residency toward :meth:`residency_targets` output:
        missing desired slots are uploaded over the coldest undesired
        residents (one batched upload + device-map refresh per bucket).
        Returns ``(uploads, bytes)``.
        """
        if not targets:
            return 0, 0
        t0 = self.tracer.now_us()
        ups = 0
        nbytes = 0
        pending = {bk: [] for bk in self._bkeys}
        pend_rows = {bk: [] for bk in self._bkeys}
        for i, l, desired in targets:
            bk = self._bkeys[i]
            m = self.meta[i]
            scores = self.ema[l, m.start:m.start + m.count]
            want = sorted(
                s for s in desired if self.slot_row[bk][l, s] < 0
            )
            if not want:
                continue
            # recovery ladder: transiently failing prefetch uploads are
            # deferred with logical-step backoff (dropped from this
            # boundary's placement); the rest arrive verified
            want, rows_pay = self._clear_for_upload(i, l, want, "prefetch")
            if not want:
                continue
            placed = self._place(i, l, want, set(desired),
                                 lambda s, scores=scores: scores[s])
            pending[bk].extend(placed)
            if rows_pay is not None:
                pend_rows[bk].extend(rows_pay)
            ups += len(placed)
        for bk in self._bkeys:  # one batched upload + map per bucket
            if pending[bk]:
                nbytes += self._upload_batch(
                    bk, pending[bk], pend_rows[bk] or None
                )
                self._refresh_map(bk)
        if ups:
            self.tracer.complete(
                "expert_upload", track="experts", cat="offload", start_us=t0,
                args={"kind": "prefetch", "uploads": ups, "bytes": nbytes},
            )
        return ups, nbytes

    def prefetch(self) -> Tuple[int, int]:
        """Upload the EMA-hottest slots ahead of need (between steps):
        :meth:`residency_targets` (pure) followed by
        :meth:`apply_residency` (converge). Kept as the one-call form
        for direct drivers and tests; the engine goes through the
        resource controller, which folds the target set into its
        boundary plan as an ``upload_experts`` action.
        """
        return self.apply_residency(self.residency_targets())

    # ------------------------------------------- async double-buffering
    def issue_async(self, targets) -> Tuple[int, int]:
        """Stage one boundary's prefetch uploads *without touching the
        live residency state* — the overlap half of async expert
        streaming (docs/serving_offload.md).

        The engine calls this right after dispatching a megastep: the
        recovery ladder runs immediately (an in-flight transfer failure
        is a prefetch failure — deferred with the same deterministic
        backoff), payload rows are gathered through the tier ladder, and
        the post-upload device buffers are *built* (``.at[].set`` on
        immutable jax arrays returns new buffers, so the dispatch is
        enqueued and the copy proceeds while the megastep computes) but
        **not** swapped in. Placement runs on copies of the residency
        tables; the live tables — and the live buffers the running
        megastep (and any miss replay) uses — are untouched until
        :meth:`commit_async` flips them at the next boundary. At most
        one batch is in flight; a second issue before commit is a no-op.
        Returns ``(uploads, bytes)`` staged.
        """
        if not targets or self._inflight is not None:
            return 0, 0
        t0_us = self.tracer.now_us()
        live_sr, live_rs = self.slot_row, self.row_slot
        # placement mutates the snapshot tables only: the in-flight
        # megastep keeps a consistent (tables, buffers, map) view
        self.slot_row = {bk: a.copy() for bk, a in live_sr.items()}
        self.row_slot = {bk: a.copy() for bk, a in live_rs.items()}
        versions = dict(self._bucket_version)
        budgets = tuple(self._budgets)
        pending = {bk: [] for bk in self._bkeys}
        pend_rows = {bk: [] for bk in self._bkeys}
        ups = 0
        nbytes = 0
        staged_arrays: Dict[str, Dict] = {}
        try:
            for i, l, desired in targets:
                bk = self._bkeys[i]
                m = self.meta[i]
                scores = self.ema[l, m.start:m.start + m.count]
                want = sorted(
                    s for s in desired if self.slot_row[bk][l, s] < 0
                )
                if not want:
                    continue
                want, rows_pay = self._clear_for_upload(
                    i, l, want, "prefetch"
                )
                if not want:
                    continue
                placed = self._place(i, l, want, set(desired),
                                     lambda s, scores=scores: scores[s])
                pending[bk].extend(placed)
                if rows_pay is not None:
                    pend_rows[bk].extend(rows_pay)
                ups += len(placed)
            for bk in self._bkeys:
                if pending[bk]:
                    staged_arrays[bk], nb = self._build_upload(
                        bk, pending[bk], pend_rows[bk] or None
                    )
                    nbytes += nb
        finally:
            staged_sr, staged_rs = self.slot_row, self.row_slot
            self.slot_row, self.row_slot = live_sr, live_rs
        if ups == 0:
            return 0, 0
        self._inflight = {
            "arrays": staged_arrays,
            "slot_row": staged_sr,
            "row_slot": staged_rs,
            "versions": versions,
            "budgets": budgets,
            "uploads": ups,
            "nbytes": nbytes,
            "t0_us": t0_us,
        }
        return ups, nbytes

    def commit_async(self) -> Tuple[int, int, int, float]:
        """Flip the double buffer at a megastep boundary: swap the
        staged device buffers, residency tables, and device maps in —
        unless any bucket's content version moved since issue (a miss
        upload or budget grow landed mid-flight), in which case the
        whole staged batch is **dropped** (the stale buffers are missing
        those rows; the next boundary re-plans from fresh targets).
        Dropping can never corrupt outputs — residency placement is
        output-invariant and the miss-replay backstop is unchanged.
        Returns ``(committed_uploads, dropped_uploads, bytes, wait_s)``
        where ``wait_s`` is the residual wall time spent waiting for
        staged transfers that had not finished landing (the un-hidden
        remainder; ~0 when the megastep fully covered the copy).
        """
        inf, self._inflight = self._inflight, None
        if inf is None:
            return 0, 0, 0, 0.0
        if tuple(self._budgets) != inf["budgets"] or any(
            self._bucket_version[bk] != v
            for bk, v in inf["versions"].items()
        ):
            self.tracer.instant(
                "expert_upload_dropped", track="experts", cat="offload",
                uploads=inf["uploads"],
            )
            return 0, inf["uploads"], 0, 0.0
        t0 = time.time()
        for arrs in inf["arrays"].values():
            jax.block_until_ready(jax.tree.leaves(arrs))
        wait_s = time.time() - t0
        for bk, arrs in inf["arrays"].items():
            self.ce.arrays[bk] = arrs
            self._bucket_version[bk] += 1
        self.slot_row = inf["slot_row"]
        self.row_slot = inf["row_slot"]
        for bk in inf["arrays"]:
            self._refresh_map(bk)
        self.tracer.complete(
            "expert_upload", track="experts", cat="offload",
            start_us=inf["t0_us"],
            args={"kind": "async", "uploads": inf["uploads"],
                  "bytes": inf["nbytes"]},
        )
        return inf["uploads"], 0, inf["nbytes"], wait_s

    # -------------------------------------------------------- housekeeping
    def prune_backoff(self) -> int:
        """Drop prefetch-backoff entries that can never be consumed
        again: rows that were permanently **degraded** (their target-bit
        upload is never re-attempted — ``_clear_for_upload`` serves the
        cached lower-rung copy first) and rows that became **resident**
        through another path (a miss upload landed them, proving the
        transport; the deferral is moot). The controller calls this at
        every plan boundary, so ``_retry_after`` stays bounded by the
        set of live, non-resident, still-failing rows instead of
        accumulating one entry per fault ever fired. Returns the number
        of entries pruned."""
        stale = [
            key for key in self._retry_after
            if key in self._degraded_rows
            or self.slot_row[key[0]][key[1], key[2]] >= 0
        ]
        for key in stale:
            del self._retry_after[key]
            if key in self._degraded_rows:
                self._attempts.pop(key, None)
        return len(stale)
