"""Continuous-batching decode engine over the paged KV pool.

The engine owns two jitted programs, both with static shapes so they
compile exactly once each:

* **prefill chunk** — one request's prompt streams through
  :func:`repro.models.transformer.paged_prefill_chunk` in fixed-size
  chunks, writing K/V straight into the request's pages (no dense
  [L,B,S,…] cache, no per-wave re-prefill). The final chunk's logits give
  the first generated token — the TTFT event.
* **decode megastep** — all ``max_slots`` slots advance up to
  ``decode_horizon`` tokens through one
  :func:`repro.models.transformer.paged_decode_horizon` program: an
  on-device ``lax.scan`` over H single-step bodies with on-device
  sampling (greedy argmax by default, categorical at
  ``temperature > 0``) feeding each step's token into the next, and
  per-slot stop logic (emission budget exhausted, EOS emitted, slot
  inactive) folded into the carried ``active`` mask. The pool arrays are
  donated, so the multi-GB cache is updated in place.

**What syncs when.** The host orchestration cost — one jitted dispatch,
one ``device→host`` fetch, one Python bookkeeping pass — is paid once
per *megastep*, not once per token: the engine fetches the ``[H, slots]``
emitted-token matrix plus its emit mask, per-step activation, and
per-step dispatch counts in a single sync, then applies up to
``H · slots`` tokens host-side. ``H = 1`` reproduces the historical
per-token program exactly (the A/B baseline); any ``H`` emits greedy
tokens bit-identical to ``dense_greedy_reference`` because each scan
step runs the same traced body as ``paged_decode_step``.
:class:`repro.serving.metrics.ServingMetrics` reconstructs per-logical-
step records from each megastep (emit counts, activation and pool gauges
are exact per step — admissions, queue depth and page utilization are
genuinely constant within a megastep since all scheduling happens at its
boundary) and counts dispatches/syncs per token, the horizon's
deterministic witness.

Between megasteps the (host-side)
:class:`repro.serving.scheduler.Scheduler` admits queued requests into
freed slots — continuous batching with no wave barrier and no dummy
padding, FCFS at megastep granularity. The model path is the standard
bundle tree, including PMQ-compressed experts (``moe_ce`` buckets, paper
§3.2) and OTP deterministic decode masks (§3.4 τ→0 argmax) when present.

**Dynamic page growth + preemption.** Admission reserves pages for the
prompt plus the first megastep's writes; before each megastep the engine
grows every active slot's block table **horizon-ahead** — enough pages
for all ``min(H, budget)`` KV writes the fused program will perform
(oldest admission first), so no write inside the scan can land on an
unallocated page. When the pool runs dry, the youngest-admitted /
least-progress request is preempted — its pages are swapped to a host
backing store (``preempt_mode="swap"``) or dropped (``"recompute"``) —
and it rejoins the FCFS queue at the head. On re-admission the engine
swap-restores the pages or re-prefills ``prompt + out[:-1]``; greedy
outputs are bit-identical either way for any pool that admits the
largest single request (fuzzed in ``tests/test_serving_sim.py``). Block
tables keep their static ``[max_slots, max_blocks_per_slot]`` shape
throughout — growth only fills in rows between jitted programs, so
nothing recompiles.

**Host-offloaded expert buckets + replay semantics.** With
``resident_experts`` set (PMQ params only), cold expert rows live in
host memory (:class:`repro.serving.offload.ExpertOffloadManager`) and
the jitted programs read a budget-shaped resident partition. Between
megasteps the controller plan uploads the router-stats-EMA-hottest
experts (an ``upload_experts`` convergence action computed from
``offload.residency_targets()``); because routing happens inside the jitted
program, a **miss** is only observable afterwards — from the reported
``[H, L, slots]`` dispatch counts, whose step-major flattening is the
horizon-union working set in computation order. The engine then uploads
the missing experts synchronously and **replays the whole megastep**:
KV writes land at position-determined destinations and the token
sequence is deterministic (greedy, or categorical under the megastep's
fixed key), so a replay simply overwrites every write with identical
values — the same authentic-prefix induction as the single-step case,
now bounded by ``H · num_layers`` replays. Greedy outputs are therefore
bit-identical to the all-resident engine for any budget that holds the
megastep working set (fuzzed in ``tests/test_offload.py``). The
megastep timer reports **compute** (first run) and **offload overhead**
(uploads + replays) as separate metrics — ``decode_step_s`` and
``tokens_per_s`` stay honest end-to-end wall-clock, and the new split
makes the replay share separately attributable instead of silently
folded in.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tf
from .controller import PlanAction, ResourceController
from .faults import (
    DeadlineExceeded,
    ExpertUploadFailed,
    FaultPlan,
    LivelockDetected,
    PoisonedRequest,
    RequestCancelled,
    ServingFault,
    SwapFault,
    WatchdogTimeout,
)
from .kvcache import PagedKVCache, PoolExhausted
from .metrics import ServingMetrics
from .scheduler import Request, Scheduler, VALID_POLICIES
from .trace import ExpertRoutingTelemetry, MetricsConsumer, SpanTracer

__all__ = [
    "EngineConfig", "PagedServingEngine", "dense_greedy_reference",
    "quantized_greedy_reference",
]


def dense_greedy_reference(cfg, params, prompt: np.ndarray, max_new: int):
    """Greedy decode through the *dense* cache — the equivalence oracle
    for the paged engine (tests and examples assert paged == dense).

    Returns ``(tokens, per_step_logits)`` where ``per_step_logits[i]`` is
    the last-token logits [V] that produced ``tokens[i]``. Run it with the
    engine's ``model_cfg`` so both sides use drop-free expert capacity.
    """
    from ..models.registry import get_model

    bundle = get_model(cfg)
    cache, logits = bundle.prefill(params, {"tokens": jnp.asarray(prompt[None])})
    # the prefill cache covers exactly the prompt; extend for decode
    pad = ((0, 0), (0, 0), (0, max_new), (0, 0), (0, 0))
    cache = dict(cache, k=jnp.pad(cache["k"], pad), v=jnp.pad(cache["v"], pad))
    toks, steps = [], [np.asarray(logits[0, -1])]
    cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    toks.append(int(cur[0, 0]))
    for step in range(max_new - 1):
        cache, logits = bundle.decode_step(
            params, cache, cur, jnp.int32(len(prompt) + step)
        )
        steps.append(np.asarray(logits[0, -1]))
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(cur[0, 0]))
    return toks, steps


def quantized_greedy_reference(cfg, params, prompt: np.ndarray, max_new: int,
                               *, kv_bits: int = 8, block_size: int = 16,
                               use_otp: bool = True,
                               ffn_backend: Optional[str] = None) -> List[int]:
    """Greedy decode oracle for **int8-KV** engines: a fresh
    single-request, single-slot, ``H = 1``, prefix-cache-off paged
    engine with the same ``kv_bits``.

    Quantized greedy outputs cannot be compared against
    :func:`dense_greedy_reference` — the dense cache attends to
    unquantized rows, so its logits differ by design. The invariant the
    quantized engine *does* keep is batch-composition independence:
    per-row quantization depends only on the row values, so a request's
    codes (hence its tokens) are identical whether it runs alone here or
    co-scheduled/preempted/prefix-shared in a loaded engine — that
    equality is what the fuzz harness asserts, and page geometry does
    not enter the math (any ``block_size`` gives the same tokens).
    """
    prompt = np.ascontiguousarray(prompt, np.int32)
    pages = -(-(len(prompt) + max_new) // block_size)
    eng = PagedServingEngine(cfg, params, EngineConfig(
        max_slots=1, block_size=block_size, num_blocks=pages,
        max_blocks_per_slot=pages, prefill_chunk=block_size,
        decode_horizon=1, reserve_full=True, use_otp=use_otp,
        ffn_backend=ffn_backend, kv_bits=kv_bits, prefix_cache=False,
        trace_level="off",
    ))
    out = eng.serve([Request(rid=0, prompt=prompt, max_new=max_new)])
    return out[0]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    block_size: int = 16
    num_blocks: int = 64
    max_blocks_per_slot: int = 8
    prefill_chunk: int = 16
    use_otp: bool = True  # OTP decode masks when the model carries them
    # Preempted-request restore path: "swap" moves victim KV pages to a
    # host backing store and uploads them back at re-admission (bit-exact,
    # costs PCIe/host bandwidth); "recompute" drops the pages and
    # re-prefills prompt + generated-so-far (costs FLOPs, no host memory).
    preempt_mode: str = "swap"
    # True restores the PR-1 admission policy: reserve prompt + max_new
    # pages up front so growth/preemption never trigger — the baseline leg
    # of the --pool-blocks pressure sweeps.
    reserve_full: bool = False
    # Serving must be batch-composition independent: a request's tokens
    # cannot change because of who it was co-scheduled with (continuous
    # batching reshuffles neighbors every step) nor how its prompt was
    # chunked. Expert capacity is therefore raised to the drop-free bound
    # (cap ≥ tokens·top_k ⇔ capacity_factor ≥ num_experts) inside the
    # engine's jitted steps.
    drop_free_capacity: bool = True
    # Per-layer device budget (in permuted expert slots) for PMQ buckets;
    # None keeps every bucket fully resident. Requires compressed params
    # ("moe_ce" in the stacked block tree). Cold rows live in host memory
    # and are prefetched by a router-stats EMA; misses replay the step.
    resident_experts: Optional[int] = None
    # EMA decay of the per-(layer, slot) dispatch counts driving prefetch.
    prefetch_ema: float = 0.8
    # Async expert streaming (docs/serving_offload.md): the controller's
    # prefetch plan is *issued* right after each megastep's program
    # dispatch (double-buffered — built against immutable jax arrays
    # while the megastep computes on the live ones) and *committed* at
    # the next boundary; stale batches (a miss/grow landed mid-flight)
    # are dropped and re-planned. Outputs are bit-identical with this on
    # or off (fuzzed in tests/test_serving_sim.py); only the timing —
    # decode_offload_frac — changes. False keeps the synchronous PR-3
    # path: apply_residency blocks the boundary.
    async_offload: bool = False
    # Three-tier expert store (repro.serving.tierstore): a directory to
    # spill the packed PMQ buckets into as mmap'd .npy images (CRC
    # manifest, verified on every read). The full in-memory host copies
    # are dropped after the spill — cold rows are then served
    # disk → host cache → device. None keeps the two-tier host store.
    offload_dir: Optional[str] = None
    # Byte budget of the warm host row cache between the disk images and
    # the device partitions (only with offload_dir). None = unbounded;
    # 0 = every fetch reads (and CRC-verifies) the mmap.
    host_expert_bytes: Optional[int] = None
    # Compressed expert-FFN implementation inside the jitted programs:
    # "grouped" (default — bucket-at-a-time grouped GEMM, Pallas moe_gmm
    # on TPU / jnp oracle on CPU), "scan" (legacy per-expert scan, the
    # A/B baseline), "ref"/"interpret" (grouped layout, forced kernel
    # backend). Trace-time static: changing it costs one retrace, using
    # it never retraces. None = repro.core.compressed_moe default.
    ffn_backend: Optional[str] = None
    # Fused decode horizon H: one jitted megastep advances every slot up
    # to H tokens with on-device sampling, paying one dispatch + one
    # host sync per megastep instead of per token. H = 1 reproduces the
    # historical per-token program (the A/B baseline); greedy outputs
    # are bit-identical across H. Trace-time static.
    decode_horizon: int = dataclasses.field(
        default_factory=lambda: int(os.environ.get(
            "REPRO_DECODE_HORIZON", "8"))
    )
    # On-device sampling inside the horizon scan: 0 (default) compiles
    # greedy argmax — the path every bit-identity invariant runs; > 0
    # compiles categorical sampling from logits/T, seeded per megastep
    # from sample_seed so runs (and offload replays) are deterministic.
    temperature: float = 0.0
    sample_seed: int = 0
    # Shared-prefix KV reuse: admission probes a prefix → physical-page-
    # run cache (exact token keys, LRU) and shares matching page-aligned
    # pages copy-on-write instead of re-prefilling them; fresh prompts
    # register their page-boundary prefixes after prefill. Greedy outputs
    # are bit-identical with the cache on or off (fuzzed in
    # tests/test_serving_sim.py) — cached pages hold exactly the KV the
    # skipped prefill would have written.
    prefix_cache: bool = False
    # int8 KV quantization: 8 stores the pools as uint8 per-row affine
    # codes with per-(layer, page, row, kv-head) scale/zero tables (see
    # repro.core.quantizers.quantize_kv_rows), halving-plus KV bytes per
    # token at fixed pool geometry; None keeps fp pools (today's path,
    # byte-for-byte untouched). Quantized greedy outputs are batch-
    # composition independent (per-row params depend only on the row) and
    # equal quantized_greedy_reference bit-for-bit, but differ from the
    # dense fp oracle by design.
    kv_bits: Optional[int] = None
    # Request-lifecycle tracing (repro.serving.trace): "off" records no
    # events (lifecycle facts still reach the metrics consumer, so
    # counters() are invariant to this knob), "spans" records
    # span/instant/flow events, "full" adds per-step gauges + the
    # expert-routing telemetry. Host-side only — never traced into jit.
    trace_level: str = dataclasses.field(
        default_factory=lambda: os.environ.get("REPRO_TRACE_LEVEL", "off")
    )
    # Multi-tenant scheduling policy (docs/serving_scheduling.md):
    # "fcfs" (historical single-tenant behavior), "priority" (classes
    # first, FCFS within), "fair" (priority + weighted deficit round-
    # robin over per-tenant decode-token grants). Policies reorder
    # *when* requests run, never *what* they emit — outputs stay
    # batch-composition independent under every policy.
    policy: str = "fcfs"
    # Per-tenant WDRR weights for policy="fair", as a hashable tuple of
    # (tenant, weight) pairs (EngineConfig is frozen/hashable); unlisted
    # tenants weigh 1.0. None ⇒ all tenants weigh 1.0.
    tenant_weights: Optional[Tuple[Tuple[str, float], ...]] = None
    # SLO-aware admission: a fresh request that cannot admit at a
    # boundary after waiting more than this many logical decode steps
    # (deterministic — the sim/bench budget) or this many wall-clock
    # seconds (launch/serve's --ttft-budget-ms) is *shed*: removed from
    # the queue with an empty output and a "shed" lifecycle event,
    # instead of queueing unboundedly. None disables shedding.
    ttft_budget_steps: Optional[int] = None
    ttft_budget_s: Optional[float] = None
    # ---- fault plane (docs/serving_robustness.md) ----
    # Precision-ladder degradation: when an expert row's target-bit
    # upload persistently fails (past upload_max_retries), serve a
    # lower-bit copy of that row (codes snapped to the next ladder rung,
    # scale/zero kept) instead of failing closed. Off by default — the
    # bit-exact contract then holds unconditionally: recovery either
    # reproduces the fault-free run or raises ExpertUploadFailed.
    degrade_experts: bool = False
    # Bounded miss-path retries per expert row before degrade/fail.
    upload_max_retries: int = 3
    # Wall-clock megastep watchdog: a megastep slower than this fails
    # the engine closed with WatchdogTimeout (None = off; tests drive it
    # through the engine's injectable ``_clock``).
    watchdog_timeout_s: Optional[float] = None
    # No-progress livelock guard: this many consecutive megastep
    # boundaries with work but zero emitted tokens / finished requests
    # fail closed with LivelockDetected. Logical steps — deterministic.
    livelock_steps: int = 4096


@functools.lru_cache(maxsize=None)
def _jitted_steps(model_cfg, use_otp: bool, ffn_backend: Optional[str] = None,
                  horizon: int = 1, temperature: float = 0.0):
    """Compiled decode-megastep/prefill builders, shared across engines
    with the same (hashable, frozen) model config and the same static
    horizon/sampling knobs — jit caching then dedupes by array shapes
    *and pytree structure* (fp and int8 engines trace different
    programs off the same builder), so two engines differing only in
    pool geometry cost one trace each, not one per instance.

    Both programs take and return the ``quant`` scale/zero tables right
    after the pools (``None`` on fp engines — an empty pytree that
    donates and returns as nothing): the tables are pool metadata and
    must travel through every donated round-trip with the codes they
    dequantize.
    """
    hooks = {"use_otp": use_otp, "ffn_backend": ffn_backend}

    def decode_fn(params, k, v, quant, token, positions, tables, active,
                  budgets, eos_ids, key):
        cache = {"k": k, "v": v, "block_tables": tables, "active": active}
        if quant is not None:
            cache["kv_quant"] = quant
        new_cache, toks, emits, info = tf.paged_decode_horizon(
            params, cache, token, positions, model_cfg, horizon=horizon,
            budgets=budgets, eos_ids=eos_ids, moe_hooks=hooks,
            temperature=temperature, rng_key=key,
        )
        return (
            new_cache["k"], new_cache["v"], new_cache.get("kv_quant"),
            toks, emits, info["expert_activation"], info["slot_counts"],
        )

    def prefill_fn(params, k, v, quant, tokens, start, valid_len, table_row):
        cache = {"k": k, "v": v, "block_tables": table_row}
        if quant is not None:
            cache["kv_quant"] = quant
        new_cache, logits, info = tf.paged_prefill_chunk(
            params, cache, tokens, start, valid_len, model_cfg, moe_hooks=hooks
        )
        return (
            new_cache["k"], new_cache["v"], new_cache.get("kv_quant"),
            logits, info["slot_counts"],
        )

    return (
        jax.jit(decode_fn, donate_argnums=(1, 2, 3)),
        jax.jit(prefill_fn, donate_argnums=(1, 2, 3)),
    )


class PagedServingEngine:
    """Serve requests against a transformer-family model bundle tree."""

    def __init__(self, cfg, params, engine_cfg: Optional[EngineConfig] = None,
                 faults: Optional[FaultPlan] = None):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"paged serving supports transformer families, got {cfg.family}"
            )
        self.cfg = cfg
        self.ecfg = engine_cfg or EngineConfig()
        self.model_cfg = cfg
        if cfg.is_moe and self.ecfg.drop_free_capacity:
            self.model_cfg = dataclasses.replace(
                cfg,
                moe_capacity_factor=float(
                    max(cfg.moe_capacity_factor, cfg.num_experts)
                ),
            )
        if self.ecfg.preempt_mode not in ("swap", "recompute"):
            raise ValueError(
                f"preempt_mode must be 'swap' or 'recompute', "
                f"got {self.ecfg.preempt_mode!r}"
            )
        if self.ecfg.decode_horizon < 1:
            raise ValueError(
                f"decode_horizon must be ≥ 1, got {self.ecfg.decode_horizon}"
            )
        if self.ecfg.temperature < 0.0:
            raise ValueError(
                f"temperature must be ≥ 0, got {self.ecfg.temperature}"
            )
        if self.ecfg.policy not in VALID_POLICIES:
            raise ValueError(
                f"policy must be one of {VALID_POLICIES}, "
                f"got {self.ecfg.policy!r}"
            )
        if (
            self.ecfg.ttft_budget_steps is not None
            and self.ecfg.ttft_budget_steps < 0
        ):
            raise ValueError(
                f"ttft_budget_steps must be ≥ 0, "
                f"got {self.ecfg.ttft_budget_steps}"
            )
        if self.ecfg.ttft_budget_s is not None and self.ecfg.ttft_budget_s < 0:
            raise ValueError(
                f"ttft_budget_s must be ≥ 0, got {self.ecfg.ttft_budget_s}"
            )
        if self.ecfg.livelock_steps < 1:
            raise ValueError(
                f"livelock_steps must be ≥ 1, got {self.ecfg.livelock_steps}"
            )
        # fault plane: the plan is mutable/unhashable, so it rides next
        # to the frozen EngineConfig rather than inside it
        self.faults = faults
        cfg = self.model_cfg
        # metrics + tracer come first: every downstream component
        # (offload, cache, scheduler) records through the tracer, and the
        # metrics consume its lifecycle stream. The consumer holds a
        # *getter* so callers that reset ``engine.metrics`` (benchmark
        # warmups) keep feeding the live instance.
        self.metrics = ServingMetrics()
        self.tracer = SpanTracer(
            self.ecfg.trace_level,
            consumers=(MetricsConsumer(lambda: self.metrics),),
        )
        self.offload = None
        if self.ecfg.resident_experts is not None:
            blocks = params.get("blocks") if isinstance(params, dict) else None
            if not isinstance(blocks, dict) or "moe_ce" not in blocks:
                raise ValueError(
                    "resident_experts requires PMQ-compressed params "
                    "(a stacked 'moe_ce' entry in params['blocks'])"
                )
            from .offload import ExpertOffloadManager

            self.offload = ExpertOffloadManager(
                blocks["moe_ce"],
                resident_slots=self.ecfg.resident_experts,
                ema_decay=self.ecfg.prefetch_ema,
                tracer=self.tracer,
                faults=faults,
                degrade=self.ecfg.degrade_experts,
                max_retries=self.ecfg.upload_max_retries,
                offload_dir=self.ecfg.offload_dir,
                host_budget_bytes=self.ecfg.host_expert_bytes,
            )
            params = dict(params, blocks=dict(blocks, moe_ce=self.offload.ce))
        elif self.ecfg.async_offload or self.ecfg.offload_dir is not None:
            raise ValueError(
                "async_offload/offload_dir require resident_experts "
                "(there is no expert streaming to overlap or tier)"
            )
        # async expert streaming: upload_experts plan targets deferred
        # from the boundary to right after the next program dispatch
        self._pending_expert_targets: Tuple = ()
        self.params = params
        self.cache = PagedKVCache.create(
            cfg,
            num_blocks=self.ecfg.num_blocks,
            block_size=self.ecfg.block_size,
            max_slots=self.ecfg.max_slots,
            max_blocks_per_slot=self.ecfg.max_blocks_per_slot,
            kv_bits=self.ecfg.kv_bits,
            prefix_cache=self.ecfg.prefix_cache,
        )
        self.cache.set_tracer(self.tracer)
        self.cache.faults = faults
        self.scheduler = Scheduler(
            self.cache, reserve_full=self.ecfg.reserve_full,
            horizon=self.ecfg.decode_horizon, tracer=self.tracer,
            policy=self.ecfg.policy,
            tenant_weights=(
                dict(self.ecfg.tenant_weights)
                if self.ecfg.tenant_weights is not None else None
            ),
        )
        # one declarative controller owns slots, pages, and resident
        # experts: each boundary it observes, reconciles against the
        # policy's target state, and emits the plan _execute_plan runs
        self.controller = ResourceController(
            self.scheduler, offload=self.offload, tracer=self.tracer,
            ttft_budget_steps=self.ecfg.ttft_budget_steps,
            ttft_budget_s=self.ecfg.ttft_budget_s,
            faults=faults,
        )
        self.results: Dict[int, List[int]] = {}
        # rid → the typed ServingFault a request terminated with; its
        # results[rid] entry holds whatever tokens it emitted before
        self.errors: Dict[int, ServingFault] = {}
        self._cancel_requests: set = set()
        self._no_progress = 0
        # injectable wall clock (watchdog tests swap in a fake); the
        # watchdog itself is a HeartbeatTable over the single "megastep"
        # host, beaten at each megastep's start and checked at its end
        self._clock = time.time
        self._watchdog = None
        if self.ecfg.watchdog_timeout_s is not None:
            from ..runtime.fault_tolerance import HeartbeatTable

            self._watchdog = HeartbeatTable(
                ["megastep"], timeout=float(self.ecfg.watchdog_timeout_s),
            )
        self._step_idx = 0  # logical decode steps completed
        self._megastep_idx = 0  # fused megasteps run (sampling-key index)
        # two independent key streams off sample_seed: decode megasteps
        # fold in the megastep index, prefill first-token draws fold in
        # the request id (admission-order independent, replay stable)
        base = jax.random.PRNGKey(self.ecfg.sample_seed)
        self._sample_key = jax.random.fold_in(base, 0)
        self._prefill_key = jax.random.fold_in(base, 1)
        self._last_run_stats: Dict[str, float] = {}
        # PMQ trees report per-slot dispatch counts; the capacity gauge
        # needs the slot total to turn them into a utilization fraction
        blocks = params.get("blocks") if isinstance(params, dict) else None
        self._num_slots = (
            blocks["moe_ce"].num_slots
            if isinstance(blocks, dict) and "moe_ce" in blocks else None
        )
        # expert-routing telemetry: per-(layer, slot) dispatch histograms
        # + drift/Gini gauges + the bit-misallocation report, fed from
        # the slot_counts every jitted program already reports. PMQ trees
        # only (slot_counts has trailing dim 0 otherwise), and only when
        # tracing is on — disabled tracing must cost nothing.
        self._ce_meta = (
            blocks["moe_ce"].meta
            if isinstance(blocks, dict) and "moe_ce" in blocks else None
        )
        self.routing = (
            ExpertRoutingTelemetry()
            if self.tracer.enabled and self._num_slots else None
        )
        self._decode, self._prefill = _jitted_steps(
            self.model_cfg, self.ecfg.use_otp, self.ecfg.ffn_backend,
            self.ecfg.decode_horizon, float(self.ecfg.temperature),
        )

    # ----------------------------------------------------- observability
    def routing_report(self) -> Optional[Dict]:
        """Bit-misallocation report: observed per-(layer, expert-slot)
        dispatch frequency joined against the PMQ bit assignment (see
        :meth:`repro.serving.trace.ExpertRoutingTelemetry
        .bit_misallocation_report`). ``None`` unless the model is
        PMQ-compressed and tracing collected routing traffic."""
        if self.routing is None or self._ce_meta is None:
            return None
        degraded = None
        if self.offload is not None and self.offload.degraded:
            degraded = {
                k: to_bits for k, (_, to_bits) in self.offload.degraded.items()
            }
        return self.routing.bit_misallocation_report(
            self._ce_meta, degraded=degraded
        )

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        req.arrival_s = time.time()
        self.scheduler.submit(req, self._step_idx)

    def cancel(self, rid: int) -> bool:
        """Request cancellation of a live request. Marked immediately;
        applied at the next safe point — the next megastep boundary, or
        between prefill chunks if the request is mid-prefill — where its
        slot, pages, and prefix-cache refs are released atomically and
        ``errors[rid]`` records a :class:`RequestCancelled`. Returns
        whether ``rid`` was live (waiting or active) when called."""
        live = {r.rid for r in self.scheduler.waiting}
        live.update(r.rid for r in self.scheduler.active.values())
        if rid not in live:
            return False
        self._cancel_requests.add(rid)
        return True

    def serve(self, requests: Iterable[Request]) -> Dict[int, List[int]]:
        """Submit + run; returns outputs for *this* batch only (``run``'s
        ``results`` keep accumulating across calls on a live engine)."""
        reqs = list(requests)
        for r in reqs:
            self.submit(r)
        self.run()
        return {r.rid: self.results[r.rid] for r in reqs}

    # -------------------------------------------------------------- loop
    def run(self) -> Dict[int, List[int]]:
        """Drive admission + growth + decode until queue and slots drain."""
        while self.step():
            pass
        return dict(self.results)

    def step(self) -> bool:
        """One engine round (megastep boundary): reconcile resources —
        the controller observes the pools, computes the target state,
        and emits the convergence plan this engine executes (grow /
        preempt page tables horizon-ahead, admit or shed waiters,
        upload experts) — then advance every active slot up to
        ``decode_horizon`` tokens in one fused jitted program. Returns
        whether work remains — the simulation harness drives this
        directly to interleave arrivals with decode.

        The fault plane hooks in here: the boundary advances the
        :class:`FaultPlan`'s logical step, applies pending cancellations
        and expired deadlines (typed per-request termination with an
        atomic release), and runs the watchdog + livelock guards that
        fail the whole engine closed (:meth:`_fail_closed`) rather than
        hang or serve silently corrupted state.
        """
        if self.faults is not None:
            self.faults.at_step(self._step_idx)
        self._apply_cancellations()
        self._apply_deadlines()
        if not self.scheduler.has_work():
            return False
        progress0 = (
            self._step_idx,
            sum(len(v) for v in self.results.values()),
        )
        try:
            self._converge()
            if not self.scheduler.active:
                if not self.scheduler.waiting:
                    return False
                if self.controller.last_pool_penalty <= 0:
                    held = (
                        self.cache.prefix.pages_held
                        if self.cache.prefix is not None else frozenset()
                    )
                    if not held:
                        # unreachable for pools that admit the largest
                        # request (submit guards that); kept as a thrash
                        # circuit-breaker
                        head = self.scheduler.waiting[0]
                        raise PoolExhausted(
                            f"request {head.rid} needs "
                            f"{self.cache.blocks_needed(head.context_tokens)} "
                            f"blocks but cannot be admitted "
                            f"({self.cache.allocator.num_free} free)"
                        )
                    # blocked head on an otherwise idle pool: the prefix
                    # cache is pure optimization, and the hit-entry
                    # protect set can pin pages the eviction walk will
                    # never reclaim — drop the cache and retry admission
                    # next boundary instead of declaring exhaustion
                    self.cache.clear_prefix_cache()
                # no megastep this boundary (transient pool pressure or a
                # just-cleared cache), but fall through to the no-progress
                # accounting — a *persistent* stall must eventually fail
                # closed as a livelock, not spin forever
            else:
                t_start = self._clock()
                if self._watchdog is not None:
                    self._watchdog.beat("megastep", now=t_start)
                self._decode_megastep()
                if self._watchdog is not None and self._watchdog.failed(
                    now=self._clock()
                ):
                    raise WatchdogTimeout(
                        f"megastep exceeded the "
                        f"{self.ecfg.watchdog_timeout_s}s watchdog budget"
                    )
        except (ExpertUploadFailed, WatchdogTimeout) as exc:
            self._fail_closed(exc)
        progress1 = (
            self._step_idx,
            sum(len(v) for v in self.results.values()),
        )
        if self.scheduler.has_work() and progress1 == progress0:
            self._no_progress += 1
            if self._no_progress >= self.ecfg.livelock_steps:
                self._fail_closed(LivelockDetected(
                    f"{self._no_progress} consecutive megastep boundaries "
                    f"with work but no progress"
                ))
        else:
            self._no_progress = 0
        return self.scheduler.has_work()

    # --------------------------------------------------- typed termination
    def _terminate(self, req: Request, exc: ServingFault, kind: str) -> None:
        """Terminate one request with a typed error: release every
        resource it holds atomically (slot, pages, prefix-cache refs,
        swap image), record its partial output and the error, and emit
        the lifecycle event. The released pool passes check_consistency
        — a terminated request can never leak pages or refcounts."""
        track = f"slot{req.slot}" if req.slot >= 0 else "queue"
        self.scheduler.cancel_release(req)
        self._cancel_requests.discard(req.rid)
        self.errors[req.rid] = exc
        self.results[req.rid] = req.out
        self.tracer.lifecycle(
            kind, track=track, rid=req.rid, step=self._step_idx,
            tokens=len(req.out),
        )
        self.tracer.flow("f", req.rid, track=track)

    def _find_live(self, rid: int) -> Optional[Request]:
        for r in self.scheduler.active.values():
            if r.rid == rid:
                return r
        return self._find_waiting(rid)

    def _apply_cancellations(self) -> None:
        for rid in sorted(self._cancel_requests):
            req = self._find_live(rid)
            if req is None:
                self._cancel_requests.discard(rid)
                continue
            self._terminate(
                req, RequestCancelled(f"request {rid} cancelled", rid=rid),
                "cancel",
            )

    def _apply_deadlines(self) -> None:
        live = list(self.scheduler.active.values())
        live.extend(self.scheduler.waiting)
        for req in live:
            if req.deadline_steps is None:
                continue
            if self._step_idx - req.submit_step >= req.deadline_steps:
                self._terminate(
                    req,
                    DeadlineExceeded(
                        f"request {req.rid} missed its "
                        f"{req.deadline_steps}-step deadline",
                        rid=req.rid,
                    ),
                    "deadline",
                )

    def _fail_closed(self, exc: ServingFault) -> None:
        """Engine-level fatal: terminate *every* live request with the
        typed error, releasing all slots, pages, and prefix refs so the
        pool drains clean (check_consistency passes, zero leaks), then
        re-raise. Never hang, never serve silent corruption."""
        live = list(self.scheduler.active.values())
        live.extend(self.scheduler.waiting)
        for req in live:
            self.scheduler.cancel_release(req)
            self.errors[req.rid] = exc
            self.results[req.rid] = req.out
        self._cancel_requests.clear()
        self.tracer.lifecycle(
            "fail_closed", track="engine", step=self._step_idx,
            error=type(exc).__name__, requests=len(live),
        )
        raise exc

    # ----------------------------------------------------- reconciliation
    def _converge(self) -> None:
        """One reconciliation pass at a megastep boundary: the controller
        observes the pools, diffs against the policy's target state, and
        this engine executes the convergence plan in order. All
        admit/preempt/grow/evict/upload decisions live in the plan; the
        executors below only carry them out (and emit the lifecycle
        events every action must flow through)."""
        if self.offload is not None and self.ecfg.async_offload:
            # flip the double buffer first: staged expert uploads from
            # the megastep that just ran either commit (buffers, tables
            # and maps swap together) or drop as stale — before the
            # controller observes residency to plan this boundary
            committed, dropped, nbytes, wait_s = self.offload.commit_async()
            if committed or dropped:
                self.metrics.record_async_commit(
                    committed, dropped, nbytes, wait_s
                )
        plan = self.controller.plan_boundary(self._step_idx, time.time())
        self._execute_plan(plan)

    def _execute_plan(self, plan: List[PlanAction]) -> None:
        for action in plan:
            kind = action.kind
            if kind == "admit":
                self._execute_admit(action)
            elif kind == "preempt":
                self._execute_preempt(action)
            elif kind == "grow":
                self._execute_grow(action)
            elif kind == "evict_prefix":
                if self.cache.prefix is not None:
                    self.cache.prefix.evict_for(
                        action.pages, frozenset(action.protect)
                    )
            elif kind == "shed":
                self._execute_shed(action)
            elif kind == "upload_experts":
                if self.ecfg.async_offload:
                    # defer: issued right after the next program
                    # dispatch (overlapped with its compute), committed
                    # at the next boundary — one-boundary-stale targets,
                    # which placement-invariance makes safe
                    self._pending_expert_targets = action.targets
                else:
                    t0 = time.time()
                    uploads, nbytes = self.offload.apply_residency(
                        action.targets
                    )
                    if uploads:
                        self.metrics.record_expert_prefetch(uploads, nbytes)
                        # the boundary blocked on this upload — the
                        # stall async streaming exists to hide
                        self.metrics.record_upload_stall(time.time() - t0)
            else:
                raise ValueError(f"unknown plan action kind {kind!r}")

    def _find_waiting(self, rid: int) -> Optional[Request]:
        for r in self.scheduler.waiting:
            if r.rid == rid:
                return r
        return None

    # --------------------------------------------------------- admission
    def _execute_admit(self, action: PlanAction) -> None:
        req = self._find_waiting(action.rid)
        if req is None:
            return  # defensive: the planner plans each waiter once
        active_before = len(self.scheduler.active)
        # sample the depth before admit_planned removes the request, so
        # the recorded value counts the request being admitted (the
        # depth the admission decision actually saw)
        depth_before = self.scheduler.queue_depth
        wait_steps = self._step_idx - req.submit_step
        req = self.scheduler.admit_planned(req, self._step_idx)
        if req is None:
            return  # plan/pool divergence: drop the step, stay queued
        track = f"slot{req.slot}"
        # lifecycle events feed the metrics consumer *and* (when
        # tracing is on) the event log; the flow hop stitches the
        # request's journey from the queue track onto its slot track
        self.tracer.lifecycle(
            "admit", track=track, rid=req.rid, slot=req.slot,
            step=self._step_idx, active_before=active_before,
            queue_depth=depth_before, resumed=req.preempt_count > 0,
            tenant=req.tenant, priority=req.priority,
            wait_steps=wait_steps,
        )
        self.tracer.flow("t", req.rid, track=track)
        if self.cache.prefix is not None and req.preempt_count == 0:
            # every fresh admission is a cache probe: hit/miss + the
            # prefill tokens the shared pages saved (full hits also
            # skip the first-token logits dispatch entirely)
            if req.cached_tokens > 0:
                self.tracer.lifecycle(
                    "prefix_hit", track=track, rid=req.rid,
                    tokens_saved=req.cached_tokens,
                    full=req.cached_logits is not None,
                )
            else:
                self.tracer.lifecycle(
                    "prefix_miss", track=track, rid=req.rid,
                )
        try:
            if req.swapped is not None:  # swap-restore a preempted slot
                try:
                    nbytes = self.cache.swap_in(
                        req.slot, req.swapped, rid=req.rid
                    )
                except SwapFault:
                    # corrupted/failed swap payload: discard it and fall
                    # back to recompute re-prefill — bit-exact, so the
                    # recovery is invisible to outputs
                    self.tracer.lifecycle(
                        "swap_fallback", track=track, rid=req.rid,
                        site="swap_in",
                    )
                    req.swapped = None
                    self._prefill_request(req, resume=True)
                else:
                    self.tracer.lifecycle(
                        "swap_in", track=track, rid=req.rid, slot=req.slot,
                        nbytes=nbytes,
                    )
                    req.swapped = None
            elif req.pos > 0:  # recompute-restore: re-prefill the context
                self._prefill_request(req, resume=True)
            else:
                t0 = time.time()
                self._prefill_request(req)
                now = time.time()
                self.metrics.record_ttft(
                    now - req.arrival_s, now - t0, tenant=req.tenant
                )
                self.results[req.rid] = req.out
        except (RequestCancelled, PoisonedRequest) as exc:
            # per-request faults mid-prefill terminate exactly this
            # request; any KV it wrote dies with its released pages
            self._terminate(
                req, exc,
                "cancel" if isinstance(exc, RequestCancelled) else "poisoned",
            )
            return
        if req.done:  # max_new == 1: first token is the only token
            slot = req.slot
            self.scheduler.finish(slot)
            self.tracer.lifecycle(
                "release", track=track, rid=req.rid, slot=slot,
                step=self._step_idx,
            )
            self.tracer.flow("f", req.rid, track=track)

    def _execute_shed(self, action: PlanAction) -> None:
        req = self._find_waiting(action.rid)
        if req is None:
            return
        self.scheduler.shed(req, self._step_idx)
        self.results[req.rid] = []  # served nothing, honestly
        self.tracer.lifecycle(
            "shed", track="queue", rid=req.rid, step=self._step_idx,
            tenant=req.tenant, priority=req.priority,
            wait_steps=action.waited_steps,
        )
        # the request's journey ends on the queue track — it never
        # reached a slot
        self.tracer.flow("f", req.rid, track="queue")

    def _prefill_request(self, req: Request, resume: bool = False) -> None:
        """Stream a context through chunked prefill into the slot's pages.

        Fresh requests prefill the prompt and emit the first token
        (TTFT). ``resume=True`` rebuilds a recompute-mode preempted slot:
        the context is ``prompt + out[:-1]`` (everything already written
        to KV before eviction) and the final chunk's logits are discarded
        — they re-predict the already-known ``out[-1]``.

        **Shared-prefix fast path.** A fresh request admitted through a
        prefix-cache hit starts prefill at ``req.cached_tokens`` — the
        shared/COW pages already hold that prefix's KV, bit-identical to
        what the skipped chunks would have written. A *full*-prompt hit
        carries the registration-time final-token logits
        (``req.cached_logits``) and dispatches **zero** prefill programs.
        Afterwards the freshly prefilled prompt registers its own
        page-boundary prefixes (+ final logits) back into the cache.
        """
        if resume:
            seq = np.concatenate(
                [req.prompt, np.asarray(req.out[:-1], np.int32)]
            )
            assert len(seq) == req.pos, (len(seq), req.pos)
        else:
            seq = req.prompt
        p_len = len(seq)
        c = self.ecfg.prefill_chunk
        track = f"slot{req.slot}"
        off0 = 0 if resume else min(req.cached_tokens, p_len)
        if not resume and req.cached_logits is not None and off0 >= p_len:
            last = np.asarray(req.cached_logits)
        else:
            assert off0 < p_len, (off0, p_len)  # scheduler demotes no-logits full hits
            table_row = jnp.asarray(
                self.cache.block_tables[req.slot : req.slot + 1]
            )
            logits = None
            for off in range(off0, p_len, c):
                if req.rid in self._cancel_requests:
                    # mid-prefill cancellation: stop streaming chunks
                    # now; the caller releases the slot (and any KV
                    # already written dies with the pages)
                    raise RequestCancelled(
                        f"request {req.rid} cancelled mid-prefill",
                        rid=req.rid,
                    )
                n = min(c, p_len - off)
                chunk = np.zeros((1, c), np.int32)
                chunk[0, :n] = seq[off : off + n]
                args = (
                    jnp.asarray(chunk), jnp.int32(off), jnp.int32(n),
                    table_row,
                )
                t0 = self.tracer.now_us()
                logits, counts = self._run_offloaded(
                    self._prefill, args, kind="prefill", track=track
                )
                self.metrics.record_prefill_runs(self._last_run_stats["runs"])
                self.tracer.complete(
                    "prefill_chunk", track=track, cat="prefill", start_us=t0,
                    args={"rid": req.rid, "offset": off, "tokens": n,
                          "resume": resume,
                          "runs": int(self._last_run_stats["runs"])},
                )
                self._record_capacity_util(counts, c)
            if resume:
                return
            jax.block_until_ready(logits)
            last = np.asarray(logits)[0, -1]
        if self.faults is not None:
            spec = self.faults.fire("logits", req.rid)
            if spec is not None:
                self.tracer.lifecycle(
                    "fault", track=track, site="logits", mode=spec.mode,
                    rid=req.rid,
                )
                last = np.array(last, copy=True)
                last[0] = np.nan
        # finite guard: non-finite first-token logits (a poisoned
        # request) must never reach sampling or the prefix cache — the
        # request terminates with a typed error and a clean release
        if not np.all(np.isfinite(last)):
            raise PoisonedRequest(
                f"request {req.rid}: non-finite prefill logits",
                rid=req.rid,
            )
        self.cache.register_prefix(req.prompt, req.slot, last_logits=last)
        if self.ecfg.temperature > 0.0:
            # the TTFT token is sampled too — same categorical draw the
            # horizon scan applies to every later token
            tok = int(jax.random.categorical(
                jax.random.fold_in(self._prefill_key, req.rid),
                jnp.asarray(last) / jnp.float32(self.ecfg.temperature),
            ))
        else:
            tok = int(np.argmax(last))
        req.out.append(tok)
        req.pos = p_len
        # the TTFT token is tenant output too — without this the
        # per-tenant ledger undercounts every request by exactly one
        self.metrics.record_tenant_tokens(req.tenant, 1)
        self.tracer.instant(
            "first_token", track=track, cat="prefill", rid=req.rid, token=tok
        )

    # --------------------------------------------------- expert residency
    def _run_offloaded(self, program, args, kind: str = "decode",
                       track: str = "engine"):
        """Run one jitted program (prefill chunk or decode megastep)
        under the expert-residency contract: re-run after a synchronous
        upload until every expert the program actually dispatched to was
        resident *during* the run — only then are its outputs (and KV
        writes, which land at position-determined destinations and carry
        a deterministic token sequence, so a replay simply overwrites
        them with identical values) identical to the all-resident
        engine. Returns ``(*payload, counts)`` — everything the program
        emitted after the donated pools, with the trailing dispatch
        counts already fetched to host numpy (this fetch is the
        megastep's one host sync). ``self._last_run_stats`` records the
        run count and the compute/offload wall-time split: the first run
        is pure decode/prefill math, everything after it (uploads +
        replays) is offload overhead that used to conflate into the
        latency metric.
        """
        if self.offload is not None:
            self.offload.begin_step()
        missed = False
        runs = 0
        compute_s = 0.0
        offload_s = 0.0
        while True:
            t0 = time.time()
            t0_us = self.tracer.now_us()
            out = program(
                self.params, self.cache.k, self.cache.v, self.cache.quant,
                *args,
            )
            self.cache.k, self.cache.v = out[0], out[1]
            if out[2] is not None:  # quantized pools: scale/zero tables
                self.cache.quant = out[2]
            payload = out[3:-1]
            if runs == 0 and self._pending_expert_targets:
                # async expert streaming: the program is dispatched but
                # its counts not yet fetched — stage the boundary's
                # prefetch uploads now so the copies land while it
                # computes; the flip happens at the next boundary
                targets = self._pending_expert_targets
                self._pending_expert_targets = ()
                ti = time.time()
                ups, _ = self.offload.issue_async(targets)
                if ups:
                    self.metrics.record_async_issue(ups, time.time() - ti)
            # the one host sync: dispatch counts ([L, num_slots] for a
            # prefill chunk, [H, L, num_slots] for a decode megastep;
            # trailing dim 0 outside PMQ) — fetched for the offload miss
            # check and the capacity-utilization gauge
            counts = np.asarray(out[-1])
            runs += 1
            dt = time.time() - t0
            # run 1 is the program's real math; every later run is a
            # miss replay — the compute-vs-offload split, visible per run
            self.tracer.complete(
                "compute" if runs == 1 else "replay", track=track,
                cat=kind, start_us=t0_us, args={"run": runs},
            )
            if runs == 1:
                compute_s = dt
            else:
                offload_s += dt
            if self.offload is None:
                self._last_run_stats = {
                    "runs": runs, "compute_s": compute_s,
                    "offload_s": offload_s,
                }
                return payload + (counts,)
            t1 = time.time()
            # ensure_resident normalizes [L,S] and [H,L,S] itself
            uploads, nbytes = self.offload.ensure_resident(counts)
            if uploads == 0:
                if missed:
                    self.metrics.record_expert_miss_step()
                else:
                    self.metrics.record_expert_hit()
                self.offload.update_stats(counts)
                self._last_run_stats = {
                    "runs": runs, "compute_s": compute_s,
                    "offload_s": offload_s + (time.time() - t1),
                }
                return payload + (counts,)
            missed = True
            offload_s += time.time() - t1
            self.metrics.record_expert_miss(uploads, nbytes)

    def _record_capacity_util(self, counts: np.ndarray, t: int) -> None:
        """Feed the MoE capacity-padding gauge from one logical step's
        reported ``slot_counts`` ([L, num_slots]): routed (token, choice)
        pairs over the dispatch buffer's total capacity rows
        (``L · num_slots · cap`` for the ``t`` tokens the program ran).
        The complement is the dead-padding compute the grouped FFN path
        skips (see serving.metrics)."""
        if self._num_slots is None or counts is None or counts.size == 0:
            return
        from ..models.moe import dispatch_capacity

        cap = dispatch_capacity(self.model_cfg, t)
        denom = counts.shape[0] * self._num_slots * cap
        # slot_counts are pre-clip dispatch counts; clamp to cap so pairs
        # dropped by capacity (possible with drop_free_capacity=False)
        # don't push the occupied-row gauge past 1.0
        occupied = np.minimum(counts, cap).sum()
        self.metrics.record_capacity_utilization(
            float(occupied) / float(denom)
        )
        if self.routing is not None:
            gauges = self.routing.update(counts)
            if gauges:
                self.tracer.counter("routing", track="engine", **gauges)

    # ---------------------------------------------------- growth/preempt
    def _note_preempt(self, vreq: Request, vslot: int, *, for_rid: int,
                      for_tenant: str) -> None:
        """Lifecycle bookkeeping for one executed preemption."""
        vtrack = f"slot{vslot}"
        self.tracer.lifecycle(
            "preempt", track=vtrack, rid=vreq.rid, slot=vslot,
            step=self._step_idx, mode=self.ecfg.preempt_mode,
            swap_bytes=vreq.swapped.nbytes if vreq.swapped else 0,
            tenant=vreq.tenant, for_rid=for_rid, for_tenant=for_tenant,
        )
        self.tracer.flow("t", vreq.rid, track=vtrack)

    def _execute_preempt(self, action: PlanAction) -> None:
        vreq = self.scheduler.active.get(action.slot)
        if vreq is None or vreq.rid != action.rid:
            return  # defensive: plan victims are live actives
        swap = self.ecfg.preempt_mode == "swap"
        vreq = self.scheduler.preempt(action.slot, swap=swap)
        self._note_preempt(
            vreq, action.slot, for_rid=action.for_rid,
            for_tenant=action.for_tenant,
        )

    def _execute_grow(self, action: PlanAction) -> None:
        """Grow one active slot **horizon-ahead**: enough pages to
        cover all ``min(H, budget)`` KV writes of the coming megastep,
        so no write inside the fused scan can land on an unallocated
        page — growth, like every pool-pressure decision, happens only
        at megastep boundaries.

        The controller's page ledger simulates allocator + prefix-cache
        state exactly, so by the time a grow executes its pages are
        available (planned preemptions and prefix evictions ran
        earlier in the plan). The reactive loop below is a safety net
        for ledger/pool divergence only — it falls back to the
        historical policy-ordered preemption rather than crashing.
        """
        slot = action.slot
        req = self.scheduler.active.get(slot)
        if req is None or req.rid != action.rid:
            return  # the grower itself was victimized earlier in the plan
        need = self.cache.slot_deficit(
            slot, req.pos + req.next_decode_writes(self.ecfg.decode_horizon)
        )
        if need <= 0:
            return
        swap = self.ecfg.preempt_mode == "swap"
        # LRU-evictable prefix-cache pages count as available —
        # cache.grow evicts entries before preemption ever triggers
        while (
            self.cache.available_pages() < need
            and slot in self.scheduler.active
        ):
            vslot = self.scheduler.pick_victim()
            vreq = self.scheduler.preempt(vslot, swap=swap)
            self._note_preempt(
                vreq, vslot, for_rid=req.rid, for_tenant=req.tenant
            )
        if slot in self.scheduler.active:
            self.cache.grow(slot, need)

    # ------------------------------------------------------------ decode
    def _decode_megastep(self) -> None:
        """Advance every active slot up to ``decode_horizon`` tokens in
        one fused jitted program, then apply the fetched ``[H, slots]``
        token matrix host-side: one dispatch, one host sync, one Python
        pass per megastep. Per-logical-step metrics are reconstructed
        from the emit mask (exact) and the megastep wall time (spread
        evenly — see serving.metrics)."""
        b = self.ecfg.max_slots
        h = self.ecfg.decode_horizon
        tokens = np.zeros((b, 1), np.int32)
        positions = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        budgets = np.zeros((b,), np.int32)
        eos_ids = np.full((b,), -1, np.int32)
        for slot, req in self.scheduler.active.items():
            tokens[slot, 0] = req.out[-1]
            positions[slot] = req.pos
            active[slot] = True
            budgets[slot] = req.max_new - len(req.out)
            eos_ids[slot] = req.eos_id
        # one key per megastep (unused under greedy): offload replays of
        # the same megastep reuse it, so sampled runs replay bit-identically
        key = None
        if self.ecfg.temperature > 0.0:
            key = jax.random.fold_in(self._sample_key, self._megastep_idx)
        t0 = time.time()
        t0_us = self.tracer.now_us()
        toks, emits, acts, counts = self._run_offloaded(
            self._decode,
            (jnp.asarray(tokens), jnp.asarray(positions),
             self.cache.tables_device(), jnp.asarray(active),
             jnp.asarray(budgets), jnp.asarray(eos_ids), key),
        )
        toks = np.asarray(toks)          # [H, B] (-1 where not emitted)
        emits = np.asarray(emits)        # [H, B] bool
        acts = np.asarray(acts)          # [H]
        dt = time.time() - t0
        stats = self._last_run_stats
        # logical steps that emitted ≥ 1 token; trailing all-stopped scan
        # steps computed garbage and recorded nothing
        emitting = np.flatnonzero(emits.any(axis=1))
        steps_run = len(emitting)
        self.metrics.record_megastep(
            steps_run, stats["compute_s"], stats["offload_s"],
            stats["runs"], stats["runs"],
        )
        # the megastep span (engine track) plus one decode span per
        # active slot, all sharing the megastep's extent — the per-slot
        # view shows who actually emitted inside the fused program
        self.tracer.complete(
            "megastep", track="engine", cat="decode", start_us=t0_us,
            args={"megastep": self._megastep_idx, "horizon": h,
                  "active": int(active.sum()), "steps": steps_run,
                  "runs": int(stats["runs"])},
        )
        for slot, req in self.scheduler.active.items():
            self.tracer.complete(
                "decode", track=f"slot{slot}", cat="decode", start_us=t0_us,
                args={"rid": req.rid, "tokens": int(emits[:, slot].sum())},
            )
        self.tracer.counter(
            "pool", track="engine",
            page_util=self.cache.utilization,
            queue_depth=self.scheduler.queue_depth,
            active=int(active.sum()),
        )
        per_step_s = dt / max(steps_run, 1)
        for s in emitting:
            # queue depth / page utilization are genuinely constant
            # within a megastep (all scheduling happens at the boundary)
            self.metrics.record_decode_step(
                per_step_s, int(emits[s].sum()), float(acts[s]),
                self.scheduler.queue_depth,
                page_utilization=self.cache.utilization,
            )
            self._record_capacity_util(counts[s], b)
        if self.offload is not None:
            self.metrics.record_expert_residency(self.offload.resident_bytes)
        for slot, req in list(self.scheduler.active.items()):
            last_s = 0
            emitted = 0
            for s in range(h):
                if emits[s, slot]:
                    req.out.append(int(toks[s, slot]))
                    req.pos += 1
                    last_s = s
                    emitted += 1
            # fairness accounting: debit the tenant's WDRR grant and
            # record the per-tenant token counters (policy witnesses)
            self.scheduler.note_tokens(req.tenant, emitted)
            self.metrics.record_tenant_tokens(req.tenant, emitted)
            if req.done:
                self.scheduler.finish(slot)
                track = f"slot{slot}"
                self.tracer.lifecycle(
                    "release", track=track, rid=req.rid, slot=slot,
                    step=self._step_idx + last_s,
                )
                self.tracer.flow("f", req.rid, track=track)
        self._step_idx += steps_run
        self._megastep_idx += 1
