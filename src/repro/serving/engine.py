"""Continuous-batching decode engine over the paged KV pool.

The engine owns two jitted programs, both with static shapes so they
compile exactly once each:

* **prefill chunk** — one request's prompt streams through
  :func:`repro.models.transformer.paged_prefill_chunk` in fixed-size
  chunks, writing K/V straight into the request's pages (no dense
  [L,B,S,…] cache, no per-wave re-prefill). The final chunk's logits give
  the first generated token — the TTFT event.
* **decode step** — all ``max_slots`` slots advance one token through
  :func:`repro.models.transformer.paged_decode_step`; slots decode at
  different logical lengths via per-slot positions, inactive slots are
  masked from K/V writes. The pool arrays are donated, so the multi-GB
  cache is updated in place.

Between steps the (host-side) :class:`repro.serving.scheduler.Scheduler`
admits queued requests into freed slots — continuous batching with no
wave barrier and no dummy padding. The model path is the standard bundle
tree, including PMQ-compressed experts (``moe_ce`` buckets, paper §3.2)
and OTP deterministic decode masks (§3.4 τ→0 argmax) when present; the
per-step expert-activation rate lands in
:class:`repro.serving.metrics.ServingMetrics`.

**Dynamic page growth + preemption.** Admission reserves pages for the
prompt only; before each decode step the engine grows every active
slot's block table to cover its next write position (oldest admission
first). When the pool runs dry, the youngest-admitted / least-progress
request is preempted — its pages are swapped to a host backing store
(``preempt_mode="swap"``) or dropped (``"recompute"``) — and it rejoins
the FCFS queue at the head. On re-admission the engine swap-restores the
pages or re-prefills ``prompt + out[:-1]``; greedy outputs are
bit-identical either way for any pool that admits the largest single
request (fuzzed in ``tests/test_serving_sim.py``). Block tables keep
their static ``[max_slots, max_blocks_per_slot]`` shape throughout —
growth only fills in rows between jitted steps, so nothing recompiles.

**Host-offloaded expert buckets.** With ``resident_experts`` set (PMQ
params only), cold expert rows live in host memory
(:class:`repro.serving.offload.ExpertOffloadManager`) and the jitted
programs read a budget-shaped resident partition. Between steps the
engine prefetches the router-stats-EMA-hottest experts alongside
``_ensure_pages``; because routing happens inside the jitted step, a
**miss** is only observable afterwards — the engine then uploads the
missing experts synchronously and replays the program (KV writes land
at position-determined destinations, so the replay overwrites them with
correct values). Greedy outputs are therefore bit-identical to the
all-resident engine for any budget that holds the per-step working set
(fuzzed in ``tests/test_offload.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tf
from .kvcache import PagedKVCache, PoolExhausted
from .metrics import ServingMetrics
from .scheduler import Request, Scheduler

__all__ = ["EngineConfig", "PagedServingEngine", "dense_greedy_reference"]


def dense_greedy_reference(cfg, params, prompt: np.ndarray, max_new: int):
    """Greedy decode through the *dense* cache — the equivalence oracle
    for the paged engine (tests and examples assert paged == dense).

    Returns ``(tokens, per_step_logits)`` where ``per_step_logits[i]`` is
    the last-token logits [V] that produced ``tokens[i]``. Run it with the
    engine's ``model_cfg`` so both sides use drop-free expert capacity.
    """
    from ..models.registry import get_model

    bundle = get_model(cfg)
    cache, logits = bundle.prefill(params, {"tokens": jnp.asarray(prompt[None])})
    # the prefill cache covers exactly the prompt; extend for decode
    pad = ((0, 0), (0, 0), (0, max_new), (0, 0), (0, 0))
    cache = dict(cache, k=jnp.pad(cache["k"], pad), v=jnp.pad(cache["v"], pad))
    toks, steps = [], [np.asarray(logits[0, -1])]
    cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    toks.append(int(cur[0, 0]))
    for step in range(max_new - 1):
        cache, logits = bundle.decode_step(
            params, cache, cur, jnp.int32(len(prompt) + step)
        )
        steps.append(np.asarray(logits[0, -1]))
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(cur[0, 0]))
    return toks, steps


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    block_size: int = 16
    num_blocks: int = 64
    max_blocks_per_slot: int = 8
    prefill_chunk: int = 16
    use_otp: bool = True  # OTP decode masks when the model carries them
    # Preempted-request restore path: "swap" moves victim KV pages to a
    # host backing store and uploads them back at re-admission (bit-exact,
    # costs PCIe/host bandwidth); "recompute" drops the pages and
    # re-prefills prompt + generated-so-far (costs FLOPs, no host memory).
    preempt_mode: str = "swap"
    # True restores the PR-1 admission policy: reserve prompt + max_new
    # pages up front so growth/preemption never trigger — the baseline leg
    # of the --pool-blocks pressure sweeps.
    reserve_full: bool = False
    # Serving must be batch-composition independent: a request's tokens
    # cannot change because of who it was co-scheduled with (continuous
    # batching reshuffles neighbors every step) nor how its prompt was
    # chunked. Expert capacity is therefore raised to the drop-free bound
    # (cap ≥ tokens·top_k ⇔ capacity_factor ≥ num_experts) inside the
    # engine's jitted steps.
    drop_free_capacity: bool = True
    # Per-layer device budget (in permuted expert slots) for PMQ buckets;
    # None keeps every bucket fully resident. Requires compressed params
    # ("moe_ce" in the stacked block tree). Cold rows live in host memory
    # and are prefetched by a router-stats EMA; misses replay the step.
    resident_experts: Optional[int] = None
    # EMA decay of the per-(layer, slot) dispatch counts driving prefetch.
    prefetch_ema: float = 0.8
    # Compressed expert-FFN implementation inside the jitted programs:
    # "grouped" (default — bucket-at-a-time grouped GEMM, Pallas moe_gmm
    # on TPU / jnp oracle on CPU), "scan" (legacy per-expert scan, the
    # A/B baseline), "ref"/"interpret" (grouped layout, forced kernel
    # backend). Trace-time static: changing it costs one retrace, using
    # it never retraces. None = repro.core.compressed_moe default.
    ffn_backend: Optional[str] = None


@functools.lru_cache(maxsize=None)
def _jitted_steps(model_cfg, use_otp: bool, ffn_backend: Optional[str] = None):
    """Compiled decode/prefill step builders, shared across engines with
    the same (hashable, frozen) model config — jit caching then dedupes
    by array shapes, so two engines differing only in pool geometry cost
    one trace each, not one per instance."""
    hooks = {"use_otp": use_otp, "ffn_backend": ffn_backend}

    def decode_fn(params, k, v, token, positions, tables, active):
        cache = {"k": k, "v": v, "block_tables": tables, "active": active}
        new_cache, logits, info = tf.paged_decode_step(
            params, cache, token, positions, model_cfg, moe_hooks=hooks
        )
        return (
            new_cache["k"], new_cache["v"], logits,
            info["expert_activation"], info["slot_counts"],
        )

    def prefill_fn(params, k, v, tokens, start, valid_len, table_row):
        cache = {"k": k, "v": v, "block_tables": table_row}
        new_cache, logits, info = tf.paged_prefill_chunk(
            params, cache, tokens, start, valid_len, model_cfg, moe_hooks=hooks
        )
        return new_cache["k"], new_cache["v"], logits, info["slot_counts"]

    return (
        jax.jit(decode_fn, donate_argnums=(1, 2)),
        jax.jit(prefill_fn, donate_argnums=(1, 2)),
    )


class PagedServingEngine:
    """Serve requests against a transformer-family model bundle tree."""

    def __init__(self, cfg, params, engine_cfg: Optional[EngineConfig] = None):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"paged serving supports transformer families, got {cfg.family}"
            )
        self.cfg = cfg
        self.ecfg = engine_cfg or EngineConfig()
        self.model_cfg = cfg
        if cfg.is_moe and self.ecfg.drop_free_capacity:
            self.model_cfg = dataclasses.replace(
                cfg,
                moe_capacity_factor=float(
                    max(cfg.moe_capacity_factor, cfg.num_experts)
                ),
            )
        if self.ecfg.preempt_mode not in ("swap", "recompute"):
            raise ValueError(
                f"preempt_mode must be 'swap' or 'recompute', "
                f"got {self.ecfg.preempt_mode!r}"
            )
        cfg = self.model_cfg
        self.offload = None
        if self.ecfg.resident_experts is not None:
            blocks = params.get("blocks") if isinstance(params, dict) else None
            if not isinstance(blocks, dict) or "moe_ce" not in blocks:
                raise ValueError(
                    "resident_experts requires PMQ-compressed params "
                    "(a stacked 'moe_ce' entry in params['blocks'])"
                )
            from .offload import ExpertOffloadManager

            self.offload = ExpertOffloadManager(
                blocks["moe_ce"],
                resident_slots=self.ecfg.resident_experts,
                ema_decay=self.ecfg.prefetch_ema,
            )
            params = dict(params, blocks=dict(blocks, moe_ce=self.offload.ce))
        self.params = params
        self.cache = PagedKVCache.create(
            cfg,
            num_blocks=self.ecfg.num_blocks,
            block_size=self.ecfg.block_size,
            max_slots=self.ecfg.max_slots,
            max_blocks_per_slot=self.ecfg.max_blocks_per_slot,
        )
        self.scheduler = Scheduler(self.cache, reserve_full=self.ecfg.reserve_full)
        self.metrics = ServingMetrics()
        self.results: Dict[int, List[int]] = {}
        self._step_idx = 0
        self._last_activation = None  # set by _run_offloaded (decode only)
        self._last_slot_counts = None  # [L, num_slots] of the last program
        # PMQ trees report per-slot dispatch counts; the capacity gauge
        # needs the slot total to turn them into a utilization fraction
        blocks = params.get("blocks") if isinstance(params, dict) else None
        self._num_slots = (
            blocks["moe_ce"].num_slots
            if isinstance(blocks, dict) and "moe_ce" in blocks else None
        )
        self._decode, self._prefill = _jitted_steps(
            self.model_cfg, self.ecfg.use_otp, self.ecfg.ffn_backend
        )

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        req.arrival_s = time.time()
        self.scheduler.submit(req, self._step_idx)

    def serve(self, requests: Iterable[Request]) -> Dict[int, List[int]]:
        """Submit + run; returns outputs for *this* batch only (``run``'s
        ``results`` keep accumulating across calls on a live engine)."""
        reqs = list(requests)
        for r in reqs:
            self.submit(r)
        self.run()
        return {r.rid: self.results[r.rid] for r in reqs}

    # -------------------------------------------------------------- loop
    def run(self) -> Dict[int, List[int]]:
        """Drive admission + growth + decode until queue and slots drain."""
        while self.step():
            pass
        return dict(self.results)

    def step(self) -> bool:
        """One engine round: admit what fits, grow/preempt page tables,
        decode every active slot one token. Returns whether work remains —
        the simulation harness drives this directly to interleave
        arrivals with decode steps.
        """
        if not self.scheduler.has_work():
            return False
        self._admit_all()
        self._ensure_pages()
        self._prefetch_experts()
        if not self.scheduler.active:
            if self.scheduler.waiting:
                # unreachable for pools that admit the largest request
                # (submit guards that); kept as a thrash circuit-breaker
                head = self.scheduler.waiting[0]
                raise PoolExhausted(
                    f"request {head.rid} needs "
                    f"{self.cache.blocks_needed(head.context_tokens)} blocks "
                    f"but cannot be admitted "
                    f"({self.cache.allocator.num_free} free)"
                )
            return False
        self._decode_once()
        return self.scheduler.has_work()

    # --------------------------------------------------------- admission
    def _admit_all(self) -> None:
        while True:
            active_before = len(self.scheduler.active)
            # sample the depth before try_admit pops the queue head, so the
            # recorded value counts the request being admitted (the depth
            # the admission decision actually saw)
            depth_before = self.scheduler.queue_depth
            req = self.scheduler.try_admit(self._step_idx)
            if req is None:
                return
            self.metrics.record_admission(
                req.rid, req.slot, self._step_idx, active_before,
                depth_before, resumed=req.preempt_count > 0,
            )
            if req.swapped is not None:  # swap-restore a preempted slot
                self.metrics.record_swap_in(
                    self.cache.swap_in(req.slot, req.swapped)
                )
                req.swapped = None
            elif req.pos > 0:  # recompute-restore: re-prefill the context
                self._prefill_request(req, resume=True)
            else:
                t0 = time.time()
                self._prefill_request(req)
                now = time.time()
                self.metrics.record_ttft(now - req.arrival_s, now - t0)
                self.results[req.rid] = req.out
            if req.done:  # max_new == 1: first token is the only token
                self.scheduler.finish(req.slot)
                self.metrics.record_release(req.rid, req.slot, self._step_idx)

    def _prefill_request(self, req: Request, resume: bool = False) -> None:
        """Stream a context through chunked prefill into the slot's pages.

        Fresh requests prefill the prompt and emit the first token
        (TTFT). ``resume=True`` rebuilds a recompute-mode preempted slot:
        the context is ``prompt + out[:-1]`` (everything already written
        to KV before eviction) and the final chunk's logits are discarded
        — they re-predict the already-known ``out[-1]``.
        """
        if resume:
            seq = np.concatenate(
                [req.prompt, np.asarray(req.out[:-1], np.int32)]
            )
            assert len(seq) == req.pos, (len(seq), req.pos)
        else:
            seq = req.prompt
        p_len = len(seq)
        c = self.ecfg.prefill_chunk
        table_row = jnp.asarray(self.cache.block_tables[req.slot : req.slot + 1])
        logits = None
        for off in range(0, p_len, c):
            n = min(c, p_len - off)
            chunk = np.zeros((1, c), np.int32)
            chunk[0, :n] = seq[off : off + n]
            args = (jnp.asarray(chunk), jnp.int32(off), jnp.int32(n), table_row)
            logits = self._run_offloaded(self._prefill, args)
            self._record_capacity_util(c)
        if resume:
            return
        jax.block_until_ready(logits)
        req.out.append(int(np.argmax(np.asarray(logits)[0, -1])))
        req.pos = p_len

    # --------------------------------------------------- expert residency
    def _run_offloaded(self, program, args, *, is_decode: bool = False):
        """Run one jitted program (prefill chunk or decode step) under the
        expert-residency contract: re-run after a synchronous upload until
        every expert the program actually dispatched to was resident
        *during* the run — only then are its outputs (and KV writes,
        which land at position-determined destinations and are simply
        overwritten by a replay) identical to the all-resident engine.
        Returns the program's logits; extra outputs are consumed here
        (``is_decode`` marks the decode program, whose 4th output is the
        expert-activation scalar).
        """
        if self.offload is not None:
            self.offload.begin_step()
        missed = False
        while True:
            out = program(self.params, self.cache.k, self.cache.v, *args)
            self.cache.k, self.cache.v = out[0], out[1]
            logits = out[2]
            self._last_activation = out[3] if is_decode else None
            # [L, num_slots] dispatch counts ([L, 0] outside PMQ): kept
            # for the capacity-utilization gauge even without offload
            self._last_slot_counts = np.asarray(out[-1])
            if self.offload is None:
                return logits
            counts = self._last_slot_counts
            uploads, nbytes = self.offload.ensure_resident(counts)
            if uploads == 0:
                if missed:
                    self.metrics.record_expert_miss_step()
                else:
                    self.metrics.record_expert_hit()
                self.offload.update_stats(counts)
                return logits
            missed = True
            self.metrics.record_expert_miss(uploads, nbytes)

    def _record_capacity_util(self, t: int) -> None:
        """Feed the MoE capacity-padding gauge from the step's reported
        ``slot_counts``: routed (token, choice) pairs over the dispatch
        buffer's total capacity rows (``L · num_slots · cap`` for the
        ``t`` tokens the program ran). The complement is the dead-padding
        compute the grouped FFN path skips (see serving.metrics)."""
        counts = self._last_slot_counts
        if self._num_slots is None or counts is None or counts.size == 0:
            return
        from ..models.moe import dispatch_capacity

        cap = dispatch_capacity(self.model_cfg, t)
        denom = counts.shape[0] * self._num_slots * cap
        # slot_counts are pre-clip dispatch counts; clamp to cap so pairs
        # dropped by capacity (possible with drop_free_capacity=False)
        # don't push the occupied-row gauge past 1.0
        occupied = np.minimum(counts, cap).sum()
        self.metrics.record_capacity_utilization(
            float(occupied) / float(denom)
        )

    def _prefetch_experts(self) -> None:
        """Upload the EMA-hottest experts ahead of the next decode step —
        the residency twin of ``_ensure_pages`` (issue: router-stats
        prefetch between steps; misses inside the step replay)."""
        if self.offload is None:
            return
        uploads, nbytes = self.offload.prefetch()
        if uploads:
            self.metrics.record_expert_prefetch(uploads, nbytes)

    # ---------------------------------------------------- growth/preempt
    def _ensure_pages(self) -> None:
        """Grow every active slot to cover its next decode write.

        Oldest admission first, so the eldest request always wins the
        page contest; on exhaustion the scheduler preempts the youngest
        (possibly the grower itself — then it simply stops running and
        rejoins at the queue head). ``reserve_full`` engines never need
        growth: admission already covered ``prompt + max_new``.
        """
        swap = self.ecfg.preempt_mode == "swap"
        for slot, req in sorted(
            self.scheduler.active.items(), key=lambda kv: kv[1].admit_seq
        ):
            if slot not in self.scheduler.active:
                continue  # preempted earlier in this pass
            need = (
                self.cache.blocks_needed(req.pos + 1)
                - len(self.cache.slot_blocks[slot])
            )
            if need <= 0:
                continue
            while (
                self.cache.allocator.num_free < need
                and slot in self.scheduler.active
            ):
                vslot = self.scheduler.pick_victim()
                vreq = self.scheduler.preempt(vslot, swap=swap)
                self.metrics.record_preemption(
                    vreq.rid, vslot, self._step_idx, self.ecfg.preempt_mode,
                    swap_bytes=vreq.swapped.nbytes if vreq.swapped else 0,
                )
            if slot in self.scheduler.active:
                self.cache.grow(slot, need)

    # ------------------------------------------------------------ decode
    def _decode_once(self) -> None:
        b = self.ecfg.max_slots
        tokens = np.zeros((b, 1), np.int32)
        positions = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for slot, req in self.scheduler.active.items():
            tokens[slot, 0] = req.out[-1]
            positions[slot] = req.pos
            active[slot] = True
        t0 = time.time()
        logits = self._run_offloaded(
            self._decode,
            (jnp.asarray(tokens), jnp.asarray(positions),
             self.cache.tables_device(), jnp.asarray(active)),
            is_decode=True,
        )
        jax.block_until_ready(logits)
        dt = time.time() - t0
        self._record_capacity_util(b)
        self.metrics.record_decode_step(
            dt, int(active.sum()), float(self._last_activation),
            self.scheduler.queue_depth,
            page_utilization=self.cache.utilization,
        )
        if self.offload is not None:
            self.metrics.record_expert_residency(self.offload.resident_bytes)
        logits_np = np.asarray(logits)
        for slot, req in list(self.scheduler.active.items()):
            req.out.append(int(np.argmax(logits_np[slot, -1])))
            req.pos += 1
            if req.done:
                self.scheduler.finish(slot)
                self.metrics.record_release(req.rid, slot, self._step_idx)
        self._step_idx += 1
