"""Admission queue + continuous batching over paged-KV slots.

No wave barrier and no dummy padding (contrast
:class:`repro.launch.serve.BatchedServer`): a request is admitted the
moment a slot *and* enough KV pages are free, joins the running batch at
the next decode step, and frees its pages the step it finishes — the
engine never waits for the slowest request of a wave.

Admission only needs **prompt-sized** pages (``reserve_full=False``, the
default): decode pages are granted on demand via
:meth:`repro.serving.kvcache.PagedKVCache.grow`, so the pool can be
sized far below the worst-case ``Σ (prompt + max_new)``. When growth
hits an empty free list the engine **preempts** a victim instead of
failing: the victim is swapped out (or dropped for re-prefill) and
re-queued **at the head** of the queue, so it is the first to reclaim
freed pages. ``reserve_full=True`` restores the PR-1 behavior (pages
for ``prompt + max_new`` reserved at admission, growth and preemption
never trigger) — the conservative baseline the ``--pool-blocks``
benchmark sweep compares against.

**Tenant-aware policy** (see docs/serving_scheduling.md). Every request
carries a ``tenant`` label and an integer ``priority`` class (higher =
more urgent). Three scheduling policies:

* ``fcfs`` — the historical single-tenant behavior: queue order
  admission, youngest-admitted victim. Tenant/priority are recorded but
  ignored.
* ``priority`` — admission considers higher classes first (stable
  within a class, so FCFS inside each class); preemption victimizes the
  *lowest* class first, youngest within the class.
* ``fair`` — ``priority`` ordering refined by per-tenant token-rate
  fairness: a weighted deficit round-robin over decode-token grants.
  Each megastep boundary every backlogged tenant earns
  ``weight × horizon`` grant tokens; emitting tokens debits the
  tenant's deficit; among equal-priority waiters the tenant with the
  largest deficit (most underserved relative to its weight) admits
  first.

Policies only reorder *when* requests run — per-request outputs stay
bit-identical to the dense reference under every policy (the
batch-composition-independence invariant; fuzzed in
``tests/test_serving_sim.py``).

**SLO shed.** With a TTFT budget configured, a fresh request that
cannot be admitted at a boundary *and* has already waited past the
budget is shed (removed from the queue with an empty output and a
``shed`` lifecycle event) instead of queueing unboundedly. Preempted
requests are never shed — they have tokens invested.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from .faults import InvalidRequest, SwapFault
from .kvcache import PagedKVCache, PoolExhausted, SwappedKV

__all__ = ["Request", "Scheduler", "VALID_POLICIES"]

#: scheduling policies accepted by :class:`Scheduler` / ``EngineConfig``
VALID_POLICIES = ("fcfs", "priority", "fair")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [P] int32
    max_new: int = 16
    # stop token: generation ends the step this id is emitted (the EOS
    # token itself is kept in ``out``); -1 disables. The fused decode
    # horizon folds this into its on-device per-slot stop mask.
    eos_id: int = -1
    # ---- multi-tenant policy (ignored under policy="fcfs") ----
    tenant: str = "default"
    priority: int = 0  # higher = more urgent; victim selection walks up
    # logical-step deadline: the request must finish within this many
    # megastep boundaries of submission or it terminates with
    # DeadlineExceeded (None = no deadline). Logical steps, never
    # seconds — deadline expiry replays bit-identically.
    deadline_steps: Optional[int] = None
    # ---- filled in by scheduler/engine ----
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    pos: int = 0  # next kv write position (= current logical length)
    submit_step: int = -1
    admit_step: int = -1
    admit_seq: int = -1  # monotone admission counter (victim ordering)
    shed_step: int = -1  # step the request was SLO-shed at (-1: not shed)
    preempt_count: int = 0
    swapped: Optional[SwappedKV] = None  # host KV while preempted (swap mode)
    arrival_s: float = 0.0  # wall-clock submit time (TTFT anchor)
    # ---- shared-prefix admission (set by try_admit on a cache hit) ----
    # prompt tokens whose KV the slot received from the prefix cache
    # (shared or COW-copied pages) — prefill starts at this offset
    cached_tokens: int = 0
    # final-prompt-token logits from a *full*-prompt cache hit: the
    # engine skips prefill entirely and derives the first token from
    # this array (bit-identical to what prefill would have computed)
    cached_logits: Optional[np.ndarray] = None

    @property
    def total_tokens(self) -> int:
        """KV entries the request can ever write (prompt + decode)."""
        return len(self.prompt) + self.max_new

    @property
    def context_tokens(self) -> int:
        """KV entries needed at (re-)admission: the prompt for a fresh
        request, the full generated-so-far context for a preempted one."""
        return self.pos if self.pos > 0 else len(self.prompt)

    @property
    def done(self) -> bool:
        if self.eos_id >= 0 and self.out and self.out[-1] == self.eos_id:
            return True
        return len(self.out) >= self.max_new

    def next_decode_writes(self, horizon: int) -> int:
        """KV writes the next megastep performs for this request: one per
        emitted token, capped by the horizon and the remaining emission
        budget. Fresh requests count from after their prefill token
        (``max(len(out), 1)``); the floor of 1 keeps the historical
        one-write reservation for ``max_new == 1`` requests that finish
        at prefill. An EOS may end generation earlier — the extra pages
        are simply released at finish.
        """
        budget = self.max_new - max(len(self.out), 1)
        return max(1, min(horizon, budget))


class Scheduler:
    """Pure host-side bookkeeping; the engine drives it between steps
    (megastep boundaries — with a decode horizon ``H > 1`` admission,
    growth and preemption all happen between fused H-step programs, and
    page reservations cover every KV write of the coming megastep)."""

    def __init__(self, cache: PagedKVCache, *, reserve_full: bool = False,
                 horizon: int = 1, tracer=None, policy: str = "fcfs",
                 tenant_weights: Optional[Dict[str, float]] = None):
        if horizon < 1:
            raise ValueError(f"horizon must be ≥ 1, got {horizon}")
        if policy not in VALID_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; expected one of {VALID_POLICIES}"
            )
        if tenant_weights is not None:
            for t, w in tenant_weights.items():
                if w <= 0:
                    raise ValueError(f"tenant weight for {t!r} must be > 0, got {w}")
        if tracer is None:
            from .trace import NULL_TRACER

            tracer = NULL_TRACER
        self.cache = cache
        self.reserve_full = reserve_full
        self.horizon = horizon
        self.tracer = tracer
        self.policy = policy
        self.tenant_weights = dict(tenant_weights or {})
        self.waiting: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}  # slot -> request
        self._admit_seq = 0
        # WDRR over decode-token grants (policy="fair"): tenant -> deficit.
        # Integer token counts with float weights; entries exist only for
        # currently-backlogged tenants (classic DRR: an idle tenant does
        # not bank credit).
        self._deficit: Dict[str, float] = {}

    # ---------------------------------------------------------- queue
    def submit(self, req: Request, step_idx: int = 0) -> None:
        """Enqueue one request. Malformed inputs are rejected *here*
        with :class:`InvalidRequest` (a ``ValueError`` subclass) —
        typed, at submit time — instead of failing deep inside admission
        or the jitted prefill."""
        if len(req.prompt) == 0:
            raise InvalidRequest(
                f"request {req.rid}: empty prompt", rid=req.rid
            )
        if req.max_new < 1:
            raise InvalidRequest(
                f"request {req.rid}: max_new must be ≥ 1, got {req.max_new}",
                rid=req.rid,
            )
        if req.priority < 0:
            raise InvalidRequest(
                f"request {req.rid}: negative priority {req.priority}",
                rid=req.rid,
            )
        if req.deadline_steps is not None and req.deadline_steps < 1:
            raise InvalidRequest(
                f"request {req.rid}: deadline_steps must be ≥ 1, "
                f"got {req.deadline_steps}",
                rid=req.rid,
            )
        live = {r.rid for r in self.waiting}
        live.update(r.rid for r in self.active.values())
        if req.rid in live:
            raise InvalidRequest(
                f"request {req.rid}: rid already live", rid=req.rid
            )
        if req.total_tokens > self.cache.max_slot_tokens():
            raise ValueError(
                f"request {req.rid}: {req.total_tokens} tokens exceed the "
                f"per-slot maximum {self.cache.max_slot_tokens()} "
                f"(max_blocks_per_slot × block_size)"
            )
        if self.cache.blocks_needed(req.total_tokens) > self.cache.allocator.num_blocks:
            # growth + preemption guarantee completion only for pools that
            # admit the largest single request; reject the rest up front
            # instead of thrashing (admit → grow → self-preempt forever)
            raise PoolExhausted(
                f"request {req.rid} needs "
                f"{self.cache.blocks_needed(req.total_tokens)} blocks but the "
                f"whole pool has {self.cache.allocator.num_blocks}"
            )
        req.submit_step = step_idx
        self.waiting.append(req)
        # flow origin: the request's journey starts on the queue track and
        # is stitched to its slot tracks via per-request flow ids
        self.tracer.instant(
            "enqueue", track="queue", cat="lifecycle", rid=req.rid,
            step=step_idx, prompt_tokens=len(req.prompt),
            max_new=req.max_new, queue_depth=len(self.waiting),
        )
        self.tracer.flow("s", req.rid, track="queue")

    def growth_reserve(self) -> int:
        """Pages the current actives need for their next megastep's KV
        writes (up to ``horizon`` per slot, capped by each request's
        remaining budget).

        Admission leaves this many pages untouched so a new request never
        starves a running one into preempting it right back out — an
        admitted request is guaranteed to survive ≥ 1 megastep.
        """
        if self.reserve_full:
            return 0  # full reservation: actives never grow
        need = 0
        for slot, req in self.active.items():
            need += self.cache.slot_deficit(
                slot, req.pos + req.next_decode_writes(self.horizon)
            )
        return need

    # ------------------------------------------------- policy ordering
    def admission_order(self) -> List[Request]:
        """Waiting requests in the order the controller should consider
        them for admission this boundary.

        ``fcfs``: queue order. ``priority``: higher classes first,
        stable (FCFS within a class). ``fair``: priority classes first,
        then the tenant with the largest WDRR deficit (most underserved
        relative to its weight), then queue order — ``sorted`` is stable,
        so equal keys preserve FCFS.
        """
        waiting = list(self.waiting)
        if self.policy == "fcfs":
            return waiting
        if self.policy == "priority":
            return sorted(waiting, key=lambda r: -r.priority)
        return sorted(
            waiting,
            key=lambda r: (-r.priority, -self._deficit.get(r.tenant, 0.0)),
        )

    def refresh_grants(self) -> None:
        """WDRR grant refresh, called once per megastep boundary.

        Every *backlogged* tenant (has a waiting or active request)
        earns ``weight × horizon`` decode-token credit; idle tenants are
        dropped (no banked credit). Deficits are clamped to
        ``8 × weight × horizon`` so a tenant that is backlogged but
        unschedulable (e.g. huge requests) cannot accumulate unbounded
        claim over future boundaries.
        """
        if self.policy != "fair":
            return
        backlogged = {r.tenant for r in self.waiting}
        backlogged.update(r.tenant for r in self.active.values())
        quantum = float(self.horizon)
        for t in sorted(backlogged):
            w = self.tenant_weights.get(t, 1.0)
            d = self._deficit.get(t, 0.0) + w * quantum
            self._deficit[t] = min(d, 8.0 * w * quantum)
        for t in list(self._deficit):
            if t not in backlogged:
                del self._deficit[t]

    def note_tokens(self, tenant: str, n: int) -> None:
        """Debit ``n`` emitted decode tokens against a tenant's grant."""
        if self.policy != "fair" or n <= 0:
            return
        if tenant in self._deficit:
            self._deficit[tenant] -= float(n)

    def deficits(self) -> Dict[str, float]:
        """Snapshot of per-tenant WDRR deficits (observability)."""
        return dict(self._deficit)

    # ------------------------------------------------------- admission
    @staticmethod
    def _is_fresh(req: Request) -> bool:
        """Never admitted: no KV context, no swap image, no output."""
        return req.pos == 0 and req.swapped is None and not req.out

    def peek_prefix(self, req: Request):
        """Prefix-cache probe for a fresh request, with the full-match
        demotion rule (a full-prompt hit without cached logits is
        demoted to ``prompt[:-1]`` — at least one token must stream
        through prefill to produce first-token logits, and its KV
        rewrite must land on a private page, never a shared one).
        Mutates cache LRU/hit state; the controller's planning-time
        equivalent is the non-mutating ledger peek.
        """
        if not self._is_fresh(req):
            return None
        entry = self.cache.prefix_lookup(req.prompt)
        if (
            entry is not None
            and entry.n_tokens == len(req.prompt)
            and entry.last_logits is None
        ):
            entry = self.cache.prefix_lookup(req.prompt[:-1])
        return entry

    def admit_tokens(self, req: Request) -> int:
        """KV entries an admission must reserve pages for: context plus
        the writes of the first decode megastep (``prompt + max_new``
        under ``reserve_full``)."""
        return (
            req.total_tokens if self.reserve_full
            else req.context_tokens + req.next_decode_writes(self.horizon)
        )

    def admit_planned(self, req: Request, step_idx: int) -> Optional[Request]:
        """Admit a specific waiting request (controller plan execution).

        Re-validates against live pool state — pages for the admission
        tokens (:meth:`admit_tokens`) minus any shared-prefix pages,
        leaving :meth:`growth_reserve` headroom untouched so a new
        request never starves a running one into preempting it right
        back out. Returns ``None`` if the request no longer fits (the
        plan step is dropped; the request stays queued).

        **Shared-prefix reuse.** A fresh request (never preempted —
        resumed requests rebuild private pages, so swap-in never writes
        a shared one) probes the prefix cache first: a hit shares the
        match's page-aligned pages copy-on-write, shrinking both the
        page bill and the prefill work to the non-cached suffix.
        """
        entry = self.peek_prefix(req)
        tokens = self.admit_tokens(req)
        if not self.cache.can_admit(
            tokens, headroom=self.growth_reserve(), prefix_entry=entry
        ):
            return None
        self.waiting.remove(req)
        req.slot = self.cache.acquire_slot(
            tokens, prefix_entry=entry, rid=req.rid
        )
        if entry is not None:
            req.cached_tokens = entry.n_tokens
            req.cached_logits = (
                entry.last_logits
                if entry.n_tokens == len(req.prompt) else None
            )
        req.admit_step = step_idx
        req.admit_seq = self._admit_seq
        self._admit_seq += 1
        self.active[req.slot] = req
        return req

    def try_admit(self, step_idx: int) -> Optional[Request]:
        """Head-of-queue admission (FCFS semantics; kept for direct
        drivers and tests — the engine now admits via controller plans,
        which reduce to exactly this under ``policy="fcfs"``)."""
        if not self.waiting:
            return None
        return self.admit_planned(self.waiting[0], step_idx)

    def shed(self, req: Request, step_idx: int) -> Request:
        """SLO load-shed: remove a waiting request from the queue.

        Only fresh (never-admitted) requests are shed — preempted ones
        have decode tokens invested and always resume. The caller emits
        the ``shed`` lifecycle event and records the empty result.
        """
        if not self._is_fresh(req):
            raise ValueError(f"request {req.rid}: only fresh requests shed")
        self.waiting.remove(req)
        req.shed_step = step_idx
        return req

    # ------------------------------------------------------- preemption
    def victim_key(self, req: Request):
        """Victim ordering: ``max`` over actives picks the victim.

        ``fcfs``: the youngest admission — least progress, so eviction
        wastes the least work (``admit_seq`` is unique and monotone, so
        the oldest-admitted active is never victimized while others run
        — the page contest always has a winner, no livelock).
        ``priority``/``fair``: lowest priority class first, youngest
        within the class — a high-priority grower evicts background work
        before peers. The same no-livelock argument holds on the
        refined order: the (highest-class, oldest) active is never
        victimized while others run.
        """
        if self.policy == "fcfs":
            return (0, req.admit_seq)
        return (-req.priority, req.admit_seq)

    def pick_victim(self) -> int:
        """Deterministic policy-ordered victim (see :meth:`victim_key`)."""
        slot, _ = max(
            self.active.items(), key=lambda kv: self.victim_key(kv[1])
        )
        return slot

    def preempt(self, slot: int, *, swap: bool) -> Request:
        """Evict one active request and re-queue it at the FCFS head.

        ``swap=True`` moves its KV pages to the host backing store
        (bit-exact restore at re-admission); ``swap=False`` drops them —
        the engine re-prefills ``prompt + out[:-1]`` on resume. Either
        way the pages and the slot are free when this returns. An
        injected/real swap-out failure degrades the preemption to
        recompute mode (``swap_fallback`` lifecycle event) — recompute
        re-prefill is bit-exact, so recovery is invisible to outputs.
        """
        req = self.active.pop(slot)
        if swap:
            try:
                req.swapped = self.cache.swap_out(slot, req.pos, rid=req.rid)
            except SwapFault:
                self.tracer.lifecycle(
                    "swap_fallback", track="queue", rid=req.rid,
                    site="swap_out",
                )
                swap = False
        if not swap:
            req.swapped = None
            self.cache.release_slot(slot)
        req.slot = -1
        req.preempt_count += 1
        # a resumed request rebuilds fully private pages — drop any
        # prefix-admission state so re-prefill streams the whole context
        req.cached_tokens = 0
        req.cached_logits = None
        self.waiting.appendleft(req)
        return req

    def finish(self, slot: int) -> Request:
        """Release a finished request's slot + pages (block recycling)."""
        req = self.active.pop(slot)
        self.cache.release_slot(slot)
        return req

    def cancel_release(self, req: Request) -> None:
        """Atomically release *everything* a cancelled/errored request
        holds, wherever it is in its lifecycle: an active slot's pages
        (prefix-shared pages just drop one refcount hold — the cache and
        any co-holders are untouched), a waiting queue entry, a swap
        image, and any prefix-admission state. Safe to call on a request
        that holds nothing. The engine's cancel/deadline/fail-closed
        paths all funnel through here so a terminated request can never
        leak pages or refcounts."""
        if req.slot >= 0 and req.slot in self.active:
            if self.active[req.slot] is req:
                self.active.pop(req.slot)
                self.cache.release_slot(req.slot)
        req.slot = -1
        try:
            self.waiting.remove(req)
        except ValueError:
            pass
        req.swapped = None
        req.cached_tokens = 0
        req.cached_logits = None

    # ---------------------------------------------------------- state
    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.active)

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)
