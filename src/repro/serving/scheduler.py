"""Admission queue + continuous batching over paged-KV slots.

No wave barrier and no dummy padding (contrast
:class:`repro.launch.serve.BatchedServer`): a request is admitted the
moment a slot *and* enough KV pages are free, joins the running batch at
the next decode step, and frees its pages the step it finishes — the
engine never waits for the slowest request of a wave. Pages are reserved
up front for ``prompt + max_new`` tokens so a running request can never
hit pool exhaustion mid-flight (dynamic page growth + preemption is a
follow-on, see ROADMAP "Serving").
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from .kvcache import PagedKVCache

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [P] int32
    max_new: int = 16
    # ---- filled in by scheduler/engine ----
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    pos: int = 0  # next kv write position (= current logical length)
    submit_step: int = -1
    admit_step: int = -1
    arrival_s: float = 0.0  # wall-clock submit time (TTFT anchor)

    @property
    def total_tokens(self) -> int:
        """KV entries the request can ever write (prompt + decode)."""
        return len(self.prompt) + self.max_new

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class Scheduler:
    """Pure host-side bookkeeping; the engine drives it between steps."""

    def __init__(self, cache: PagedKVCache):
        self.cache = cache
        self.waiting: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}  # slot -> request

    # ---------------------------------------------------------- queue
    def submit(self, req: Request, step_idx: int = 0) -> None:
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be ≥ 1")
        if req.total_tokens > self.cache.max_slot_tokens():
            raise ValueError(
                f"request {req.rid}: {req.total_tokens} tokens exceed the "
                f"per-slot maximum {self.cache.max_slot_tokens()} "
                f"(max_blocks_per_slot × block_size)"
            )
        req.submit_step = step_idx
        self.waiting.append(req)

    def try_admit(self, step_idx: int) -> Optional[Request]:
        """FCFS admission: head of queue starts iff slot + pages free."""
        if not self.waiting:
            return None
        req = self.waiting[0]
        if not self.cache.can_admit(req.total_tokens):
            return None
        self.waiting.popleft()
        req.slot = self.cache.acquire_slot(req.total_tokens)
        req.admit_step = step_idx
        self.active[req.slot] = req
        return req

    def finish(self, slot: int) -> Request:
        """Release a finished request's slot + pages (block recycling)."""
        req = self.active.pop(slot)
        self.cache.release_slot(slot)
        return req

    # ---------------------------------------------------------- state
    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.active)

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)
