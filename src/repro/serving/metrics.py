"""Serving observability: TTFT, per-token latency, queue depth, expert
activation, preemption/swap traffic, page utilization.

``expert_activation`` is the fraction of the router's top-k expert slots
actually executed per decode step — 1.0 without OTP; with the §3.4
deterministic decode masks the paper's >20% activation reduction shows
up here as a sustained value ≲ 0.8. ``mid_flight_admissions`` counts
requests admitted after decoding already started — the observable
signature of continuous batching (a wave batcher would show 0: every
admission happens at step 0 of its wave). ``preemptions`` / ``swap_*``
count the dynamic-growth pressure path: victims evicted when the page
pool ran dry, and the host↔device KV bytes moved to serve them.
``page_utilization`` gauges how full the pool runs — the whole point of
on-demand growth is pushing it toward 1.0 without corruption.
``capacity_utilization`` gauges what fraction of the MoE dispatch
buffer's capacity rows carried a routed (token, choice) pair each
logical step — the dead padding ``1 - util`` is exactly the compute the
grouped expert-GEMM path skips via its ragged ``num_active`` frontier
(the per-expert scan paid for every row), so this gauge is the
serving-side witness of that win.
``expert_prefetch_*`` / ``expert_*_bytes`` / ``expert_resident_bytes``
cover host-offloaded PMQ buckets (:mod:`repro.serving.offload`): a
*hit* is a logical step (decode step or prefill chunk) whose whole
expert working set was resident on the first run, a *miss* is a step
that needed ≥ 1 replay after synchronous uploads; upload bytes split
into ahead-of-need prefetch traffic and miss traffic, and the
resident-bytes gauge tracks the device footprint the budget actually
bought.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List

import numpy as np

__all__ = ["ServingMetrics"]


def _mean(xs) -> float:
    return float(np.mean(xs)) if len(xs) else 0.0


def _p95(xs) -> float:
    return float(np.percentile(xs, 95)) if len(xs) else 0.0


@dataclasses.dataclass
class ServingMetrics:
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    prefill_s: List[float] = dataclasses.field(default_factory=list)
    decode_step_s: List[float] = dataclasses.field(default_factory=list)
    active_per_step: List[int] = dataclasses.field(default_factory=list)
    queue_depth: List[int] = dataclasses.field(default_factory=list)
    expert_activation: List[float] = dataclasses.field(default_factory=list)
    page_utilization: List[float] = dataclasses.field(default_factory=list)
    capacity_utilization: List[float] = dataclasses.field(default_factory=list)
    admissions: List[Dict] = dataclasses.field(default_factory=list)
    slot_releases: List[Dict] = dataclasses.field(default_factory=list)
    preemptions: List[Dict] = dataclasses.field(default_factory=list)
    swap_out_bytes: int = 0
    swap_in_bytes: int = 0
    # host-offloaded expert buckets (repro.serving.offload)
    expert_prefetch_hits: int = 0
    expert_prefetch_misses: int = 0
    expert_miss_uploads: int = 0
    expert_prefetch_uploads: int = 0
    expert_miss_bytes: int = 0
    expert_prefetch_bytes: int = 0
    expert_resident_bytes: List[int] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------ record
    def record_admission(
        self, rid: int, slot: int, step_idx: int, active_before: int,
        queue_depth: int, resumed: bool = False,
    ) -> None:
        """``queue_depth`` is the waiting-queue depth *at admission time*,
        i.e. including the request being admitted (the engine samples it
        before the scheduler pops the queue head)."""
        self.admissions.append(
            {"rid": rid, "slot": slot, "step": step_idx,
             "active_before": active_before, "queue_depth": queue_depth,
             "resumed": resumed}
        )

    def record_ttft(self, seconds: float, prefill_seconds: float) -> None:
        self.ttft_s.append(seconds)
        self.prefill_s.append(prefill_seconds)

    def record_decode_step(
        self, seconds: float, n_active: int, expert_activation: float,
        queue_depth: int, page_utilization: float = 0.0,
    ) -> None:
        self.decode_step_s.append(seconds)
        self.active_per_step.append(n_active)
        self.expert_activation.append(expert_activation)
        self.queue_depth.append(queue_depth)
        self.page_utilization.append(page_utilization)

    def record_capacity_utilization(self, frac: float) -> None:
        """Routed (token, choice) pairs ÷ total expert capacity rows for
        one logical step (decode step or prefill chunk) — derived from
        the jitted program's reported ``slot_counts``, so it is
        deterministic per trace."""
        self.capacity_utilization.append(float(frac))

    def record_release(self, rid: int, slot: int, step_idx: int) -> None:
        self.slot_releases.append({"rid": rid, "slot": slot, "step": step_idx})

    def record_preemption(
        self, rid: int, slot: int, step_idx: int, mode: str,
        swap_bytes: int = 0,
    ) -> None:
        self.preemptions.append(
            {"rid": rid, "slot": slot, "step": step_idx, "mode": mode,
             "swap_bytes": swap_bytes}
        )
        self.swap_out_bytes += swap_bytes

    def record_swap_in(self, nbytes: int) -> None:
        self.swap_in_bytes += nbytes

    def record_expert_hit(self) -> None:
        """One logical step (decode step / prefill chunk) found its whole
        working set resident on the first run — no replay."""
        self.expert_prefetch_hits += 1

    def record_expert_miss_step(self) -> None:
        """One logical step needed ≥ 1 replay before accepting."""
        self.expert_prefetch_misses += 1

    def record_expert_miss(self, uploads: int, nbytes: int) -> None:
        """One replay's synchronous uploads (``uploads`` expert rows);
        the owning step is counted once via :meth:`record_expert_miss_step`."""
        self.expert_miss_uploads += uploads
        self.expert_miss_bytes += nbytes

    def record_expert_prefetch(self, uploads: int, nbytes: int) -> None:
        """Ahead-of-need uploads driven by the router-stats EMA."""
        self.expert_prefetch_uploads += uploads
        self.expert_prefetch_bytes += nbytes

    def record_expert_residency(self, nbytes: int) -> None:
        self.expert_resident_bytes.append(int(nbytes))

    # ----------------------------------------------------------- derived
    @property
    def mid_flight_admissions(self) -> int:
        """Admissions into a batch that was already decoding (turnover).

        Resumed re-admissions of preempted requests are excluded — they
        are pressure artifacts, not the continuous-batching signature
        this metric exists to surface.
        """
        return sum(
            1 for a in self.admissions
            if a["step"] > 0 and a["active_before"] > 0
            and not a.get("resumed")
        )

    @property
    def expert_hit_rate(self) -> float:
        """Fraction of logical steps served without any replay."""
        total = self.expert_prefetch_hits + self.expert_prefetch_misses
        return self.expert_prefetch_hits / total if total else 1.0

    @property
    def expert_upload_bytes(self) -> int:
        """Total host→device expert traffic (prefetch + miss)."""
        return self.expert_miss_bytes + self.expert_prefetch_bytes

    def counters(self) -> Dict:
        """The wall-clock-free slice of the metrics: identical traces on
        identical engines must produce *identical* counters (the
        deterministic-replay test asserts dict equality on this)."""
        return {
            "admissions": list(self.admissions),
            "slot_releases": list(self.slot_releases),
            "preemptions": list(self.preemptions),
            "swap_out_bytes": self.swap_out_bytes,
            "swap_in_bytes": self.swap_in_bytes,
            "active_per_step": list(self.active_per_step),
            "queue_depth": list(self.queue_depth),
            "page_utilization": list(self.page_utilization),
            "capacity_utilization": list(self.capacity_utilization),
            "generated_tokens": int(np.sum(self.active_per_step)) if self.active_per_step else 0,
            "expert_prefetch_hits": self.expert_prefetch_hits,
            "expert_prefetch_misses": self.expert_prefetch_misses,
            "expert_miss_uploads": self.expert_miss_uploads,
            "expert_prefetch_uploads": self.expert_prefetch_uploads,
            "expert_miss_bytes": self.expert_miss_bytes,
            "expert_prefetch_bytes": self.expert_prefetch_bytes,
            "expert_resident_bytes": list(self.expert_resident_bytes),
        }

    def summary(self) -> Dict[str, float]:
        total_decode = float(np.sum(self.decode_step_s)) if self.decode_step_s else 0.0
        gen_tokens = int(np.sum(self.active_per_step)) if self.active_per_step else 0
        return {
            "requests": len(self.ttft_s),
            "ttft_mean_s": _mean(self.ttft_s),
            "ttft_p95_s": _p95(self.ttft_s),
            "prefill_mean_s": _mean(self.prefill_s),
            "decode_step_mean_s": _mean(self.decode_step_s),
            "decode_step_p95_s": _p95(self.decode_step_s),
            # only *active* slots count as generated tokens — no dummy
            # padding inflates throughput here
            "tokens_per_s": gen_tokens / total_decode if total_decode else 0.0,
            "generated_tokens": gen_tokens,
            "queue_depth_mean": _mean(self.queue_depth),
            "queue_depth_max": float(max(self.queue_depth)) if self.queue_depth else 0.0,
            "expert_activation_mean": _mean(self.expert_activation),
            "mid_flight_admissions": self.mid_flight_admissions,
            "slot_releases": len(self.slot_releases),
            "preemptions": len(self.preemptions),
            "swap_out_bytes": int(self.swap_out_bytes),
            "swap_in_bytes": int(self.swap_in_bytes),
            "swap_bytes": int(self.swap_out_bytes + self.swap_in_bytes),
            "page_util_mean": _mean(self.page_utilization),
            "page_util_p95": _p95(self.page_utilization),
            "capacity_util_mean": _mean(self.capacity_utilization),
            "capacity_util_p95": _p95(self.capacity_utilization),
            "expert_hit_rate": self.expert_hit_rate,
            "expert_prefetch_misses": int(self.expert_prefetch_misses),
            "expert_miss_uploads": int(self.expert_miss_uploads),
            "expert_prefetch_uploads": int(self.expert_prefetch_uploads),
            "expert_miss_bytes": int(self.expert_miss_bytes),
            "expert_prefetch_bytes": int(self.expert_prefetch_bytes),
            "expert_upload_bytes": int(self.expert_upload_bytes),
            "expert_resident_bytes_last": (
                int(self.expert_resident_bytes[-1])
                if self.expert_resident_bytes else 0
            ),
        }

    def to_json(self) -> str:
        return json.dumps(self.summary(), sort_keys=True)
