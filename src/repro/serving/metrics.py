"""Serving observability: TTFT, per-token latency, queue depth, expert
activation, preemption/swap traffic, page utilization.

``expert_activation`` is the fraction of the router's top-k expert slots
actually executed per decode step — 1.0 without OTP; with the §3.4
deterministic decode masks the paper's >20% activation reduction shows
up here as a sustained value ≲ 0.8. ``mid_flight_admissions`` counts
requests admitted after decoding already started — the observable
signature of continuous batching (a wave batcher would show 0: every
admission happens at step 0 of its wave). ``preemptions`` / ``swap_*``
count the dynamic-growth pressure path: victims evicted when the page
pool ran dry, and the host↔device KV bytes moved to serve them.
``page_utilization`` gauges how full the pool runs — the whole point of
on-demand growth is pushing it toward 1.0 without corruption.
``capacity_utilization`` gauges what fraction of the MoE dispatch
buffer's capacity rows carried a routed (token, choice) pair each
logical step — the dead padding ``1 - util`` is exactly the compute the
grouped expert-GEMM path skips via its ragged ``num_active`` frontier
(the per-expert scan paid for every row), so this gauge is the
serving-side witness of that win.
``expert_prefetch_*`` / ``expert_*_bytes`` / ``expert_resident_bytes``
cover host-offloaded PMQ buckets (:mod:`repro.serving.offload`): a
*hit* is a logical program (decode megastep or prefill chunk) whose
whole expert working set was resident on the first run, a *miss* is one
that needed ≥ 1 replay after synchronous uploads; upload bytes split
into ahead-of-need prefetch traffic and miss traffic, and the
resident-bytes gauge tracks the device footprint the budget actually
bought.

**Megastep reconstruction.** With a fused decode horizon the engine
syncs once per megastep, so per-*token* timing is no longer directly
observable: :meth:`record_megastep` logs each megastep's wall time
split into **compute** (the first program run — what decode math
actually costs) and **offload overhead** (synchronous miss uploads +
replays, previously indistinguishable inside the decode timer; the
split makes their share — ``decode_offload_frac`` — attributable, while
``decode_step_s``/``tokens_per_s`` deliberately remain end-to-end
wall-clock so throughput never overstates what the engine actually
served), and the engine reconstructs per-logical-step entries by
spreading the megastep wall time evenly over the steps that emitted
tokens (``active_per_step`` / ``expert_activation`` / page+capacity
gauges stay exact per logical step — they come from the device). Wall-clock seconds can never live in
:meth:`counters` (identical replays differ in time); the deterministic
witnesses of the horizon win are the **count** fields —
``decode_dispatches`` / ``decode_replays`` / ``decode_host_syncs`` per
generated token drop by ~H×.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

__all__ = ["ServingMetrics"]


def _mean(xs) -> float:
    return float(np.mean(xs)) if len(xs) else 0.0


def _p95(xs) -> float:
    return float(np.percentile(xs, 95)) if len(xs) else 0.0


@dataclasses.dataclass
class ServingMetrics:
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    prefill_s: List[float] = dataclasses.field(default_factory=list)
    decode_step_s: List[float] = dataclasses.field(default_factory=list)
    active_per_step: List[int] = dataclasses.field(default_factory=list)
    queue_depth: List[int] = dataclasses.field(default_factory=list)
    expert_activation: List[float] = dataclasses.field(default_factory=list)
    page_utilization: List[float] = dataclasses.field(default_factory=list)
    capacity_utilization: List[float] = dataclasses.field(default_factory=list)
    admissions: List[Dict] = dataclasses.field(default_factory=list)
    # re-admissions of preempted requests (kept out of ``admissions`` so
    # queue-depth / TTFT / mid-flight summaries stay honest under churn —
    # a resumed victim is pool pressure, not fresh demand)
    readmissions: List[Dict] = dataclasses.field(default_factory=list)
    slot_releases: List[Dict] = dataclasses.field(default_factory=list)
    preemptions: List[Dict] = dataclasses.field(default_factory=list)
    # SLO load-sheds: fresh requests past their TTFT budget rejected at
    # a boundary instead of queueing unboundedly
    sheds: List[Dict] = dataclasses.field(default_factory=list)
    # resource-controller reconciliation: plan count + action histogram
    plans: int = 0
    plan_actions: Dict[str, int] = dataclasses.field(default_factory=dict)
    # decode tokens emitted per tenant (fairness witness)
    tenant_tokens: Dict[str, int] = dataclasses.field(default_factory=dict)
    # wall-clock TTFT per tenant (summary only, never in counters)
    ttft_by_tenant: Dict[str, List[float]] = dataclasses.field(default_factory=dict)
    swap_out_bytes: int = 0
    swap_in_bytes: int = 0
    # host-offloaded expert buckets (repro.serving.offload)
    expert_prefetch_hits: int = 0
    expert_prefetch_misses: int = 0
    expert_miss_uploads: int = 0
    expert_prefetch_uploads: int = 0
    expert_miss_bytes: int = 0
    expert_prefetch_bytes: int = 0
    expert_resident_bytes: List[int] = dataclasses.field(default_factory=list)
    # async expert streaming (offload.issue_async/commit_async): rows
    # staged while a megastep computed, rows committed at the flip, and
    # rows dropped because a mid-flight miss/grow staled the batch — all
    # deterministic per trace. The seconds live in summary() only:
    # upload_stall_s is boundary wall time *blocked* on uploads (the
    # whole apply_residency call when synchronous, the residual
    # commit wait when async), upload_hidden_s the issue-time staging
    # cost overlapped with compute.
    uploads_overlapped: int = 0
    uploads_committed: int = 0
    uploads_dropped_stale: int = 0
    upload_stall_s: List[float] = dataclasses.field(default_factory=list)
    upload_hidden_s: List[float] = dataclasses.field(default_factory=list)
    # three-tier store (repro.serving.tierstore): per-tier fetch counts
    # and disk bytes read, fed through tier_fetch lifecycle events
    tier_host_hits: int = 0
    tier_disk_hits: int = 0
    tier_disk_bytes: int = 0
    # fused decode-horizon megasteps (one jitted dispatch + one host sync
    # covers up to H logical decode steps; replays are offload misses)
    # shared-prefix KV reuse (repro.serving.kvcache.PrefixCache): a *hit*
    # is a fresh admission whose prompt matched a cached prefix —
    # ``prefix_tokens_saved`` counts the prompt tokens it did not
    # re-prefill, ``prefix_full_hits`` the admissions that skipped
    # prefill entirely (full-prompt match, cached first-token logits) —
    # and ``cow_copies`` the partial tail pages duplicated
    # copy-on-write. Misses are fresh admissions checked against an
    # enabled cache that matched nothing.
    prefix_hits: int = 0
    prefix_misses: int = 0
    prefix_full_hits: int = 0
    prefix_tokens_saved: int = 0
    cow_copies: int = 0
    # fault plane (repro.serving.faults): injections observed per site,
    # recoveries (upload retries / swap recompute fallbacks / degraded
    # serves) and typed terminations (cancel / deadline / poisoned) —
    # all integer counts so they replay bit-identically
    fault_injected: int = 0
    faults_by_site: Dict[str, int] = dataclasses.field(default_factory=dict)
    upload_retries: int = 0
    degraded_serves: int = 0
    swap_fallbacks: int = 0
    cancelled: int = 0
    deadline_exceeded: int = 0
    poisoned: int = 0
    megasteps: int = 0
    megastep_logical_steps: List[int] = dataclasses.field(default_factory=list)
    decode_compute_s: List[float] = dataclasses.field(default_factory=list)
    decode_offload_s: List[float] = dataclasses.field(default_factory=list)
    decode_dispatches: int = 0
    decode_replays: int = 0
    decode_host_syncs: int = 0
    prefill_dispatches: int = 0
    prefill_replays: int = 0

    # ------------------------------------------------------------ record
    def record_admission(
        self, rid: int, slot: int, step_idx: int, active_before: int,
        queue_depth: int, resumed: bool = False, tenant: str = "default",
        priority: int = 0, wait_steps: int = -1,
    ) -> None:
        """``queue_depth`` is the waiting-queue depth *at admission time*,
        i.e. including the request being admitted (the engine samples it
        before the scheduler pops the queue head). ``resumed=True``
        delegates to :meth:`record_readmission` — a preempted request
        re-entering is not fresh demand and must not distort queue-depth
        or TTFT bookkeeping (its TTFT anchor stays the original
        ``arrival_s``)."""
        rec = {"rid": rid, "slot": slot, "step": step_idx,
               "active_before": active_before, "queue_depth": queue_depth,
               "resumed": resumed, "tenant": tenant, "priority": priority,
               "wait_steps": wait_steps}
        if resumed:
            self.record_readmission(rec)
        else:
            self.admissions.append(rec)

    def record_readmission(self, rec: Dict) -> None:
        """A preempted request re-acquired a slot (churn, not demand)."""
        self.readmissions.append(dict(rec, resumed=True))

    def record_shed(self, rid: int, step_idx: int, tenant: str = "default",
                    priority: int = 0, wait_steps: int = 0) -> None:
        """One fresh request rejected past its TTFT budget."""
        self.sheds.append(
            {"rid": rid, "step": step_idx, "tenant": tenant,
             "priority": priority, "wait_steps": wait_steps}
        )

    def record_plan(self, n_actions: int, **kind_counts: int) -> None:
        """One non-empty controller plan: total actions plus the
        per-kind histogram (admits/preempts/grows/…)."""
        self.plans += 1
        for k, v in kind_counts.items():
            if v:
                self.plan_actions[k] = self.plan_actions.get(k, 0) + int(v)

    def record_tenant_tokens(self, tenant: str, n: int) -> None:
        if n > 0:
            self.tenant_tokens[tenant] = self.tenant_tokens.get(tenant, 0) + int(n)

    def record_ttft(self, seconds: float, prefill_seconds: float,
                    tenant: str = "default") -> None:
        self.ttft_s.append(seconds)
        self.prefill_s.append(prefill_seconds)
        self.ttft_by_tenant.setdefault(tenant, []).append(seconds)

    def record_decode_step(
        self, seconds: float, n_active: int, expert_activation: float,
        queue_depth: int, page_utilization: Optional[float] = None,
    ) -> None:
        """``page_utilization=None`` means the caller has no pool gauge —
        the sample is skipped, not recorded as a real 0.0 (which would
        drag ``page_util_mean`` down)."""
        self.decode_step_s.append(seconds)
        self.active_per_step.append(n_active)
        self.expert_activation.append(expert_activation)
        self.queue_depth.append(queue_depth)
        if page_utilization is not None:
            self.page_utilization.append(page_utilization)

    def record_megastep(
        self, logical_steps: int, compute_s: float, offload_s: float,
        dispatches: int, syncs: int,
    ) -> None:
        """One fused decode megastep: ``logical_steps`` token-emitting
        horizon steps, timed as ``compute_s`` (first program run — pure
        decode math) + ``offload_s`` (miss uploads and replays, which
        previously conflated into the decode timer); ``dispatches``
        counts jitted program invocations including replays and
        ``syncs`` the device→host fetches. The count fields are
        deterministic per trace and land in :meth:`counters`; the
        seconds land in :meth:`summary` only."""
        self.megasteps += 1
        self.megastep_logical_steps.append(int(logical_steps))
        self.decode_compute_s.append(float(compute_s))
        self.decode_offload_s.append(float(offload_s))
        self.decode_dispatches += int(dispatches)
        self.decode_replays += int(dispatches) - 1
        self.decode_host_syncs += int(syncs)

    def record_prefill_runs(self, dispatches: int) -> None:
        """One prefill chunk's program invocations (> 1 ⇒ offload
        replays)."""
        self.prefill_dispatches += int(dispatches)
        self.prefill_replays += int(dispatches) - 1

    def record_capacity_utilization(self, frac: float) -> None:
        """Routed (token, choice) pairs ÷ total expert capacity rows for
        one logical step (decode step or prefill chunk) — derived from
        the jitted program's reported ``slot_counts``, so it is
        deterministic per trace."""
        self.capacity_utilization.append(float(frac))

    def record_release(self, rid: int, slot: int, step_idx: int) -> None:
        self.slot_releases.append({"rid": rid, "slot": slot, "step": step_idx})

    def record_preemption(
        self, rid: int, slot: int, step_idx: int, mode: str,
        swap_bytes: int = 0, tenant: str = "default", for_rid: int = -1,
        for_tenant: str = "",
    ) -> None:
        """``for_rid``/``for_tenant`` identify the beneficiary — the
        growing/admitting request the freed pages serve (cross-tenant
        preemption is visible as ``tenant != for_tenant``)."""
        self.preemptions.append(
            {"rid": rid, "slot": slot, "step": step_idx, "mode": mode,
             "swap_bytes": swap_bytes, "tenant": tenant,
             "for_rid": for_rid, "for_tenant": for_tenant}
        )
        self.swap_out_bytes += swap_bytes

    def record_swap_in(self, nbytes: int) -> None:
        self.swap_in_bytes += nbytes

    def record_expert_hit(self) -> None:
        """One logical step (decode step / prefill chunk) found its whole
        working set resident on the first run — no replay."""
        self.expert_prefetch_hits += 1

    def record_expert_miss_step(self) -> None:
        """One logical step needed ≥ 1 replay before accepting."""
        self.expert_prefetch_misses += 1

    def record_expert_miss(self, uploads: int, nbytes: int) -> None:
        """One replay's synchronous uploads (``uploads`` expert rows);
        the owning step is counted once via :meth:`record_expert_miss_step`."""
        self.expert_miss_uploads += uploads
        self.expert_miss_bytes += nbytes

    def record_expert_prefetch(self, uploads: int, nbytes: int) -> None:
        """Ahead-of-need uploads driven by the router-stats EMA."""
        self.expert_prefetch_uploads += uploads
        self.expert_prefetch_bytes += nbytes

    def record_expert_residency(self, nbytes: int) -> None:
        self.expert_resident_bytes.append(int(nbytes))

    def record_async_issue(self, uploads: int, hidden_s: float) -> None:
        """One staged (double-buffered) upload batch issued while a
        program computed: ``uploads`` rows overlapped; ``hidden_s`` is
        the host-side staging time hidden behind the dispatch."""
        self.uploads_overlapped += int(uploads)
        self.upload_hidden_s.append(float(hidden_s))

    def record_async_commit(self, committed: int, dropped: int,
                            nbytes: int, wait_s: float) -> None:
        """One boundary flip: ``committed`` staged rows swapped in (they
        count as prefetch uploads — same traffic, different timing) or
        ``dropped`` rows invalidated by a mid-flight miss/grow;
        ``wait_s`` is the residual un-hidden transfer wait."""
        self.uploads_committed += int(committed)
        self.uploads_dropped_stale += int(dropped)
        if committed:
            self.record_expert_prefetch(int(committed), int(nbytes))
        self.upload_stall_s.append(float(wait_s))

    def record_upload_stall(self, seconds: float) -> None:
        """Boundary wall time blocked on a synchronous prefetch upload
        (the whole apply_residency call). Folded into
        ``decode_offload_frac`` so the synchronous baseline's stall is
        attributable — and erasable by async overlap."""
        self.upload_stall_s.append(float(seconds))

    def record_tier_fetch(self, tier: str, nbytes: int) -> None:
        """One expert-row fetch through the tiered backing store."""
        if tier == "host":
            self.tier_host_hits += 1
        else:
            self.tier_disk_hits += 1
            self.tier_disk_bytes += int(nbytes)

    def record_prefix_hit(self, tokens_saved: int, full: bool = False) -> None:
        """One fresh admission reused a cached prefix: ``tokens_saved``
        prompt tokens skipped prefill; ``full`` means the whole prompt
        (and its first-token logits) was cached — zero prefill
        dispatches for the request."""
        self.prefix_hits += 1
        self.prefix_tokens_saved += int(tokens_saved)
        if full:
            self.prefix_full_hits += 1

    def record_prefix_miss(self) -> None:
        """One fresh admission probed an enabled prefix cache and
        matched nothing (it prefills fully, then registers)."""
        self.prefix_misses += 1

    def record_cow_copy(self) -> None:
        """One copy-on-write duplication of a shared partial tail page."""
        self.cow_copies += 1

    # ------------------------------------------------------- fault plane
    def record_fault(self, site: str) -> None:
        """One injected fault fired at ``site`` (FaultPlan.fire)."""
        self.fault_injected += 1
        self.faults_by_site[site] = self.faults_by_site.get(site, 0) + 1

    def record_upload_retry(self) -> None:
        """One expert-upload attempt repeated after a transient fault or
        checksum mismatch (the recovered attempt, not the failure)."""
        self.upload_retries += 1

    def record_degrade(self) -> None:
        """One expert row pinned to a lower rung of the PMQ precision
        ladder after its target-bit upload persistently failed."""
        self.degraded_serves += 1

    def record_swap_fallback(self) -> None:
        """One preempted request whose KV swap payload failed checksum
        or I/O and fell back to bit-exact recompute re-prefill."""
        self.swap_fallbacks += 1

    def record_cancel(self) -> None:
        """One request terminated by client ``cancel(rid)``."""
        self.cancelled += 1

    def record_deadline(self) -> None:
        """One request terminated past its ``deadline_steps``."""
        self.deadline_exceeded += 1

    def record_poisoned(self) -> None:
        """One request terminated by the non-finite logits guard."""
        self.poisoned += 1

    # ----------------------------------------------------------- derived
    @property
    def mid_flight_admissions(self) -> int:
        """Admissions into a batch that was already decoding (turnover).

        Resumed re-admissions of preempted requests are excluded — they
        are pressure artifacts, not the continuous-batching signature
        this metric exists to surface.
        """
        return sum(
            1 for a in self.admissions
            if a["step"] > 0 and a["active_before"] > 0
            and not a.get("resumed")
        )

    @property
    def expert_hit_rate(self) -> float:
        """Fraction of logical steps served without any replay."""
        total = self.expert_prefetch_hits + self.expert_prefetch_misses
        return self.expert_prefetch_hits / total if total else 1.0

    @property
    def expert_upload_bytes(self) -> int:
        """Total host→device expert traffic (prefetch + miss)."""
        return self.expert_miss_bytes + self.expert_prefetch_bytes

    def counters(self) -> Dict:
        """The wall-clock-free slice of the metrics: identical traces on
        identical engines must produce *identical* counters (the
        deterministic-replay test asserts dict equality on this)."""
        return {
            "admissions": list(self.admissions),
            "readmissions": list(self.readmissions),
            "sheds": list(self.sheds),
            "plans": self.plans,
            "plan_actions": dict(sorted(self.plan_actions.items())),
            "tenant_tokens": dict(sorted(self.tenant_tokens.items())),
            "slot_releases": list(self.slot_releases),
            "preemptions": list(self.preemptions),
            "swap_out_bytes": self.swap_out_bytes,
            "swap_in_bytes": self.swap_in_bytes,
            "active_per_step": list(self.active_per_step),
            "queue_depth": list(self.queue_depth),
            "page_utilization": list(self.page_utilization),
            "capacity_utilization": list(self.capacity_utilization),
            "generated_tokens": int(np.sum(self.active_per_step)) if self.active_per_step else 0,
            "expert_prefetch_hits": self.expert_prefetch_hits,
            "expert_prefetch_misses": self.expert_prefetch_misses,
            "expert_miss_uploads": self.expert_miss_uploads,
            "expert_prefetch_uploads": self.expert_prefetch_uploads,
            "expert_miss_bytes": self.expert_miss_bytes,
            "expert_prefetch_bytes": self.expert_prefetch_bytes,
            "expert_resident_bytes": list(self.expert_resident_bytes),
            "uploads_overlapped": self.uploads_overlapped,
            "uploads_committed": self.uploads_committed,
            "uploads_dropped_stale": self.uploads_dropped_stale,
            "tier_host_hits": self.tier_host_hits,
            "tier_disk_hits": self.tier_disk_hits,
            "tier_disk_bytes": self.tier_disk_bytes,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_full_hits": self.prefix_full_hits,
            "prefix_tokens_saved": self.prefix_tokens_saved,
            "cow_copies": self.cow_copies,
            "fault_injected": self.fault_injected,
            "faults_by_site": dict(sorted(self.faults_by_site.items())),
            "upload_retries": self.upload_retries,
            "degraded_serves": self.degraded_serves,
            "swap_fallbacks": self.swap_fallbacks,
            "cancelled": self.cancelled,
            "deadline_exceeded": self.deadline_exceeded,
            "poisoned": self.poisoned,
            "megasteps": self.megasteps,
            "megastep_logical_steps": list(self.megastep_logical_steps),
            "decode_dispatches": self.decode_dispatches,
            "decode_replays": self.decode_replays,
            "decode_host_syncs": self.decode_host_syncs,
            "prefill_dispatches": self.prefill_dispatches,
            "prefill_replays": self.prefill_replays,
        }

    def summary(self) -> Dict[str, float]:
        total_decode = float(np.sum(self.decode_step_s)) if self.decode_step_s else 0.0
        gen_tokens = int(np.sum(self.active_per_step)) if self.active_per_step else 0
        return {
            "requests": len(self.ttft_s),
            "ttft_mean_s": _mean(self.ttft_s),
            "ttft_p95_s": _p95(self.ttft_s),
            "prefill_mean_s": _mean(self.prefill_s),
            "decode_step_mean_s": _mean(self.decode_step_s),
            "decode_step_p95_s": _p95(self.decode_step_s),
            # only *active* slots count as generated tokens — no dummy
            # padding inflates throughput here; an empty run reports None
            # (distinguishable from an infinitely-amortized one)
            "tokens_per_s": (
                gen_tokens / total_decode
                if gen_tokens and total_decode else None
            ),
            "generated_tokens": gen_tokens,
            "queue_depth_mean": _mean(self.queue_depth),
            "queue_depth_max": float(max(self.queue_depth)) if self.queue_depth else 0.0,
            "expert_activation_mean": _mean(self.expert_activation),
            "mid_flight_admissions": self.mid_flight_admissions,
            "slot_releases": len(self.slot_releases),
            "preemptions": len(self.preemptions),
            "readmissions": len(self.readmissions),
            "sheds": len(self.sheds),
            "plans": int(self.plans),
            "plan_actions": dict(sorted(self.plan_actions.items())),
            "tenant_tokens": dict(sorted(self.tenant_tokens.items())),
            "ttft_p95_s_by_tenant": {
                t: _p95(xs)
                for t, xs in sorted(self.ttft_by_tenant.items())
            },
            "swap_out_bytes": int(self.swap_out_bytes),
            "swap_in_bytes": int(self.swap_in_bytes),
            "swap_bytes": int(self.swap_out_bytes + self.swap_in_bytes),
            "page_util_mean": _mean(self.page_utilization),
            "page_util_p95": _p95(self.page_utilization),
            "capacity_util_mean": _mean(self.capacity_utilization),
            "capacity_util_p95": _p95(self.capacity_utilization),
            "expert_hit_rate": self.expert_hit_rate,
            "expert_prefetch_misses": int(self.expert_prefetch_misses),
            "expert_miss_uploads": int(self.expert_miss_uploads),
            "expert_prefetch_uploads": int(self.expert_prefetch_uploads),
            "expert_miss_bytes": int(self.expert_miss_bytes),
            "expert_prefetch_bytes": int(self.expert_prefetch_bytes),
            "expert_upload_bytes": int(self.expert_upload_bytes),
            "expert_resident_bytes_last": (
                int(self.expert_resident_bytes[-1])
                if self.expert_resident_bytes else 0
            ),
            "prefix_hits": int(self.prefix_hits),
            "prefix_misses": int(self.prefix_misses),
            "prefix_full_hits": int(self.prefix_full_hits),
            "prefix_tokens_saved": int(self.prefix_tokens_saved),
            "prefix_hit_rate": (
                self.prefix_hits / (self.prefix_hits + self.prefix_misses)
                if (self.prefix_hits + self.prefix_misses) else None
            ),
            "cow_copies": int(self.cow_copies),
            "fault_injected": int(self.fault_injected),
            "upload_retries": int(self.upload_retries),
            "degraded_serves": int(self.degraded_serves),
            "swap_fallbacks": int(self.swap_fallbacks),
            "cancelled": int(self.cancelled),
            "deadline_exceeded": int(self.deadline_exceeded),
            "poisoned": int(self.poisoned),
            "megasteps": int(self.megasteps),
            "decode_compute_mean_s": _mean(self.decode_compute_s),
            "decode_offload_mean_s": _mean(self.decode_offload_s),
            # expert-streaming time a request actually waited for: miss
            # uploads + replays (decode_offload_s) plus boundary upload
            # stalls — synchronous prefetch pays the whole upload here,
            # async overlap only its residual commit wait, which is what
            # the async-offload bench leg gates on
            "decode_offload_frac": (
                (float(np.sum(self.decode_offload_s))
                 + float(np.sum(self.upload_stall_s)))
                / max(float(np.sum(self.decode_compute_s))
                      + float(np.sum(self.decode_offload_s))
                      + float(np.sum(self.upload_stall_s)), 1e-12)
                if (self.decode_compute_s or self.upload_stall_s) else 0.0
            ),
            "upload_stall_s": float(np.sum(self.upload_stall_s)),
            "upload_hidden_s": float(np.sum(self.upload_hidden_s)),
            "uploads_overlapped": int(self.uploads_overlapped),
            "uploads_committed": int(self.uploads_committed),
            "uploads_dropped_stale": int(self.uploads_dropped_stale),
            "tier_host_hits": int(self.tier_host_hits),
            "tier_disk_hits": int(self.tier_disk_hits),
            "tier_disk_bytes": int(self.tier_disk_bytes),
            "decode_dispatches": int(self.decode_dispatches),
            "decode_replays": int(self.decode_replays),
            "decode_host_syncs": int(self.decode_host_syncs),
            "prefill_dispatches": int(self.prefill_dispatches),
            "prefill_replays": int(self.prefill_replays),
            # the horizon's deterministic win: jitted dispatches and host
            # syncs per generated token drop from ~1 toward ~1/H; None
            # when nothing was generated (0.0 would read as free)
            "dispatches_per_token": (
                self.decode_dispatches / gen_tokens if gen_tokens else None
            ),
            "syncs_per_token": (
                self.decode_host_syncs / gen_tokens if gen_tokens else None
            ),
            # ... and per *logical decode step* from exactly 1 toward 1/H
            # (per-token folds in batch width; per-step isolates the
            # horizon amortization itself)
            "dispatches_per_step": (
                self.decode_dispatches
                / max(int(np.sum(self.megastep_logical_steps)), 1)
                if self.megastep_logical_steps else None
            ),
        }

    def to_json(self, include_counters: bool = False) -> str:
        """Summary as JSON; ``include_counters=True`` nests the
        wall-clock-free :meth:`counters` slice alongside it under
        ``{"summary": …, "counters": …}`` so the deterministic data is
        serializable too (the default shape is unchanged)."""
        if include_counters:
            return json.dumps(
                {"summary": self.summary(), "counters": self.counters()},
                sort_keys=True,
            )
        return json.dumps(self.summary(), sort_keys=True)
