"""repro.serving — continuous-batching decode engine for compressed MoE.

The production serving substrate around the MC# compressed model path
(PMQ bit-bucketed experts, §3.2; OTP deterministic decode masks, §3.4):

* :mod:`repro.serving.kvcache` — block-table paged KV pool (slots of
  different lengths share one preallocated pool; no per-wave re-prefill)
  with on-demand page growth and a host-memory swap store for preempted
  slots,
* :mod:`repro.serving.scheduler` — admission queue + continuous batching
  (finished requests free their blocks, queued ones join mid-flight;
  admission needs prompt-sized pages only, and under pool pressure a
  policy-ordered victim is preempted and re-queued at the head), with
  multi-tenant policy: priority classes, per-tenant weighted-deficit
  token fairness, and SLO-budgeted admission (load shedding),
* :mod:`repro.serving.controller` — the declarative resource
  controller: one reconciliation loop (observe → target → plan →
  converge) owning request slots, KV + prefix-cache pages, and
  resident expert partitions; the engine executes its bounded
  convergence plans instead of mutating the pools imperatively
  (docs/serving_scheduling.md),
* :mod:`repro.serving.engine` — fused decode-horizon megasteps (one
  jitted program advances every slot up to H tokens with on-device
  greedy/temperature sampling and per-slot stop logic — one dispatch +
  one host sync per megastep) + chunked prefill over the model bundle;
  grows block tables horizon-ahead between megasteps and swap-restores
  or re-prefills preempted slots,
* :mod:`repro.serving.metrics` — TTFT, per-token latency, queue depth,
  per-step expert-activation rate (the paper's >20% activation-reduction
  claim as an observable serving metric), preemption/swap counters,
  page-utilization gauges, and expert prefetch hit/miss + upload-byte
  counters,
* :mod:`repro.serving.offload` — host-offloaded PMQ expert buckets:
  cold quantized-expert rows live in host memory and a router-stats EMA
  prefetches the hot set onto the device (budget-shaped resident
  partitions; misses upload synchronously and replay the step),
* :mod:`repro.serving.trace` — request-lifecycle span tracer (Chrome
  trace-event / Perfetto export + deterministic JSONL whose wall-clock-
  free projection is bit-identical across replays) and expert-routing
  telemetry: per-(layer, slot) dispatch histograms, EMA-drift and Gini
  load gauges, and the bit-misallocation report joining observed routing
  frequency against the PMQ bit assignment (see docs/observability.md),
* :mod:`repro.serving.faults` — the deterministic fault plane: seeded,
  replayable :class:`FaultPlan` schedules injected at the real seams
  (expert uploads, KV swaps, page pool, logits) and the typed
  :class:`ServingFault` hierarchy backing the engine's
  bit-exact-or-typed-error contract — checksummed host payloads with
  re-fetch on mismatch, bounded upload retries that degrade down the
  PMQ precision ladder, request deadlines + cancellation, and a
  megastep watchdog / livelock guard that fails closed
  (docs/serving_robustness.md).
"""
from .controller import (
    Observation,
    PlanAction,
    ResourceController,
    TargetState,
)
from .engine import (
    EngineConfig,
    PagedServingEngine,
    quantized_greedy_reference,
)
from .faults import (
    DeadlineExceeded,
    ExpertUploadFailed,
    FaultPlan,
    FaultSpec,
    InvalidRequest,
    LivelockDetected,
    PoisonedRequest,
    RequestCancelled,
    ServingFault,
    SwapFault,
    WatchdogTimeout,
    checksum_tree,
)
from .kvcache import (
    BlockAllocator,
    PagedKVCache,
    PoolExhausted,
    PrefixCache,
    PrefixEntry,
    SwappedKV,
)
from .metrics import ServingMetrics
from .offload import ExpertOffloadManager
from .scheduler import Request, Scheduler, VALID_POLICIES
from .trace import (
    ExpertRoutingTelemetry,
    MetricsConsumer,
    SpanTracer,
    validate_chrome_trace,
    validate_events,
)

__all__ = [
    "BlockAllocator",
    "DeadlineExceeded",
    "EngineConfig",
    "ExpertOffloadManager",
    "ExpertRoutingTelemetry",
    "ExpertUploadFailed",
    "FaultPlan",
    "FaultSpec",
    "InvalidRequest",
    "LivelockDetected",
    "MetricsConsumer",
    "Observation",
    "PoisonedRequest",
    "PagedKVCache",
    "PagedServingEngine",
    "PlanAction",
    "PoolExhausted",
    "PrefixCache",
    "PrefixEntry",
    "Request",
    "RequestCancelled",
    "ResourceController",
    "quantized_greedy_reference",
    "Scheduler",
    "ServingFault",
    "ServingMetrics",
    "SpanTracer",
    "SwapFault",
    "SwappedKV",
    "TargetState",
    "VALID_POLICIES",
    "WatchdogTimeout",
    "checksum_tree",
    "validate_chrome_trace",
    "validate_events",
]
