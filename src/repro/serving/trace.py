"""Request-lifecycle tracing + expert-routing telemetry.

The serving engine has five interacting dynamic mechanisms — continuous
batching, preemption/swap, host-offloaded expert residency,
grouped-GEMM dispatch, fused decode megasteps — and flat counters
cannot attribute *why* a trace was slow (miss replays? preemption
storms? cold experts? dead capacity?). This module is the attribution
layer: a low-overhead structured :class:`SpanTracer` records typed
span/instant/counter/flow events over the full request lifecycle
(enqueue → admit → prefill chunks → decode megasteps with
compute/replay split → expert prefetch/miss uploads → page grow →
preempt/swap → release) on per-slot tracks with per-request flow IDs.

**Two exports, one contract.** Traces export as Chrome trace-event JSON
(:meth:`SpanTracer.chrome_trace` — drop the file on https://ui.perfetto.dev)
and as a JSONL event log (:meth:`SpanTracer.write_jsonl`). Every event
separates *deterministic* fields (seq, name, phase, category, track,
flow id, args — all derived from the trace being served, never from the
clock) from *wall-clock* fields (``ts_us``/``dur_us``). The
wall-clock-free projection (:meth:`SpanTracer.deterministic_events` /
``deterministic_jsonl``) of two replays of the same trace on the same
engine must be **bit-identical** — the event-stream extension of
:meth:`repro.serving.metrics.ServingMetrics.counters`' determinism
contract, asserted in ``tests/test_trace.py``.

**Levels.** ``off`` records nothing (every hook early-returns — tracing
disabled costs < 2% and changes no metric counters), ``spans`` records
lifecycle spans/instants/flows, ``full`` additionally records per-step
counter events (pool/queue gauges, routing drift/Gini) and feeds the
expert-routing telemetry.

**Metrics as a consumer.** Lifecycle facts the metrics used to
book-keep in parallel (admission, release, preemption, swap-in) now
flow through :meth:`SpanTracer.lifecycle`: consumers (the
:class:`MetricsConsumer` adapter) are dispatched *always*, even at
level ``off`` — so ``counters()`` is byte-identical with tracing on or
off — while the event record itself is gated on the level.

**Expert-routing telemetry.** :class:`ExpertRoutingTelemetry`
accumulates per-(layer, expert-slot) dispatch histograms from the
``slot_counts`` every jitted program already reports, tracks an
EMA-drift gauge (total-variation distance between each step's routing
distribution and its running EMA — routing churn the prefetcher must
chase) and a per-layer load-imbalance Gini gauge, and joins observed
routing frequency against the PMQ bit assignment in
:meth:`ExpertRoutingTelemetry.bit_misallocation_report` — the
serving-side witness of the paper's expert-significance story (MC#
§3.2 allocates static bit-widths from expert significance; MC-MoE's
activated-frequency importance and EAC-MoE's expert-selection-aware
compression hinge on exactly this observed-vs-allocated signal).
``hot_low_bit`` entries are experts whose observed dispatch share
exceeds the uniform share yet sit in the lowest-bit bucket;
``cold_high_bit`` the inverse — both are bit-reallocation candidates.

Schema validation (:func:`validate_events` /
:func:`validate_chrome_trace`) is callable as a CLI — CI runs the
serving smoke with tracing and validates every artifact::

    PYTHONPATH=src python -m repro.serving.trace results/*.trace.json
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

__all__ = [
    "TRACE_LEVELS",
    "SpanTracer",
    "NULL_TRACER",
    "MetricsConsumer",
    "ExpertRoutingTelemetry",
    "gini",
    "validate_events",
    "validate_chrome_trace",
]

TRACE_LEVELS: Tuple[str, ...] = ("off", "spans", "full")
_LEVEL = {name: i for i, name in enumerate(TRACE_LEVELS)}

# wall-clock keys — stripped by the deterministic projection, required
# (where applicable) by the schema; everything else in an event must be
# replay-deterministic
_WALL_KEYS = ("ts_us", "dur_us")
_PHASES = frozenset({"X", "i", "C", "s", "t", "f"})
_ARG_TYPES = (str, int, float, bool, type(None))


class SpanTracer:
    """Structured span/instant/counter/flow recorder for one engine.

    Events live in :attr:`events` in record order (deterministic, since
    the engine's control flow is deterministic per served trace). A
    span's event is recorded at *exit* — children therefore precede
    their parent in the buffer, which both exports tolerate (Chrome
    nests by ts/dur; the JSONL consumer has ``seq``).
    """

    def __init__(self, level: str = "off", consumers: Iterable = ()):
        if level not in _LEVEL:
            raise ValueError(
                f"trace level {level!r} not in {TRACE_LEVELS}"
            )
        self.level_name = level
        self.level = _LEVEL[level]
        self.consumers = list(consumers)
        self.events: List[Dict] = []
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------- state
    @property
    def enabled(self) -> bool:
        """Spans/instants/flows are recorded."""
        return self.level >= _LEVEL["spans"]

    @property
    def full(self) -> bool:
        """Counter events + routing telemetry are recorded too."""
        return self.level >= _LEVEL["full"]

    def reset(self) -> None:
        """Drop recorded events and re-anchor the clock (e.g. after a
        warmup pass). Consumers and level are kept."""
        self.events.clear()
        self._t0 = time.perf_counter()

    def now_us(self) -> float:
        """Wall-clock microseconds since tracer creation/reset."""
        return (time.perf_counter() - self._t0) * 1e6

    def _record(self, ev: Dict) -> None:
        ev["seq"] = len(self.events)
        self.events.append(ev)

    # ------------------------------------------------------------ record
    def complete(self, name: str, *, track: str, cat: str,
                 start_us: float, end_us: Optional[float] = None,
                 args: Optional[Dict] = None) -> None:
        """Record one complete ("X") span from an explicit start time —
        the building block for spans whose args are only known at exit
        (e.g. an upload's row/byte counts)."""
        if not self.enabled:
            return
        end = self.now_us() if end_us is None else end_us
        self._record({
            "ph": "X", "name": name, "cat": cat, "track": track,
            "args": dict(args or {}),
            "ts_us": round(start_us, 3),
            "dur_us": round(max(end - start_us, 0.0), 3),
        })

    @contextlib.contextmanager
    def span(self, name: str, *, track: str, cat: str, **args):
        """Context-managed span; recorded as one "X" event at exit."""
        if not self.enabled:
            yield
            return
        t0 = self.now_us()
        try:
            yield
        finally:
            self.complete(name, track=track, cat=cat, start_us=t0,
                          args=args)

    def instant(self, name: str, *, track: str, cat: str, **args) -> None:
        if not self.enabled:
            return
        self._record({
            "ph": "i", "name": name, "cat": cat, "track": track,
            "args": args, "ts_us": round(self.now_us(), 3),
        })

    def counter(self, name: str, *, track: str, **values) -> None:
        """Gauge samples (Chrome "C" events) — ``full`` level only."""
        if not self.full:
            return
        self._record({
            "ph": "C", "name": name, "cat": "gauge", "track": track,
            "args": {k: float(v) for k, v in values.items()},
            "ts_us": round(self.now_us(), 3),
        })

    def flow(self, phase: str, rid: int, *, track: str) -> None:
        """Per-request flow events: ``"s"`` at enqueue, ``"t"`` at every
        lifecycle hop (admit / preempt / resume), ``"f"`` at release —
        Perfetto draws the arrows that stitch one request's journey
        across queue and slot tracks."""
        if not self.enabled:
            return
        if phase not in ("s", "t", "f"):
            raise ValueError(f"flow phase must be s/t/f, got {phase!r}")
        self._record({
            "ph": phase, "name": "request", "cat": "request",
            "track": track, "id": int(rid),
            "ts_us": round(self.now_us(), 3),
        })

    def lifecycle(self, kind: str, *, track: str, **fields) -> None:
        """One structured lifecycle fact (admit / release / preempt /
        swap_in / enqueue …). Consumers are dispatched **always** —
        :class:`ServingMetrics` book-keeps through this path, so its
        deterministic counters cannot depend on the trace level — while
        the instant event is only recorded when tracing is enabled."""
        for c in self.consumers:
            c.on_lifecycle(kind, fields)
        if self.enabled:
            self.instant(kind, track=track, cat="lifecycle", **fields)

    # ------------------------------------------------------------ export
    def deterministic_events(self) -> List[Dict]:
        """The wall-clock-free projection: identical replays of the same
        trace must produce *bit-identical* output (list and dict order
        included — events are in record order, args in insertion order)."""
        return [
            {k: v for k, v in ev.items() if k not in _WALL_KEYS}
            for ev in self.events
        ]

    def deterministic_jsonl(self) -> str:
        return "\n".join(
            json.dumps(ev, sort_keys=True)
            for ev in self.deterministic_events()
        )

    def write_jsonl(self, path: str, deterministic: bool = False) -> None:
        """One JSON object per line; ``deterministic=True`` writes the
        wall-clock-free projection (the replay-comparable artifact)."""
        events = (
            self.deterministic_events() if deterministic else self.events
        )
        with open(path, "w") as fh:
            for ev in events:
                fh.write(json.dumps(ev, sort_keys=True) + "\n")

    def _track_ids(self) -> Dict[str, int]:
        """track name → Chrome tid, in first-appearance order (which is
        deterministic because event order is)."""
        ids: Dict[str, int] = {}
        for ev in self.events:
            t = ev["track"]
            if t not in ids:
                ids[t] = len(ids) + 1
        return ids

    @staticmethod
    def _sort_index(track: str) -> int:
        """Stable Perfetto track ordering: engine first, then the queue,
        slot tracks by index, pool/experts at the bottom."""
        if track == "engine":
            return 0
        if track == "queue":
            return 1
        if track.startswith("slot"):
            try:
                return 10 + int(track[4:])
            except ValueError:
                return 10
        return {"pool": 900, "experts": 901}.get(track, 500)

    def chrome_trace(self, extra: Optional[Dict] = None) -> Dict:
        """Chrome trace-event JSON (the dict; dump it to a ``.json`` file
        and open in Perfetto / chrome://tracing). ``extra`` lands under
        ``otherData`` — e.g. the bit-misallocation report rides along
        inside the trace artifact."""
        ids = self._track_ids()
        out: List[Dict] = [{
            "ph": "M", "pid": 1, "tid": 0, "name": "process_name",
            "args": {"name": "repro.serving"},
        }]
        for track, tid in ids.items():
            out.append({"ph": "M", "pid": 1, "tid": tid,
                        "name": "thread_name", "args": {"name": track}})
            out.append({"ph": "M", "pid": 1, "tid": tid,
                        "name": "thread_sort_index",
                        "args": {"sort_index": self._sort_index(track)}})
        for ev in self.events:
            base = {
                "ph": ev["ph"], "name": ev["name"], "cat": ev["cat"],
                "pid": 1, "tid": ids[ev["track"]], "ts": ev["ts_us"],
            }
            if ev["ph"] == "X":
                base["dur"] = ev["dur_us"]
                base["args"] = ev["args"]
            elif ev["ph"] == "i":
                base["s"] = "t"
                base["args"] = ev["args"]
            elif ev["ph"] == "C":
                base["args"] = ev["args"]
            else:  # flow s/t/f
                base["id"] = ev["id"]
                if ev["ph"] == "f":
                    base["bp"] = "e"
            out.append(base)
        doc = {"traceEvents": out, "displayTimeUnit": "ms"}
        if extra:
            doc["otherData"] = extra
        return doc

    def write_chrome(self, path: str, extra: Optional[Dict] = None) -> None:
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(extra), fh)


#: Shared disabled tracer — the default for components constructed
#: outside an engine (scheduler/kvcache/offload unit tests); every hook
#: early-returns and no consumer is attached.
NULL_TRACER = SpanTracer("off")


class MetricsConsumer:
    """Routes lifecycle trace events into :class:`ServingMetrics` — the
    metrics become a consumer of the event stream instead of a parallel
    bookkeeping path. Holds a *getter* rather than the metrics object so
    callers that reset ``engine.metrics`` (benchmark warmups) keep
    feeding the live instance."""

    def __init__(self, get_metrics: Callable):
        self._get = get_metrics

    def on_lifecycle(self, kind: str, f: Dict) -> None:
        m = self._get()
        if kind == "admit":
            m.record_admission(
                f["rid"], f["slot"], f["step"], f["active_before"],
                f["queue_depth"], resumed=f.get("resumed", False),
                tenant=f.get("tenant", "default"),
                priority=f.get("priority", 0),
                wait_steps=f.get("wait_steps", -1),
            )
        elif kind == "release":
            m.record_release(f["rid"], f["slot"], f["step"])
        elif kind == "preempt":
            m.record_preemption(
                f["rid"], f["slot"], f["step"], f["mode"],
                swap_bytes=f.get("swap_bytes", 0),
                tenant=f.get("tenant", "default"),
                for_rid=f.get("for_rid", -1),
                for_tenant=f.get("for_tenant", ""),
            )
        elif kind == "shed":
            m.record_shed(
                f["rid"], f["step"], tenant=f.get("tenant", "default"),
                priority=f.get("priority", 0),
                wait_steps=f.get("wait_steps", 0),
            )
        elif kind == "plan":
            m.record_plan(
                f.get("actions", 0),
                admits=f.get("admits", 0),
                preempts=f.get("preempts", 0),
                grows=f.get("grows", 0),
                prefix_evictions=f.get("prefix_evictions", 0),
                sheds=f.get("sheds", 0),
                expert_uploads=f.get("expert_uploads", 0),
            )
        elif kind == "swap_in":
            m.record_swap_in(f["nbytes"])
        elif kind == "prefix_hit":
            m.record_prefix_hit(
                f["tokens_saved"], full=f.get("full", False)
            )
        elif kind == "prefix_miss":
            m.record_prefix_miss()
        elif kind == "cow_copy":
            m.record_cow_copy()
        elif kind == "fault":
            m.record_fault(f["site"])
        elif kind == "retry":
            m.record_upload_retry()
        elif kind == "degrade":
            m.record_degrade()
        elif kind == "swap_fallback":
            m.record_swap_fallback()
        elif kind == "tier_fetch":
            m.record_tier_fetch(f["tier"], f.get("nbytes", 0))
        elif kind == "cancel":
            m.record_cancel()
        elif kind == "deadline":
            m.record_deadline()
        elif kind == "poisoned":
            m.record_poisoned()
        # other kinds (enqueue, first_token, …) carry no metric state


# --------------------------------------------------------------- telemetry
def gini(x) -> float:
    """Gini coefficient of a non-negative load vector — 0 for perfectly
    balanced expert traffic, → 1 as a few experts absorb everything."""
    x = np.sort(np.asarray(x, np.float64))
    n, s = x.size, float(x.sum())
    if n == 0 or s == 0.0:
        return 0.0
    cum = np.cumsum(x) / s
    return float((n + 1 - 2 * cum.sum()) / n)


class ExpertRoutingTelemetry:
    """Per-(layer, expert-slot) dispatch accounting over the
    ``slot_counts`` every jitted decode/prefill program already reports.

    All inputs are device-computed and deterministic per served trace,
    so everything here (histogram, drift, Gini, report) belongs to the
    deterministic side of the tracing contract.
    """

    def __init__(self, ema_decay: float = 0.9):
        self.ema_decay = float(ema_decay)
        self.hist: Optional[np.ndarray] = None  # [L, S] int64 totals
        self.ema: Optional[np.ndarray] = None  # [L, S] per-layer dist EMA
        self.steps = 0
        self.last_drift = 0.0
        self.last_gini = 0.0

    def update(self, counts) -> Optional[Dict[str, float]]:
        """Fold one logical step's ``[L, num_slots]`` dispatch counts in.
        Returns the refreshed gauges — ``routing_drift`` (mean over
        layers of the total-variation distance between this step's
        routing distribution and the running EMA) and ``routing_gini``
        (mean per-layer Gini of the cumulative histogram) — or ``None``
        for empty counts."""
        counts = np.asarray(counts)
        if counts.size == 0 or counts.ndim != 2:
            return None
        counts = counts.astype(np.int64)
        if self.hist is None:
            self.hist = np.zeros(counts.shape, np.int64)
            self.ema = np.full(counts.shape, 1.0 / counts.shape[1])
        self.hist += counts
        self.steps += 1
        tot = counts.sum(axis=1, keepdims=True)
        # layers that dispatched nothing this step contribute no drift
        p = np.where(tot > 0, counts / np.maximum(tot, 1), self.ema)
        self.last_drift = float(
            np.mean(0.5 * np.abs(p - self.ema).sum(axis=1))
        )
        d = self.ema_decay
        self.ema = d * self.ema + (1.0 - d) * p
        self.last_gini = float(
            np.mean([gini(row) for row in self.hist])
        )
        return {
            "routing_drift": self.last_drift,
            "routing_gini": self.last_gini,
        }

    def bit_misallocation_report(self, meta,
                                 degraded: Optional[Dict] = None
                                 ) -> Optional[Dict]:
        """Join observed routing frequency against the PMQ bit
        assignment (``meta`` = :class:`repro.core.compressed_moe
        .BucketMeta` tuple). Per (layer, slot): observed dispatch count,
        frequency, frequency rank (0 = hottest, stable on ties) and the
        slot's allocated bit-width; per layer the Pearson correlation
        between frequency and bits (positive = bits follow observed
        significance — the paper's §3.2 story holding at serve time) and
        the reallocation candidates: ``hot_low_bit`` slots carry an
        above-uniform share at the minimum width, ``cold_high_bit``
        slots a below-uniform share at the maximum width.

        ``degraded`` (optional) maps ``(layer, slot) → served bits`` for
        experts pinned to a lower rung of the precision ladder after
        persistent upload failures (docs/serving_robustness.md): each
        entry gains a ``served_bits`` column (= allocated bits when not
        degraded) and the report a top-level ``degraded_experts`` list."""
        if self.hist is None:
            return None
        degraded = dict(degraded or {})
        num_layers, num_slots = self.hist.shape
        bits = np.zeros(num_slots, np.int64)
        for m in meta:
            bits[m.start:m.start + m.count] = m.bits
        lo, hi = int(bits.min()), int(bits.max())
        uniform = 1.0 / num_slots
        layers: List[Dict] = []
        corrs: List[float] = []
        for l in range(num_layers):
            h = self.hist[l]
            tot = int(h.sum())
            freq = h / tot if tot else np.zeros(num_slots)
            order = np.argsort(-h, kind="stable")
            rank = np.empty(num_slots, np.int64)
            rank[order] = np.arange(num_slots)
            corr = None
            if tot and lo != hi and float(np.std(freq)) > 0.0:
                corr = float(np.corrcoef(freq, bits.astype(np.float64))[0, 1])
                corrs.append(corr)
            hot_low = [int(s) for s in range(num_slots)
                       if freq[s] > uniform and bits[s] == lo]
            cold_high = [int(s) for s in range(num_slots)
                         if freq[s] < uniform and bits[s] == hi]
            layers.append({
                "layer": l,
                "total_dispatch": tot,
                "freq_bits_corr": corr,
                "hot_low_bit": hot_low if lo != hi else [],
                "cold_high_bit": cold_high if lo != hi else [],
                "entries": [
                    {"slot": int(s), "bits": int(bits[s]),
                     "served_bits": int(degraded.get((l, s), bits[s])),
                     "count": int(h[s]), "freq": float(freq[s]),
                     "freq_rank": int(rank[s])}
                    for s in range(num_slots)
                ],
            })
        return {
            "steps": self.steps,
            "num_layers": num_layers,
            "num_slots": num_slots,
            "bits_per_slot": [int(b) for b in bits],
            "mean_freq_bits_corr": (
                float(np.mean(corrs)) if corrs else None
            ),
            "degraded_experts": [
                {"layer": int(l), "slot": int(s),
                 "from_bits": int(bits[s]) if s < num_slots else None,
                 "to_bits": int(tb)}
                for (l, s), tb in sorted(degraded.items())
            ],
            "layers": layers,
        }


# -------------------------------------------------------------- validation
def _fail(msg: str, ev: Dict) -> None:
    raise ValueError(f"trace schema: {msg}: {json.dumps(ev, sort_keys=True)[:200]}")


def validate_events(events: Iterable[Dict]) -> int:
    """Validate JSONL-form events (the tracer's native record shape).
    Returns the number of events checked; raises ``ValueError`` on the
    first violation."""
    n = 0
    prev_seq = -1
    for ev in events:
        n += 1
        for key, typ in (("ph", str), ("name", str), ("cat", str),
                         ("track", str), ("seq", int)):
            if not isinstance(ev.get(key), typ):
                _fail(f"missing/bad {key!r}", ev)
        if ev["ph"] not in _PHASES:
            _fail(f"phase {ev['ph']!r} not in {sorted(_PHASES)}", ev)
        if ev["seq"] <= prev_seq:
            _fail("seq not strictly increasing", ev)
        prev_seq = ev["seq"]
        if not isinstance(ev.get("ts_us"), (int, float)):
            _fail("missing wall-clock ts_us", ev)
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur_us"), (int, float)) or ev["dur_us"] < 0:
                _fail("X event needs dur_us >= 0", ev)
        if ev["ph"] in ("s", "t", "f"):
            if not isinstance(ev.get("id"), int):
                _fail("flow event needs an int id", ev)
        elif not isinstance(ev.get("args", {}), dict):
            _fail("args must be a dict", ev)
        else:
            for k, v in ev.get("args", {}).items():
                if not isinstance(k, str) or not isinstance(v, _ARG_TYPES):
                    _fail(f"arg {k!r} must be a JSON scalar", ev)
    return n


def validate_chrome_trace(doc: Dict) -> int:
    """Validate a Chrome trace-event JSON document (what
    :meth:`SpanTracer.write_chrome` emits / Perfetto opens). Returns
    the number of events checked; raises ``ValueError`` on violation."""
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise ValueError("trace schema: document needs a traceEvents list")
    n = 0
    for ev in doc["traceEvents"]:
        n += 1
        if not isinstance(ev, dict):
            _fail("event must be an object", {"got": str(type(ev))})
        ph = ev.get("ph")
        if ph not in _PHASES | {"M"}:
            _fail(f"phase {ph!r}", ev)
        for key in ("name", "pid", "tid"):
            if key not in ev:
                _fail(f"missing {key!r}", ev)
        if ph == "M":
            if ev["name"] not in ("process_name", "thread_name",
                                  "thread_sort_index"):
                _fail("unknown metadata event", ev)
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            _fail("missing ts", ev)
        if ph == "X" and (not isinstance(ev.get("dur"), (int, float))
                          or ev["dur"] < 0):
            _fail("X event needs dur >= 0", ev)
        if ph in ("s", "t", "f") and not isinstance(ev.get("id"), int):
            _fail("flow event needs an int id", ev)
    return n


def main(argv: Optional[List[str]] = None) -> int:
    """``python -m repro.serving.trace FILE...`` — validate trace
    artifacts (``.json`` Chrome documents / ``.jsonl`` event logs)."""
    import argparse
    import glob as globmod

    p = argparse.ArgumentParser(
        description="validate serving trace artifacts against the schema"
    )
    p.add_argument("paths", nargs="+",
                   help=".trace.json (Chrome) or .jsonl (event log) files;"
                        " globs ok")
    args = p.parse_args(argv)
    files: List[str] = []
    for pat in args.paths:
        hits = sorted(globmod.glob(pat))
        files.extend(hits if hits else [pat])
    failed = False
    for path in files:
        try:
            with open(path) as fh:
                if path.endswith(".jsonl"):
                    n = validate_events(
                        json.loads(line) for line in fh if line.strip()
                    )
                else:
                    n = validate_chrome_trace(json.load(fh))
            print(f"{path}: OK ({n} events)")
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"{path}: FAIL — {e}")
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
