"""Block-table paged KV cache (vLLM-style) for the serving engine.

One preallocated pool ``[L, num_blocks, block_size, Hkv, dh]`` per K and
V replaces the dense ``[L, B, S, Hkv, dh]`` cache: a slot's logical
position ``p`` lives at physical page ``block_tables[slot, p // bs]``,
offset ``p % bs``. Slots of different lengths therefore share the pool —
a finished request's pages return to the free list immediately and the
next queued request reuses them, so pool sizing follows the *sum* of
live context lengths instead of ``max_slots × max_len``.

Slots grow **on demand**: admission reserves pages for the prompt only
and :meth:`PagedKVCache.grow` appends decode pages between jitted
programs. With a fused decode horizon the engine reserves **horizon
ahead** — before each megastep every active slot is grown to cover all
``min(H, budget)`` KV writes the fused program will perform
(:meth:`slot_deficit` computes the gap), so growth, preemption and every
other pool-pressure decision happen at megastep boundaries only; the
pool can still be sized well below the worst-case ``prompt + max_new``
sum. Under pressure a victim slot's pages move to a host-memory backing
store (:meth:`swap_out` → :class:`SwappedKV` → :meth:`swap_in`) — the
device pages are freed immediately and the bit-exact KV is restored when
the preempted request is re-admitted.

Host-side bookkeeping (:class:`BlockAllocator`, slot tables) is plain
python/numpy — it runs between jitted steps. Device-side gathers go
through :func:`repro.kernels.ops.paged_attention`; writes compute a flat
destination ``page * bs + offset`` per new token inside the jitted step
(:func:`repro.models.transformer.paged_decode_step`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from .faults import SwapFault, checksum_tree

__all__ = [
    "BlockAllocator", "PagedKVCache", "PoolExhausted", "SwappedKV",
    "PrefixCache", "PrefixEntry",
]


class PoolExhausted(RuntimeError):
    """Raised when an allocation asks for more pages than are free."""


class BlockAllocator:
    """Refcounted free-list allocator over ``num_blocks`` fixed-size
    pages — copy-on-write sharing for the prefix cache.

    :meth:`alloc` hands out pages at refcount 1; :meth:`incref` adds a
    holder (a prefix-cache entry, or a second slot sharing a cached
    prefix page); :meth:`free` *releases one hold* — the page returns to
    the free list only when its refcount hits zero, so releasing a slot
    whose prefix pages are still cached (or shared with a live
    neighbor) never corrupts the other holders.

    Invariants (tested): an allocation either returns exactly ``n``
    distinct free pages or raises :class:`PoolExhausted` leaving state
    untouched; freeing/increfing a page not currently allocated raises
    ``ValueError`` (double-free guard); a freed page becomes allocatable
    again only at refcount 0 (recycling); ``num_free +
    len(allocated) == num_blocks`` always.
    """

    def __init__(self, num_blocks: int):
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._refcount: Dict[int, int] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def allocated(self) -> frozenset:
        """Pages with refcount ≥ 1."""
        return frozenset(self._refcount)

    @property
    def free_pages(self) -> tuple:
        """Snapshot of the free list (for invariant checks)."""
        return tuple(self._free)

    def refcount(self, block: int) -> int:
        """Current holders of ``block`` (0 = free)."""
        return self._refcount.get(block, 0)

    def alloc(self, n: int) -> List[int]:
        """Return ``n`` distinct free pages at refcount 1;
        ``alloc(0) == []`` and is a guaranteed no-op on allocator
        state."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n == 0:
            return []
        if n > len(self._free):
            raise PoolExhausted(
                f"requested {n} blocks, {len(self._free)} free "
                f"of {self.num_blocks}"
            )
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._refcount[b] = 1
        return blocks

    def incref(self, blocks: List[int]) -> None:
        """Add one hold to each page — atomically: every page is
        validated live before any count moves (an unknown page raises
        ``ValueError`` with state untouched). Duplicates in ``blocks``
        are allowed and each add a hold (a slot sharing the same page
        twice cannot happen, but two entries of the prefix cache may)."""
        for b in blocks:
            if b not in self._refcount:
                raise ValueError(f"incref of unallocated block {b}")
        for b in blocks:
            self._refcount[b] += 1

    def free(self, blocks: List[int]) -> None:
        """Release one hold per page — atomically: the whole list is
        validated (allocated, no duplicates) before any count moves, so
        a bad entry raises ``ValueError`` with allocator state untouched
        instead of half-freeing the good prefix. Pages reaching
        refcount 0 return to the free list; shared pages simply drop a
        holder."""
        seen: set = set()
        for b in blocks:
            if b not in self._refcount or b in seen:
                raise ValueError(f"double free / unknown block {b}")
            seen.add(b)
        for b in blocks:
            self._refcount[b] -= 1
            if self._refcount[b] == 0:
                del self._refcount[b]
                self._free.append(b)


@dataclasses.dataclass
class SwappedKV:
    """Host-memory backing store of one preempted slot's KV pages.

    Whole pages are saved (the partial tail page included), so
    :meth:`PagedKVCache.swap_in` restores a bit-exact cache — a resumed
    request's re-read KV is indistinguishable from never having been
    preempted. Quantized pools additionally save the per-row scale/zero
    tables (``quant``), so codes and their dequant parameters travel
    together and restore bit-exactly too.
    """

    k: np.ndarray  # [L, n_pages, BS, Hkv, dh]
    v: np.ndarray
    n_tokens: int  # valid kv entries covered by the saved pages
    quant: Optional[Dict[str, np.ndarray]] = None  # [L, n_pages, BS, Hkv] × 4
    # CRC of the pristine payload at swap-out time; swap-in verifies it
    # and raises SwapFault on mismatch (engine recovers by recompute
    # re-prefill — docs/serving_robustness.md)
    checksum: Optional[int] = None

    def payload_checksum(self) -> int:
        tree = {"k": self.k, "v": self.v}
        if self.quant is not None:
            tree["quant"] = self.quant
        return checksum_tree(tree)

    @property
    def n_pages(self) -> int:
        return self.k.shape[1]

    @property
    def nbytes(self) -> int:
        n = self.k.nbytes + self.v.nbytes
        if self.quant is not None:
            n += sum(a.nbytes for a in self.quant.values())
        return n


@dataclasses.dataclass
class PrefixEntry:
    """One cached prefix: an exact token string → the physical pages
    holding its KV. ``pages`` covers tokens ``[0, n_tokens)`` in order;
    every page carries one allocator hold owned by this entry.
    ``last_logits`` is set on **full-prompt** entries only — the
    prompt's final-token logits, letting a full hit skip prefill
    entirely (the first sampled token is derived from the identical
    array the non-cached path would have computed)."""

    key: bytes  # prompt[:n_tokens].tobytes() — exact, collision-free
    pages: List[int]
    n_tokens: int
    last_logits: Optional[np.ndarray] = None
    hits: int = 0


class PrefixCache:
    """LRU prefix → physical-page-run cache layered on the block-table
    indirection (tentpole (a) of ROADMAP item 2).

    **Key granularity.** Keys are the *exact token bytes* of the prefix
    (no lossy hashing — a hash collision would silently serve wrong KV).
    A fresh prompt registers one entry per full-page boundary
    (``prompt[:j·BS]`` for ``j = 1..P//BS``) plus a full-prompt entry
    (which may end mid-page and carries ``last_logits``), so a later
    prompt sharing any page-aligned prefix — a system-prompt template —
    matches the longest cached boundary even when its suffix diverges.

    **Sharing rules.** Page-aligned entry pages are *immutable* (fully
    covered by prompt tokens; the owner never writes them again) and are
    shared directly via :meth:`BlockAllocator.incref`. The full-prompt
    entry's partial tail page is the one page the owning slot keeps
    writing (its decode tokens land at rows ≥ ``P % BS``), so a sharer
    receives a private **copy-on-write** duplicate at admission — the
    first divergent write is its first decode token, so the copy is
    made eagerly (``cow_copy`` trace event) rather than trapped.

    **Eviction.** Entries are LRU (lookup refreshes recency); evicting
    an entry releases one hold per page — pages held *only* by the cache
    return to the free list, pages shared with live slots stay until
    the slots finish. :meth:`reclaimable` counts the pages eviction
    could actually free right now, which admission/growth add to the
    allocator's free count before resorting to preemption.
    """

    def __init__(self, allocator: BlockAllocator, block_size: int,
                 tracer=None):
        from collections import OrderedDict

        if tracer is None:
            from .trace import NULL_TRACER

            tracer = NULL_TRACER
        self.allocator = allocator
        self.block_size = block_size
        self.tracer = tracer
        self._entries: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        # page → number of cache entries holding it (≤ allocator refcount)
        self.holds: Dict[int, int] = {}

    # ------------------------------------------------------------- state
    @property
    def n_entries(self) -> int:
        return len(self._entries)

    @property
    def pages_held(self) -> frozenset:
        return frozenset(self.holds)

    def snapshot(self) -> List[PrefixEntry]:
        """Entries in LRU order (oldest first), **without** touching
        recency or hit counts — the controller's planning-time view.
        Callers must treat the entries as read-only; actual lookups
        (which refresh LRU state) happen at plan execution via
        :meth:`lookup`."""
        return list(self._entries.values())

    def reclaimable(self, protect: frozenset = frozenset()) -> int:
        """Pages :meth:`evict_for` could actually free right now: count
        the holds dropped if every entry *not touching* ``protect``
        (pages an in-flight admission is about to share — their entries
        are skipped by eviction) were evicted; a page frees iff that
        covers its whole allocator refcount (no live-slot reference, no
        protected-entry hold)."""
        drop: Dict[int, int] = {}
        for ent in self._entries.values():
            if protect and not protect.isdisjoint(ent.pages):
                continue
            for pg in ent.pages:
                drop[pg] = drop.get(pg, 0) + 1
        return sum(
            1 for pg, d in drop.items()
            if d == self.allocator.refcount(pg)
        )

    # ------------------------------------------------------------ lookup
    def lookup(self, prompt: np.ndarray) -> Optional[PrefixEntry]:
        """Longest cached prefix of ``prompt``: the full prompt first,
        then page boundaries descending. A hit moves the entry to the
        LRU tail (most recent)."""
        prompt = np.ascontiguousarray(prompt, np.int32)
        p = len(prompt)
        bs = self.block_size
        probes = [p] + [j * bs for j in range(p // bs, 0, -1)
                        if j * bs != p]
        for n in probes:
            ent = self._entries.get(prompt[:n].tobytes())
            if ent is not None:
                self._entries.move_to_end(ent.key)
                ent.hits += 1
                return ent
        return None

    # ---------------------------------------------------------- register
    def register(self, prompt: np.ndarray, blocks: List[int],
                 last_logits: Optional[np.ndarray] = None) -> int:
        """Cache every page-boundary prefix of ``prompt`` plus the full
        prompt (with its final-token logits), mapping onto the slot's
        ``blocks``. Existing keys are left untouched (their pages
        already hold identical KV — registering the same bytes twice
        must not leak holds). Returns the number of new entries."""
        prompt = np.ascontiguousarray(prompt, np.int32)
        p = len(prompt)
        bs = self.block_size
        added = 0
        bounds = [j * bs for j in range(1, p // bs + 1)]
        if p % bs or not bounds:
            bounds.append(p)  # full-prompt entry ends mid-page
        for n in bounds:
            key = prompt[:n].tobytes()
            npages = -(-n // bs)
            logits = last_logits if n == p else None
            ent = self._entries.get(key)
            if ent is not None:
                # same bytes ⇒ same KV content; keep the incumbent pages
                # but attach logits if this registration has them and the
                # incumbent (a boundary entry of a longer prompt) doesn't
                if logits is not None and ent.last_logits is None:
                    ent.last_logits = np.asarray(logits)
                continue
            pages = list(blocks[:npages])
            self.allocator.incref(pages)
            for pg in pages:
                self.holds[pg] = self.holds.get(pg, 0) + 1
            ent = PrefixEntry(
                key=key, pages=pages, n_tokens=n,
                last_logits=(
                    np.asarray(logits) if logits is not None else None
                ),
            )
            self._entries[key] = ent
            added += 1
        return added

    # ----------------------------------------------------------- evict
    def _release(self, ent: PrefixEntry) -> None:
        self.allocator.free(ent.pages)
        for pg in ent.pages:
            self.holds[pg] -= 1
            if self.holds[pg] == 0:
                del self.holds[pg]
        del self._entries[ent.key]

    def evict_for(self, n_pages: int,
                  protect: frozenset = frozenset()) -> int:
        """Evict LRU entries until ``n_pages`` pages are free (or no
        evictable entry remains). Entries touching ``protect`` — pages
        an in-flight admission is sharing — are skipped. Returns the
        number of entries evicted."""
        evicted = 0
        while self.allocator.num_free < n_pages:
            victim = None
            for ent in self._entries.values():  # LRU order
                if not protect or protect.isdisjoint(ent.pages):
                    victim = ent
                    break
            if victim is None:
                break
            self._release(victim)
            evicted += 1
            self.tracer.instant(
                "prefix_evict", track="pool", cat="kv",
                tokens=victim.n_tokens, pages=len(victim.pages),
                free=self.allocator.num_free,
            )
        return evicted

    def clear(self) -> None:
        """Drop every entry (releases all cache holds) — drain-time
        teardown and the sim harness's pool-accounting hook."""
        for ent in list(self._entries.values()):
            self._release(ent)

    # ------------------------------------------------------- invariants
    def check_consistency(self) -> None:
        """Cache-side invariants: holds mirror entries exactly; every
        held page is live in the allocator with refcount ≥ holds; entry
        page counts match their token counts."""
        recount: Dict[int, int] = {}
        for ent in self._entries.values():
            if len(ent.pages) != -(-ent.n_tokens // self.block_size):
                raise AssertionError(
                    f"prefix entry {ent.n_tokens} tokens / "
                    f"{len(ent.pages)} pages mismatch"
                )
            for pg in ent.pages:
                recount[pg] = recount.get(pg, 0) + 1
        if recount != self.holds:
            raise AssertionError("prefix cache holds out of sync")
        for pg, h in self.holds.items():
            if self.allocator.refcount(pg) < h:
                raise AssertionError(
                    f"page {pg}: allocator refcount "
                    f"{self.allocator.refcount(pg)} < cache holds {h}"
                )


@dataclasses.dataclass
class PagedKVCache:
    """Pool arrays + per-slot block tables for ``max_slots`` sequences.

    The jnp pool arrays ``k``/``v`` are *donated* through the jitted
    decode/prefill steps — the engine reassigns them after every call.
    Everything else is host state.
    """

    k: jnp.ndarray  # [L, NB, BS, Hkv, dh] — uint8 codes when kv_bits set
    v: jnp.ndarray
    block_size: int
    max_slots: int
    max_blocks_per_slot: int
    allocator: BlockAllocator
    block_tables: np.ndarray  # [max_slots, MB] int32, 0-padded
    slot_blocks: Dict[int, List[int]]
    free_slots: List[int]
    # int8 per-page KV quantization (tentpole (b) of ROADMAP item 2):
    # kv_bits selects the code width (None = fp pools, today's path
    # untouched); ``quant`` holds the per-row affine dequant tables
    # {k_scale, k_zero, v_scale, v_zero}, each [L, NB, BS, Hkv] f32 —
    # page-granular metadata living alongside the pool exactly like the
    # block tables, donated through the jitted steps with the pools.
    kv_bits: Optional[int] = None
    quant: Optional[Dict[str, jnp.ndarray]] = None
    # shared-prefix page cache (None = disabled); admission shares its
    # page runs copy-on-write via the refcounted allocator
    prefix: Optional[PrefixCache] = None
    # optional FaultPlan (repro.serving.faults): swap_out / swap_in
    # consult it to inject payload corruption and I/O errors
    faults: object = None
    # device copy of block_tables, rebuilt only after admission/release —
    # the per-token decode loop must not pay a host→device upload
    _tables_device: object = None
    # span tracer (repro.serving.trace.SpanTracer); the engine installs
    # its own, standalone caches keep the shared no-op singleton
    tracer: object = None

    def __post_init__(self):
        if self.tracer is None:
            from .trace import NULL_TRACER

            self.tracer = NULL_TRACER

    def set_tracer(self, tracer) -> None:
        self.tracer = tracer
        if self.prefix is not None:
            self.prefix.tracer = tracer

    @classmethod
    def create(
        cls,
        cfg,
        *,
        num_blocks: int,
        block_size: int,
        max_slots: int,
        max_blocks_per_slot: int,
        dtype=None,
        kv_bits: Optional[int] = None,
        prefix_cache: bool = False,
    ) -> "PagedKVCache":
        if kv_bits is not None and kv_bits != 8:
            raise ValueError(
                f"kv_bits supports 8 (int8 codes) or None (fp pools), "
                f"got {kv_bits}"
            )
        dt = dtype or (jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        shape = (
            cfg.num_layers, num_blocks, block_size,
            cfg.num_kv_heads, cfg.head_dim,
        )
        quant = None
        if kv_bits is not None:
            dt = jnp.uint8
            qshape = shape[:-1]  # [L, NB, BS, Hkv]: one pair per KV row
            quant = {
                name: jnp.zeros(qshape, jnp.float32)
                for name in ("k_scale", "k_zero", "v_scale", "v_zero")
            }
        allocator = BlockAllocator(num_blocks)
        return cls(
            k=jnp.zeros(shape, dt),
            v=jnp.zeros(shape, dt),
            block_size=block_size,
            max_slots=max_slots,
            max_blocks_per_slot=max_blocks_per_slot,
            allocator=allocator,
            block_tables=np.zeros((max_slots, max_blocks_per_slot), np.int32),
            slot_blocks={},
            free_slots=list(range(max_slots - 1, -1, -1)),
            kv_bits=kv_bits,
            quant=quant,
            prefix=(
                PrefixCache(allocator, block_size) if prefix_cache else None
            ),
        )

    # ------------------------------------------------------------- slots
    def blocks_needed(self, total_tokens: int) -> int:
        return -(-total_tokens // self.block_size)

    def max_slot_tokens(self) -> int:
        return self.max_blocks_per_slot * self.block_size

    def slot_deficit(self, slot: int, total_tokens: int) -> int:
        """Pages a live slot still needs to cover ``total_tokens`` kv
        entries — the engine grows by this before each megastep so every
        write of the fused decode program lands on an allocated page."""
        return max(
            0,
            self.blocks_needed(total_tokens) - len(self.slot_blocks[slot]),
        )

    def shared_prefix_pages(self, entry: Optional[PrefixEntry]) -> int:
        """Directly shareable pages of a prefix match: its page-aligned
        full pages. A partial tail page (full-prompt entries) is not
        shared — the sharer gets a private copy-on-write duplicate, so
        it still costs one fresh page."""
        if entry is None:
            return 0
        return entry.n_tokens // self.block_size

    def available_pages(self, protect: frozenset = frozenset()) -> int:
        """Free pages plus what prefix-cache eviction could free — the
        number growth/admission may count on before preempting."""
        n = self.allocator.num_free
        if self.prefix is not None:
            n += self.prefix.reclaimable(protect)
        return n

    def can_admit(self, total_tokens: int, headroom: int = 0,
                  prefix_entry: Optional[PrefixEntry] = None) -> bool:
        """``headroom`` pages are spoken for (pending growth of already
        active slots) — admission may only use what's left above them.
        A prefix match shrinks the bill to the *fresh* (non-shared)
        pages, and LRU-evictable cache pages count as available (the
        match's own pages are protected from that eviction)."""
        n = self.blocks_needed(total_tokens)
        fresh = n - self.shared_prefix_pages(prefix_entry)
        protect = (
            frozenset(prefix_entry.pages) if prefix_entry is not None
            else frozenset()
        )
        return (
            bool(self.free_slots)
            and fresh <= self.available_pages(protect) - headroom
            and n <= self.max_blocks_per_slot
        )

    def _copy_page(self, src: int, dst: int, rid: int = -1) -> None:
        """Copy-on-write page duplication (device-side): K/V rows and,
        on quantized pools, their scale/zero rows move together so the
        copy dequantizes bit-identically to the original."""
        t0 = self.tracer.now_us()
        self.k = self.k.at[:, dst].set(self.k[:, src])
        self.v = self.v.at[:, dst].set(self.v[:, src])
        if self.quant is not None:
            self.quant = {
                name: a.at[:, dst].set(a[:, src])
                for name, a in self.quant.items()
            }
        self.tracer.lifecycle(
            "cow_copy", track="pool", rid=rid, src_page=src, dst_page=dst,
        )
        self.tracer.complete(
            "cow_copy_span", track="pool", cat="kv", start_us=t0,
            args={"src": src, "dst": dst},
        )

    def acquire_slot(self, total_tokens: int,
                     prefix_entry: Optional[PrefixEntry] = None,
                     rid: int = -1) -> int:
        """Reserve a slot + enough pages for ``total_tokens`` kv entries.

        With a ``prefix_entry`` (from :meth:`prefix_lookup`) the match's
        page-aligned pages are **shared** (incref, no allocation, no
        prefill needed for those tokens) and only the suffix is freshly
        allocated; a full-prompt match ending mid-page additionally
        copies its partial tail page into the first fresh page (COW —
        the sharer's decode writes land there and must not corrupt the
        other holders). LRU cache entries are evicted as needed to make
        room, never touching the match's own pages."""
        n = self.blocks_needed(total_tokens)
        if n > self.max_blocks_per_slot:
            raise PoolExhausted(
                f"{total_tokens} tokens need {n} blocks > "
                f"max_blocks_per_slot={self.max_blocks_per_slot}"
            )
        if not self.free_slots:
            raise PoolExhausted("no free slots")
        if prefix_entry is None:
            if self.prefix is not None:
                self.prefix.evict_for(n)
            blocks = self.allocator.alloc(n)  # raises before slot consumed
        else:
            full = self.shared_prefix_pages(prefix_entry)
            tail = 1 if prefix_entry.n_tokens % self.block_size else 0
            fresh_needed = n - full
            if fresh_needed < tail:
                raise ValueError(
                    f"prefix match of {prefix_entry.n_tokens} tokens "
                    f"cannot seed a {total_tokens}-token slot"
                )
            protect = frozenset(prefix_entry.pages)
            self.prefix.evict_for(fresh_needed, protect)
            fresh = self.allocator.alloc(fresh_needed)  # raises first
            shared = list(prefix_entry.pages[:full])
            self.allocator.incref(shared)
            blocks = shared + fresh
            if tail:
                self._copy_page(prefix_entry.pages[full], fresh[0], rid=rid)
        slot = self.free_slots.pop()
        self.slot_blocks[slot] = blocks
        self.block_tables[slot] = 0
        self.block_tables[slot, : len(blocks)] = blocks
        self._tables_device = None
        return slot

    # ----------------------------------------------------------- prefix
    def prefix_lookup(self, prompt: np.ndarray) -> Optional[PrefixEntry]:
        """Longest cached prefix of ``prompt`` (None when the prefix
        cache is disabled or misses)."""
        if self.prefix is None:
            return None
        return self.prefix.lookup(prompt)

    def register_prefix(self, prompt: np.ndarray, slot: int,
                        last_logits: Optional[np.ndarray] = None) -> int:
        """Cache the freshly prefilled prompt's page-boundary prefixes +
        the full prompt (with its final-token logits) from a live slot's
        pages. No-op when the prefix cache is disabled."""
        if self.prefix is None:
            return 0
        return self.prefix.register(
            prompt, self.slot_blocks[slot], last_logits
        )

    def clear_prefix_cache(self) -> None:
        if self.prefix is not None:
            self.prefix.clear()

    def grow(self, slot: int, n: int) -> List[int]:
        """Append ``n`` pages to a live slot (on-demand growth).

        LRU prefix-cache entries are evicted first when the free list is
        short (cached prefixes are a best-effort accelerator; a running
        request's pages are not). Raises :class:`PoolExhausted` — leaving
        the slot untouched — when the pool is still out of pages (the
        scheduler preempts a victim and retries) or the slot would
        exceed ``max_blocks_per_slot``.
        """
        have = len(self.slot_blocks[slot])
        if have + n > self.max_blocks_per_slot:
            raise PoolExhausted(
                f"slot {slot}: growing {have}+{n} blocks exceeds "
                f"max_blocks_per_slot={self.max_blocks_per_slot}"
            )
        if self.prefix is not None:
            self.prefix.evict_for(n)
        blocks = self.allocator.alloc(n)  # raises with state untouched
        if not blocks:
            return blocks
        self.slot_blocks[slot].extend(blocks)
        self.block_tables[slot, have : have + len(blocks)] = blocks
        self._tables_device = None
        self.tracer.instant(
            "page_grow", track="pool", cat="kv", slot=slot, pages=len(blocks),
            slot_pages=len(self.slot_blocks[slot]),
            free=self.allocator.num_free,
        )
        return blocks

    # ------------------------------------------------------------- swap
    def swap_out(self, slot: int, n_tokens: int, rid: int = -1) -> SwappedKV:
        """Move a victim slot's pages to host memory and free the slot.

        Device→host copy of the slot's whole pages, then the pages and
        the slot return to the free lists — the caller re-queues the
        request and restores via :meth:`swap_in` at re-admission. The
        payload carries a CRC of its pristine bytes. An injected
        ``swap_out``/``fail`` fault raises :class:`SwapFault` *before*
        any state moves (the engine falls back to recompute-mode
        preemption); ``corrupt`` damages the host payload after the CRC
        is taken, so swap-in's verification catches it.
        """
        spec = self.faults.fire("swap_out", rid) if self.faults else None
        if spec is not None:
            self.tracer.lifecycle(
                "fault", track="pool", site="swap_out", mode=spec.mode,
                rid=int(rid), slot=int(slot),
            )
            if spec.mode == "fail":
                raise SwapFault(
                    f"injected swap-out I/O failure (slot {slot})",
                    rid=(int(rid) if rid >= 0 else None),
                )
        blocks = self.slot_blocks[slot]
        idx = np.asarray(blocks, np.int32)
        t0 = self.tracer.now_us()
        swapped = SwappedKV(
            k=np.array(self.k[:, idx]),
            v=np.asarray(self.v[:, idx]),
            n_tokens=n_tokens,
            quant=(
                {n: np.asarray(a[:, idx]) for n, a in self.quant.items()}
                if self.quant is not None else None
            ),
        )
        swapped.checksum = swapped.payload_checksum()
        if spec is not None and spec.mode == "corrupt":
            # in-transit damage: the checksum above describes the
            # pristine payload, so swap-in's verification must trip
            swapped.k.view(np.uint8).reshape(-1)[0] ^= 0xFF
        self.release_slot(slot)
        self.tracer.complete(
            "kv_swap_out", track="pool", cat="kv", start_us=t0,
            args={"slot": slot, "pages": swapped.n_pages,
                  "bytes": swapped.nbytes},
        )
        return swapped

    def swap_in(self, slot: int, swapped: SwappedKV, rid: int = -1) -> int:
        """Restore swapped pages into a freshly acquired slot.

        The slot must already hold at least ``swapped.n_pages`` pages
        (admission sizes it from the request's context length). Returns
        the bytes uploaded (host→device) for the swap-traffic metric.
        The payload's CRC is verified before any device state moves; a
        mismatch (real corruption, or an injected ``swap_in`` fault)
        raises :class:`SwapFault` and leaves the slot untouched — the
        engine discards the swap and recovers by recompute re-prefill.
        """
        blocks = self.slot_blocks[slot][: swapped.n_pages]
        if len(blocks) < swapped.n_pages:
            raise ValueError(
                f"slot {slot} holds {len(self.slot_blocks[slot])} pages, "
                f"swap-in needs {swapped.n_pages}"
            )
        if self.quant is not None and swapped.quant is None:
            raise ValueError("quantized pool restored from fp swap")
        spec = self.faults.fire("swap_in", rid) if self.faults else None
        if spec is not None:
            self.tracer.lifecycle(
                "fault", track="pool", site="swap_in", mode=spec.mode,
                rid=int(rid), slot=int(slot),
            )
            if spec.mode == "fail":
                raise SwapFault(
                    f"injected swap-in I/O failure (slot {slot})",
                    rid=(int(rid) if rid >= 0 else None),
                )
            # corrupt: damage the host payload right before the verify
            swapped.k = np.array(swapped.k, copy=True)
            swapped.k.view(np.uint8).reshape(-1)[0] ^= 0xFF
        if (swapped.checksum is not None
                and swapped.payload_checksum() != swapped.checksum):
            raise SwapFault(
                f"swap payload failed checksum for slot {slot}",
                rid=(int(rid) if rid >= 0 else None),
            )
        idx = jnp.asarray(np.asarray(blocks, np.int32))
        t0 = self.tracer.now_us()
        self.k = self.k.at[:, idx].set(jnp.asarray(swapped.k, self.k.dtype))
        self.v = self.v.at[:, idx].set(jnp.asarray(swapped.v, self.v.dtype))
        if self.quant is not None:
            self.quant = {
                n: a.at[:, idx].set(jnp.asarray(swapped.quant[n]))
                for n, a in self.quant.items()
            }
        self.tracer.complete(
            "kv_swap_in", track="pool", cat="kv", start_us=t0,
            args={"slot": slot, "pages": swapped.n_pages,
                  "bytes": swapped.nbytes},
        )
        return swapped.nbytes

    def release_slot(self, slot: int) -> None:
        self.allocator.free(self.slot_blocks.pop(slot))
        self.block_tables[slot] = 0
        self.free_slots.append(slot)
        self._tables_device = None

    # -------------------------------------------------------- observability
    @property
    def utilization(self) -> float:
        """Fraction of pool pages currently held by live slots."""
        return 1.0 - self.allocator.num_free / self.allocator.num_blocks

    def check_consistency(self) -> None:
        """Assert the allocator/table invariants the simulation harness
        fuzzes after every step. With copy-on-write refcounts, "no page
        owned by two live slots" generalizes to exact refcount
        accounting: every allocated page's refcount equals the number of
        live-slot references plus prefix-cache holds (≥ 1 — every
        refcounted page is reachable from a block table or the cache),
        no page is both free and referenced, page conservation holds
        over the union, block tables mirror ``slot_blocks``, and the
        slot free-list is disjoint from live slots. Cheap (host-only).
        """
        slot_refs: Dict[int, int] = {}
        for bl in self.slot_blocks.values():
            for b in bl:
                slot_refs[b] = slot_refs.get(b, 0) + 1
        holds = self.prefix.holds if self.prefix is not None else {}
        referenced = set(slot_refs) | set(holds)
        if referenced != set(self.allocator.allocated):
            raise AssertionError(
                "referenced pages out of sync with allocator (unreachable "
                "refcounted page or untracked reference)"
            )
        free = self.allocator.free_pages
        if len(free) != len(set(free)):
            raise AssertionError("duplicate page in the free list")
        if set(free) & referenced:
            raise AssertionError("page both free and referenced")
        if len(free) + len(referenced) != self.allocator.num_blocks:
            raise AssertionError(
                f"page conservation violated: {len(free)} free + "
                f"{len(referenced)} referenced != {self.allocator.num_blocks}"
            )
        for b in referenced:
            want = slot_refs.get(b, 0) + holds.get(b, 0)
            got = self.allocator.refcount(b)
            if got != want:
                raise AssertionError(
                    f"page {b}: refcount {got} != {slot_refs.get(b, 0)} "
                    f"slot refs + {holds.get(b, 0)} cache holds"
                )
        for slot, bl in self.slot_blocks.items():
            if slot in self.free_slots:
                raise AssertionError(f"live slot {slot} also in free_slots")
            if len(bl) != len(set(bl)):
                raise AssertionError(f"slot {slot} lists a page twice")
            if len(bl) > self.max_blocks_per_slot:
                raise AssertionError(f"slot {slot} over max_blocks_per_slot")
            if list(self.block_tables[slot, : len(bl)]) != bl:
                raise AssertionError(f"block table row {slot} != slot_blocks")
        if self.prefix is not None:
            self.prefix.check_consistency()

    def tables_device(self) -> jnp.ndarray:
        if self._tables_device is None:
            self._tables_device = jnp.asarray(self.block_tables)
        return self._tables_device
