"""Block-table paged KV cache (vLLM-style) for the serving engine.

One preallocated pool ``[L, num_blocks, block_size, Hkv, dh]`` per K and
V replaces the dense ``[L, B, S, Hkv, dh]`` cache: a slot's logical
position ``p`` lives at physical page ``block_tables[slot, p // bs]``,
offset ``p % bs``. Slots of different lengths therefore share the pool —
a finished request's pages return to the free list immediately and the
next queued request reuses them, so pool sizing follows the *sum* of
live context lengths instead of ``max_slots × max_len``.

Slots grow **on demand**: admission reserves pages for the prompt only
and :meth:`PagedKVCache.grow` appends decode pages between jitted
programs. With a fused decode horizon the engine reserves **horizon
ahead** — before each megastep every active slot is grown to cover all
``min(H, budget)`` KV writes the fused program will perform
(:meth:`slot_deficit` computes the gap), so growth, preemption and every
other pool-pressure decision happen at megastep boundaries only; the
pool can still be sized well below the worst-case ``prompt + max_new``
sum. Under pressure a victim slot's pages move to a host-memory backing
store (:meth:`swap_out` → :class:`SwappedKV` → :meth:`swap_in`) — the
device pages are freed immediately and the bit-exact KV is restored when
the preempted request is re-admitted.

Host-side bookkeeping (:class:`BlockAllocator`, slot tables) is plain
python/numpy — it runs between jitted steps. Device-side gathers go
through :func:`repro.kernels.ops.paged_attention`; writes compute a flat
destination ``page * bs + offset`` per new token inside the jitted step
(:func:`repro.models.transformer.paged_decode_step`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

__all__ = ["BlockAllocator", "PagedKVCache", "PoolExhausted", "SwappedKV"]


class PoolExhausted(RuntimeError):
    """Raised when an allocation asks for more pages than are free."""


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` fixed-size pages.

    Invariants (tested): an allocation either returns exactly ``n``
    distinct free pages or raises :class:`PoolExhausted` leaving state
    untouched; freeing a page not currently allocated raises
    ``ValueError`` (double-free guard); freed pages become allocatable
    again (recycling).
    """

    def __init__(self, num_blocks: int):
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._allocated: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def allocated(self) -> frozenset:
        return frozenset(self._allocated)

    @property
    def free_pages(self) -> tuple:
        """Snapshot of the free list (for invariant checks)."""
        return tuple(self._free)

    def alloc(self, n: int) -> List[int]:
        """Return ``n`` distinct free pages; ``alloc(0) == []`` and is a
        guaranteed no-op on allocator state."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n == 0:
            return []
        if n > len(self._free):
            raise PoolExhausted(
                f"requested {n} blocks, {len(self._free)} free "
                f"of {self.num_blocks}"
            )
        blocks = [self._free.pop() for _ in range(n)]
        self._allocated.update(blocks)
        return blocks

    def free(self, blocks: List[int]) -> None:
        """Return pages to the free list — atomically: the whole list is
        validated (allocated, no duplicates) before any page moves, so a
        bad entry raises ``ValueError`` with allocator state untouched
        instead of half-freeing the good prefix."""
        seen: set = set()
        for b in blocks:
            if b not in self._allocated or b in seen:
                raise ValueError(f"double free / unknown block {b}")
            seen.add(b)
        for b in blocks:
            self._allocated.remove(b)
            self._free.append(b)


@dataclasses.dataclass
class SwappedKV:
    """Host-memory backing store of one preempted slot's KV pages.

    Whole pages are saved (the partial tail page included), so
    :meth:`PagedKVCache.swap_in` restores a bit-exact cache — a resumed
    request's re-read KV is indistinguishable from never having been
    preempted.
    """

    k: np.ndarray  # [L, n_pages, BS, Hkv, dh]
    v: np.ndarray
    n_tokens: int  # valid kv entries covered by the saved pages

    @property
    def n_pages(self) -> int:
        return self.k.shape[1]

    @property
    def nbytes(self) -> int:
        return self.k.nbytes + self.v.nbytes


@dataclasses.dataclass
class PagedKVCache:
    """Pool arrays + per-slot block tables for ``max_slots`` sequences.

    The jnp pool arrays ``k``/``v`` are *donated* through the jitted
    decode/prefill steps — the engine reassigns them after every call.
    Everything else is host state.
    """

    k: jnp.ndarray  # [L, NB, BS, Hkv, dh]
    v: jnp.ndarray
    block_size: int
    max_slots: int
    max_blocks_per_slot: int
    allocator: BlockAllocator
    block_tables: np.ndarray  # [max_slots, MB] int32, 0-padded
    slot_blocks: Dict[int, List[int]]
    free_slots: List[int]
    # device copy of block_tables, rebuilt only after admission/release —
    # the per-token decode loop must not pay a host→device upload
    _tables_device: object = None
    # span tracer (repro.serving.trace.SpanTracer); the engine installs
    # its own, standalone caches keep the shared no-op singleton
    tracer: object = None

    def __post_init__(self):
        if self.tracer is None:
            from .trace import NULL_TRACER

            self.tracer = NULL_TRACER

    @classmethod
    def create(
        cls,
        cfg,
        *,
        num_blocks: int,
        block_size: int,
        max_slots: int,
        max_blocks_per_slot: int,
        dtype=None,
    ) -> "PagedKVCache":
        dt = dtype or (jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        shape = (
            cfg.num_layers, num_blocks, block_size,
            cfg.num_kv_heads, cfg.head_dim,
        )
        return cls(
            k=jnp.zeros(shape, dt),
            v=jnp.zeros(shape, dt),
            block_size=block_size,
            max_slots=max_slots,
            max_blocks_per_slot=max_blocks_per_slot,
            allocator=BlockAllocator(num_blocks),
            block_tables=np.zeros((max_slots, max_blocks_per_slot), np.int32),
            slot_blocks={},
            free_slots=list(range(max_slots - 1, -1, -1)),
        )

    # ------------------------------------------------------------- slots
    def blocks_needed(self, total_tokens: int) -> int:
        return -(-total_tokens // self.block_size)

    def max_slot_tokens(self) -> int:
        return self.max_blocks_per_slot * self.block_size

    def slot_deficit(self, slot: int, total_tokens: int) -> int:
        """Pages a live slot still needs to cover ``total_tokens`` kv
        entries — the engine grows by this before each megastep so every
        write of the fused decode program lands on an allocated page."""
        return max(
            0,
            self.blocks_needed(total_tokens) - len(self.slot_blocks[slot]),
        )

    def can_admit(self, total_tokens: int, headroom: int = 0) -> bool:
        """``headroom`` pages are spoken for (pending growth of already
        active slots) — admission may only use what's left above them."""
        n = self.blocks_needed(total_tokens)
        return (
            bool(self.free_slots)
            and n <= self.allocator.num_free - headroom
            and n <= self.max_blocks_per_slot
        )

    def acquire_slot(self, total_tokens: int) -> int:
        """Reserve a slot + enough pages for ``total_tokens`` kv entries."""
        n = self.blocks_needed(total_tokens)
        if n > self.max_blocks_per_slot:
            raise PoolExhausted(
                f"{total_tokens} tokens need {n} blocks > "
                f"max_blocks_per_slot={self.max_blocks_per_slot}"
            )
        if not self.free_slots:
            raise PoolExhausted("no free slots")
        blocks = self.allocator.alloc(n)  # raises before slot is consumed
        slot = self.free_slots.pop()
        self.slot_blocks[slot] = blocks
        self.block_tables[slot] = 0
        self.block_tables[slot, : len(blocks)] = blocks
        self._tables_device = None
        return slot

    def grow(self, slot: int, n: int) -> List[int]:
        """Append ``n`` pages to a live slot (on-demand growth).

        Raises :class:`PoolExhausted` — leaving the slot untouched — when
        the pool is out of pages (the scheduler preempts a victim and
        retries) or the slot would exceed ``max_blocks_per_slot``.
        """
        have = len(self.slot_blocks[slot])
        if have + n > self.max_blocks_per_slot:
            raise PoolExhausted(
                f"slot {slot}: growing {have}+{n} blocks exceeds "
                f"max_blocks_per_slot={self.max_blocks_per_slot}"
            )
        blocks = self.allocator.alloc(n)  # raises with state untouched
        if not blocks:
            return blocks
        self.slot_blocks[slot].extend(blocks)
        self.block_tables[slot, have : have + len(blocks)] = blocks
        self._tables_device = None
        self.tracer.instant(
            "page_grow", track="pool", cat="kv", slot=slot, pages=len(blocks),
            slot_pages=len(self.slot_blocks[slot]),
            free=self.allocator.num_free,
        )
        return blocks

    # ------------------------------------------------------------- swap
    def swap_out(self, slot: int, n_tokens: int) -> SwappedKV:
        """Move a victim slot's pages to host memory and free the slot.

        Device→host copy of the slot's whole pages, then the pages and
        the slot return to the free lists — the caller re-queues the
        request and restores via :meth:`swap_in` at re-admission.
        """
        blocks = self.slot_blocks[slot]
        idx = np.asarray(blocks, np.int32)
        t0 = self.tracer.now_us()
        swapped = SwappedKV(
            k=np.asarray(self.k[:, idx]),
            v=np.asarray(self.v[:, idx]),
            n_tokens=n_tokens,
        )
        self.release_slot(slot)
        self.tracer.complete(
            "kv_swap_out", track="pool", cat="kv", start_us=t0,
            args={"slot": slot, "pages": swapped.n_pages,
                  "bytes": swapped.nbytes},
        )
        return swapped

    def swap_in(self, slot: int, swapped: SwappedKV) -> int:
        """Restore swapped pages into a freshly acquired slot.

        The slot must already hold at least ``swapped.n_pages`` pages
        (admission sizes it from the request's context length). Returns
        the bytes uploaded (host→device) for the swap-traffic metric.
        """
        blocks = self.slot_blocks[slot][: swapped.n_pages]
        if len(blocks) < swapped.n_pages:
            raise ValueError(
                f"slot {slot} holds {len(self.slot_blocks[slot])} pages, "
                f"swap-in needs {swapped.n_pages}"
            )
        idx = jnp.asarray(np.asarray(blocks, np.int32))
        t0 = self.tracer.now_us()
        self.k = self.k.at[:, idx].set(jnp.asarray(swapped.k, self.k.dtype))
        self.v = self.v.at[:, idx].set(jnp.asarray(swapped.v, self.v.dtype))
        self.tracer.complete(
            "kv_swap_in", track="pool", cat="kv", start_us=t0,
            args={"slot": slot, "pages": swapped.n_pages,
                  "bytes": swapped.nbytes},
        )
        return swapped.nbytes

    def release_slot(self, slot: int) -> None:
        self.allocator.free(self.slot_blocks.pop(slot))
        self.block_tables[slot] = 0
        self.free_slots.append(slot)
        self._tables_device = None

    # -------------------------------------------------------- observability
    @property
    def utilization(self) -> float:
        """Fraction of pool pages currently held by live slots."""
        return 1.0 - self.allocator.num_free / self.allocator.num_blocks

    def check_consistency(self) -> None:
        """Assert the allocator/table invariants the simulation harness
        fuzzes: no page owned by two live slots, free-count conservation,
        block tables mirroring ``slot_blocks``, slot free-list disjoint
        from live slots. Cheap (host-only) — callable after every step.
        """
        used = [b for bl in self.slot_blocks.values() for b in bl]
        if len(used) != len(set(used)):
            raise AssertionError("page owned by two live slots")
        if set(used) != set(self.allocator.allocated):
            raise AssertionError("slot_blocks out of sync with allocator")
        free = self.allocator.free_pages
        if len(free) != len(set(free)):
            raise AssertionError("duplicate page in the free list")
        if len(free) + len(used) != self.allocator.num_blocks:
            raise AssertionError(
                f"page conservation violated: {len(free)} free + "
                f"{len(used)} used != {self.allocator.num_blocks}"
            )
        for slot, bl in self.slot_blocks.items():
            if slot in self.free_slots:
                raise AssertionError(f"live slot {slot} also in free_slots")
            if len(bl) > self.max_blocks_per_slot:
                raise AssertionError(f"slot {slot} over max_blocks_per_slot")
            if list(self.block_tables[slot, : len(bl)]) != bl:
                raise AssertionError(f"block table row {slot} != slot_blocks")

    def tables_device(self) -> jnp.ndarray:
        if self._tables_device is None:
            self._tables_device = jnp.asarray(self.block_tables)
        return self._tables_device
