"""Block-table paged KV cache (vLLM-style) for the serving engine.

One preallocated pool ``[L, num_blocks, block_size, Hkv, dh]`` per K and
V replaces the dense ``[L, B, S, Hkv, dh]`` cache: a slot's logical
position ``p`` lives at physical page ``block_tables[slot, p // bs]``,
offset ``p % bs``. Slots of different lengths therefore share the pool —
a finished request's pages return to the free list immediately and the
next queued request reuses them, so pool sizing follows the *sum* of
live context lengths instead of ``max_slots × max_len``.

Host-side bookkeeping (:class:`BlockAllocator`, slot tables) is plain
python/numpy — it runs between jitted steps. Device-side gathers go
through :func:`repro.kernels.ops.paged_attention`; writes compute a flat
destination ``page * bs + offset`` per new token inside the jitted step
(:func:`repro.models.transformer.paged_decode_step`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

__all__ = ["BlockAllocator", "PagedKVCache", "PoolExhausted"]


class PoolExhausted(RuntimeError):
    """Raised when an allocation asks for more pages than are free."""


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` fixed-size pages.

    Invariants (tested): an allocation either returns exactly ``n``
    distinct free pages or raises :class:`PoolExhausted` leaving state
    untouched; freeing a page not currently allocated raises
    ``ValueError`` (double-free guard); freed pages become allocatable
    again (recycling).
    """

    def __init__(self, num_blocks: int):
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._allocated: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            raise PoolExhausted(
                f"requested {n} blocks, {len(self._free)} free "
                f"of {self.num_blocks}"
            )
        blocks = [self._free.pop() for _ in range(n)]
        self._allocated.update(blocks)
        return blocks

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._allocated:
                raise ValueError(f"double free / unknown block {b}")
            self._allocated.remove(b)
            self._free.append(b)


@dataclasses.dataclass
class PagedKVCache:
    """Pool arrays + per-slot block tables for ``max_slots`` sequences.

    The jnp pool arrays ``k``/``v`` are *donated* through the jitted
    decode/prefill steps — the engine reassigns them after every call.
    Everything else is host state.
    """

    k: jnp.ndarray  # [L, NB, BS, Hkv, dh]
    v: jnp.ndarray
    block_size: int
    max_slots: int
    max_blocks_per_slot: int
    allocator: BlockAllocator
    block_tables: np.ndarray  # [max_slots, MB] int32, 0-padded
    slot_blocks: Dict[int, List[int]]
    free_slots: List[int]
    # device copy of block_tables, rebuilt only after admission/release —
    # the per-token decode loop must not pay a host→device upload
    _tables_device: object = None

    @classmethod
    def create(
        cls,
        cfg,
        *,
        num_blocks: int,
        block_size: int,
        max_slots: int,
        max_blocks_per_slot: int,
        dtype=None,
    ) -> "PagedKVCache":
        dt = dtype or (jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        shape = (
            cfg.num_layers, num_blocks, block_size,
            cfg.num_kv_heads, cfg.head_dim,
        )
        return cls(
            k=jnp.zeros(shape, dt),
            v=jnp.zeros(shape, dt),
            block_size=block_size,
            max_slots=max_slots,
            max_blocks_per_slot=max_blocks_per_slot,
            allocator=BlockAllocator(num_blocks),
            block_tables=np.zeros((max_slots, max_blocks_per_slot), np.int32),
            slot_blocks={},
            free_slots=list(range(max_slots - 1, -1, -1)),
        )

    # ------------------------------------------------------------- slots
    def blocks_needed(self, total_tokens: int) -> int:
        return -(-total_tokens // self.block_size)

    def max_slot_tokens(self) -> int:
        return self.max_blocks_per_slot * self.block_size

    def can_admit(self, total_tokens: int) -> bool:
        n = self.blocks_needed(total_tokens)
        return (
            bool(self.free_slots)
            and n <= self.allocator.num_free
            and n <= self.max_blocks_per_slot
        )

    def acquire_slot(self, total_tokens: int) -> int:
        """Reserve a slot + enough pages for ``total_tokens`` kv entries."""
        n = self.blocks_needed(total_tokens)
        if n > self.max_blocks_per_slot:
            raise PoolExhausted(
                f"{total_tokens} tokens need {n} blocks > "
                f"max_blocks_per_slot={self.max_blocks_per_slot}"
            )
        if not self.free_slots:
            raise PoolExhausted("no free slots")
        blocks = self.allocator.alloc(n)  # raises before slot is consumed
        slot = self.free_slots.pop()
        self.slot_blocks[slot] = blocks
        self.block_tables[slot] = 0
        self.block_tables[slot, : len(blocks)] = blocks
        self._tables_device = None
        return slot

    def release_slot(self, slot: int) -> None:
        self.allocator.free(self.slot_blocks.pop(slot))
        self.block_tables[slot] = 0
        self.free_slots.append(slot)
        self._tables_device = None

    def tables_device(self) -> jnp.ndarray:
        if self._tables_device is None:
            self._tables_device = jnp.asarray(self.block_tables)
        return self._tables_device
