"""Deterministic fault injection + the typed serving-failure taxonomy.

The serving stack's core contract — greedy outputs bit-identical to
``dense_greedy_reference`` under any batch composition — is proven on
the happy path by the sim harness. This module supplies the *unhappy*
path: a seeded, replayable :class:`FaultPlan` that injects failures at
the real seams of the engine, and the typed error hierarchy the engine
fails closed with when recovery is impossible.

Fault sites (see docs/serving_robustness.md for the recovery ladder):

``upload``
    Host→device copy of a PMQ expert-bucket row
    (``offload._upload_batch``). Key: ``(layer, slot)``.
    ``corrupt`` = payload damaged in transit (caught by the per-row
    checksum, re-fetched); ``fail`` = transient/persistent I/O error
    (retried with logical-step backoff, then degraded to a lower-bit
    copy or failed closed).
``swap_out`` / ``swap_in``
    KV page traffic for preempted slots (``kvcache.swap_out`` /
    ``swap_in``). Key: request id. ``corrupt`` damages the host payload
    (caught by the :class:`~repro.serving.kvcache.SwappedKV` checksum);
    ``fail`` raises. Both recover by falling back to bit-exact
    recompute re-prefill.
``pool``
    Transient page-pool exhaustion: the controller's ``Observation``
    sees ``arg`` fewer free pages than physically exist (planning-only
    — batch-composition independence keeps outputs unchanged).
    Key: ``None``.
``logits``
    A poisoned request: the final prefill logits row turns non-finite.
    Key: request id. The engine's finite-guard terminates exactly that
    request with :class:`PoisonedRequest` and a clean release.

A plan is *replayable*: it is keyed on the logical step (the engine
calls :meth:`FaultPlan.at_step` at every megastep boundary — never a
wall clock) and the call sequence of ``fire(site, key)``, which is
itself a deterministic function of the request trace and engine config.
Two runs with equal plans inject byte-identical faults, so the fuzzed
fail-closed invariant (bit-exact-or-typed-error, counters replay
bit-identically) is checkable.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Hashable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FAULT_MODES",
    "FAULT_SITES",
    "DeadlineExceeded",
    "ExpertUploadFailed",
    "FaultPlan",
    "FaultSpec",
    "InvalidRequest",
    "LivelockDetected",
    "PoisonedRequest",
    "RequestCancelled",
    "ServingFault",
    "SwapFault",
    "WatchdogTimeout",
    "checksum_tree",
    "corrupt_tree",
]

FAULT_SITES = ("upload", "swap_out", "swap_in", "pool", "logits")
FAULT_MODES = ("fail", "corrupt")


# --------------------------------------------------------------- errors
class ServingFault(RuntimeError):
    """Base of every typed serving failure (the fail-closed contract:
    a request either completes bit-identical to the fault-free run or
    terminates with a subclass of this — never silent corruption)."""

    def __init__(self, msg: str, *, rid: Optional[int] = None):
        super().__init__(msg)
        self.rid = rid


class RequestCancelled(ServingFault):
    """Client called ``engine.cancel(rid)`` mid-flight."""


class DeadlineExceeded(ServingFault):
    """``Request.deadline_steps`` elapsed before completion."""


class PoisonedRequest(ServingFault):
    """Non-finite logits surfaced for this request (finite-guard)."""


class ExpertUploadFailed(ServingFault):
    """An expert row's target-bit upload failed past the retry budget
    and precision-ladder degradation was disabled or impossible."""


class SwapFault(ServingFault):
    """KV swap payload failed its checksum or I/O (internal: the engine
    recovers by recompute re-prefill; surfaces only on double faults)."""


class WatchdogTimeout(ServingFault):
    """A megastep exceeded the wall-clock watchdog budget."""


class LivelockDetected(ServingFault):
    """The engine had work but made no progress for too many
    consecutive megastep boundaries."""


class InvalidRequest(ServingFault, ValueError):
    """Rejected at ``Scheduler.submit`` time (empty prompt,
    non-positive ``max_new``, negative priority, duplicate live rid,
    non-positive deadline). Also a ``ValueError`` so callers predating
    the typed taxonomy keep working."""


# ----------------------------------------------------------- fault plan
@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault. ``key=None`` is a wildcard (first matching
    call fires it); ``count=-1`` never exhausts (persistent)."""

    site: str
    mode: str = "fail"
    key: Optional[Hashable] = None
    step: int = 0  # arms at logical step >= step
    until: Optional[int] = None  # disarms at logical step >= until
    count: int = 1  # max firings; -1 = persistent
    arg: int = 0  # site-specific magnitude (pool: pages hidden)

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(f"fault site {self.site!r} not in {FAULT_SITES}")
        if self.mode not in FAULT_MODES:
            raise ValueError(f"fault mode {self.mode!r} not in {FAULT_MODES}")


class FaultPlan:
    """A deterministic, replayable fault schedule.

    The engine advances :attr:`step` at every megastep boundary
    (:meth:`at_step`); injection sites call :meth:`fire` with their
    site name and key and act on the returned spec (or ``None``).
    Matching consumes the spec's ``count``, so a plan's firings are a
    pure function of the (deterministic) call sequence.
    """

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs: List[FaultSpec] = list(specs)
        self.step = 0
        self._fired = [0] * len(self.specs)
        self.injected = 0
        # (step, site, key, mode) per firing — the replay-checkable log
        self.log: List[Tuple[int, str, Optional[Hashable], str]] = []

    def at_step(self, step: int) -> None:
        self.step = int(step)

    def fire(self, site: str, key: Optional[Hashable] = None
             ) -> Optional[FaultSpec]:
        """Consume and return the first armed spec matching
        ``(site, key)`` at the current logical step, else ``None``."""
        for i, s in enumerate(self.specs):
            if s.site != site:
                continue
            if s.key is not None and s.key != key:
                continue
            if self.step < s.step:
                continue
            if s.until is not None and self.step >= s.until:
                continue
            if s.count >= 0 and self._fired[i] >= s.count:
                continue
            self._fired[i] += 1
            self.injected += 1
            self.log.append((self.step, site, key, s.mode))
            return s
        return None

    def replay(self) -> "FaultPlan":
        """A fresh plan with the same schedule (for replay runs)."""
        return FaultPlan(self.specs)

    @classmethod
    def generate(
        cls,
        seed: int,
        *,
        n_faults: int = 4,
        max_step: int = 24,
        sites: Sequence[str] = FAULT_SITES,
        rids: Sequence[int] = (),
        expert_keys: Sequence[Tuple[int, int]] = (),
        persistent: bool = False,
        max_count: int = 2,
    ) -> "FaultPlan":
        """Seeded random schedule for fuzzing. ``rids`` feeds the
        swap/logits keys, ``expert_keys`` the ``(layer, slot)`` upload
        keys (empty = wildcard faults). ``persistent=False`` keeps every
        fault transient — the regime where recovery must reproduce the
        fault-free run bit-identically."""
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(int(n_faults)):
            site = str(sites[int(rng.integers(len(sites)))])
            if site == "pool":
                mode = "fail"
            elif site == "logits":
                mode = "corrupt"
            else:
                mode = FAULT_MODES[int(rng.integers(2))]
            key: Optional[Hashable] = None
            if site == "upload" and expert_keys:
                key = tuple(expert_keys[int(rng.integers(len(expert_keys)))])
            elif site in ("swap_out", "swap_in", "logits") and rids:
                key = int(rids[int(rng.integers(len(rids)))])
            count = -1 if persistent else int(rng.integers(1, max_count + 1))
            specs.append(FaultSpec(
                site=site, mode=mode, key=key,
                step=int(rng.integers(0, max_step)), count=count,
                arg=int(rng.integers(1, 9)),
            ))
        return cls(specs)


# ------------------------------------------------------------ checksums
def checksum_tree(tree) -> int:
    """CRC32 folded over every array leaf of ``tree`` in deterministic
    (tree-flatten) order — the integrity tag carried by host-side
    payloads (expert bucket rows, ``SwappedKV`` pages)."""
    import jax

    crc = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        crc = zlib.crc32(np.ascontiguousarray(leaf).tobytes(), crc)
    return crc


def corrupt_tree(tree):
    """A structurally identical copy of ``tree`` with the first leaf's
    leading byte bit-flipped — the canonical injected payload
    corruption (guaranteed to break :func:`checksum_tree`)."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        a = np.array(leaf, copy=True)
        if i == 0 and a.size:
            raw = a.view(np.uint8).reshape(-1)
            raw[0] ^= 0xFF
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out)
