"""Declarative resource controller: observe → target → plan → converge.

One controller owns every runtime resource pool the serving stack
juggles — request slots (:mod:`.scheduler`), KV pages + prefix-cache
pages (:mod:`.kvcache`), and resident expert partitions
(:mod:`.offload`). Each megastep boundary it

(a) **observes** a consistent snapshot of the world (queue composition
    per tenant, slot occupancy, free + reclaimable pages, prefix-cache
    LRU, per-(layer, expert) routing EMA from the telemetry),
(b) computes a declarative **target state** — which requests should
    hold slots, how many pages each may grow, which expert rows should
    be resident per bucket — and
(c) emits a **bounded plan** of convergence actions (admit / preempt /
    grow / evict-prefix / shed / upload-experts) that the engine
    executes in order.

The reconciliation pattern (dagster's ``asset_reconciliation_sensor``:
compute target from observed lag, converge incrementally) replaces the
imperative per-step ``_ensure_pages`` / ``_prefetch_experts`` /
admit-loop call sites that used to mutate the pools directly from
``engine.py``. The plan is bounded by construction: at most one
preempt + one grow per observed active, one admit-or-shed per observed
waiter, and one expert-upload action per boundary.

**Exactness.** Planning simulates page accounting on a
:class:`_PageLedger` that mirrors :class:`~.kvcache.BlockAllocator` /
:class:`~.kvcache.PrefixCache` semantics *exactly* (refcounts, LRU
eviction order, reclaimability = drop-count == refcount, copy-on-write
admission math), so a planned action never fails at execution time
under single-threaded stepping. Execution still re-validates every
admission against live state (:meth:`Scheduler.admit_planned`) and
growth keeps a reactive preemption fallback, so a divergence would
degrade to the old imperative behavior rather than crash.

Scheduling policy (which waiter admits first, who gets victimized) is
delegated to the :class:`~.scheduler.Scheduler`'s policy methods —
``admission_order`` / ``victim_key`` — so the controller is policy-
agnostic; see docs/serving_scheduling.md for the glossary and the
fairness × preemption × residency interactions.

Every planned action flows through the lifecycle-event stream when the
engine executes it, so traces, counters, and the batch-composition-
independence invariant survive the refactor unchanged; the plan itself
is additionally visible as one ``plan`` lifecycle event per non-empty
boundary (scalar action counts only).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .scheduler import Request, Scheduler

__all__ = ["PlanAction", "Observation", "TargetState", "ResourceController"]


@dataclasses.dataclass(frozen=True)
class PlanAction:
    """One convergence step. ``kind`` ∈ {admit, preempt, grow,
    evict_prefix, shed, upload_experts}; the other fields are
    kind-specific (see docs/serving_scheduling.md for the glossary)."""

    kind: str
    rid: int = -1            # admit/shed: the request; preempt: the victim
    slot: int = -1           # preempt/grow: the slot acted on
    tenant: str = ""         # admit/preempt/shed: the request's tenant
    pages: int = 0           # grow: pages to append; evict_prefix: free target
    protect: Tuple[int, ...] = ()  # evict_prefix: pages the admission shares
    for_rid: int = -1        # preempt: the grower the freed pages serve
    for_tenant: str = ""     # preempt: that grower's tenant
    waited_steps: int = 0    # shed: logical steps the request waited
    uploads: int = 0         # upload_experts: (layer, bucket) groups touched
    targets: Tuple = ()      # upload_experts: ((bucket, layer, desired…), …)


@dataclasses.dataclass(frozen=True)
class _PrefixSnap:
    """Read-only planning view of one prefix-cache entry."""

    key: bytes
    pages: Tuple[int, ...]
    n_tokens: int
    has_logits: bool


@dataclasses.dataclass(frozen=True)
class Observation:
    """Consistent snapshot of observed state at a megastep boundary."""

    step_idx: int
    now_s: float
    free_pages: int
    free_slot_count: int
    refcounts: Dict[int, int]                 # allocated page → holders
    slot_pages: Dict[int, Tuple[int, ...]]    # live slot → its pages
    prefix_entries: Tuple[_PrefixSnap, ...]   # LRU order, oldest first
    active: Tuple[Tuple[int, Request], ...]   # (slot, req), admit_seq order
    waiting: Tuple[Request, ...]              # policy admission order
    tenants: Dict[str, Dict[str, int]]        # tenant → queue composition
    deficits: Dict[str, float]                # tenant → WDRR deficit
    # injected transient page-pool exhaustion (``pool`` fault site):
    # admission behaves as if this many free pages were unavailable.
    # Planning-only — held pages are real and growth is untouched, so
    # the penalty defers admissions (output-invariant by the
    # batch-composition-independence contract) without ever invalidating
    # the ledger's exactness for pages the pool actually holds.
    pool_penalty: int = 0


@dataclasses.dataclass(frozen=True)
class TargetState:
    """The declarative target the plan converges toward."""

    hold_slots: Tuple[int, ...]       # rids that hold a slot after converge
    page_targets: Dict[int, int]      # rid → total pages it may hold
    admit_rids: Tuple[int, ...]       # waiters that should gain a slot
    shed_rids: Tuple[int, ...]        # waiters past their TTFT budget
    victim_rids: Tuple[int, ...]      # actives that should yield their slot
    expert_targets: Tuple = ()        # ((bucket, layer, desired…), …)


class _PageLedger:
    """Pure page-accounting simulation over an :class:`Observation`.

    Mirrors :class:`~.kvcache.BlockAllocator` refcount semantics and
    :class:`~.kvcache.PrefixCache` LRU eviction / reclaimability
    *exactly*, so the planner can pre-play evict/preempt/grow/admit
    sequences and know what the real pools will do at execution time.
    Pages granted by planned grows have no physical identity yet; they
    are tracked as per-slot fresh counts (refcount 1 by construction —
    a grown page is never shared until prefill registers it, which
    happens after the plan window closes).
    """

    def __init__(self, obs: Observation, block_size: int):
        self.block_size = block_size
        self.free = obs.free_pages
        self.ref: Dict[int, int] = dict(obs.refcounts)
        self.slot_pages: Dict[int, List[int]] = {
            s: list(p) for s, p in obs.slot_pages.items()
        }
        self.slot_fresh: Dict[int, int] = {}
        self.free_slots = obs.free_slot_count
        self.entries: List[dict] = [
            {
                "key": e.key,
                "pages": tuple(e.pages),
                "n_tokens": e.n_tokens,
                "has_logits": e.has_logits,
                "alive": True,
            }
            for e in obs.prefix_entries
        ]
        self._by_key = {e["key"]: e for e in self.entries}

    # ------------------------------------------------------ allocator ops
    def _drop_ref(self, pg: int) -> None:
        self.ref[pg] -= 1
        if self.ref[pg] == 0:
            del self.ref[pg]
            self.free += 1

    def _evict_entry(self, ent: dict) -> None:
        ent["alive"] = False
        for pg in ent["pages"]:
            self._drop_ref(pg)

    def reclaimable(self, protect: frozenset = frozenset()) -> int:
        drop: Dict[int, int] = {}
        for ent in self.entries:
            if not ent["alive"]:
                continue
            if protect and not protect.isdisjoint(ent["pages"]):
                continue
            for pg in ent["pages"]:
                drop[pg] = drop.get(pg, 0) + 1
        return sum(1 for pg, d in drop.items() if d == self.ref.get(pg, 0))

    def available(self, protect: frozenset = frozenset()) -> int:
        return self.free + self.reclaimable(protect)

    def evict_for(self, n: int, protect: frozenset = frozenset()) -> int:
        """LRU eviction until ``n`` pages are free (or nothing evictable
        remains) — byte-for-byte the :meth:`PrefixCache.evict_for` walk."""
        evicted = 0
        while self.free < n:
            victim = None
            for ent in self.entries:  # LRU order, oldest first
                if ent["alive"] and (
                    not protect or protect.isdisjoint(ent["pages"])
                ):
                    victim = ent
                    break
            if victim is None:
                break
            self._evict_entry(victim)
            evicted += 1
        return evicted

    # -------------------------------------------------------- slot ops
    def preempt(self, slot: int) -> None:
        """Victim's pages free by refcount (shared prefix pages survive
        as cache holds); planned-grow fresh pages return outright."""
        for pg in self.slot_pages.pop(slot, []):
            self._drop_ref(pg)
        self.free += self.slot_fresh.pop(slot, 0)
        self.free_slots += 1

    def grow(self, slot: int, n: int) -> None:
        self.evict_for(n)
        assert self.free >= n, "planner grow after evict_for must fit"
        self.free -= n
        self.slot_fresh[slot] = self.slot_fresh.get(slot, 0) + n

    def admit(self, fresh_pages: int, shared: Tuple[int, ...]) -> None:
        """Caller ran :meth:`evict_for` (with the admission's protect
        set) first; mirrors ``acquire_slot``: fresh pages allocate,
        shared prefix pages gain one reference."""
        assert self.free >= fresh_pages, "planner admit after evict_for"
        self.free -= fresh_pages
        for pg in shared:
            self.ref[pg] = self.ref.get(pg, 0) + 1
        self.free_slots -= 1

    # ------------------------------------------------------ prefix peek
    def peek_prefix(self, prompt: np.ndarray) -> Optional[dict]:
        """Non-mutating twin of :meth:`PrefixCache.lookup` over the
        *surviving* (non-evicted-in-plan) entries, including the
        full-hit-without-logits demotion to ``prompt[:-1]``."""
        ent = self._probe(prompt)
        if (
            ent is not None
            and ent["n_tokens"] == len(prompt)
            and not ent["has_logits"]
        ):
            ent = self._probe(prompt[: len(prompt) - 1])
        return ent

    def _probe(self, prompt: np.ndarray) -> Optional[dict]:
        prompt = np.ascontiguousarray(prompt, np.int32)
        p = len(prompt)
        bs = self.block_size
        probes = [p] + [j * bs for j in range(p // bs, 0, -1) if j * bs != p]
        for n in probes:
            ent = self._by_key.get(prompt[:n].tobytes())
            if ent is not None and ent["alive"]:
                return ent
        return None


class ResourceController:
    """The reconciliation loop over slots, pages, and resident experts.

    ``plan_boundary(step_idx, now_s)`` = observe → reconcile → plan; the
    engine executes the returned actions in order and then runs the
    megastep. Policy ordering lives in the scheduler; page math in the
    ledger; expert targets in ``offload.residency_targets()`` (pure).
    """

    def __init__(self, scheduler: Scheduler, offload=None, tracer=None,
                 *, ttft_budget_steps: Optional[int] = None,
                 ttft_budget_s: Optional[float] = None, faults=None):
        if ttft_budget_steps is not None and ttft_budget_steps < 0:
            raise ValueError("ttft_budget_steps must be ≥ 0")
        if ttft_budget_s is not None and ttft_budget_s < 0:
            raise ValueError("ttft_budget_s must be ≥ 0")
        if tracer is None:
            from .trace import NULL_TRACER

            tracer = NULL_TRACER
        self.scheduler = scheduler
        self.cache = scheduler.cache
        self.offload = offload
        self.tracer = tracer
        self.ttft_budget_steps = ttft_budget_steps
        self.ttft_budget_s = ttft_budget_s
        self.faults = faults
        self.last_pool_penalty = 0

    # ---------------------------------------------------------- observe
    def observe(self, step_idx: int, now_s: float = 0.0) -> Observation:
        cache, sched = self.cache, self.scheduler
        refcounts = {
            pg: cache.allocator.refcount(pg) for pg in cache.allocator.allocated
        }
        prefix_entries: Tuple[_PrefixSnap, ...] = ()
        if cache.prefix is not None:
            prefix_entries = tuple(
                _PrefixSnap(
                    key=e.key,
                    pages=tuple(e.pages),
                    n_tokens=e.n_tokens,
                    has_logits=e.last_logits is not None,
                )
                for e in cache.prefix.snapshot()
            )
        active = tuple(
            sorted(sched.active.items(), key=lambda kv: kv[1].admit_seq)
        )
        waiting = tuple(sched.admission_order())
        tenants: Dict[str, Dict[str, int]] = {}
        for r in sched.waiting:
            t = tenants.setdefault(
                r.tenant, {"waiting": 0, "active": 0, "queued_tokens": 0}
            )
            t["waiting"] += 1
            t["queued_tokens"] += r.total_tokens
        for r in sched.active.values():
            t = tenants.setdefault(
                r.tenant, {"waiting": 0, "active": 0, "queued_tokens": 0}
            )
            t["active"] += 1
        pool_penalty = 0
        if self.faults is not None:
            spec = self.faults.fire("pool")
            if spec is not None:
                pool_penalty = max(0, int(spec.arg))
                self.tracer.lifecycle(
                    "fault", track="pool", site="pool", mode=spec.mode,
                    pages=pool_penalty, step=step_idx,
                )
        # the engine's thrash circuit-breaker consults this: an injected
        # penalty makes "nothing admitted though the queue has work" a
        # legitimate *transient* state, not a livelock
        self.last_pool_penalty = pool_penalty
        return Observation(
            step_idx=step_idx,
            now_s=now_s,
            free_pages=cache.allocator.num_free,
            free_slot_count=len(cache.free_slots),
            refcounts=refcounts,
            slot_pages={
                s: tuple(p) for s, p in cache.slot_blocks.items()
            },
            prefix_entries=prefix_entries,
            active=active,
            waiting=waiting,
            tenants=tenants,
            deficits=sched.deficits(),
            pool_penalty=pool_penalty,
        )

    # -------------------------------------------------------- reconcile
    def _overdue(self, req: Request, obs: Observation) -> bool:
        """Past its TTFT budget? (shed-eligible iff also fresh)"""
        if self.ttft_budget_steps is not None:
            if obs.step_idx - req.submit_step > self.ttft_budget_steps:
                return True
        if self.ttft_budget_s is not None:
            if obs.now_s - req.arrival_s > self.ttft_budget_s:
                return True
        return False

    def reconcile(self, obs: Observation) -> Tuple[TargetState, List[PlanAction]]:
        """Diff observed state against the policy's desires; return the
        target plus the ordered convergence plan. Pure over ``obs`` and
        the ledger — no pool is touched here."""
        sched = self.scheduler
        cache = self.cache
        ledger = _PageLedger(obs, cache.block_size)
        actions: List[PlanAction] = []
        horizon = sched.horizon

        # ---- phase 1: page convergence for surviving actives ----------
        # Oldest-admitted first (the historical _ensure_pages walk).
        # A slot that cannot get its next-megastep pages triggers policy-
        # ordered preemption; the grower may victimize itself, in which
        # case it yields instead of growing.
        alive: Dict[int, Request] = {s: r for s, r in obs.active}
        victims: List[Request] = []
        page_targets: Dict[int, int] = {}
        for slot, req in obs.active:
            if slot not in alive:
                continue
            need = cache.slot_deficit(
                slot, req.pos + req.next_decode_writes(horizon)
            )
            page_targets[req.rid] = len(ledger.slot_pages.get(slot, ())) + max(need, 0)
            if need <= 0:
                continue
            while ledger.available() < need and slot in alive:
                vslot = max(
                    alive, key=lambda s: sched.victim_key(alive[s])
                )
                vreq = alive.pop(vslot)
                victims.append(vreq)
                actions.append(PlanAction(
                    kind="preempt", rid=vreq.rid, slot=vslot,
                    tenant=vreq.tenant, for_rid=req.rid,
                    for_tenant=req.tenant,
                ))
                ledger.preempt(vslot)
            if slot not in alive:
                continue  # self-preempted: the pages go back to the pool
            if ledger.free < need and ledger.reclaimable() > 0:
                actions.append(PlanAction(
                    kind="evict_prefix", pages=need, for_rid=req.rid,
                ))
            ledger.grow(slot, need)
            actions.append(PlanAction(
                kind="grow", rid=req.rid, slot=slot, tenant=req.tenant,
                pages=need,
            ))

        # ---- phase 2: admission + SLO shed over the policy order ------
        # Candidates are the boundary's *observed* waiters (requests the
        # plan itself preempts re-queue at the head but sit out until the
        # next boundary, matching the historical admit-before-grow
        # timing). Strict order: a waiter that fits admits; an overdue
        # fresh waiter that cannot admit is shed; the first blocked
        # non-sheddable waiter ends *admission* (no out-of-order
        # admission within a policy's order — FCFS stays FCFS), but the
        # shed scan continues past it: a blocked head must not let
        # overdue waiters behind it queue unboundedly.
        admits: List[Request] = []
        sheds: List[Request] = []
        admitting = True
        for req in obs.waiting:
            fits = False
            if admitting:
                entry = None
                if cache.prefix is not None and Scheduler._is_fresh(req):
                    entry = ledger.peek_prefix(req.prompt)
                tokens = sched.admit_tokens(req)
                n = cache.blocks_needed(tokens)
                shared = (
                    entry["n_tokens"] // cache.block_size
                    if entry is not None else 0
                )
                fresh_pages = n - shared
                protect = (
                    frozenset(entry["pages"]) if entry is not None
                    else frozenset()
                )
                fits = (
                    ledger.free_slots > 0
                    and n <= cache.max_blocks_per_slot
                    # pool_penalty: injected transient exhaustion defers
                    # admission this boundary (planning-only, see
                    # Observation)
                    and fresh_pages <= ledger.available(protect)
                    - obs.pool_penalty
                )
            if fits:
                if ledger.free < fresh_pages:
                    actions.append(PlanAction(
                        kind="evict_prefix", pages=fresh_pages,
                        protect=tuple(sorted(protect)), for_rid=req.rid,
                    ))
                ledger.evict_for(fresh_pages, protect)
                shared_pages = (
                    entry["pages"][:shared] if entry is not None else ()
                )
                ledger.admit(fresh_pages, tuple(shared_pages))
                admits.append(req)
                page_targets[req.rid] = n
                actions.append(PlanAction(
                    kind="admit", rid=req.rid, tenant=req.tenant,
                ))
            elif Scheduler._is_fresh(req) and self._overdue(req, obs):
                sheds.append(req)
                actions.append(PlanAction(
                    kind="shed", rid=req.rid, tenant=req.tenant,
                    waited_steps=obs.step_idx - req.submit_step,
                ))
            else:
                admitting = False

        # ---- phase 3: expert residency convergence --------------------
        expert_targets: Tuple = ()
        if self.offload is not None:
            expert_targets = tuple(self.offload.residency_targets())
            if expert_targets:
                actions.append(PlanAction(
                    kind="upload_experts",
                    uploads=len(expert_targets),
                    targets=expert_targets,
                ))

        victim_rids = tuple(r.rid for r in victims)
        target = TargetState(
            hold_slots=tuple(
                r.rid for _, r in obs.active if r.rid not in set(victim_rids)
            ) + tuple(r.rid for r in admits),
            page_targets=page_targets,
            admit_rids=tuple(r.rid for r in admits),
            shed_rids=tuple(r.rid for r in sheds),
            victim_rids=victim_rids,
            expert_targets=expert_targets,
        )
        return target, actions

    # ------------------------------------------------------------- plan
    def plan_boundary(self, step_idx: int, now_s: float = 0.0) -> List[PlanAction]:
        """One full reconciliation pass: refresh fairness grants,
        observe, reconcile, emit the ``plan`` lifecycle event (scalar
        action counts), and hand the ordered plan to the engine."""
        self.scheduler.refresh_grants()
        if self.offload is not None:
            # housekeeping before observing residency: drop prefetch-
            # backoff entries for rows that degraded or became resident
            # meanwhile, so the deferral map stays bounded
            self.offload.prune_backoff()
        obs = self.observe(step_idx, now_s)
        _, actions = self.reconcile(obs)
        if actions:
            counts: Dict[str, int] = {}
            for a in actions:
                counts[a.kind] = counts.get(a.kind, 0) + 1
            self.tracer.lifecycle(
                "plan", track="pool", step=step_idx,
                actions=len(actions),
                admits=counts.get("admit", 0),
                preempts=counts.get("preempt", 0),
                grows=counts.get("grow", 0),
                prefix_evictions=counts.get("evict_prefix", 0),
                sheds=counts.get("shed", 0),
                expert_uploads=counts.get("upload_experts", 0),
            )
        return actions
