"""Elastic scaling: rebuild the mesh at a smaller data extent and reshard.

On node loss the pod can usually be re-provisioned as a smaller clean
rectangle (e.g. data 16 → 12). The recipe:

1. ``shrink_mesh`` builds the new mesh (model extent preserved — TP/EP
   layouts never change, only DP width);
2. the checkpoint restores with the *new* shardings
   (``Checkpointer.restore(..., shardings=...)``);
3. the global batch is preserved by raising gradient-accumulation
   (``accum_for``), so optimization dynamics are unchanged.

Single-host CPU tests exercise the same code with tiny fake meshes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["shrink_mesh", "accum_for", "reshard_tree"]


def shrink_mesh(mesh: Mesh, new_data: int) -> Mesh:
    """Same axis names, smaller ``data`` extent (divisor of device count)."""
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    assert "data" in sizes, names
    assert new_data <= sizes["data"]
    sizes["data"] = new_data
    n_needed = int(np.prod(list(sizes.values())))
    devs = mesh.devices.reshape(-1)[:n_needed]
    return Mesh(devs.reshape([sizes[n] for n in names]), names)


def accum_for(global_batch: int, per_step_batch: int) -> int:
    """Gradient-accumulation factor preserving the global batch."""
    assert global_batch % per_step_batch == 0
    return global_batch // per_step_batch


def reshard_tree(tree, shardings):
    """device_put a pytree onto new shardings (elastic restore path)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings
    )
