"""Fault-tolerance runtime: failure detection, auto-restore, stragglers.

Single-process simulation of the multi-host control plane with the same
interfaces a real deployment wires to ``jax.distributed``:

* :class:`HeartbeatTable` — deadline-based failure detector (hosts post
  heartbeats; ``failed()`` after ``timeout``).
* :class:`StragglerMonitor` — per-step wall-time tracker; a host whose
  rolling median exceeds ``threshold ×`` fleet median is flagged. On TPU
  pods the mitigation is re-sharding that host's data shard away, which
  reuses the elastic path (``repro.runtime.elastic``).
* :class:`ResilientLoop` — wraps a step function with
  checkpoint-restore-retry semantics: on failure, restore the latest
  checkpoint and continue (optionally on a shrunken mesh).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional

__all__ = ["HeartbeatTable", "StragglerMonitor", "ResilientLoop", "FailurePolicy"]


class HeartbeatTable:
    """Deadline failure detector over an injectable clock.

    ``clock`` (default ``time.monotonic``) supplies the timestamps for
    every call that omits an explicit ``now`` — the serving watchdog and
    the unit tests drive the table with a fake clock, so expiry is
    deterministic and never sleeps."""

    def __init__(self, hosts: List[int], timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self._last: Dict[int, float] = {h: clock() for h in hosts}

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self._last[host] = now if now is not None else self.clock()

    def failed(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else self.clock()
        return [h for h, t in self._last.items() if now - t > self.timeout]

    def alive(self, now: Optional[float] = None) -> List[int]:
        bad = set(self.failed(now))
        return [h for h in self._last if h not in bad]


class StragglerMonitor:
    """Rolling median step times per host; flags slow hosts. Step times
    come from the caller's clock of choice (``record`` takes durations,
    not timestamps), so the monitor is deterministic by construction."""

    def __init__(self, window: int = 16, threshold: float = 1.5):
        self.window = window
        self.threshold = threshold
        self._times: Dict[int, deque] = defaultdict(lambda: deque(maxlen=window))

    def record(self, host: int, step_time: float) -> None:
        self._times[host].append(step_time)

    def _median(self, xs) -> float:
        s = sorted(xs)
        return s[len(s) // 2]

    def stragglers(self) -> List[int]:
        meds = {
            h: self._median(ts) for h, ts in self._times.items() if len(ts) >= 4
        }
        if len(meds) < 2:
            return []
        fleet = self._median(list(meds.values()))
        return [h for h, m in meds.items() if m > self.threshold * fleet]


@dataclasses.dataclass
class FailurePolicy:
    max_restarts: int = 3
    restore_fn: Optional[Callable[[], None]] = None  # restore latest ckpt
    shrink_fn: Optional[Callable[[], None]] = None  # elastic re-mesh
    shrink_after: int = 2  # restarts before giving up capacity


class ResilientLoop:
    """Run a training loop with restart-on-failure semantics.

    ``step_fn(step) -> metrics`` may raise; the loop restores from the
    checkpointer and retries, shrinking the mesh after repeated failures.
    All side effects are injected, so the policy is unit-testable without
    real hardware faults.
    """

    def __init__(self, policy: FailurePolicy):
        self.policy = policy
        self.restarts = 0
        self.events: List[Dict] = []

    def run(self, step_fn: Callable[[int], dict], start: int, steps: int):
        step = start
        metrics = None
        while step < start + steps:
            try:
                metrics = step_fn(step)
                step += 1
            except Exception as e:  # noqa: BLE001 - any step fault
                self.restarts += 1
                self.events.append({"step": step, "error": repr(e)})
                if self.restarts > self.policy.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.policy.max_restarts}"
                    ) from e
                if (
                    self.restarts >= self.policy.shrink_after
                    and self.policy.shrink_fn is not None
                ):
                    self.policy.shrink_fn()
                    self.events.append({"step": step, "action": "shrink"})
                if self.policy.restore_fn is not None:
                    self.policy.restore_fn()
                    self.events.append({"step": step, "action": "restore"})
        return metrics
