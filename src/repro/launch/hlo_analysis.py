"""Static analysis of optimized (post-SPMD) HLO text.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis does **not**
multiply while-loop bodies by their trip count, so scan-over-layers
programs (all of ours — compile-time independence of depth) under-count
FLOPs/bytes by ~num_layers×. This module parses ``compiled.as_text()``
into a computation graph, extracts per-computation

* dot FLOPs (``2 · prod(result) · prod(contracting dims)``),
* HBM traffic at *fusion granularity* (a fusion's operands + result move
  through HBM once; fused interiors live in registers/VMEM),
* collective operand bytes per collective kind,

and propagates multipliers through ``while`` edges (trip count recovered
from the loop condition's comparison constant), ``fusion``/``call``/
``conditional`` edges. Shapes in post-partitioning HLO are per-device, so
every total below is **per device**.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloSummary", "analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|u4|s4|pred|c64|c128)\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_NO_TRAFFIC_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    # pure layout/elementwise-relabel ops: fused into neighbors on TPU
    "copy", "transpose", "reshape", "broadcast", "convert",
}

# ops whose HBM cost is ~their result (reads are subsets / fused)
_RESULT_ONLY_OPS = {
    "slice", "dynamic-slice", "dynamic-update-slice", "concatenate",
    "gather", "scatter", "pad", "reduce", "select-and-scatter", "reverse",
}


def _shape_bytes(text: str) -> int:
    """Total bytes of every dtype[shape] token in ``text``."""
    tot = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        tot += n * _DTYPE_BYTES[dt]
    return tot


def _shape_dims(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result_shape: str  # raw text
    args: List[str]  # operand instruction names
    raw: str


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: Dict[str, _Instr]
    raw_lines: List[str]


@dataclasses.dataclass
class HloSummary:
    flops: float  # per device
    hbm_bytes: float  # per device (fusion-granular model)
    collective_bytes: Dict[str, float]  # per device, operand bytes by kind
    dot_flops_by_comp: Dict[str, float]
    trip_counts: Dict[str, int]
    num_collectives: Dict[str, int]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


# shape may be a tuple with layout braces: (s32[], f32[8,64]{1,0});
# the op name is the first bare `word(` after the shape (non-greedy)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$"
)
# params may nest parens (tuple args): greedy match up to `->`
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{$")
_TRIP_BC_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], str]:
    comps: Dict[str, _Comp] = {}
    entry = None
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEADER_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = _Comp(m.group(1), {}, [])
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                continue
        else:
            if stripped == "}" or stripped.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            cur.raw_lines.append(stripped)
            im = _INSTR_RE.match(stripped)
            if im:
                name, shape, op, rest = im.groups()
                args = re.findall(r"%([\w\.\-]+)", rest.split(")", 1)[0])
                cur.instrs[name] = _Instr(name, op, shape, args, stripped)
    return comps, entry or ""


def _trip_count(cond: _Comp) -> int:
    """Recover the static trip count from the loop condition: the constant
    in ``compare(%iv, %c), direction=LT`` (scan-style loops)."""
    consts: Dict[str, int] = {}
    for ln in cond.raw_lines:
        m = re.match(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*\S+\s+constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond.raw_lines:
        if " compare(" in ln and "direction=LT" in ln:
            for arg in re.findall(r"%([\w\.\-]+)", ln.split("compare(", 1)[1]):
                if arg in consts:
                    return consts[arg]
    # GE/GT countdown loops or unknown: be conservative
    if consts:
        return max(consts.values())
    return 1


def _dot_flops(instr: _Instr, comp: _Comp) -> float:
    res = _shape_dims(instr.result_shape)
    if res is None:
        return 0.0
    _, rdims = res
    out = 1.0
    for d in rdims:
        out *= d
    # contracting size from lhs shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.raw)
    contract = 1.0
    if m and instr.args:
        lhs = comp.instrs.get(instr.args[0])
        lhs_dims: Optional[List[int]] = None
        if lhs is not None:
            sh = _shape_dims(lhs.result_shape)
            lhs_dims = sh[1] if sh else None
        if lhs_dims is None:
            # operand defined elsewhere (parameter with inline shape in raw)
            sh = _shape_dims(instr.raw.split("dot(", 1)[1])
            lhs_dims = sh[1] if sh else []
        if m.group(1):
            for ax in m.group(1).split(","):
                ax = int(ax)
                if lhs_dims and ax < len(lhs_dims):
                    contract *= lhs_dims[ax]
    return 2.0 * out * contract


def analyze_hlo(text: str, default_trip: int = 1) -> HloSummary:
    comps, entry = _parse_computations(text)

    # per-computation local stats + edges
    local_flops: Dict[str, float] = {}
    local_bytes: Dict[str, float] = {}
    local_coll: Dict[str, Dict[str, float]] = {}
    local_coll_n: Dict[str, Dict[str, int]] = {}
    edges: Dict[str, List[Tuple[str, int]]] = {}  # comp -> [(callee, mult)]
    trip_counts: Dict[str, int] = {}

    for cname, comp in comps.items():
        fl = 0.0
        by = 0.0
        coll: Dict[str, float] = {}
        coll_n: Dict[str, int] = {}
        edges[cname] = []
        for ln in comp.raw_lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            name, shape, op, rest = im.groups()
            instr = comp.instrs[name]
            base_op = op.replace("-start", "")
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ln)
                cm = re.search(r"condition=%?([\w\.\-]+)", ln)
                tb = _TRIP_BC_RE.search(ln)  # XLA's own known_trip_count
                if tb:
                    trips = int(tb.group(1))
                elif cm and cm.group(1) in comps:
                    trips = _trip_count(comps[cm.group(1)])
                else:
                    trips = default_trip
                if bm:
                    edges[cname].append((bm.group(1), trips))
                    trip_counts[bm.group(1)] = trips
                continue
            if op in ("fusion", "call", "async-start"):
                for callee in re.findall(r"(?:calls|to_apply)=%?([\w\.\-]+)", ln):
                    edges[cname].append((callee, 0))  # 0 → bytes-only skip
                # fusion-granular HBM traffic: operands + result
                by += _shape_bytes(ln)
                continue
            if op == "conditional":
                for callee in re.findall(
                    r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w\.\-,%]+)",
                    ln,
                ):
                    for c2 in callee.replace("%", "").split(","):
                        if c2 in comps:
                            edges[cname].append((c2, 1))
                continue
            if base_op in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                operands = ln.split("(", 1)[1]
                b = 0
                for a in instr.args:
                    src = comp.instrs.get(a)
                    if src is not None:
                        b += _shape_bytes(src.result_shape)
                if b == 0:  # inline-shaped operands
                    b = _shape_bytes(operands)
                coll[base_op] = coll.get(base_op, 0.0) + b
                coll_n[base_op] = coll_n.get(base_op, 0) + 1
                by += _shape_bytes(instr.result_shape) + b
                continue
            if op == "dot":
                fl += _dot_flops(instr, comp)
                # dots stream both operands (weights re-read every step)
                by += _shape_bytes(instr.result_shape)
                for a in instr.args:
                    src = comp.instrs.get(a)
                    if src is not None:
                        by += _shape_bytes(src.result_shape)
                continue
            if op in _NO_TRAFFIC_OPS:
                continue
            if op in _RESULT_ONLY_OPS:
                by += _shape_bytes(instr.result_shape)
                continue
            # other compute op: write + one subsequent read (operands are
            # results of earlier ops — counting them again would triple-
            # count every edge)
            by += 2 * _shape_bytes(instr.result_shape)
        local_flops[cname] = fl
        local_bytes[cname] = by
        local_coll[cname] = coll
        local_coll_n[cname] = coll_n

    # FLOPs inside fused computations count at the fusion site multiplier;
    # bytes inside fused computations do NOT (VMEM). Build two multiplier
    # passes: flops-multiplier follows all edges, bytes-multiplier follows
    # while/conditional edges only.
    def propagate(follow_fusion: bool) -> Dict[str, float]:
        mult: Dict[str, float] = {entry: 1.0}
        order = [entry]
        seen = {entry}
        # BFS over call graph (acyclic in HLO)
        i = 0
        while i < len(order):
            c = order[i]
            i += 1
            for callee, trips in edges.get(c, []):
                if callee not in comps:
                    continue
                m = mult.get(c, 0.0)
                if trips == 0:  # fusion/call edge
                    inc = m if follow_fusion else 0.0
                else:
                    inc = m * trips if follow_fusion else mult.get(c, 0.0) * trips
                if not follow_fusion and trips == 0:
                    # bytes: descend into call/fusion bodies with 0 (already
                    # counted at call site)
                    inc = 0.0
                mult[callee] = mult.get(callee, 0.0) + inc
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
        return mult

    mult_flops = propagate(follow_fusion=True)
    mult_bytes = propagate(follow_fusion=False)
    # collectives are never inside fusions; use bytes multipliers (while-aware)
    flops = sum(local_flops[c] * mult_flops.get(c, 0.0) for c in comps)
    hbm = sum(local_bytes[c] * mult_bytes.get(c, 0.0) for c in comps)
    coll_total: Dict[str, float] = {}
    coll_count: Dict[str, int] = {}
    for c in comps:
        m = mult_bytes.get(c, 0.0)
        for k, v in local_coll[c].items():
            coll_total[k] = coll_total.get(k, 0.0) + v * m
            coll_count[k] = coll_count.get(k, 0) + int(local_coll_n[c][k] * max(m, 0))
    dot_by_comp = {
        c: local_flops[c] * mult_flops.get(c, 0.0)
        for c in comps
        if local_flops[c] > 0
    }
    return HloSummary(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=coll_total,
        dot_flops_by_comp=dot_by_comp,
        trip_counts=trip_counts,
        num_collectives=coll_count,
    )
