"""Spec-level builders for quantized/compressed model parameter trees.

The dry-run never materializes parameters — these builders construct the
*pytree structure* (PackedTensor / CompressedExperts containers holding
``ShapeDtypeStruct`` leaves via ``jax.eval_shape``) for:

* the PMQ-compressed MoE LM (stacked per-layer arrays so the model's
  ``lax.scan`` slices each layer's packed experts — DESIGN.md §5.4), and
* uniform ``attn_bits``-quantized dense models (the paper's "Uni"
  baseline, which is what PMQ degenerates to without experts).

``concrete=True`` returns zero-filled real arrays (used by tests and the
serve example on reduced configs).
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from ..core.packing import PackedTensor
from ..core.pipeline import synthetic_stacked_compressed
from ..core import otp as otp_mod

__all__ = [
    "make_compressed_moe_params",
    "quantize_dense_param_tree",
    "make_otp_stacked",
]

_PER = {1: 8, 2: 4, 3: 8, 4: 2, 8: 1}


def _pt_stack(l: int, k: int, n: int, bits: int, group: int) -> PackedTensor:
    """PackedTensor with a leading stacked layer dim (scan slices it)."""
    if bits == 3:
        data = (
            jnp.zeros((l, k // 4, n), jnp.uint8),
            jnp.zeros((l, k // 8, n), jnp.uint8),
        )
    else:
        data = jnp.zeros((l, k // _PER[bits], n), jnp.uint8)
    ng = (k + group - 1) // group
    return PackedTensor(
        data=data,
        scale=jnp.zeros((l, ng, n), jnp.bfloat16),
        zero=jnp.zeros((l, ng, n), jnp.bfloat16),
        bits=bits,
        shape=(k, n),
        group=group,
        axis=0,
    )


def _build_compressed_moe(cfg, avg_bits: float, with_otp: bool):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    l, d = cfg.num_layers, cfg.d_model
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ab, g = cfg.quant.attn_bits, cfg.quant.group
    attn = {
        "wq": {"w": _pt_stack(l, d, hq * dh, ab, g)},
        "wk": {"w": _pt_stack(l, d, hkv * dh, ab, g)},
        "wv": {"w": _pt_stack(l, d, hkv * dh, ab, g)},
        "wo": {"w": _pt_stack(l, hq * dh, d, ab, g)},
    }
    if cfg.qk_norm:
        attn["q_norm"] = jnp.zeros((l, dh), dt)
        attn["k_norm"] = jnp.zeros((l, dh), dt)
    moe_p: Dict = {"router": {"w": jnp.zeros((l, d, cfg.num_experts), jnp.float32)}}
    if cfg.num_shared_experts:
        f = cfg.d_ff_expert * cfg.num_shared_experts
        moe_p["shared"] = {
            "w_gate": {"w": _pt_stack(l, d, f, ab, g)},
            "w_up": {"w": _pt_stack(l, d, f, ab, g)},
            "w_down": {"w": _pt_stack(l, f, d, ab, g)},
        }
    blocks = {
        "ln1": jnp.zeros((l, d), dt),
        "attn": attn,
        "ln2": jnp.zeros((l, d), dt),
        "moe": moe_p,
        "moe_ce": synthetic_stacked_compressed(cfg, avg_bits),
    }
    if with_otp:
        blocks["otp"] = make_otp_stacked(cfg, concrete=True)
    params = {
        "embed": jnp.zeros((cfg.vocab_size, d), dt),
        "blocks": blocks,
        "final_norm": jnp.zeros((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jnp.zeros((cfg.vocab_size, d), dt)
    return params


def make_compressed_moe_params(
    cfg, avg_bits: float = 2.25, with_otp: bool = False, concrete: bool = False
):
    """Stacked compressed-LM param tree (spec by default)."""
    build = partial(_build_compressed_moe, cfg, avg_bits, with_otp)
    return build() if concrete else jax.eval_shape(build)


def make_otp_stacked(cfg, concrete: bool = True):
    l, d, k = cfg.num_layers, cfg.d_model, cfg.top_k
    tree = {
        "fc1": jnp.zeros((l, d, k), jnp.float32),
        "fc2": jnp.zeros((l, 2 * k, k), jnp.float32),
    }
    return tree if concrete else jax.eval_shape(lambda: tree)


def quantize_dense_param_tree(param_spec, cfg):
    """Uniform-quantized spec: stacked [L,K,N] / flat [K,N] ``w`` leaves →
    PackedTensor specs at ``cfg.quant.attn_bits`` (embeddings stay 16-bit,
    matching the paper's accounting). Works on SDS trees (dry-run)."""
    ab, g = cfg.quant.attn_bits, cfg.quant.group

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        nd = getattr(leaf, "ndim", 0)
        if name != "w" or nd not in (2, 3):
            return leaf
        if nd == 3:
            l, k, n = leaf.shape
            if k % g or k % 8:
                return leaf
            return jax.eval_shape(lambda: _pt_stack(l, k, n, ab, g))
        k, n = leaf.shape
        if k % g or k % 8:
            return leaf
        spec = jax.eval_shape(lambda: _pt_stack(1, k, n, ab, g))
        # drop the stacked dim for flat leaves
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), spec
        )

    return jax.tree_util.tree_map_with_path(
        one, param_spec, is_leaf=lambda x: hasattr(x, "ndim")
    )