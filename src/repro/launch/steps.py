"""Step-function builders shared by dryrun / train / serve launchers.

Each builder returns ``(fn, arg_specs, in_shardings, donate_argnums)``
ready for ``jax.jit(...).lower(*arg_specs)``:

* ``train`` — full training (value_and_grad + AdamW; microbatch
  gradient accumulation via ``lax.scan`` when ``accum > 1``);
* ``train-otp`` — the paper's OTP router distillation on a frozen
  PMQ-compressed backbone (kimi-k2 default — DESIGN.md §9);
* ``prefill`` / ``decode`` — serving steps, bf16 or PMQ-quantized
  (``precision='quant'``: compressed experts for MoE, uniform
  ``attn_bits`` for dense — the paper's "Uni" degenerate case).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import layers as Lx
from ..models import transformer as tf
from ..models.registry import ModelBundle, get_model
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..parallel import sharding as shd
from . import specs as spec_mod

__all__ = ["StepArtifacts", "build_step"]


@dataclasses.dataclass
class StepArtifacts:
    name: str
    fn: Any
    arg_specs: Tuple
    in_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict
    out_shardings: Any = None


def _batch_shardings(mesh, batch_spec):
    ba = shd.batch_axes(mesh)

    def one(leaf):
        nd = leaf.ndim
        spec = P(*([ba] + [None] * (nd - 1))) if nd >= 1 else P()
        if not shd._divides(leaf.shape, spec, mesh):
            spec = P(*([None] * nd))
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, batch_spec)


def _cache_shardings(mesh, cache_spec, long_context: bool):
    ba = shd.batch_axes(mesh)

    def one(leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd == 5:  # [L, B, S, H, dh]
            spec = shd.cache_pspec(
                mesh, leaf.shape, prefer="seq" if long_context else "batch"
            )
        elif nd >= 2:
            # [L, B, ...] states: batch on dim 1 when it divides
            spec = P(None, ba, *([None] * (nd - 2)))
        else:
            spec = P(*([None] * nd))
        if not shd._divides(leaf.shape, spec, mesh):
            spec = P(*([None] * nd))
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, cache_spec)


def _opt_shardings(mesh, opt_spec, param_shardings, stacked_prefixes=None):
    """Optimizer state: mirror of the param sharding **plus ZeRO-1 FSDP** —
    m/v/master additionally shard over ``data`` on the first unsharded
    axis that divides. Scalars and 8-bit flat states handled explicitly.
    """
    import re

    stacked_prefixes = stacked_prefixes or shd.STACKED_PREFIXES
    data = mesh.shape.get("data", 1)

    def one(path, leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd == 0:
            return NamedSharding(mesh, P())
        ps = shd._path_str(path)
        suffix = ps.split("/")[-1]
        core = re.sub(r"^per_param/", "", ps)
        core = re.sub(r"/(m|v|master|q|scale)(/[0-9]+)?$", "", core)
        if suffix in ("q", "scale") and nd == 1:
            # 8-bit flattened state: shard across everything that divides
            for axes in (("data", "model"), ("data",), ("model",)):
                if all(a in mesh.shape for a in axes):
                    spec = P(axes)
                    if shd._divides(leaf.shape, spec, mesh):
                        return NamedSharding(mesh, spec)
            return NamedSharding(mesh, P(None))
        stacked = any(core.startswith(pref) for pref in stacked_prefixes)
        spec = shd.param_spec_for_path(core, nd, stacked)
        if not shd._divides(leaf.shape, spec, mesh):
            spec = P(*([None] * nd))
        # ZeRO-1: add "data" on the first free, divisible axis
        parts = list(spec) + [None] * (nd - len(spec))
        for ax in range(nd):
            if parts[ax] is None and leaf.shape[ax] % data == 0 and data > 1:
                parts[ax] = "data"
                break
        spec2 = P(*parts)
        if shd._divides(leaf.shape, spec2, mesh):
            return NamedSharding(mesh, spec2)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, opt_spec)


def _zero1_sharding(mesh, leaf, param_sharding):
    """Gradient sharding for ZeRO-1: the param spec + ``data`` on the
    first free divisible axis (matches the optimizer-state layout)."""
    nd = getattr(leaf, "ndim", 0)
    data = mesh.shape.get("data", 1)
    if nd == 0 or data <= 1:
        return param_sharding
    try:
        base = list(param_sharding.spec) + [None] * nd
    except Exception:
        return param_sharding
    parts = base[:nd]
    for ax in range(nd):
        if parts[ax] is None and leaf.shape[ax] % data == 0:
            parts[ax] = "data"
            break
    spec = P(*parts)
    if shd._divides(leaf.shape, spec, mesh):
        return NamedSharding(mesh, spec)
    return param_sharding


def _etp_ok(cfg, mesh, group: int) -> bool:
    f = cfg.d_ff_expert
    data = mesh.shape.get("data", 1)
    return (
        data > 1 and f and f % data == 0
        and (f // data) % group == 0 and (f // group) % data == 0
    )


def _apply_etp_weight_shardings(shardings, params_spec, cfg, mesh):
    """2-D storage for compressed expert arrays (EP×expert-TP): matches
    the shard_map region's in_specs so kimi-scale packed weights use every
    chip (322 GB / 256 instead of / 16)."""
    if not (cfg.is_moe and _etp_ok(cfg, mesh, cfg.quant.group)):
        return shardings

    def one(path, sh, leaf):
        ps = shd._path_str(path)
        if "moe_ce" not in ps:
            return sh
        nd = getattr(leaf, "ndim", 0)
        stacked = ps.startswith("blocks")
        base = 1 if stacked else 0  # leading layer dim
        if nd < base + 3:
            return sh
        if "w_down" in ps:
            spec = [None] * nd
            spec[base] = "model"
            spec[base + 1] = "data"
        else:  # w_gate / w_up: F column-parallel (last dim)
            spec = [None] * nd
            spec[base] = "model"
            spec[nd - 1] = "data"
        spec = P(*spec)
        if shd._divides(leaf.shape, spec, mesh):
            return NamedSharding(mesh, spec)
        return sh

    return jax.tree_util.tree_map_with_path(
        lambda pth, sh, lf: one(pth, sh, lf), shardings, params_spec
    )


def build_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    *,
    train_mode: str = "auto",
    precision: str = "auto",
    avg_bits: float = 2.25,
    accum: int = 1,
    state_bits: int = 32,
) -> StepArtifacts:
    bundle = get_model(cfg)
    step_kind, kwargs = bundle.input_specs(shape)
    meta: Dict = {"arch": cfg.name, "shape": shape.name, "kind": step_kind}

    if step_kind == "train":
        if train_mode == "auto":
            train_mode = "otp" if cfg.name.startswith("kimi") else "full"
        if accum == 0:  # auto: token-scaled buffers (dispatch/attention
            # backward) must fit per microbatch — tuned in EXPERIMENTS §Perf
            accum = {
                "moonshot-v1-16b-a3b": 4,
                "kimi-k2-1t-a32b": 8,
                "command-r-35b": 16,
                "gemma3-27b": 8,
                "qwen3-14b": 2,
                "recurrentgemma-2b": 2,
            }.get(cfg.name, 1)
        meta["train_mode"] = train_mode
        if train_mode == "otp":
            return _build_otp_train(
                cfg, shape, mesh, bundle, kwargs, meta, avg_bits, accum
            )
        return _build_full_train(
            cfg, shape, mesh, bundle, kwargs, meta, accum, state_bits
        )

    if precision == "auto":
        precision = "quant" if cfg.is_moe else "bf16"
    meta["precision"] = precision
    if step_kind == "prefill":
        return _build_prefill(cfg, shape, mesh, bundle, kwargs, meta, precision, avg_bits)
    return _build_decode(cfg, shape, mesh, bundle, kwargs, meta, precision, avg_bits)


# ------------------------------------------------------------------ train
def _build_full_train(cfg, shape, mesh, bundle, kwargs, meta, accum, state_bits):
    params_spec = bundle.param_shapes()
    ocfg = AdamWConfig(
        state_bits=state_bits, master=(cfg.dtype == "bfloat16")
    )
    opt_spec = jax.eval_shape(partial(adamw_init, cfg=ocfg), params_spec)
    batch_spec = kwargs["batch"]
    meta["accum"] = accum

    def train_step(params, opt_state, batch):
        def loss_fn(p, b):
            loss, m = bundle.train_loss(p, b)
            return loss, m

        if accum > 1:
            # microbatch gradient accumulation: scan over accum slices.
            # The f32 accumulator lives on the ZeRO-1 (data×model) layout —
            # per-micro grads reduce-scatter into it (ZeRO-2-style), so the
            # buffer is params/(data·model), not params/model.
            def micro(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                grads = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(g, s),
                    grads, zero1_sh,
                )
                return (
                    jax.tree.map(jnp.add, gacc, grads),
                    lacc + loss,
                ), None

            def split(leaf):
                b = leaf.shape[0]
                return leaf.reshape(accum, b // accum, *leaf.shape[1:])

            mbs = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, jnp.float32), s
                ),
                params, zero1_sh,
            )
            (grads, loss), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        # ZeRO-1: scatter grads onto the optimizer-state sharding so the
        # update math runs fully sharded (one RS here, one AG on params)
        grads = jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, zero1_sh,
        )
        params, opt_state = adamw_update(params, grads, opt_state, ocfg)
        return params, opt_state, loss

    p_sh = shd.make_param_shardings(mesh, params_spec)
    o_sh = _opt_shardings(mesh, opt_spec, p_sh)
    zero1_sh = jax.tree.map(
        lambda leaf, sh: _zero1_sharding(mesh, leaf, sh),
        params_spec, p_sh,
    )
    b_sh = _batch_shardings(mesh, batch_spec)
    return StepArtifacts(
        name="train_step",
        fn=train_step,
        arg_specs=(params_spec, opt_spec, batch_spec),
        in_shardings=(p_sh, o_sh, b_sh),
        donate_argnums=(0, 1),
        meta=meta,
    )


def _build_otp_train(cfg, shape, mesh, bundle, kwargs, meta, avg_bits, accum=1):
    """OTP distillation on the frozen compressed backbone (paper Eq. 14)."""
    frozen_spec = spec_mod.make_compressed_moe_params(cfg, avg_bits)
    otp_spec = spec_mod.make_otp_stacked(cfg, concrete=False)
    ocfg = AdamWConfig(lr=2e-3, weight_decay=0.0)
    opt_spec = jax.eval_shape(partial(adamw_init, cfg=ocfg), otp_spec)
    batch_spec = {"tokens": kwargs["batch"]["tokens"]}
    lam = 1.0
    meta["accum"] = accum

    def otp_train_step(otp_params, opt_state, frozen, batch, rng):
        def loss_fn(op, tokens):
            blocks_s = dict(frozen["blocks"])
            blocks_s["otp"] = op
            params_s = dict(frozen, blocks=blocks_s)
            hs, mask_l1, _ = tf.forward_hidden(
                params_s, tokens, cfg, moe_hooks={"otp_rng": rng, "otp_tau": 1.0}
            )
            ht, _, _ = tf.forward_hidden(
                frozen, tokens, cfg, moe_hooks={"use_otp": False}
            )
            ht = jax.lax.stop_gradient(ht)
            emb = frozen.get("unembed", frozen["embed"])
            kl = Lx.chunked_kl(hs, ht, emb, cfg.logits_chunk)
            return kl + lam * mask_l1 / cfg.num_layers, (kl, mask_l1)

        tokens = batch["tokens"]
        if accum > 1:
            b = tokens.shape[0]
            micros = tokens.reshape(accum, b // accum, -1)

            def micro(carry, tk):
                gacc, lacc, kacc = carry
                (loss, (kl, _)), grads = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(otp_params, tk)
                return (
                    jax.tree.map(jnp.add, gacc, grads),
                    lacc + loss, kacc + kl,
                ), None

            zeros = jax.tree.map(lambda p: jnp.zeros_like(p), otp_params)
            (grads, loss, kl), _ = jax.lax.scan(
                micro, (zeros, jnp.float32(0), jnp.float32(0)), micros
            )
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss, kl = loss / accum, kl / accum
        else:
            (loss, (kl, l1)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                otp_params, tokens
            )
        otp_params, opt_state = adamw_update(otp_params, grads, opt_state, ocfg)
        return otp_params, opt_state, loss, kl

    p_sh = shd.make_param_shardings(mesh, otp_spec)
    o_sh = _opt_shardings(mesh, opt_spec, p_sh)
    f_sh = shd.make_param_shardings(mesh, frozen_spec)
    f_sh = _apply_etp_weight_shardings(f_sh, frozen_spec, cfg, mesh)
    b_sh = _batch_shardings(mesh, batch_spec)
    rng_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return StepArtifacts(
        name="otp_train_step",
        fn=otp_train_step,
        arg_specs=(otp_spec, opt_spec, frozen_spec, batch_spec, rng_spec),
        in_shardings=(p_sh, o_sh, f_sh, b_sh, NamedSharding(mesh, P(None))),
        donate_argnums=(0, 1),
        meta=meta,
    )


# ------------------------------------------------------------------ serve
def _serve_params_spec(cfg, bundle, precision, avg_bits):
    if precision == "bf16":
        return bundle.param_shapes()
    if cfg.is_moe:
        return spec_mod.make_compressed_moe_params(cfg, avg_bits)
    return spec_mod.quantize_dense_param_tree(bundle.param_shapes(), cfg)


def _build_prefill(cfg, shape, mesh, bundle, kwargs, meta, precision, avg_bits):
    params_spec = _serve_params_spec(cfg, bundle, precision, avg_bits)
    batch_spec = kwargs["batch"]

    def prefill_step(params, batch):
        return bundle.prefill(params, batch)

    p_sh = shd.make_param_shardings(mesh, params_spec)
    if precision == "quant":
        p_sh = _apply_etp_weight_shardings(p_sh, params_spec, cfg, mesh)
    b_sh = _batch_shardings(mesh, batch_spec)
    # the returned KV cache must leave the step sharded (it feeds decode)
    out_spec = jax.eval_shape(bundle.prefill, params_spec, batch_spec)
    cache_sh = _cache_shardings(mesh, out_spec[0], False)
    logits_sh = jax.tree.map(
        lambda l: NamedSharding(mesh, P(*([None] * l.ndim))), out_spec[1]
    )
    return StepArtifacts(
        name="prefill_step",
        fn=prefill_step,
        arg_specs=(params_spec, batch_spec),
        in_shardings=(p_sh, b_sh),
        donate_argnums=(),
        meta=meta,
        out_shardings=(cache_sh, logits_sh),
    )


def _build_decode(cfg, shape, mesh, bundle, kwargs, meta, precision, avg_bits):
    params_spec = _serve_params_spec(cfg, bundle, precision, avg_bits)
    if precision == "bf16":
        cache_spec = kwargs["cache"]
    else:
        batch_spec_p = bundle.batch_specs(shape, "prefill")
        cache_spec, _ = jax.eval_shape(
            bundle.prefill, params_spec, batch_spec_p
        )
    token_spec, pos_spec = kwargs["token"], kwargs["pos"]

    def decode_fn(params, cache, token, pos):
        return bundle.decode_step(params, cache, token, pos)

    long_ctx = shape.name.startswith("long")
    p_sh = shd.make_param_shardings(mesh, params_spec)
    if precision == "quant":
        p_sh = _apply_etp_weight_shardings(p_sh, params_spec, cfg, mesh)
    c_sh = _cache_shardings(mesh, cache_spec, long_ctx)
    t_sh = _batch_shardings(mesh, token_spec)
    # the updated cache must leave the step sharded like it came in
    out_spec = jax.eval_shape(
        bundle.decode_step, params_spec, cache_spec, token_spec, pos_spec
    )
    out_cache_sh = _cache_shardings(mesh, out_spec[0], long_ctx)
    logits_sh = jax.tree.map(
        lambda l: NamedSharding(mesh, P(*([None] * l.ndim))), out_spec[1]
    )
    return StepArtifacts(
        name="decode_step",
        fn=decode_fn,
        arg_specs=(params_spec, cache_spec, token_spec, pos_spec),
        in_shardings=(p_sh, c_sh, t_sh, NamedSharding(mesh, P())),
        donate_argnums=(1,),
        meta=meta,
        out_shardings=(out_cache_sh, logits_sh),
    )
