"""Training launcher: dense full-training or OTP distillation, with
checkpoint/restart, elastic re-mesh, straggler monitoring.

Real runs on this container use reduced configs (``--reduced``) — the
end-to-end example (examples/train_moe_100m.py) trains a ~100M MoE LM for
a few hundred steps. Full configs are exercised via the dry-run. On a
real multi-host pod, pass ``--coordinator`` to initialize
``jax.distributed`` first; everything else is identical.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpointer import Checkpointer
from ..configs.base import SHAPES, ShapeConfig
from ..configs.registry import ARCH_IDS, get_config
from ..data.pipeline import HostDataLoader
from ..models.registry import get_model
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.schedule import warmup_cosine
from ..runtime.fault_tolerance import FailurePolicy, ResilientLoop, StragglerMonitor

__all__ = ["train_reduced", "main"]


def train_reduced(
    arch: str,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    seed: int = 0,
    resume: bool = True,
    log_every: int = 10,
):
    """Single-host training of the reduced config (CI-sized end-to-end)."""
    cfg = get_config(arch).reduced()
    bundle = get_model(cfg)
    ocfg = AdamWConfig(lr=lr)
    loader = HostDataLoader(
        vocab=cfg.vocab_size, global_batch=batch, seq_len=seq, seed=seed
    )

    params = bundle.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params, ocfg)
    start_step = 0
    ckpt = None
    if ckpt_dir:
        ckpt = Checkpointer(ckpt_dir, keep=2)
        last = ckpt.latest_step()
        if resume and last is not None:
            state = ckpt.restore(last, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = last + 1

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            loss, m = bundle.train_loss(p, batch)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr_scale = warmup_cosine(opt_state["step"], warmup=10, total=steps)
        params, opt_state = adamw_update(params, grads, opt_state, ocfg, lr_scale)
        return params, opt_state, loss

    monitor = StragglerMonitor()
    history = []
    for step in range(start_step, steps):
        t0 = time.time()
        b = {k: jnp.asarray(v) for k, v in loader.batch_at(step).items()}
        params, opt_state, loss = step_fn(params, opt_state, b)
        dt = time.time() - t0
        monitor.record(0, dt)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {float(loss):.4f} ({dt*1e3:.0f} ms)")
        history.append(float(loss))
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(steps - 1, {"params": params, "opt": opt_state}, blocking=True)
        ckpt.wait()
    return params, history


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=ARCH_IDS, required=True)
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--coordinator", default=None,
                   help="host:port → jax.distributed.initialize (multi-host)")
    args = p.parse_args()
    if args.coordinator:
        jax.distributed.initialize(coordinator_address=args.coordinator)
    _, hist = train_reduced(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, ckpt_dir=args.ckpt_dir,
    )
    print(json.dumps({"first_loss": hist[0], "last_loss": hist[-1]}))


if __name__ == "__main__":
    main()
