"""Production mesh builders (assignment: MULTI-POD DRY-RUN step 1).

Functions, not module-level constants — importing this module never
touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16×16 = 256 chips single-pod, 2×16×16 multi-pod.

    Axes: ``pod`` (DCN), ``data`` (DP/FSDP), ``model`` (TP/EP/SP).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for unit tests (requires forced host device count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
