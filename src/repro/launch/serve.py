"""Serving launcher: continuous batching with a paged KV cache.

The default path is :class:`repro.serving.engine.PagedServingEngine`:

* block-table paged KV pool — slots of different lengths share one
  preallocated pool; finished requests free their pages immediately,
* admission queue + continuous batching — queued requests join the
  running batch mid-flight (no wave barrier, no dummy padding),
* dynamic page growth + preemption — admission reserves prompt-sized
  pages, decode pages are granted on demand, and under pool pressure
  (``--pool-blocks``) the youngest request is swapped out to host memory
  (``--preempt-mode swap``) or re-prefilled (``recompute``);
  ``--no-preempt`` restores the conservative full-reservation baseline,
* chunked prefill for long prompts,
* bf16 or PMQ-compressed weights (§3.2 bit buckets; ``--pmq`` compresses
  the demo model in-process); OTP masks at decode time (deterministic
  argmax — the τ→0 limit, paper §3.4),
* host-offloaded expert buckets (``--resident-experts N``, implies
  ``--pmq``): cold PMQ rows live in host memory, a router-stats EMA
  prefetches the hot set, misses upload synchronously and replay
  (:mod:`repro.serving.offload`),
* async expert streaming (``--async-offload``): planner-driven uploads
  stage into shadow device buffers while the current megastep computes
  and commit at the next boundary — outputs stay bit-identical to the
  synchronous path; ``--offload-dir DIR`` extends the store to a third
  tier (mmap'd CRC-checked packed buckets on disk behind a
  byte-budgeted pinned host cache, ``--host-expert-bytes B``)
  (docs/serving_offload.md),
* TTFT / per-token latency / queue depth / expert-activation metrics
  (:mod:`repro.serving.metrics`),
* request-lifecycle tracing (``--trace-out trace.json`` writes a
  Perfetto-viewable Chrome trace + JSONL event log; ``--trace-level``
  picks the detail) and expert-routing telemetry incl. the
  bit-misallocation report (:mod:`repro.serving.trace`,
  docs/observability.md),
* multi-tenant scheduling policy (``--policy fcfs|priority|fair``,
  ``--tenant-weights a=2,b=1`` for weighted-deficit token fairness,
  ``--ttft-budget-ms`` for SLO load shedding) executed through the
  declarative resource controller — every admit/preempt/grow/shed/
  expert-upload is a reconciliation plan step
  (:mod:`repro.serving.controller`, docs/serving_scheduling.md),
* the fail-closed fault plane (``--chaos-seed N`` attaches a seeded
  deterministic FaultPlan, ``--deadline-steps N`` bounds every request):
  injected faults recover bit-exact (retry / re-fetch / degrade) or
  terminate typed — never wrong tokens (:mod:`repro.serving.faults`,
  docs/serving_robustness.md).

:class:`BatchedServer` is the legacy static *wave* batcher kept for
comparison (``--legacy``): it pads every wave with dummy requests and
re-prefills per wave — the baseline the paged engine exists to beat.

Runs reduced configs end-to-end on CPU (examples/serve_batched.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import ARCH_IDS, get_config
from ..models.registry import get_model
from ..serving import EngineConfig, PagedServingEngine
from ..serving import Request as PagedRequest

__all__ = ["BatchedServer", "Request", "main"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int = 16
    out: Optional[List[int]] = None


class BatchedServer:
    """Static-batch wave server over a fixed slot count (legacy baseline).

    Kept for A/B comparison against the paged engine: it admits in waves,
    pads short waves with dummy requests, and holds every slot until the
    wave's longest request finishes.
    """

    def __init__(self, cfg, params, max_slots: int = 4, prompt_len: int = 32):
        self.cfg = cfg
        self.bundle = get_model(cfg)
        self.params = params
        self.max_slots = max_slots
        self.prompt_len = prompt_len
        self._decode = jax.jit(self.bundle.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(self.bundle.prefill)
        self.stats = {"prefill_s": [], "decode_s": [], "active": []}

    def _pad_prompts(self, reqs: List[Request]) -> jnp.ndarray:
        toks = np.zeros((len(reqs), self.prompt_len), np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[-self.prompt_len :]
            toks[i, -len(p) :] = p
        return jnp.asarray(toks)

    def serve(self, reqs: List[Request]) -> Dict[int, List[int]]:
        """Serve a wave of requests (grouped into slot-sized batches)."""
        results: Dict[int, List[int]] = {}
        for i in range(0, len(reqs), self.max_slots):
            wave = reqs[i : i + self.max_slots]
            while len(wave) < self.max_slots:  # pad wave with a dummy
                # max_new=0: a dummy must never extend the wave's decode
                # loop nor count toward latency/throughput stats
                wave = wave + [Request(rid=-1, prompt=wave[0].prompt, max_new=0)]
            tokens = self._pad_prompts(wave)
            max_new = max(r.max_new for r in wave)
            t0 = time.time()
            cache, logits = self._prefill(self.params, {"tokens": tokens})
            jax.block_until_ready(logits)
            self.stats["prefill_s"].append(time.time() - t0)
            # the prefill cache covers exactly the prompt; extend it so
            # decode steps have somewhere to write their K/V
            pad = ((0, 0), (0, 0), (0, max_new), (0, 0), (0, 0))
            cache = dict(
                cache, k=jnp.pad(cache["k"], pad), v=jnp.pad(cache["v"], pad)
            )
            cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            outs = [[] for _ in wave]
            for j, r in enumerate(wave):
                if r.rid >= 0 and r.max_new > 0:
                    outs[j].append(int(cur[j, 0]))
            for step in range(max_new - 1):
                # each decode step writes at the next cache position —
                # never clamp to prompt_len-1 (that overwrote one slot
                # forever and decoded against a stale cache)
                pos = jnp.int32(self.prompt_len + step)
                t0 = time.time()
                cache, logits = self._decode(self.params, cache, cur, pos)
                jax.block_until_ready(logits)
                self.stats["decode_s"].append(time.time() - t0)
                self.stats["active"].append(
                    sum(1 for r in wave if r.rid >= 0 and step + 1 < r.max_new)
                )
                cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
                for j, r in enumerate(wave):
                    if r.rid >= 0 and step + 1 < r.max_new:
                        outs[j].append(int(cur[j, 0]))
            for j, r in enumerate(wave):
                if r.rid >= 0:
                    results[r.rid] = outs[j]
        return results

    def summary(self) -> Dict[str, float]:
        d = np.asarray(self.stats["decode_s"])
        # throughput counts only real (non-dummy, still-decoding) slots
        gen = float(np.sum(self.stats["active"]))
        return {
            "prefill_mean_s": float(np.mean(self.stats["prefill_s"])),
            "decode_mean_s": float(np.mean(d)) if d.size else 0.0,
            "decode_p95_s": float(np.percentile(d, 95)) if d.size else 0.0,
            "tokens_per_s": gen / float(d.sum()) if d.size else 0.0,
        }


def _compress_for_serving(cfg, params):
    """PMQ-compress the demo model on synthetic calibration tokens (the
    layer-uniform stacked layout from repro.core.pipeline — the same
    layout benchmarks/serving_latency.py serves)."""
    from ..core import pipeline

    rng = np.random.default_rng(0)
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 64)).astype(np.int32)
    )
    calib = pipeline.calibrate(params, tokens, cfg)
    params_c, _ = pipeline.compress_for_serving(params, calib, cfg)
    return params_c


def _parse_tenant_weights(spec: str):
    """Parse ``"name=w,name=w"`` into the hashable pair tuple
    EngineConfig carries; refuses empty names, repeats, and w <= 0."""
    pairs = []
    seen = set()
    for item in spec.split(","):
        name, eq, w = item.partition("=")
        try:
            weight = float(w)
        except ValueError:
            weight = -1.0
        if not name or not eq or weight <= 0 or name in seen:
            raise SystemExit(
                "--tenant-weights expects 'name=w,name=w' with unique "
                f"names and w > 0 (got {spec!r})"
            )
        seen.add(name)
        pairs.append((name, weight))
    return tuple(pairs)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=ARCH_IDS, default="moonshot-v1-16b-a3b")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--block-size", type=int, default=16)
    p.add_argument("--pmq", action="store_true",
                   help="serve PMQ-compressed experts (§3.2 bit buckets) "
                        "instead of full-precision weights")
    p.add_argument("--resident-experts", type=int, default=None,
                   metavar="N",
                   help="per-layer device budget in expert slots; cold "
                        "PMQ rows are offloaded to host memory and "
                        "prefetched by router stats (implies --pmq)")
    p.add_argument("--async-offload", action="store_true",
                   help="double-buffer planner-driven expert uploads: "
                        "residency targets stage into shadow device "
                        "buffers while the current megastep computes and "
                        "commit at the next boundary; outputs stay bit-"
                        "identical (requires --resident-experts; see "
                        "docs/serving_offload.md)")
    p.add_argument("--offload-dir", type=str, default=None, metavar="DIR",
                   help="spill the expert store to mmap'd packed buckets "
                        "under DIR (three-tier disk <- host <- device "
                        "residency; every row fetch is CRC-checked; "
                        "requires --resident-experts)")
    p.add_argument("--host-expert-bytes", type=int, default=None,
                   metavar="B",
                   help="byte budget for the pinned host row cache in "
                        "front of --offload-dir (default: unbounded)")
    p.add_argument("--pool-blocks", type=int, default=None,
                   help="KV pool size in pages; undersize it to exercise "
                        "growth + preemption (default: worst-case demand)")
    p.add_argument("--preempt-mode", choices=["swap", "recompute"],
                   default="swap",
                   help="restore preempted requests from the host swap "
                        "store or by re-prefilling their context")
    p.add_argument("--no-preempt", action="store_true",
                   help="reserve prompt+max_new pages at admission "
                        "(PR-1 baseline: no growth, no preemption)")
    p.add_argument("--ffn-backend", choices=["grouped", "scan", "ref"],
                   default=None,
                   help="compressed expert-FFN implementation: grouped "
                        "GEMM (default; Pallas moe_gmm on TPU), the "
                        "legacy per-expert scan, or the forced jnp "
                        "reference — reproducible A/B legs from the CLI")
    p.add_argument("--decode-horizon", type=int, default=None, metavar="H",
                   help="fused decode megastep length: one jitted "
                        "program advances every slot up to H tokens with "
                        "on-device sampling — one dispatch + one host "
                        "sync per megastep (default: "
                        "REPRO_DECODE_HORIZON or 8; 1 = the per-token "
                        "baseline program)")
    p.add_argument("--temperature", type=float, default=0.0,
                   help="on-device sampling temperature inside the "
                        "horizon scan (0 = greedy argmax, the "
                        "bit-reproducible default)")
    p.add_argument("--sample-seed", type=int, default=0,
                   help="seed for temperature sampling; one subkey per "
                        "megastep, so runs replay deterministically")
    p.add_argument("--prefix-cache", action="store_true",
                   help="enable shared-prefix KV reuse: prompts sharing a "
                        "cached prefix admit onto its pages copy-on-write "
                        "and skip the prefill of the shared tokens "
                        "(outputs stay bit-identical; see "
                        "docs/serving_kv.md)")
    p.add_argument("--kv-bits", type=int, choices=[8], default=None,
                   metavar="B",
                   help="quantize the KV pool to B-bit codes with per-row "
                        "f32 scales (~2.7x KV tokens per device byte at "
                        "head_dim 16; greedy outputs stay batch-"
                        "composition independent — see docs/serving_kv.md)")
    p.add_argument("--policy", choices=["fcfs", "priority", "fair"],
                   default=None,
                   help="admission-order policy: arrival order, strict "
                        "priority classes, or weighted-deficit token "
                        "fairness across tenants (WDRR; see "
                        "docs/serving_scheduling.md); outputs are bit-"
                        "identical across policies")
    p.add_argument("--tenant-weights", type=str, default=None,
                   metavar="T=W,...",
                   help="per-tenant fairness weights for --policy fair, "
                        "e.g. 'batch=1,interactive=4'; demo requests are "
                        "assigned round-robin over the named tenants")
    p.add_argument("--ttft-budget-ms", type=float, default=None,
                   metavar="MS",
                   help="SLO admission budget: shed (reject with empty "
                        "output) any never-admitted request that has "
                        "waited longer than MS for its first token")
    p.add_argument("--chaos-seed", type=int, default=None, metavar="SEED",
                   help="attach a seeded deterministic FaultPlan (expert-"
                        "upload / KV-swap / pool / logits faults) to the "
                        "engine and print the fault-plane counters after "
                        "the run — every request still finishes bit-exact "
                        "or with a typed error (docs/serving_robustness.md)")
    p.add_argument("--deadline-steps", type=int, default=None, metavar="N",
                   help="per-request deadline in engine steps; requests "
                        "not finished within N steps of submission "
                        "terminate typed with DeadlineExceeded")
    p.add_argument("--legacy", action="store_true",
                   help="run the static wave batcher instead of the paged engine")
    p.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                   help="write a Chrome trace-event JSON (open in "
                        "ui.perfetto.dev) to PATH and the raw event log "
                        "to PATH + '.jsonl' after serving")
    p.add_argument("--trace-level", choices=["off", "spans", "full"],
                   default=None,
                   help="span tracing detail (default: 'full' when "
                        "--trace-out is given, else 'off'); lifecycle "
                        "metrics are identical at every level")
    args = p.parse_args()
    if args.legacy and (args.prefix_cache or args.kv_bits is not None):
        # both features live in the paged KV pool — the wave batcher has
        # neither pages nor a prefix index
        raise SystemExit("--prefix-cache/--kv-bits require the paged "
                         "engine (drop --legacy)")
    if args.legacy and (args.trace_out or args.trace_level not in (None, "off")):
        # the wave batcher predates the tracer — refuse rather than
        # silently emit an empty trace
        raise SystemExit("--trace-out/--trace-level require the paged "
                         "engine (drop --legacy)")
    if args.legacy and (args.chaos_seed is not None
                        or args.deadline_steps is not None):
        # the fault plane and request deadlines live in the paged
        # engine's step loop — the wave batcher has neither
        raise SystemExit("--chaos-seed/--deadline-steps require the "
                         "paged engine (drop --legacy)")
    if args.deadline_steps is not None and args.deadline_steps < 1:
        raise SystemExit("--deadline-steps must be >= 1")
    if args.legacy and (args.policy or args.tenant_weights
                        or args.ttft_budget_ms is not None):
        # scheduling policy lives in the controller loop the wave
        # batcher doesn't run
        raise SystemExit("--policy/--tenant-weights/--ttft-budget-ms "
                         "require the paged engine (drop --legacy)")
    if args.tenant_weights and (args.policy or "fcfs") != "fair":
        raise SystemExit("--tenant-weights only applies to --policy fair")
    if args.ttft_budget_ms is not None and args.ttft_budget_ms < 0:
        raise SystemExit("--ttft-budget-ms must be >= 0")
    tenant_weights = (
        _parse_tenant_weights(args.tenant_weights)
        if args.tenant_weights else None
    )
    trace_level = args.trace_level or ("full" if args.trace_out else "off")
    if args.ffn_backend:
        # process default too, so the --legacy wave batcher (no engine
        # config, plain decode_step) honors the same A/B knob
        import os

        os.environ["REPRO_FFN_BACKEND"] = args.ffn_backend
    cfg = get_config(args.arch).reduced()
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    if args.resident_experts is not None and args.legacy:
        # the wave batcher has no offload path — refuse rather than
        # silently serve everything device-resident
        raise SystemExit("--resident-experts requires the paged engine "
                         "(drop --legacy)")
    if ((args.async_offload or args.offload_dir is not None)
            and args.resident_experts is None):
        # both ride the offload manager's residency plan — nothing to
        # overlap or tier without a device budget
        raise SystemExit("--async-offload/--offload-dir require "
                         "--resident-experts")
    if args.host_expert_bytes is not None and args.offload_dir is None:
        raise SystemExit("--host-expert-bytes requires --offload-dir")
    if args.pmq or args.resident_experts is not None:
        if not cfg.is_moe:
            flag = "--pmq" if args.pmq else "--resident-experts"
            raise SystemExit(f"{flag} requires an MoE arch")
        print("compressing demo model (PMQ, layer-uniform plan)…")
        params = _compress_for_serving(cfg, params)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
        for _ in range(args.requests)
    ]
    if args.legacy:
        server = BatchedServer(cfg, params, max_slots=args.slots)
        reqs = [
            Request(rid=i, prompt=prompts[i], max_new=args.max_new)
            for i in range(args.requests)
        ]
        out = server.serve(reqs)
        print(f"served {len(out)} requests; stats: {server.summary()}")
        return
    blocks_per_req = (24 + args.max_new) // args.block_size + 2
    plan = None
    if args.chaos_seed is not None:
        from ..serving import FaultPlan

        sites = ("swap_out", "swap_in", "pool", "logits")
        if args.resident_experts is not None:
            sites = ("upload",) + sites
        plan = FaultPlan.generate(
            args.chaos_seed, n_faults=8, max_step=4 * args.max_new,
            sites=sites, rids=list(range(args.requests)),
        )
    engine = PagedServingEngine(
        cfg, params,
        EngineConfig(
            max_slots=args.slots,
            block_size=args.block_size,
            num_blocks=args.pool_blocks or args.slots * blocks_per_req,
            max_blocks_per_slot=blocks_per_req,
            preempt_mode=args.preempt_mode,
            reserve_full=args.no_preempt,
            resident_experts=args.resident_experts,
            async_offload=args.async_offload,
            offload_dir=args.offload_dir,
            host_expert_bytes=args.host_expert_bytes,
            ffn_backend=args.ffn_backend,
            temperature=args.temperature,
            sample_seed=args.sample_seed,
            trace_level=trace_level,
            prefix_cache=args.prefix_cache,
            kv_bits=args.kv_bits,
            policy=args.policy or "fcfs",
            tenant_weights=tenant_weights,
            ttft_budget_s=(
                args.ttft_budget_ms / 1000.0
                if args.ttft_budget_ms is not None else None
            ),
            **({"decode_horizon": args.decode_horizon}
               if args.decode_horizon is not None else {}),
        ),
        faults=plan,
    )
    if engine.offload is not None:
        # the engine's tree holds the resident partition + host store;
        # dropping the caller's reference releases the full-resident
        # device buckets — the memory the budget exists to reclaim
        del params
    tenant_names = (
        [t for t, _ in tenant_weights] if tenant_weights else ["default"]
    )
    out = engine.serve(
        [
            PagedRequest(rid=i, prompt=prompts[i], max_new=args.max_new,
                         tenant=tenant_names[i % len(tenant_names)],
                         deadline_steps=args.deadline_steps)
            for i in range(args.requests)
        ]
    )
    m = engine.metrics.summary()
    print(f"served {len(out)} requests; metrics: {engine.metrics.to_json()}")
    print(f"pool pressure: {m['preemptions']} preemptions, "
          f"{m['swap_bytes']} swap bytes, "
          f"page util p95 {m['page_util_p95']:.2f}")
    print(f"scheduling: policy {engine.ecfg.policy}; {m['sheds']} sheds, "
          f"{m['preemptions']} preemptions, {m['readmissions']} "
          f"readmissions, {m['plans']} plans {m['plan_actions']}; "
          f"tenant tokens {m['tenant_tokens']}")
    if args.prefix_cache:
        print(f"prefix cache: {m['prefix_hits']} hits "
              f"({m['prefix_full_hits']} full), "
              f"{m['prefix_tokens_saved']} prompt tokens reused, "
              f"{m['cow_copies']} COW copies")
    if engine.offload is not None:
        print(
            f"expert offload: budget {engine.offload.budgets} "
            f"(resident {engine.offload.resident_bytes} B of "
            f"{engine.offload.host_bytes} B host), "
            f"hit rate {m['expert_hit_rate']:.2f}, "
            f"{m['expert_prefetch_uploads']} prefetch + "
            f"{m['expert_miss_uploads']} miss uploads "
            f"({m['expert_upload_bytes']} B), "
            f"{engine.offload.grows} budget grows"
        )
        if args.async_offload:
            print(
                f"async offload: {m['uploads_overlapped']} overlapped "
                f"({m['uploads_committed']} committed, "
                f"{m['uploads_dropped_stale']} dropped stale), "
                f"stall {m['upload_stall_s']:.4f} s, "
                f"hidden {m['upload_hidden_s']:.4f} s"
            )
        if args.offload_dir is not None:
            print(
                f"expert tiers: {m['tier_host_hits']} host hits, "
                f"{m['tier_disk_hits']} disk fetches "
                f"({m['tier_disk_bytes']} B, CRC-checked)"
            )
    if plan is not None or args.deadline_steps is not None:
        ctr = engine.metrics.counters()
        print(
            f"fault plane: {ctr['fault_injected']} injected "
            f"{dict(ctr['faults_by_site'])}; "
            f"{ctr['upload_retries']} upload retries, "
            f"{ctr['degraded_serves']} degraded serves, "
            f"{ctr['swap_fallbacks']} swap fallbacks, "
            f"{ctr['cancelled']} cancelled, "
            f"{ctr['deadline_exceeded']} deadline-exceeded, "
            f"{ctr['poisoned']} poisoned"
        )
        if engine.errors:
            print("typed errors: " + ", ".join(
                f"rid {r}: {type(e).__name__}"
                for r, e in sorted(engine.errors.items())
            ))
    report = engine.routing_report()
    if report is not None:
        corr = report["mean_freq_bits_corr"]
        hot = sum(len(l["hot_low_bit"]) for l in report["layers"])
        cold = sum(len(l["cold_high_bit"]) for l in report["layers"])
        print(
            f"routing telemetry: {report['steps']} steps over "
            f"{report['num_layers']}×{report['num_slots']} (layer, slot) "
            f"cells; freq↔bits corr "
            f"{'n/a' if corr is None else f'{corr:+.2f}'}, "
            f"{hot} hot-low-bit + {cold} cold-high-bit candidates"
        )
    if args.trace_out:
        extra = {"routing_report": report} if report is not None else None
        engine.tracer.write_chrome(args.trace_out, extra=extra)
        engine.tracer.write_jsonl(args.trace_out + ".jsonl")
        print(f"trace: {len(engine.tracer.events)} events → "
              f"{args.trace_out} (+ .jsonl); open in https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
