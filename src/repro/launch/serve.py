"""Serving launcher: batched prefill + decode with PMQ/OTP compression.

Implements a minimal production-shaped serving loop:

* request queue → continuous batcher (slots with per-slot position),
* one prefill per admitted request, then batched decode steps,
* bf16 or PMQ-compressed weights; OTP masks at decode time (deterministic
  argmax — the τ→0 limit, paper §3.4),
* per-step latency stats (the Tab. 5/8 "speedup" measurements on CPU are
  relative between precisions — see benchmarks/memory_speed.py).

Runs reduced configs end-to-end on CPU (examples/serve_batched.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import ARCH_IDS, get_config
from ..models.registry import get_model

__all__ = ["BatchedServer", "main"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int = 16
    out: Optional[List[int]] = None


class BatchedServer:
    """Static-batch continuous server over a fixed slot count."""

    def __init__(self, cfg, params, max_slots: int = 4, prompt_len: int = 32):
        self.cfg = cfg
        self.bundle = get_model(cfg)
        self.params = params
        self.max_slots = max_slots
        self.prompt_len = prompt_len
        self._decode = jax.jit(self.bundle.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(self.bundle.prefill)
        self.stats = {"prefill_s": [], "decode_s": []}

    def _pad_prompts(self, reqs: List[Request]) -> jnp.ndarray:
        toks = np.zeros((len(reqs), self.prompt_len), np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[-self.prompt_len :]
            toks[i, -len(p) :] = p
        return jnp.asarray(toks)

    def serve(self, reqs: List[Request]) -> Dict[int, List[int]]:
        """Serve a wave of requests (grouped into slot-sized batches)."""
        results: Dict[int, List[int]] = {}
        for i in range(0, len(reqs), self.max_slots):
            wave = reqs[i : i + self.max_slots]
            while len(wave) < self.max_slots:  # pad wave with a dummy
                wave = wave + [Request(rid=-1, prompt=wave[0].prompt)]
            tokens = self._pad_prompts(wave)
            t0 = time.time()
            cache, logits = self._prefill(self.params, {"tokens": tokens})
            jax.block_until_ready(logits)
            self.stats["prefill_s"].append(time.time() - t0)
            cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            outs = [[] for _ in wave]
            max_new = max(r.max_new for r in wave)
            for step in range(max_new):
                pos = jnp.int32(min(self.prompt_len - 1 + step,
                                    self.prompt_len - 1))
                t0 = time.time()
                cache, logits = self._decode(self.params, cache, cur, pos)
                jax.block_until_ready(logits)
                self.stats["decode_s"].append(time.time() - t0)
                cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
                for j, r in enumerate(wave):
                    if r.rid >= 0 and step < r.max_new:
                        outs[j].append(int(cur[j, 0]))
            for j, r in enumerate(wave):
                if r.rid >= 0:
                    results[r.rid] = outs[j]
        return results

    def summary(self) -> Dict[str, float]:
        d = np.asarray(self.stats["decode_s"])
        return {
            "prefill_mean_s": float(np.mean(self.stats["prefill_s"])),
            "decode_mean_s": float(np.mean(d)) if d.size else 0.0,
            "decode_p95_s": float(np.percentile(d, 95)) if d.size else 0.0,
            "tokens_per_s": float(self.max_slots / np.mean(d)) if d.size else 0.0,
        }


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=ARCH_IDS, default="moonshot-v1-16b-a3b")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    args = p.parse_args()
    cfg = get_config(args.arch).reduced()
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, max_slots=args.slots)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=24).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    out = server.serve(reqs)
    print(f"served {len(out)} requests; stats: {server.summary()}")


if __name__ == "__main__":
    main()
