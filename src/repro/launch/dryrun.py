import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable (e)).

The two lines above MUST precede any other import — jax locks the device
count at first init. For every (architecture × input shape × mesh) cell
this driver ``jit(...).lower(specs).compile()``s the step function on the
production mesh, prints ``memory_analysis()`` / ``cost_analysis()``, runs
the while-aware HLO analyzer (FLOPs / HBM bytes / collective bytes — see
:mod:`repro.launch.hlo_analysis`), and writes one JSON per cell for the
roofline report (EXPERIMENTS.md §Dry-run/§Roofline).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from ..configs.base import SHAPES, supported_shapes
from ..configs.registry import ARCH_IDS, get_config
from ..parallel.sharding import activation_rules, sharding_rules
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh
from .steps import build_step

# v5e hardware constants (assignment)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    train_mode: str = "auto",
    precision: str = "auto",
    accum: int = 1,
    state_bits: int = 32,
    out_dir: str = "results/dryrun",
    tag: str = "",
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    art = build_step(
        cfg, shape, mesh,
        train_mode=train_mode, precision=precision, accum=accum,
        state_bits=state_bits,
    )
    if os.environ.get("DRYRUN_DEBUG_ARGS"):
        import numpy as _np

        n_dev = mesh.devices.size
        flat, _ = jax.tree_util.tree_flatten_with_path(
            (art.arg_specs, art.in_shardings)
        )
        specs = jax.tree_util.tree_leaves(art.arg_specs)
        shards = jax.tree_util.tree_leaves(
            art.in_shardings, is_leaf=lambda x: hasattr(x, "spec")
        )
        paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(art.arg_specs)[0]]
        rows = []
        for p, s, sh in zip(paths, specs, shards):
            total = _np.prod(s.shape) * s.dtype.itemsize if s.shape else s.dtype.itemsize
            shard_factor = 1
            try:
                sspec = sh.spec
                for dim, names in zip(s.shape, sspec):
                    if names is None:
                        continue
                    names = names if isinstance(names, tuple) else (names,)
                    shard_factor *= int(_np.prod([mesh.shape[n] for n in names]))
            except Exception:
                pass
            rows.append((total / shard_factor, total, p, getattr(sh, "spec", None)))
        rows.sort(key=lambda r: -r[0])
        print("  top args by per-device bytes:")
        for per_dev, total, p, spec in rows[:12]:
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
            print(f"    {per_dev/2**20:9.1f} MiB/dev (global {total/2**30:7.2f} GiB) "
                  f"{name[:80]} {spec}")
    with mesh, sharding_rules(mesh, activation_rules(mesh)):
        jit_kw = {}
        if art.out_shardings is not None:
            jit_kw["out_shardings"] = art.out_shardings
        jitted = jax.jit(
            art.fn,
            in_shardings=art.in_shardings,
            donate_argnums=art.donate_argnums,
            **jit_kw,
        )
        lowered = jitted.lower(*art.arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(f"[{arch} × {shape_name} × {'multi' if multi_pod else 'single'}] "
              f"{art.name} lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print("  memory_analysis:", mem)
        ca = compiled.cost_analysis()
        print("  cost_analysis flops:", ca.get("flops"), "bytes:",
              ca.get("bytes accessed"))
        summary = analyze_hlo(compiled.as_text())

    chips = 512 if multi_pod else 256
    per_dev_bytes = (
        mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes
    )
    coll = {k: float(v) for k, v in summary.collective_bytes.items()}
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "step": art.name,
        "meta": art.meta,
        "lower_s": t_lower,
        "compile_s": t_compile,
        # per-device (post-SPMD HLO shapes are per-device)
        "hlo_flops_per_dev": float(summary.flops),
        "hbm_bytes_per_dev": float(summary.hbm_bytes),
        "collective_bytes_per_dev": coll,
        "collective_counts": summary.num_collectives,
        "xla_cost_flops": float(ca.get("flops", 0) or 0),
        "xla_bytes_accessed": float(ca.get("bytes accessed", 0) or 0),
        "memory": {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "alias": mem.alias_size_in_bytes,
            "per_device_total": per_dev_bytes,
            "fits_16gb": bool(per_dev_bytes <= 16 * 1024**3),
        },
        # roofline terms (seconds)
        "compute_term_s": float(summary.flops) / PEAK_FLOPS,
        "memory_term_s": float(summary.hbm_bytes) / HBM_BW,
        "collective_term_s": sum(coll.values()) / ICI_BW,
        "trip_counts": {k: int(v) for k, v in summary.trip_counts.items()},
    }
    result["dominant"] = max(
        ("compute_term_s", "memory_term_s", "collective_term_s"),
        key=lambda k: result[k],
    )
    print(f"  roofline: compute {result['compute_term_s']*1e3:.2f}ms  "
          f"memory {result['memory_term_s']*1e3:.2f}ms  "
          f"collective {result['collective_term_s']*1e3:.2f}ms  "
          f"→ {result['dominant']}  per-dev {per_dev_bytes/2**30:.2f}GiB "
          f"(fits16G={result['memory']['fits_16gb']})")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = f"{arch}_{shape_name}_{result['mesh']}{suffix}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=ARCH_IDS)
    p.add_argument("--shape", choices=tuple(SHAPES))
    p.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    p.add_argument("--all", action="store_true", help="every supported cell")
    p.add_argument("--train-mode", default="auto", choices=("auto", "full", "otp"))
    p.add_argument("--precision", default="auto", choices=("auto", "bf16", "quant"))
    p.add_argument("--accum", type=int, default=0,
                   help="microbatch accumulation (0 = auto per arch)")
    p.add_argument("--state-bits", type=int, default=32, choices=(8, 32))
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--tag", default="", help="suffix for experiment variants")
    p.add_argument("--keep-going", action="store_true")
    args = p.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape_name in supported_shapes(get_config(arch)):
                cells.append((arch, shape_name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = []
    for arch, shape_name in cells:
        if shape_name not in supported_shapes(get_config(arch)):
            print(f"[skip] {arch} × {shape_name} (DESIGN.md §4)")
            continue
        for mp in meshes:
            try:
                run_cell(
                    arch, shape_name, mp,
                    train_mode=args.train_mode, precision=args.precision,
                    accum=args.accum, state_bits=args.state_bits,
                    out_dir=args.out, tag=args.tag,
                )
            except Exception:
                traceback.print_exc()
                failures.append((arch, shape_name, mp))
                if not args.keep_going:
                    raise
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete:", len(cells), "cells ×", len(meshes), "mesh(es)")


if __name__ == "__main__":
    main()
