"""PMQ — Pre-Loading Mixed-Precision Quantization (paper §3.2, Eq. 7).

Bit-width allocation as an Integer Program:

    min  Σ_i Σ_j  phi_i^α · w_i^β · (eps_ij)^γ · x_ij
    s.t. Σ_ij j·x_ij = n·b     (exact average-bit budget)
         Σ_j  x_ij  = 1  ∀i    (one width per expert)
         Σ_i x_i,3bit ≥ 1, Σ_i x_i,2bit ≥ 1   (accuracy floors)
         x_ij ∈ {0,1}

Two exact solvers, cross-checked in tests:

* :func:`allocate_block_milp` — scipy ``milp`` (the paper's LP/IP route;
  solves a 384-expert block in well under a second).
* :func:`allocate_block_dp`   — exact dynamic program over
  (expert, budget, has-2bit, has-3bit); dependency-free, deterministic.

A model-level helper distributes a fractional global budget across layers
and can optionally let sensitive layers borrow bits from insensitive ones
(beyond-paper ``layer_adaptive`` mode).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .significance import importance

__all__ = [
    "PMQPlan",
    "pmq_costs",
    "allocate_block_dp",
    "allocate_block_milp",
    "allocate_model",
]

BIT_CHOICES = (1, 2, 3)


def pmq_costs(
    phi: np.ndarray,
    w: np.ndarray,
    eps: np.ndarray,
    alpha: float = 1.0,
    beta: float = 0.5,
    gamma: float = 1.0,
) -> np.ndarray:
    """Objective coefficients ``c[i,j] = phi^α·w^β·eps^γ`` ([E, |bits|])."""
    imp = importance(phi, w, alpha, beta)  # [E]
    return imp[:, None] * np.power(np.maximum(eps, 0.0), gamma)


def allocate_block_dp(
    costs: np.ndarray,
    budget: int,
    bit_choices: Sequence[int] = BIT_CHOICES,
    require_floors: bool = True,
) -> np.ndarray:
    """Exact DP for Eq. 7. ``costs [E, |bits|]``, ``budget = n·b`` (int).

    State: (expert prefix, bits spent, seen-2bit, seen-3bit). Complexity
    O(E² · max_bits · 4 · |bits|) in time via vectorized numpy transitions,
    O(E · budget · 4) memory for exact backtracking — a 384-expert block
    solves in milliseconds. Returns the chosen bit-width per expert.
    """
    e, nb = costs.shape
    assert nb == len(bit_choices)
    lo, hi = min(bit_choices) * e, max(bit_choices) * e
    if not (lo <= budget <= hi):
        raise ValueError(f"budget {budget} infeasible for {e} experts {bit_choices}")
    inf = np.inf
    two_i = bit_choices.index(2) if 2 in bit_choices else -1
    three_i = bit_choices.index(3) if 3 in bit_choices else -1
    use_floors = require_floors and e >= 2 and two_i >= 0 and three_i >= 0

    def transition(dp, i):
        """one expert step: returns new dp [B+1, 2, 2]."""
        ndp = np.full_like(dp, inf)
        for j, bits in enumerate(bit_choices):
            shifted = np.full_like(dp, inf)
            shifted[bits:, :, :] = dp[: dp.shape[0] - bits, :, :]
            upd = shifted
            if use_floors and j == two_i:
                m = np.full_like(dp, inf)
                m[:, 1, :] = np.minimum(shifted[:, 0, :], shifted[:, 1, :])
                upd = m
            elif use_floors and j == three_i:
                m = np.full_like(dp, inf)
                m[:, :, 1] = np.minimum(shifted[:, :, 0], shifted[:, :, 1])
                upd = m
            ndp = np.minimum(ndp, upd + costs[i, j])
        return ndp

    tables = [np.full((budget + 1, 2, 2), inf)]
    tables[0][0, 0, 0] = 0.0
    for i in range(e):
        tables.append(transition(tables[i], i))

    final = tables[e]
    if use_floors:
        if np.isinf(final[budget, 1, 1]):
            raise ValueError("infeasible under floor constraints")
        state = (budget, 1, 1)
    else:
        flat = int(np.argmin(final[budget]))
        state = (budget, flat // 2, flat % 2)
        if np.isinf(final[state]):
            raise ValueError("infeasible")

    # exact backtrack: find (j, predecessor state) reproducing the value
    bits_out = np.zeros(e, np.int32)
    b, f2, f3 = state
    for i in range(e - 1, -1, -1):
        val = tables[i + 1][b, f2, f3]
        found = False
        for j, bits in enumerate(bit_choices):
            if b - bits < 0:
                continue
            # enumerate valid predecessor flags
            if use_floors and j == two_i:
                preds = [(0, f3), (1, f3)] if f2 == 1 else []
            elif use_floors and j == three_i:
                preds = [(f2, 0), (f2, 1)] if f3 == 1 else []
            else:
                preds = [(f2, f3)]
            for pf2, pf3 in preds:
                prev = tables[i][b - bits, pf2, pf3]
                if np.isfinite(prev) and np.isclose(
                    prev + costs[i, j], val, rtol=1e-9, atol=1e-12
                ):
                    bits_out[i] = bits
                    b, f2, f3 = b - bits, pf2, pf3
                    found = True
                    break
            if found:
                break
        if not found:  # pragma: no cover - numeric safety net
            raise RuntimeError("DP backtrack failed")
    return bits_out


def allocate_block_milp(
    costs: np.ndarray,
    budget: int,
    bit_choices: Sequence[int] = BIT_CHOICES,
    require_floors: bool = True,
) -> np.ndarray:
    """Eq. 7 via ``scipy.optimize.milp`` (HiGHS branch-and-bound)."""
    from scipy import optimize, sparse

    e, nb = costs.shape
    nvar = e * nb
    c = costs.reshape(-1).astype(np.float64)

    rows, cols, vals, lb, ub = [], [], [], [], []
    r = 0
    for i in range(e):  # budget row
        for j, bits in enumerate(bit_choices):
            rows.append(r), cols.append(i * nb + j), vals.append(float(bits))
    lb.append(float(budget)), ub.append(float(budget))
    r += 1
    for i in range(e):  # one-hot rows
        for j in range(nb):
            rows.append(r), cols.append(i * nb + j), vals.append(1.0)
        lb.append(1.0), ub.append(1.0)
        r += 1
    if require_floors and e >= 2:
        for target in (2, 3):
            if target in bit_choices:
                jj = bit_choices.index(target)
                for i in range(e):
                    rows.append(r), cols.append(i * nb + jj), vals.append(1.0)
                lb.append(1.0), ub.append(np.inf)
                r += 1
    a = sparse.csr_matrix((vals, (rows, cols)), shape=(r, nvar))
    res = optimize.milp(
        c,
        constraints=optimize.LinearConstraint(a, np.array(lb), np.array(ub)),
        integrality=np.ones(nvar),
        bounds=optimize.Bounds(0, 1),
    )
    if not res.success:
        raise ValueError(f"MILP failed: {res.message}")
    x = np.round(res.x).reshape(e, nb)
    return np.array([bit_choices[int(np.argmax(row))] for row in x], np.int32)


@dataclasses.dataclass
class PMQPlan:
    """Model-level allocation: ``bits[L][E]`` + bookkeeping."""

    bits: list  # list of np.ndarray [E_l]
    target_avg_bits: float
    objective: float
    layer_budgets: np.ndarray

    @property
    def avg_bits(self) -> float:
        tot = sum(int(b.sum()) for b in self.bits)
        cnt = sum(len(b) for b in self.bits)
        return tot / max(cnt, 1)

    def histogram(self) -> dict:
        h: dict = {}
        for b in self.bits:
            for v in b:
                h[int(v)] = h.get(int(v), 0) + 1
        return h


def allocate_model(
    phi: np.ndarray,
    w: np.ndarray,
    eps: np.ndarray,
    target_avg_bits: float,
    alpha: float = 1.0,
    beta: float = 0.5,
    gamma: float = 1.0,
    bit_choices: Sequence[int] = BIT_CHOICES,
    solver: str = "dp",
    layer_adaptive: bool = False,
) -> PMQPlan:
    """Allocate bit-widths for all layers.

    ``phi, w [L, E]``, ``eps [L, E, |bits|]``. Per the paper each MoE block
    gets the same integer budget ``round(E·b)`` (largest-remainder rounding
    so the *global* average hits the target exactly). ``layer_adaptive=True``
    additionally shifts whole bits between layers proportional to layer
    sensitivity ``Σ_i c[i, lowest-bit]`` (beyond-paper option).
    """
    L, E = phi.shape
    total = int(round(target_avg_bits * L * E))
    base = np.full(L, total // L)
    budgets = base.copy()
    for i in range(total - int(base.sum())):  # largest-remainder leftover
        budgets[i % L] += 1

    costs = [
        pmq_costs(phi[l], w[l], eps[l], alpha, beta, gamma) for l in range(L)
    ]
    if layer_adaptive and L > 1:
        sens = np.array([c[:, 0].sum() for c in costs])
        sens = sens / max(sens.sum(), 1e-12)
        shift = np.round((sens - 1.0 / L) * 0.5 * E).astype(np.int64)
        budgets = np.clip(
            budgets + shift, min(bit_choices) * E + 2, max(bit_choices) * E - 2
        )
        drift = total - int(budgets.sum())  # repair rounding/clipping drift
        i = 0
        while drift != 0:
            step = 1 if drift > 0 else -1
            nb = budgets[i % L] + step
            if min(bit_choices) * E + 2 <= nb <= max(bit_choices) * E - 2:
                budgets[i % L] = nb
                drift -= step
            i += 1

    alloc_fn = allocate_block_dp if solver == "dp" else allocate_block_milp
    bits, obj = [], 0.0
    for layer in range(L):
        b = alloc_fn(costs[layer], int(budgets[layer]), bit_choices)
        bits.append(b)
        for i, bv in enumerate(b):
            obj += float(costs[layer][i, list(bit_choices).index(int(bv))])
    return PMQPlan(
        bits=bits,
        target_avg_bits=target_avg_bits,
        objective=obj,
        layer_budgets=budgets,
    )
