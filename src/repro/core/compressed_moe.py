"""PMQ-compressed MoE experts: bit-bucketed storage + EP-chunked compute.

After :func:`repro.core.pmq.allocate_model` assigns per-expert bit-widths,
experts are **permuted so equal-width experts are contiguous** and stacked
into ≤3 *buckets* (one per bit-width). Each bucket is padded to a multiple
of the expert-parallel shard count so the compute scans one local expert
per shard per step — dequantized weights exist only as a
[ep, D, F]-transient in bf16, never the whole bucket (DESIGN.md §5.4).

On TPU the scan body is replaced by the ``moe_gmm`` Pallas kernel; the
jnp path below is its oracle-equivalent and the dry-run path.

The router remap (original expert id → permuted slot) rides the routing
top-k output, so the rest of the MoE layer (capacity dispatch, OTP
masking, combine) is unchanged.

**Host-offloaded residency** (serving): a bucket may be split into a
*resident* device partition of ``resident_rows[i]`` expert rows plus a
host backing store (:mod:`repro.serving.offload`). ``resident_map[bᵢ]``
maps every bucket slot to a row of the resident buffer; the compute
gathers rows back to the full ``[count, ...]`` layout, so the math —
and the bits — are identical to the all-resident path for every slot
whose resident row holds its true weights. The pytree structure is a
function of the *budget* only (array shapes + map shape), never of
*which* experts are resident, so uploads between steps never retrace
the jitted serving programs.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ref as kref
from ..models.moe import capacity_dispatch, combine, route_topk
from ..models.layers import mlp
from ..parallel.sharding import model_axis_size, shard
from . import otp as otp_mod
from .packing import packed_nbytes
from .quantizers import quantize_to_packed

__all__ = [
    "BucketMeta",
    "CompressedExperts",
    "build_compressed_experts",
    "compressed_expert_ffn",
    "compressed_moe_layer",
]


@dataclasses.dataclass(frozen=True)
class BucketMeta:
    bits: int
    start: int  # first permuted slot
    count: int  # padded expert count (multiple of ep)


@dataclasses.dataclass
class CompressedExperts:
    """Static metadata + array pytree for one layer's quantized experts.

    All-resident (the default): ``arrays[bᵢ]`` leaves span the bucket's
    full ``[count, ...]`` expert dim and ``resident_map is None``.

    Host-offloaded (serving): ``arrays[bᵢ]`` leaves span only
    ``resident_rows[i]`` device rows and ``resident_map[bᵢ]`` ([count]
    int32, or [L, count] stacked) maps each bucket slot to its resident
    row — non-resident slots point at row 0 and must not receive routed
    tokens (the serving engine's miss/replay loop guarantees that).
    """

    meta: Tuple[BucketMeta, ...]  # static
    slot_of_expert: jnp.ndarray  # [E] original id -> permuted slot
    arrays: Dict  # {bucket_i: {w_gate/w_up/w_down: {data|hi|lo, scale, zero}}}
    num_slots: int  # total padded slots
    group: int
    d_model: int
    d_ff: int
    resident_map: Optional[Dict] = None  # {bucket_i: [count] int32 -> row}
    resident_rows: Optional[Tuple[int, ...]] = None  # static, per bucket

    @property
    def weight_bytes(self) -> int:
        """Device-resident quantized bytes (= total bytes when all-resident)."""
        tot = 0
        for i, m in enumerate(self.meta):
            for w in ("w_gate", "w_up", "w_down"):
                a = self.arrays[f"b{i}"][w]
                for key in ("data", "hi", "lo", "scale", "zero"):
                    if key in a:
                        arr = a[key]
                        tot += arr.size * arr.dtype.itemsize
        return tot


def _flatten(xs):
    return [x for x in xs]


jax.tree_util.register_pytree_node(
    CompressedExperts,
    lambda ce: (
        (ce.slot_of_expert, ce.arrays, ce.resident_map),
        (ce.meta, ce.num_slots, ce.group, ce.d_model, ce.d_ff,
         ce.resident_rows),
    ),
    lambda aux, ch: CompressedExperts(
        meta=aux[0], slot_of_expert=ch[0], arrays=ch[1], num_slots=aux[1],
        group=aux[2], d_model=aux[3], d_ff=aux[4],
        resident_map=ch[2], resident_rows=aux[5],
    ),
)


def _pack_stack(ws: List[np.ndarray], bits: int, group: int,
                codes_list=None, scales=None, zeros=None,
                refine: bool = True) -> Dict:
    """Stack per-expert packed tensors of one bucket (shared bit-width)."""
    pts = []
    for i, w in enumerate(ws):
        kw = {}
        if codes_list is not None:
            kw = {
                "codes": jnp.asarray(codes_list[i]),
                "scale": jnp.asarray(scales[i]),
                "zero": jnp.asarray(zeros[i]),
            }
        pts.append(
            quantize_to_packed(jnp.asarray(w), bits, group=group, refine=refine, **kw)
        )
    out: Dict = {
        "scale": jnp.stack([p.scale for p in pts]),
        "zero": jnp.stack([p.zero for p in pts]),
    }
    if bits == 3:
        out["hi"] = jnp.stack([p.data[0] for p in pts])
        out["lo"] = jnp.stack([p.data[1] for p in pts])
    else:
        out["data"] = jnp.stack([p.data for p in pts])
    return out


def build_compressed_experts(
    experts: Dict,
    bits_per_expert: Sequence[int],
    *,
    group: int = 128,
    ep: int = 1,
    gptq_results: Optional[Dict] = None,
    refine: bool = True,
) -> CompressedExperts:
    """Quantize + bucket one layer's experts.

    ``experts`` = {"w_gate": [E, D, F], "w_up": [E, D, F], "w_down": [E, F, D]}.
    ``gptq_results[(expert, name)]`` optionally carries GPTQ codes/scales
    (:class:`repro.core.gptq.GPTQResult`) — otherwise RTN/HQQ packing.
    ``ep`` = expert-parallel shard count (buckets padded to multiples).
    """
    e = len(bits_per_expert)
    bits_arr = np.asarray(bits_per_expert)
    order = np.argsort(bits_arr, kind="stable")  # ascending bit groups
    meta: List[BucketMeta] = []
    arrays: Dict = {}
    slot_of_expert = np.full(e, -1, np.int64)
    wg = np.asarray(experts["w_gate"], np.float32)
    wu = np.asarray(experts["w_up"], np.float32)
    wd = np.asarray(experts["w_down"], np.float32)
    d, f = wg.shape[1], wg.shape[2]
    slot = 0
    for bits in sorted(set(bits_arr.tolist())):
        ids = [int(i) for i in order if bits_arr[i] == bits]
        for j, eid in enumerate(ids):
            slot_of_expert[eid] = slot + j
        count = len(ids)
        pad = (-count) % ep
        padded = count + pad
        pick = ids + [ids[-1]] * pad  # dummy slots clone the last expert
        bdict = {}
        for name, w in (("w_gate", wg), ("w_up", wu), ("w_down", wd)):
            if gptq_results is not None:
                codes = [gptq_results[(i, name)].codes for i in pick]
                scales = [gptq_results[(i, name)].scale for i in pick]
                zeros = [gptq_results[(i, name)].zero for i in pick]
                bdict[name] = _pack_stack(
                    [w[i] for i in pick], bits, group, codes, scales, zeros,
                    refine=refine,
                )
            else:
                bdict[name] = _pack_stack(
                    [w[i] for i in pick], bits, group, refine=refine
                )
        arrays[f"b{len(meta)}"] = bdict
        meta.append(BucketMeta(bits=bits, start=slot, count=padded))
        slot += padded
    return CompressedExperts(
        meta=tuple(meta),
        slot_of_expert=jnp.asarray(slot_of_expert, jnp.int32),
        arrays=arrays,
        num_slots=slot,
        group=group,
        d_model=d,
        d_ff=f,
    )


def _bmm_ep(x3, wd, bits: int, group: int):
    """Dequant-matmul vmapped over the (model-sharded) ep axis.

    ``x3 [ep, cap, K]``, ``wd`` packed arrays sliced to one local expert:
    [ep, K/per, N] (+ scale/zero [ep, ngroups, N]).
    """
    if bits == 3:
        packed = (wd["hi"], wd["lo"])
    else:
        packed = wd["data"]
    fn = lambda x2, pk, s, z: kref.quant_matmul_ref(
        x2, pk, s, z, bits=bits, group=group
    )
    return jax.vmap(fn)(x3, packed, wd["scale"], wd["zero"])


def _ep_fallback(count: int, ep: int) -> None:
    """A bucket whose padded expert count does not divide the runtime
    model-axis extent silently loses expert parallelism (the scan runs
    every expert on every shard). That only happens when the bucket was
    built with a different ``ep`` than the mesh it runs under — loud by
    default, fatal under ``REPRO_STRICT_EP=1``.
    """
    msg = (
        f"compressed_expert_ffn: bucket of {count} padded experts is not "
        f"divisible by the model-axis size {ep}; falling back to ep=1 "
        f"(expert parallelism disabled for this bucket). Rebuild the "
        f"buckets with build_compressed_experts(..., ep={ep}) to restore "
        f"EP, or set REPRO_STRICT_EP=1 to make this fatal."
    )
    strict = os.environ.get("REPRO_STRICT_EP", "0").strip().lower()
    if strict not in ("", "0", "false", "off", "no"):
        raise AssertionError(msg)
    warnings.warn(msg, RuntimeWarning, stacklevel=3)


def compressed_expert_ffn(
    ce: CompressedExperts, xp: jnp.ndarray, cap: int
) -> jnp.ndarray:
    """SwiGLU over permuted capacity layout ``xp [num_slots*cap, D]``.

    Expert-parallel execution (DESIGN.md §5.4): each bucket's experts are
    reshaped ``[count·cap, D] → [ep, local, cap, D]`` (ep = model-axis
    extent, baked into bucket padding at build time) and a ``lax.scan``
    walks the *local* expert index — every step runs one expert per model
    shard concurrently, so only one [K, N] dequantized tile exists per
    shard at a time. The capacity dim additionally shards over ``data``
    ("moe_elcd") so dispatch buffers never replicate.

    With a resident partition (``ce.resident_map``) the bucket's packed
    leaves are first gathered from the ``[resident_rows, ...]`` device
    buffer back to the full ``[count, ...]`` layout — bit-exact for every
    slot whose resident row holds its true weights (non-resident slots
    read row 0, which is only sound because they carry no routed tokens).
    """
    d = ce.d_model
    ys = []
    for i, m in enumerate(ce.meta):
        b = ce.arrays[f"b{i}"]
        if ce.resident_map is not None:
            rmap = ce.resident_map[f"b{i}"]
            b = jax.tree.map(lambda a: jnp.take(a, rmap, axis=0), b)
        ep = model_axis_size()
        if m.count % ep:
            _ep_fallback(m.count, ep)
            ep = 1
        local = m.count // ep
        xb = jax.lax.slice_in_dim(xp, m.start * cap, (m.start + m.count) * cap)
        x4 = xb.reshape(ep, local, cap, d)
        x4 = shard(x4, "moe_elcd")
        w4 = jax.tree.map(
            lambda a: jnp.moveaxis(a.reshape(ep, local, *a.shape[1:]), 1, 0),
            b,
        )  # leaves [local, ep, ...]

        def step(_, inp, bits=m.bits):
            x3, wg, wu, wd_ = inp
            h = jax.nn.silu(_bmm_ep(x3, wg, bits, ce.group)) * _bmm_ep(
                x3, wu, bits, ce.group
            )
            return None, _bmm_ep(h, wd_, bits, ce.group)

        _, y = jax.lax.scan(
            step,
            None,
            (jnp.moveaxis(x4, 1, 0), w4["w_gate"], w4["w_up"], w4["w_down"]),
        )  # y [local, ep, cap, D]
        y = jnp.moveaxis(y, 0, 1).reshape(m.count * cap, d)
        ys.append(y)
    return jnp.concatenate(ys, axis=0)


def compressed_moe_layer(
    p: Dict,
    ce: CompressedExperts,
    x: jnp.ndarray,
    cfg,
    *,
    otp_params: Optional[Dict] = None,
    otp_rng=None,
    otp_tau: float = 1.0,
    capacity_factor: Optional[float] = None,
    count_weight: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict]:
    """MoE block with PMQ experts (+ optional OTP pruning).

    ``p`` carries the (full-precision or 4-bit) router and shared experts.
    Returns ``(y [B,S,D], info)`` where info holds the OTP mask & router
    outputs (for distillation / calibration). ``info["mask_l1"]`` is the
    Eq. 14 ℓ1 statistic in both code paths. ``info["slot_counts"]`` is
    the per-permuted-slot count of dispatched (token, choice) pairs after
    OTP masking — the router statistic the serving offload prefetcher
    consumes; ``count_weight`` ([T], optional) zeroes the contribution of
    padding/inactive tokens so the counts reflect real traffic only.

    Inside a mesh context the routed region runs the shard_map EP path
    (zero all-to-all — see :mod:`repro.parallel.ep_shardmap`); a
    host-offloaded ``ce`` (``resident_map`` set) always takes the local
    path, which performs the resident-row gather.
    """
    from ..models.moe import ep_shardmap_ok
    from ..parallel.sharding import current_mesh

    mesh = current_mesh()
    if (
        mesh is not None
        and ce.resident_map is None
        and ep_shardmap_ok(cfg, mesh, x, ce.num_slots)
        and all(m.count % mesh.shape["model"] == 0 for m in ce.meta)
    ):
        from ..parallel.ep_shardmap import compressed_moe_region_sharded

        y, mask_l1 = compressed_moe_region_sharded(
            p, ce, x, cfg, mesh,
            otp_params=otp_params, otp_rng=otp_rng, otp_tau=otp_tau,
            capacity_factor=capacity_factor,
        )
        if "shared" in p:
            b, s, d = x.shape
            y = y + mlp(p["shared"], x.reshape(b * s, d)).reshape(b, s, d)
        info = {
            "probs": None, "idx": None, "gates": None, "mask": None,
            "mask_l1": mask_l1 if otp_params is not None else None,
            "slot_counts": None,
        }
        return y, info
    b, s, d = x.shape
    t = b * s
    x2 = x.reshape(t, d)
    e, k = cfg.num_experts, cfg.top_k
    probs, idx, gates = route_topk(p["router"], x2, k)
    mask = None
    if otp_params is not None:
        mask = otp_mod.otp_mask(
            otp_params, x2, idx, gates, rng=otp_rng, tau=otp_tau
        )
    # remap original expert ids -> permuted slots (dummy pads never hit)
    slots = ce.slot_of_expert[idx]
    # per-slot dispatch counts (post-mask, padding-weighted): the serving
    # offload manager's router statistic. The drop bucket (row num_slots)
    # absorbs masked / padded picks and is discarded.
    eff = slots.reshape(-1)
    if mask is not None:
        eff = jnp.where(mask.reshape(-1) > 0, eff, ce.num_slots)
    if count_weight is not None:
        cw = jnp.repeat(count_weight.reshape(-1).astype(bool), k)
        eff = jnp.where(cw, eff, ce.num_slots)
    slot_counts = (
        jnp.zeros((ce.num_slots + 1,), jnp.int32).at[eff].add(1)[:-1]
    )
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity_factor
    cap = max(8, ((int(cf * t * k / e) + 7) // 8) * 8)
    xp, dest, valid, gflat = capacity_dispatch(
        x2, slots, gates, ce.num_slots, cap, mask
    )
    xp = shard(xp, "moe_ed")
    yp = compressed_expert_ffn(ce, xp, cap)
    y = combine(yp, dest, valid, gflat, t, k)
    if "shared" in p:
        y = y + mlp(p["shared"], x2)
    info = {
        "probs": probs, "idx": idx, "gates": gates, "mask": mask,
        "mask_l1": mask.mean() if mask is not None else None,
        "slot_counts": slot_counts,
    }
    return y.reshape(b, s, d), info
