"""PMQ-compressed MoE experts: bit-bucketed storage + grouped-GEMM compute.

After :func:`repro.core.pmq.allocate_model` assigns per-expert bit-widths,
experts are **permuted so equal-width experts are contiguous** and stacked
into ≤3 *buckets* (one per bit-width). Each bucket is padded to a multiple
of the expert-parallel shard count (DESIGN.md §5.4).

**Compute path (default: ``grouped``).** The capacity-dispatch layout is
already expert-major — slot ``s`` owns rows ``[s·cap, (s+1)·cap)`` — so
each bucket's slice is a token-sorted ragged batch in disguise: the
occupied rows of every slot are a *prefix* (capacity dispatch assigns
rank-within-expert destinations). :func:`compressed_expert_ffn` compacts
those prefixes into back-to-back ``bm``-aligned groups, issues the
bucket's whole SwiGLU as grouped GEMMs via :func:`repro.kernels.ops`
(one fused gate/up call with the SwiGLU epilogue + one down call) with a
scalar-prefetched ``block_expert`` table, and scatters the results back
to the capacity layout. Row-blocks past the routed-token frontier are
skipped inside the kernel (``num_active``), so the dead compute on
unrouted capacity padding — which the old per-expert ``lax.scan`` paid
in full, dequantizing every expert against every padded row — is gone.
On TPU ``ops.moe_gmm`` lowers to the Pallas kernel in
:mod:`repro.kernels.moe_gmm`; on CPU it runs the jnp oracle
(``moe_gmm_ref``), and tests opt into ``interpret``.

**Backend knob.** ``backend=`` / ``ffn_backend=`` selects per call:
``"grouped"`` (platform-default kernel — Pallas on TPU, oracle on CPU),
``"interpret"`` / ``"ref"`` (grouped layout, forced kernel backend), or
``"scan"`` (the legacy per-expert scan, kept as the A/B baseline and
numeric reference; its dequant-matmul now routes through
``ops.quant_matmul_parts`` so even the scan gets the Pallas
dequant-GEMM on TPU). ``REPRO_FFN_BACKEND`` overrides the default
process-wide — it is read at trace time, so a jitted serving engine
keeps whichever backend it was traced with.

The router remap (original expert id → permuted slot) rides the routing
top-k output, so the rest of the MoE layer (capacity dispatch, OTP
masking, combine) is unchanged.

**Host-offloaded residency** (serving): a bucket may be split into a
*resident* device partition of ``resident_rows[i]`` expert rows plus a
host backing store (:mod:`repro.serving.offload`). ``resident_map[bᵢ]``
maps every bucket slot to a row of the resident buffer. The grouped path
never materializes the gathered bucket: the indirection is folded into
the scalar ``block_expert`` table once per bucket
(``block_expert = resident_map[block_expert]``), so the kernel fetches
resident rows directly — bit-identical to the all-resident path for
every slot whose resident row holds its true weights. The pytree
structure is a function of the *budget* only (array shapes + map shape),
never of *which* experts are resident, so uploads between steps never
retrace the jitted serving programs.
"""
from __future__ import annotations

import dataclasses
import math
import os
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..models.moe import (
    capacity_dispatch,
    combine,
    dispatch_capacity,
    route_topk,
    slot_fill_counts,
)
from ..models.layers import mlp
from ..parallel.sharding import model_axis_size, shard
from . import otp as otp_mod
from .packing import packed_nbytes
from .quantizers import quantize_to_packed

__all__ = [
    "BucketMeta",
    "CompressedExperts",
    "FFN_BACKENDS",
    "build_compressed_experts",
    "compressed_expert_ffn",
    "compressed_moe_layer",
    "default_ffn_backend",
    "gmm_block_rows",
    "grouped_bucket_ffn",
]

FFN_BACKENDS = ("grouped", "scan", "ref", "interpret")


def default_ffn_backend() -> str:
    """Process-wide expert-FFN path: ``REPRO_FFN_BACKEND`` or ``grouped``."""
    b = os.environ.get("REPRO_FFN_BACKEND", "").strip().lower() or "grouped"
    if b not in FFN_BACKENDS:
        raise ValueError(
            f"REPRO_FFN_BACKEND={b!r} not in {FFN_BACKENDS}"
        )
    return b


def _resolve_backend(backend: Optional[str]) -> Tuple[str, Optional[str]]:
    """``backend`` → ``(path, kernel_backend)``.

    ``path`` is ``"grouped"`` or ``"scan"``; ``kernel_backend`` feeds the
    :mod:`repro.kernels.ops` platform selection (None = platform default).
    """
    b = backend or default_ffn_backend()
    if b == "scan":
        return "scan", None
    if b == "grouped":
        return "grouped", None
    if b in ("ref", "interpret"):
        return "grouped", b
    raise ValueError(f"ffn backend {b!r} not in {FFN_BACKENDS}")


@dataclasses.dataclass(frozen=True)
class BucketMeta:
    bits: int
    start: int  # first permuted slot
    count: int  # padded expert count (multiple of ep)


@dataclasses.dataclass
class CompressedExperts:
    """Static metadata + array pytree for one layer's quantized experts.

    All-resident (the default): ``arrays[bᵢ]`` leaves span the bucket's
    full ``[count, ...]`` expert dim and ``resident_map is None``.

    Host-offloaded (serving): ``arrays[bᵢ]`` leaves span only
    ``resident_rows[i]`` device rows and ``resident_map[bᵢ]`` ([count]
    int32, or [L, count] stacked) maps each bucket slot to its resident
    row — non-resident slots point at row 0 and must not receive routed
    tokens (the serving engine's miss/replay loop guarantees that).
    """

    meta: Tuple[BucketMeta, ...]  # static
    slot_of_expert: jnp.ndarray  # [E] original id -> permuted slot
    arrays: Dict  # {bucket_i: {w_gate/w_up/w_down: {data|hi|lo, scale, zero}}}
    num_slots: int  # total padded slots
    group: int
    d_model: int
    d_ff: int
    resident_map: Optional[Dict] = None  # {bucket_i: [count] int32 -> row}
    resident_rows: Optional[Tuple[int, ...]] = None  # static, per bucket

    @property
    def weight_bytes(self) -> int:
        """Device-resident quantized bytes (= total bytes when all-resident)."""
        tot = 0
        for i, m in enumerate(self.meta):
            for w in ("w_gate", "w_up", "w_down"):
                a = self.arrays[f"b{i}"][w]
                for key in ("data", "hi", "lo", "scale", "zero"):
                    if key in a:
                        arr = a[key]
                        tot += arr.size * arr.dtype.itemsize
        return tot


def _flatten(xs):
    return [x for x in xs]


jax.tree_util.register_pytree_node(
    CompressedExperts,
    lambda ce: (
        (ce.slot_of_expert, ce.arrays, ce.resident_map),
        (ce.meta, ce.num_slots, ce.group, ce.d_model, ce.d_ff,
         ce.resident_rows),
    ),
    lambda aux, ch: CompressedExperts(
        meta=aux[0], slot_of_expert=ch[0], arrays=ch[1], num_slots=aux[1],
        group=aux[2], d_model=aux[3], d_ff=aux[4],
        resident_map=ch[2], resident_rows=aux[5],
    ),
)


def _pack_stack(ws: List[np.ndarray], bits: int, group: int,
                codes_list=None, scales=None, zeros=None,
                refine: bool = True) -> Dict:
    """Stack per-expert packed tensors of one bucket (shared bit-width)."""
    pts = []
    for i, w in enumerate(ws):
        kw = {}
        if codes_list is not None:
            kw = {
                "codes": jnp.asarray(codes_list[i]),
                "scale": jnp.asarray(scales[i]),
                "zero": jnp.asarray(zeros[i]),
            }
        pts.append(
            quantize_to_packed(jnp.asarray(w), bits, group=group, refine=refine, **kw)
        )
    out: Dict = {
        "scale": jnp.stack([p.scale for p in pts]),
        "zero": jnp.stack([p.zero for p in pts]),
    }
    if bits == 3:
        out["hi"] = jnp.stack([p.data[0] for p in pts])
        out["lo"] = jnp.stack([p.data[1] for p in pts])
    else:
        out["data"] = jnp.stack([p.data for p in pts])
    return out


def build_compressed_experts(
    experts: Dict,
    bits_per_expert: Sequence[int],
    *,
    group: int = 128,
    ep: int = 1,
    gptq_results: Optional[Dict] = None,
    refine: bool = True,
) -> CompressedExperts:
    """Quantize + bucket one layer's experts.

    ``experts`` = {"w_gate": [E, D, F], "w_up": [E, D, F], "w_down": [E, F, D]}.
    ``gptq_results[(expert, name)]`` optionally carries GPTQ codes/scales
    (:class:`repro.core.gptq.GPTQResult`) — otherwise RTN/HQQ packing.
    ``ep`` = expert-parallel shard count (buckets padded to multiples).
    """
    e = len(bits_per_expert)
    bits_arr = np.asarray(bits_per_expert)
    order = np.argsort(bits_arr, kind="stable")  # ascending bit groups
    meta: List[BucketMeta] = []
    arrays: Dict = {}
    slot_of_expert = np.full(e, -1, np.int64)
    wg = np.asarray(experts["w_gate"], np.float32)
    wu = np.asarray(experts["w_up"], np.float32)
    wd = np.asarray(experts["w_down"], np.float32)
    d, f = wg.shape[1], wg.shape[2]
    slot = 0
    for bits in sorted(set(bits_arr.tolist())):
        ids = [int(i) for i in order if bits_arr[i] == bits]
        for j, eid in enumerate(ids):
            slot_of_expert[eid] = slot + j
        count = len(ids)
        pad = (-count) % ep
        padded = count + pad
        pick = ids + [ids[-1]] * pad  # dummy slots clone the last expert
        bdict = {}
        for name, w in (("w_gate", wg), ("w_up", wu), ("w_down", wd)):
            if gptq_results is not None:
                codes = [gptq_results[(i, name)].codes for i in pick]
                scales = [gptq_results[(i, name)].scale for i in pick]
                zeros = [gptq_results[(i, name)].zero for i in pick]
                bdict[name] = _pack_stack(
                    [w[i] for i in pick], bits, group, codes, scales, zeros,
                    refine=refine,
                )
            else:
                bdict[name] = _pack_stack(
                    [w[i] for i in pick], bits, group, refine=refine
                )
        arrays[f"b{len(meta)}"] = bdict
        meta.append(BucketMeta(bits=bits, start=slot, count=padded))
        slot += padded
    return CompressedExperts(
        meta=tuple(meta),
        slot_of_expert=jnp.asarray(slot_of_expert, jnp.int32),
        arrays=arrays,
        num_slots=slot,
        group=group,
        d_model=d,
        d_ff=f,
    )


def _bmm_ep(x3, wd, bits: int, group: int, kernel_backend: Optional[str] = None):
    """Dequant-matmul vmapped over the (model-sharded) ep axis.

    ``x3 [ep, cap, K]``, ``wd`` packed arrays sliced to one local expert:
    [ep, K/per, N] (+ scale/zero [ep, ngroups, N]). Routed through the
    :func:`repro.kernels.ops.quant_matmul_parts` backend selection, so
    TPU shards run the fused dequant-GEMM Pallas kernel.
    """
    if bits == 3:
        fn = lambda x2, hi, lo, s, z: ops.quant_matmul_parts(
            x2, (hi, lo), s, z, bits=bits, group=group,
            backend=kernel_backend,
        )
        return jax.vmap(fn)(x3, wd["hi"], wd["lo"], wd["scale"], wd["zero"])
    fn = lambda x2, pk, s, z: ops.quant_matmul_parts(
        x2, pk, s, z, bits=bits, group=group, backend=kernel_backend
    )
    return jax.vmap(fn)(x3, wd["data"], wd["scale"], wd["zero"])


def _ep_fallback(count: int, ep: int) -> None:
    """A bucket whose padded expert count does not divide the runtime
    model-axis extent silently loses expert parallelism (the compute runs
    every expert on every shard). That only happens when the bucket was
    built with a different ``ep`` than the mesh it runs under — loud by
    default, fatal under ``REPRO_STRICT_EP=1``.
    """
    msg = (
        f"compressed_expert_ffn: bucket of {count} padded experts is not "
        f"divisible by the model-axis size {ep}; falling back to ep=1 "
        f"(expert parallelism disabled for this bucket). Rebuild the "
        f"buckets with build_compressed_experts(..., ep={ep}) to restore "
        f"EP, or set REPRO_STRICT_EP=1 to make this fatal."
    )
    strict = os.environ.get("REPRO_STRICT_EP", "0").strip().lower()
    if strict not in ("", "0", "false", "off", "no"):
        raise AssertionError(msg)
    warnings.warn(msg, RuntimeWarning, stacklevel=3)


def _gmm_parts(w: Dict, bits: int):
    pk = (w["hi"], w["lo"]) if bits == 3 else w["data"]
    return pk, w["scale"], w["zero"]


def gmm_block_rows(cap: int) -> int:
    """Row-block size ``bm`` for the grouped path at capacity ``cap``.

    ``bm`` must divide ``cap`` (so slot boundaries are block-aligned) and
    trades MXU tile height against ragged-skip granularity: each
    nonempty expert wastes < ``bm`` rows of compute, so smaller blocks
    skip more dead padding while larger blocks feed the 128-row MXU
    better. Default target 16 — drop-free serving capacities
    (cf = num_experts) run single-digit-percent utilization, where skip
    granularity dominates; override with ``REPRO_GMM_BM`` (e.g. 128 for
    long-prefill TPU runs). Always a multiple of 8 because ``cap`` is.
    """
    target = int(os.environ.get("REPRO_GMM_BM", "0") or 0) or 16
    target = max(8, ((target + 7) // 8) * 8)  # sublane-align the target
    return math.gcd(cap, target)


def grouped_bucket_ffn(
    xb: jnp.ndarray,
    wdict: Dict,
    *,
    bits: int,
    group: int,
    count: int,
    cap: int,
    kernel_backend: Optional[str] = None,
    fill: Optional[jnp.ndarray] = None,
    rmap: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """One bucket's SwiGLU over its capacity slice as grouped GEMMs.

    ``xb [count·cap, D]`` is the bucket's expert-major capacity slice;
    ``wdict`` its packed gate/up/down arrays (leading dim = ``count``,
    or the resident row count when ``rmap`` indirects). Returns
    ``[count·cap, D]`` in the same layout.

    ``fill [count]`` (optional) gives each slot's occupied-row count —
    occupancy is a *prefix* per slot (capacity dispatch ranks within the
    expert), so compaction is a pure index shuffle: slot ``s`` row ``j``
    (``j < fill[s]``) moves to ``offsets[s] + j`` where groups are packed
    back-to-back at ``bm`` boundaries. The trailing ``num_active`` block
    count lets the kernel skip every block past the routed-token
    frontier; results are scattered back so unoccupied capacity rows are
    exactly zero — identical to what the scan path computes for them.
    Without ``fill`` every capacity row is treated as live (the layout
    is already bm-aligned and expert-major, so no shuffle is needed).

    ``rmap [count]`` folds host-offload residency into the scalar
    ``block_expert`` table instead of gathering the packed bucket.
    """
    m = count * cap
    d = xb.shape[-1]
    bm = gmm_block_rows(cap)
    if fill is not None:
        fill = jnp.minimum(fill.astype(jnp.int32), cap)
        padded = ((fill + bm - 1) // bm) * bm  # [count], bm | cap ⇒ Σ ≤ m
        nblk = padded // bm
        offsets = jnp.cumsum(padded) - padded  # exclusive
        s_of = jnp.arange(m, dtype=jnp.int32) // cap
        j_of = jnp.arange(m, dtype=jnp.int32) % cap
        # capacity row (s, j) → compacted row; dropped/empty rows → m
        gdest = jnp.where(j_of < fill[s_of], offsets[s_of] + j_of, m)
        inv = jnp.zeros((m + 1,), jnp.int32)
        inv = inv.at[gdest].set(jnp.arange(m, dtype=jnp.int32) + 1)[:m]
        src = jnp.where(inv > 0, inv - 1, m)  # m = appended zero row
        x_pad = jnp.concatenate([xb, jnp.zeros((1, d), xb.dtype)], axis=0)
        xg = x_pad[src]
        block_expert = jnp.repeat(
            jnp.arange(count, dtype=jnp.int32), nblk,
            total_repeat_length=m // bm,
        )  # trailing pad entries repeat a valid id; num_active masks them
        num_active = jnp.sum(nblk).astype(jnp.int32).reshape(1)
    else:
        xg = xb
        gdest = None
        block_expert = jnp.repeat(jnp.arange(count, dtype=jnp.int32), cap // bm)
        num_active = None
    if rmap is not None:
        block_expert = rmap[block_expert].astype(jnp.int32)

    gp, gs, gz = _gmm_parts(wdict["w_gate"], bits)
    up, us, uz = _gmm_parts(wdict["w_up"], bits)
    dp, ds, dz = _gmm_parts(wdict["w_down"], bits)
    h = ops.moe_gmm_swiglu(
        xg, gp, up, gs, gz, us, uz, block_expert, num_active,
        bits=bits, group=group, backend=kernel_backend, bm=bm,
    )
    yg = ops.moe_gmm(
        h, dp, ds, dz, block_expert, num_active,
        bits=bits, group=group, backend=kernel_backend, bm=bm,
    )
    if gdest is None:
        return yg
    y_pad = jnp.concatenate([yg, jnp.zeros((1, d), yg.dtype)], axis=0)
    return y_pad[gdest]


def compressed_expert_ffn(
    ce: CompressedExperts, xp: jnp.ndarray, cap: int,
    *,
    backend: Optional[str] = None,
    slot_fill: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """SwiGLU over permuted capacity layout ``xp [num_slots*cap, D]``.

    Default (``backend="grouped"``): each bucket runs as two grouped
    GEMM calls — fused gate/up with the SwiGLU epilogue, then down —
    through :func:`grouped_bucket_ffn` (see its docstring for the
    compacted ragged layout driven by ``slot_fill``, the per-permuted-
    slot occupied-row counts from capacity dispatch). With a resident
    partition (``ce.resident_map``) the indirection is folded into the
    scalar ``block_expert`` table once per bucket, before the GEMM —
    never a per-step weight gather (non-resident slots read row 0, which
    is only sound because they carry no routed tokens).

    ``backend="scan"`` keeps the legacy expert-parallel scan (DESIGN.md
    §5.4): each bucket reshaped ``[count·cap, D] → [ep, local, cap, D]``
    (ep = model-axis extent, baked into bucket padding at build time),
    a ``lax.scan`` over the local expert index, one dequantized [K, N]
    tile per shard per step, dequant-matmul via
    ``ops.quant_matmul_parts``. It gathers resident rows back to the
    full bucket layout instead of remapping ``block_expert``.

    Under ``ep > 1`` the grouped path vmaps :func:`grouped_bucket_ffn`
    over the shard axis (the ``moe_elcd`` capacity sharding is kept); the
    production multi-host EP route is the shard_map region in
    :mod:`repro.parallel.ep_shardmap`, which calls the same primitive
    device-locally.
    """
    d = ce.d_model
    path, kb = _resolve_backend(backend)
    ys = []
    for i, m in enumerate(ce.meta):
        b = ce.arrays[f"b{i}"]
        rmap = None
        if ce.resident_map is not None:
            rmap = ce.resident_map[f"b{i}"]
        ep = model_axis_size()
        if m.count % ep:
            _ep_fallback(m.count, ep)
            ep = 1
        local = m.count // ep
        xb = jax.lax.slice_in_dim(xp, m.start * cap, (m.start + m.count) * cap)
        fill = None
        if slot_fill is not None:
            fill = jax.lax.slice_in_dim(
                slot_fill, m.start, m.start + m.count
            )

        if path == "scan":
            if rmap is not None:
                b = jax.tree.map(lambda a: jnp.take(a, rmap, axis=0), b)
            x4 = xb.reshape(ep, local, cap, d)
            x4 = shard(x4, "moe_elcd")
            w4 = jax.tree.map(
                lambda a: jnp.moveaxis(a.reshape(ep, local, *a.shape[1:]), 1, 0),
                b,
            )  # leaves [local, ep, ...]

            def step(_, inp, bits=m.bits):
                x3, wg, wu, wd_ = inp
                h = jax.nn.silu(
                    _bmm_ep(x3, wg, bits, ce.group, kb)
                ) * _bmm_ep(x3, wu, bits, ce.group, kb)
                return None, _bmm_ep(h, wd_, bits, ce.group, kb)

            _, y = jax.lax.scan(
                step,
                None,
                (jnp.moveaxis(x4, 1, 0), w4["w_gate"], w4["w_up"], w4["w_down"]),
            )  # y [local, ep, cap, D]
            ys.append(jnp.moveaxis(y, 0, 1).reshape(m.count * cap, d))
            continue

        if ep == 1:
            y = grouped_bucket_ffn(
                xb, b, bits=m.bits, group=ce.group, count=m.count, cap=cap,
                kernel_backend=kb, fill=fill, rmap=rmap,
            )
        else:
            if rmap is not None:
                # resident buffers are not ep-structured; materialize the
                # bucket gather once, then shard as usual
                b = jax.tree.map(lambda a: jnp.take(a, rmap, axis=0), b)
            x4 = xb.reshape(ep, local, cap, d)
            x4 = shard(x4, "moe_elcd")
            x3 = x4.reshape(ep, local * cap, d)
            w3 = jax.tree.map(lambda a: a.reshape(ep, local, *a.shape[1:]), b)

            def gfn(xe, we, fe, bits=m.bits):
                return grouped_bucket_ffn(
                    xe, we, bits=bits, group=ce.group, count=local, cap=cap,
                    kernel_backend=kb, fill=fe,
                )

            if fill is None:
                y = jax.vmap(lambda xe, we: gfn(xe, we, None))(x3, w3)
            else:
                y = jax.vmap(gfn)(x3, w3, fill.reshape(ep, local))
            y = y.reshape(m.count * cap, d)
        ys.append(y)
    return jnp.concatenate(ys, axis=0)


def compressed_moe_layer(
    p: Dict,
    ce: CompressedExperts,
    x: jnp.ndarray,
    cfg,
    *,
    otp_params: Optional[Dict] = None,
    otp_rng=None,
    otp_tau: float = 1.0,
    capacity_factor: Optional[float] = None,
    count_weight: Optional[jnp.ndarray] = None,
    ffn_backend: Optional[str] = None,
) -> Tuple[jnp.ndarray, Dict]:
    """MoE block with PMQ experts (+ optional OTP pruning).

    ``p`` carries the (full-precision or 4-bit) router and shared experts.
    Returns ``(y [B,S,D], info)`` where info holds the OTP mask & router
    outputs (for distillation / calibration). ``info["mask_l1"]`` is the
    Eq. 14 ℓ1 statistic in both code paths. ``info["slot_counts"]`` is
    the per-permuted-slot count of dispatched (token, choice) pairs after
    OTP masking — the router statistic the serving offload prefetcher
    consumes; ``count_weight`` ([T], optional) zeroes the contribution of
    padding/inactive tokens so the counts reflect real traffic only.
    ``ffn_backend`` selects the expert-FFN implementation (see
    :data:`FFN_BACKENDS`; default ``grouped``).

    Inside a mesh context the routed region runs the shard_map EP path
    (zero all-to-all — see :mod:`repro.parallel.ep_shardmap`); a
    host-offloaded ``ce`` (``resident_map`` set) always takes the local
    path, which folds the resident-row indirection into the grouped
    dispatch tables.
    """
    from ..models.moe import ep_shardmap_ok
    from ..parallel.sharding import current_mesh

    mesh = current_mesh()
    if (
        mesh is not None
        and ce.resident_map is None
        and ep_shardmap_ok(cfg, mesh, x, ce.num_slots)
        and all(m.count % mesh.shape["model"] == 0 for m in ce.meta)
    ):
        from ..parallel.ep_shardmap import compressed_moe_region_sharded

        y, mask_l1 = compressed_moe_region_sharded(
            p, ce, x, cfg, mesh,
            otp_params=otp_params, otp_rng=otp_rng, otp_tau=otp_tau,
            capacity_factor=capacity_factor, ffn_backend=ffn_backend,
        )
        if "shared" in p:
            b, s, d = x.shape
            y = y + mlp(p["shared"], x.reshape(b * s, d)).reshape(b, s, d)
        info = {
            "probs": None, "idx": None, "gates": None, "mask": None,
            "mask_l1": mask_l1 if otp_params is not None else None,
            "slot_counts": None,
        }
        return y, info
    b, s, d = x.shape
    t = b * s
    x2 = x.reshape(t, d)
    e, k = cfg.num_experts, cfg.top_k
    probs, idx, gates = route_topk(p["router"], x2, k)
    mask = None
    if otp_params is not None:
        mask = otp_mod.otp_mask(
            otp_params, x2, idx, gates, rng=otp_rng, tau=otp_tau
        )
    # remap original expert ids -> permuted slots (dummy pads never hit)
    slots = ce.slot_of_expert[idx]
    # per-slot dispatch counts (post-mask, padding-weighted): the serving
    # offload manager's router statistic. The drop bucket (row num_slots)
    # absorbs masked / padded picks and is discarded.
    eff = slots.reshape(-1)
    if mask is not None:
        eff = jnp.where(mask.reshape(-1) > 0, eff, ce.num_slots)
    if count_weight is not None:
        cw = jnp.repeat(count_weight.reshape(-1).astype(bool), k)
        eff = jnp.where(cw, eff, ce.num_slots)
    slot_counts = (
        jnp.zeros((ce.num_slots + 1,), jnp.int32).at[eff].add(1)[:-1]
    )
    cap = dispatch_capacity(cfg, t, capacity_factor)
    xp, dest, valid, gflat = capacity_dispatch(
        x2, slots, gates, ce.num_slots, cap, mask
    )
    # occupied-row counts after capacity clipping: occupancy is a prefix
    # per slot, so these drive the grouped path's ragged compaction
    slot_fill = slot_fill_counts(dest, valid, ce.num_slots, cap)
    xp = shard(xp, "moe_ed")
    yp = compressed_expert_ffn(
        ce, xp, cap, backend=ffn_backend, slot_fill=slot_fill
    )
    y = combine(yp, dest, valid, gflat, t, k)
    if "shared" in p:
        y = y + mlp(p["shared"], x2)
    info = {
        "probs": probs, "idx": idx, "gates": gates, "mask": mask,
        "mask_l1": mask.mean() if mask is not None else None,
        "slot_counts": slot_counts,
    }
    return y.reshape(b, s, d), info
