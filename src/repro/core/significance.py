"""Expert significance analysis (paper §3.2.1–3.2.2, Figs. 4/5).

Three signals per expert, gathered on a calibration set:

* access frequency     ``phi_i = n_i / N``            (how often routed to)
* activation weight    ``w_i = Σ_j sigma_j / N``      (mean routing weight)
* reconstruction error ``eps_{i,j}`` (Eq. 6): F-norm between the MoE layer
  output with full-precision experts and with only expert *i* quantized to
  *j* bits.

These are model-agnostic: routing statistics accumulate from ``(top-k
indices, top-k gates)`` streams, and ``eps`` is computed through a
caller-supplied layer-forward closure so any MoE variant plugs in.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = ["RouterStats", "expert_eps", "importance"]


@dataclasses.dataclass
class RouterStats:
    """Streaming accumulator for phi / w over calibration batches."""

    num_experts: int
    counts: np.ndarray = None  # [E]
    weight_sums: np.ndarray = None  # [E]
    tokens: int = 0

    def __post_init__(self):
        if self.counts is None:
            self.counts = np.zeros(self.num_experts, np.int64)
        if self.weight_sums is None:
            self.weight_sums = np.zeros(self.num_experts, np.float64)

    def update(self, topk_idx, topk_gates) -> None:
        """``topk_idx [T, k]`` int, ``topk_gates [T, k]`` float."""
        idx = np.asarray(topk_idx).reshape(-1)
        gts = np.asarray(topk_gates, np.float64).reshape(-1)
        self.counts += np.bincount(idx, minlength=self.num_experts)
        self.weight_sums += np.bincount(
            idx, weights=gts, minlength=self.num_experts
        )
        self.tokens += int(np.asarray(topk_idx).shape[0])

    @property
    def phi(self) -> np.ndarray:
        """Access frequency ``n_i / N`` (N = token count)."""
        return self.counts / max(self.tokens, 1)

    @property
    def w(self) -> np.ndarray:
        """Mean routing weight ``Σ sigma / N``."""
        return self.weight_sums / max(self.tokens, 1)


def expert_eps(
    layer_forward: Callable[[Sequence], jnp.ndarray],
    expert_weights: Sequence,
    quantize_expert: Callable[[object, int], object],
    bits_options: Sequence[int] = (1, 2, 3),
) -> np.ndarray:
    """Eq. 6: ``eps[i, j] = ||F(theta) - F(theta[e_i -> Q(e_i, j)])||_F``.

    ``layer_forward(experts) -> output`` runs the MoE layer on (captured)
    calibration activations; ``quantize_expert(e, bits)`` returns the
    fake-quantized (quantize→dequantize) weights of one expert.
    """
    base = layer_forward(list(expert_weights))
    n = len(expert_weights)
    eps = np.zeros((n, len(bits_options)), np.float64)
    for i in range(n):
        for j, bits in enumerate(bits_options):
            perturbed = list(expert_weights)
            perturbed[i] = quantize_expert(expert_weights[i], bits)
            out = layer_forward(perturbed)
            eps[i, j] = float(jnp.linalg.norm((out - base).astype(jnp.float32)))
    return eps


def importance(
    phi: np.ndarray, w: np.ndarray, alpha: float = 1.0, beta: float = 0.5
) -> np.ndarray:
    """Overall expert importance ``phi^alpha * w^beta`` (§3.2.2)."""
    return np.power(np.maximum(phi, 1e-12), alpha) * np.power(
        np.maximum(w, 1e-12), beta
    )
