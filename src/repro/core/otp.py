"""OTP — Online Top-any Pruning (paper §3.4, Eqs. 10–14, Fig. 8).

A tiny learnable router ``DM(·)`` per MoE layer (two linear layers, Tab. 1:
FC1 [d_model → k], FC2 [2k → k], mask table [k, k]) scores the *prefix
mask* candidates

    C_k = {[1...1], [1...1,0], ..., [1, 0...0]}        (Eq. 10)

over the top-k experts **sorted by gate weight** (strongest kept first).
Training samples a candidate with Gumbel-Softmax (Eq. 13, temperature τ)
so the discrete choice is differentiable; the loss (Eq. 14) distills the
masked model against the un-masked one plus a λ‖M‖₁ sparsity term.
Inference takes the argmax candidate (τ → 0 limit) — deterministic, no
noise.

The resulting mask multiplies gate weights *before* dispatch, so pruned
experts consume no capacity and no FLOPs (`repro.models.moe.moe_layer`'s
``gate_mask_fn`` hook).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "candidate_masks",
    "init_otp_router",
    "dm_logits",
    "otp_mask",
    "sample_mask_gumbel",
    "otp_losses",
    "mask_ratio",
]


def candidate_masks(k: int) -> jnp.ndarray:
    """Eq. 10 prefix-mask candidate set ``C_k [k, k]`` (keep-m-strongest).

    Row j keeps the top (k − j) experts: row 0 = all ones … row k−1 keeps
    only the strongest.
    """
    keep = k - jnp.arange(k)  # [k] : k, k-1, ..., 1
    return (jnp.arange(k)[None, :] < keep[:, None]).astype(jnp.float32)


def init_otp_router(rng, d_model: int, k: int, dtype=jnp.float32) -> Dict:
    """Learnable router DM(·) per Tab. 1: FC1 [d, k], FC2 [2k, k]."""
    k1, k2 = jax.random.split(rng)
    return {
        "fc1": jax.random.normal(k1, (d_model, k), dtype) * (d_model**-0.5),
        "fc2": jax.random.normal(k2, (2 * k, k), dtype) * ((2 * k) ** -0.5),
    }


def dm_logits(p: Dict, x2: jnp.ndarray, gates_sorted: jnp.ndarray) -> jnp.ndarray:
    """Categorical logits over C_k: DM(t_i, w) (Eq. 13 input).

    ``x2 [T, D]`` tokens, ``gates_sorted [T, k]`` the (descending) top-k
    gate weights — both token content and routing confidence inform the
    pruning decision.
    """
    h = x2.astype(jnp.float32) @ p["fc1"].astype(jnp.float32)  # [T, k]
    h = jnp.concatenate([jax.nn.silu(h), gates_sorted.astype(jnp.float32)], -1)
    return h @ p["fc2"].astype(jnp.float32)  # [T, k]


def sample_mask_gumbel(
    rng, logits: jnp.ndarray, k: int, tau: float = 1.0
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gumbel-Softmax sample over candidates (Eq. 12/13).

    Returns ``(soft_onehot [T, k], mask [T, k])`` where
    ``mask = ŷ · C_k`` (soft during training; straight-through hard mask
    keeps downstream dispatch exact while gradients flow through ŷ).
    """
    u = jax.random.uniform(rng, logits.shape, minval=1e-6, maxval=1.0 - 1e-6)
    g = -jnp.log(-jnp.log(u))
    y_soft = jax.nn.softmax((logits + g) / tau, axis=-1)  # [T, k]
    # straight-through: hard one-hot forward, soft gradient
    idx = jnp.argmax(y_soft, axis=-1)
    y_hard = jax.nn.one_hot(idx, logits.shape[-1], dtype=y_soft.dtype)
    y = y_hard + y_soft - jax.lax.stop_gradient(y_soft)
    mask = y @ candidate_masks(k)  # [T, k] (sorted-order mask)
    return y, mask


def otp_mask(p: Dict, x2: jnp.ndarray, idx, gates, *, rng=None, tau: float = 1.0):
    """Full OTP mask for the MoE hook.

    ``idx/gates [T, k]`` come from the frozen top-k router. Gates are
    sorted descending; the prefix mask is then unsorted back to the
    original top-k slot order. With ``rng=None`` → deterministic argmax
    (inference); else Gumbel sampling (training).
    """
    t, k = gates.shape
    # ordering is piecewise-constant — never differentiate through the sort
    # (also works around a broken sort-JVP in this jax build)
    order = jnp.argsort(jax.lax.stop_gradient(-gates), axis=-1)  # strongest first
    gates_sorted = jnp.take_along_axis(gates, order, axis=-1)
    logits = dm_logits(p, x2, gates_sorted)
    if rng is None:
        choice = jnp.argmax(logits, axis=-1)
        mask_sorted = candidate_masks(k)[choice]
    else:
        _, mask_sorted = sample_mask_gumbel(rng, logits, k, tau)
    # unsort: slot order[j] gets mask_sorted[j]
    inv = jnp.argsort(jax.lax.stop_gradient(order), axis=-1)
    return jnp.take_along_axis(mask_sorted, inv, axis=-1)


def mask_ratio(mask: jnp.ndarray) -> jnp.ndarray:
    """Fraction of (token, expert) slots pruned (paper's 'pruning ratio')."""
    return 1.0 - mask.mean()


def otp_losses(
    student_logits: jnp.ndarray,
    teacher_logits: jnp.ndarray,
    masks: jnp.ndarray,
    lam: float = 1.0,
) -> Tuple[jnp.ndarray, Dict]:
    """Eq. 14: distillation KL + λ·mean|M|.

    ``masks`` is the concatenation of per-layer masks (any shape); the
    paper's ℓ1 over the training batch normalizes by element count so λ is
    scale-free.
    """
    t = jax.nn.log_softmax(teacher_logits.astype(jnp.float32), axis=-1)
    s = jax.nn.log_softmax(student_logits.astype(jnp.float32), axis=-1)
    kl = jnp.sum(jnp.exp(t) * (t - s), axis=-1).mean()
    sparsity = jnp.abs(masks).mean()
    loss = kl + lam * sparsity
    return loss, {"kl": kl, "mask_l1": sparsity, "mask_ratio": 1.0 - sparsity}
