"""End-to-end MC# compression pipeline (paper Fig. 3).

Orchestrates, for a *materialized* MoE model (the trained ~100M example
models and the benchmark subjects):

1. **Calibration capture** — python-loop forward over layers recording
   router statistics (phi, w) and each MoE layer's input activations.
2. **Significance** — ``eps[L, E, |bits|]`` per Eq. 6 (layer-output F-norm
   with one expert fake-quantized at a time).
3. **PMQ allocation** — Eq. 7 IP via :mod:`repro.core.pmq`.
4. **GPTQ** — per-(expert, matrix) Hessians from the expert's routed
   tokens; error-compensated quantization at the allocated width.
5. **Assembly** — bit-bucketed :class:`CompressedExperts` per layer +
   uniform ``attn_bits`` (HQQ-refined RTN) for attention/router/shared.

The compressed model evaluates through :func:`compressed_forward`
(python loop — exact per-layer bucket structure), while the dry-run uses
the stackable synthetic layout from :func:`synthetic_stacked_compressed`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import layers as L
from ..models import moe as moe_mod
from ..models import transformer as tf
from . import pmq, significance
from .compressed_moe import (
    BucketMeta,
    CompressedExperts,
    build_compressed_experts,
    compressed_moe_layer,
)
from .gptq import GPTQResult, gptq_quantize, hessian_from_inputs
from .packing import PackedTensor
from .quantizers import quantize_to_packed

__all__ = [
    "CalibrationResult",
    "calibrate",
    "compute_eps",
    "run_pmq",
    "compress_model",
    "compress_for_serving",
    "compressed_forward",
    "synthetic_stacked_compressed",
    "quantize_tree_uniform",
    "model_weight_bytes",
]


# ----------------------------------------------------------- calibration
@dataclasses.dataclass
class CalibrationResult:
    moe_inputs: List[np.ndarray]  # per layer [T, D] (inputs to MoE)
    phi: np.ndarray  # [L, E]
    w: np.ndarray  # [L, E]
    hidden_final: np.ndarray  # [T, D] (for distillation targets)


def _block_parts(p_l, x, cfg, window):
    """Attention half of a block; returns (x_after_attn, h_pre_ffn)."""
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    h = L.rms_norm(x, p_l["ln1"], cfg.norm_eps)
    a, _ = L.attention(p_l["attn"], h, cfg, positions=pos, causal=True, window=window)
    x = x + a
    h2 = L.rms_norm(x, p_l["ln2"], cfg.norm_eps)
    return x, h2


def calibrate(params, tokens: jnp.ndarray, cfg, max_tokens: int = 16384):
    """Run calibration batches through the fp model, capturing MoE inputs
    and router statistics (paper §3.2.2)."""
    assert cfg.is_moe, "calibration targets MoE archs"
    blocks = tf.unstack_blocks(params, cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    windows = tf.layer_windows_static(cfg, tokens.shape[1])
    stats = [significance.RouterStats(cfg.num_experts) for _ in blocks]
    moe_inputs = []
    for l, p_l in enumerate(blocks):
        x, h2 = _block_parts(p_l, x, cfg, int(windows[l]))
        t = h2.reshape(-1, cfg.d_model)
        keep = min(max_tokens, t.shape[0])
        moe_inputs.append(np.asarray(t[:keep], np.float32))
        out = moe_mod.moe_layer(p_l["moe"], h2, cfg)
        stats[l].update(np.asarray(out.topk_idx), np.asarray(out.topk_gates))
        x = x + out.y
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return CalibrationResult(
        moe_inputs=moe_inputs,
        phi=np.stack([s.phi for s in stats]),
        w=np.stack([s.w for s in stats]),
        hidden_final=np.asarray(x.reshape(-1, cfg.d_model), np.float32),
    )


# ----------------------------------------------------------- eps (Eq. 6)
def _fake_quant_expert(ew: Dict, bits: int, group: int) -> Dict:
    out = {}
    for name, w in ew.items():
        pt = quantize_to_packed(jnp.asarray(w), bits, group=group, refine=False)
        out[name] = pt.dequantize(jnp.float32)
    return out


def compute_eps(
    params, calib: CalibrationResult, cfg,
    bit_choices=(1, 2, 3), group: int = 128, eps_tokens: int = 2048,
) -> np.ndarray:
    """``eps[L, E, |bits|]`` via Eq. 6 on captured calibration inputs."""
    blocks = tf.unstack_blocks(params, cfg)
    L_, E = cfg.num_layers, cfg.num_experts
    eps = np.zeros((L_, E, len(bit_choices)))
    for l, p_l in enumerate(blocks):
        h2 = jnp.asarray(calib.moe_inputs[l][:eps_tokens])[None]  # [1, T, D]
        experts = p_l["moe"]["experts"]
        ew_list = [
            {k: experts[k][i] for k in ("w_gate", "w_up", "w_down")}
            for i in range(E)
        ]

        def layer_forward(expert_list):
            stacked = {
                k: jnp.stack([e[k] for e in expert_list])
                for k in ("w_gate", "w_up", "w_down")
            }
            p_mod = dict(p_l["moe"], experts=stacked)
            return moe_mod.moe_layer(p_mod, h2, cfg).y

        eps[l] = significance.expert_eps(
            layer_forward,
            ew_list,
            lambda ew, b: _fake_quant_expert(ew, b, group),
            bit_choices,
        )
    return eps


# ------------------------------------------------------------------- PMQ
def run_pmq(
    params, calib: CalibrationResult, cfg,
    target_avg_bits: float = 2.25,
    bit_choices=(1, 2, 3),
    solver: str = "dp",
    eps: Optional[np.ndarray] = None,
    layer_adaptive: bool = False,
) -> pmq.PMQPlan:
    q = cfg.quant
    if eps is None:
        eps = compute_eps(params, calib, cfg, bit_choices, q.group)
    return pmq.allocate_model(
        calib.phi, calib.w, eps, target_avg_bits,
        alpha=q.alpha, beta=q.beta, gamma=q.gamma,
        bit_choices=bit_choices, solver=solver, layer_adaptive=layer_adaptive,
    )


# ---------------------------------------------------------------- GPTQ
def _routed_inputs(h2: np.ndarray, idx: np.ndarray, expert: int) -> np.ndarray:
    rows = np.any(idx == expert, axis=1)
    x = h2[rows]
    if x.shape[0] < 8:  # never-routed expert: fall back to all tokens
        x = h2
    return x


def _gptq_expert(ew: Dict, x: np.ndarray, bits: int, group: int) -> Dict:
    """GPTQ all three matrices of one expert given its routed inputs."""
    res = {}
    hg = hessian_from_inputs(x)
    for name in ("w_gate", "w_up"):
        res[name] = gptq_quantize(np.asarray(ew[name]), hg, bits, group)
    # down-proj sees silu(xWg)*(xWu)
    a = x @ np.asarray(ew["w_gate"], np.float64)
    a = a / (1.0 + np.exp(-a)) * (x @ np.asarray(ew["w_up"], np.float64))
    res["w_down"] = gptq_quantize(
        np.asarray(ew["w_down"]), hessian_from_inputs(a), bits, group
    )
    return res


def compress_model(
    params, calib: CalibrationResult, plan: pmq.PMQPlan, cfg,
    use_gptq: bool = True, ep: int = 1, gptq_tokens: int = 2048,
):
    """Produce the compressed parameter tree (python-loop layout).

    Returns ``(blocks_c, top)`` where ``blocks_c[l]`` holds
    ``{"ln1","attn","ln2","moe"(router/shared 4-bit),"moe_ce"}`` and
    ``top`` carries embed/final_norm (embeddings stay 16-bit, as in the
    paper's average-bit accounting).
    """
    q = cfg.quant
    blocks = tf.unstack_blocks(params, cfg)
    blocks_c = []
    for l, p_l in enumerate(blocks):
        h2 = calib.moe_inputs[l][:gptq_tokens].astype(np.float64)
        experts = p_l["moe"]["experts"]
        gptq_results = None
        if use_gptq:
            # routing of calibration tokens under the fp router
            _, idx, _ = moe_mod.route_topk(
                p_l["moe"]["router"], jnp.asarray(h2, jnp.float32), cfg.top_k
            )
            idx = np.asarray(idx)
            gptq_results = {}
            for i in range(cfg.num_experts):
                ew = {k: np.asarray(experts[k][i]) for k in ("w_gate", "w_up", "w_down")}
                res = _gptq_expert(
                    ew, _routed_inputs(h2, idx, i), int(plan.bits[l][i]), q.group
                )
                for name, r in res.items():
                    gptq_results[(i, name)] = r
        ce = build_compressed_experts(
            {k: np.asarray(experts[k]) for k in ("w_gate", "w_up", "w_down")},
            plan.bits[l], group=q.group, ep=ep, gptq_results=gptq_results,
        )
        moe_p = {"router": p_l["moe"]["router"]}
        if "shared" in p_l["moe"]:
            moe_p["shared"] = quantize_tree_uniform(
                p_l["moe"]["shared"], q.attn_bits, q.group
            )
        blk = {
            "ln1": p_l["ln1"],
            "attn": quantize_tree_uniform(p_l["attn"], q.attn_bits, q.group),
            "ln2": p_l["ln2"],
            "moe": moe_p,
            "moe_ce": ce,
        }
        blocks_c.append(blk)
    top = {
        "embed": params["embed"],
        "final_norm": params["final_norm"],
    }
    if "unembed" in params:
        top["unembed"] = params["unembed"]
    return blocks_c, top


def compress_for_serving(
    params, calib: CalibrationResult, cfg, *,
    target_avg_bits: float = 2.05, eps_tokens: int = 128,
) -> Tuple[Dict, float]:
    """Layer-uniform PMQ compression in the *stacked* serving layout.

    The PMQ plan is made layer-uniform (every layer gets layer 0's bit
    vector) so all layers share one bucket structure and ride the decode
    scan — the layout ``repro.serving`` and the serving benchmarks
    consume. Returns ``(params_compressed, avg_bits)`` where the tree
    carries ``blocks`` restacked for :mod:`repro.models.transformer`.
    """
    eps = compute_eps(params, calib, cfg, eps_tokens=eps_tokens)
    plan = run_pmq(params, calib, cfg, target_avg_bits=target_avg_bits,
                   eps=eps)
    plan.bits = [plan.bits[0]] * cfg.num_layers
    blocks_c, top = compress_model(params, calib, plan, cfg, use_gptq=False)
    out = dict(top)
    out["blocks"] = tf.restack_blocks(blocks_c)
    return out, plan.avg_bits


def quantize_tree_uniform(tree, bits: int, group: int):
    """Replace every 2-D ``w`` leaf with a PackedTensor (HQQ-refined RTN)."""

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "w" and getattr(leaf, "ndim", 0) == 2:
            return quantize_to_packed(leaf, bits, group=group, refine=True)
        return leaf

    return jax.tree_util.tree_map_with_path(one, tree)


# ----------------------------------------------------- compressed forward
def compressed_forward(
    blocks_c, top, tokens: jnp.ndarray, cfg,
    otp_params: Optional[List] = None, otp_rngs=None, otp_tau: float = 1.0,
    collect_masks: bool = False,
):
    """Python-loop forward of the compressed model → (hidden, masks)."""
    x = jnp.take(top["embed"], tokens, axis=0)
    windows = tf.layer_windows_static(cfg, tokens.shape[1])
    masks = []
    for l, p_l in enumerate(blocks_c):
        x, h2 = _block_parts(p_l, x, cfg, int(windows[l]))
        y, info = compressed_moe_layer(
            p_l["moe"], p_l["moe_ce"], h2, cfg,
            otp_params=otp_params[l] if otp_params is not None else None,
            otp_rng=otp_rngs[l] if otp_rngs is not None else None,
            otp_tau=otp_tau,
        )
        x = x + y
        if collect_masks and info["mask"] is not None:
            masks.append(info["mask"])
    x = L.rms_norm(x, top["final_norm"], cfg.norm_eps)
    return x, masks


def compressed_logits(blocks_c, top, tokens, cfg, **kw):
    hidden, masks = compressed_forward(blocks_c, top, tokens, cfg, **kw)
    emb = top.get("unembed", top["embed"])
    logits = jnp.einsum(
        "btd,vd->btv", hidden.astype(jnp.float32), emb.astype(jnp.float32)
    )
    return logits, masks


# --------------------------------------------------- dry-run synthetic CE
def synthetic_stacked_compressed(cfg, target_avg_bits: float = 2.25, ep: int = 16):
    """L-stacked CompressedExperts with identical bucket structure per
    layer (dry-run only; built under eval_shape → no allocation).

    Bucket counts are multiples of the expert-parallel extent ``ep`` (so
    the EP scan in :func:`compressed_expert_ffn` shards cleanly) solving
    ``1·a + 2·b + 3·c ≈ target`` with the paper's ≥1-expert floors.
    """
    e = cfg.num_experts
    if e % ep:
        ep = 1
    # search bucket sizes on the ep grid closest to the bit budget
    best, best_err = None, float("inf")
    for n1 in range(ep, e - ep + 1, ep):
        for n3 in range(ep, e - n1 - ep + 1, ep):
            n2 = e - n1 - n3
            avg = (n1 + 2 * n2 + 3 * n3) / e
            err = abs(avg - target_avg_bits)
            if err < best_err:
                best, best_err = (n1, n2, n3), err
    n1, n2, n3 = best
    d, f, group = cfg.d_model, cfg.d_ff_expert, cfg.quant.group
    l = cfg.num_layers
    meta = []
    arrays = {}
    start = 0
    for bits, cnt in ((1, n1), (2, n2), (3, n3)):
        if cnt == 0:
            continue
        bdict = {}
        for name, (k, n) in (
            ("w_gate", (d, f)), ("w_up", (d, f)), ("w_down", (f, d))
        ):
            # bf16 scales/zeros at deployment: 0.25 bits/weight overhead
            # (HQQ stores fp16 scales; kimi-scale f32 scales alone = 64 GB)
            entry = {
                "scale": jnp.zeros((l, cnt, (k + group - 1) // group, n), jnp.bfloat16),
                "zero": jnp.zeros((l, cnt, (k + group - 1) // group, n), jnp.bfloat16),
            }
            if bits == 3:
                entry["hi"] = jnp.zeros((l, cnt, k // 4, n), jnp.uint8)
                entry["lo"] = jnp.zeros((l, cnt, k // 8, n), jnp.uint8)
            else:
                per = 8 // bits
                entry["data"] = jnp.zeros((l, cnt, k // per, n), jnp.uint8)
            bdict[name] = entry
        arrays[f"b{len(meta)}"] = bdict
        meta.append(BucketMeta(bits=bits, start=start, count=cnt))
        start += cnt
    slot = jnp.tile(jnp.arange(e, dtype=jnp.int32)[None], (l, 1))
    return CompressedExperts(
        meta=tuple(meta), slot_of_expert=slot, arrays=arrays,
        num_slots=start, group=group, d_model=d, d_ff=f,
    )


def model_weight_bytes(blocks_c, top) -> int:
    """Total compressed weight bytes (PackedTensor-aware)."""
    tot = 0

    def add(leaf):
        nonlocal tot
        if isinstance(leaf, PackedTensor):
            tot += leaf.nbytes
        elif isinstance(leaf, CompressedExperts):
            tot += leaf.weight_bytes
        elif hasattr(leaf, "nbytes"):
            tot += leaf.nbytes

    for blk in blocks_c:
        jax.tree.map(
            add, blk,
            is_leaf=lambda x: isinstance(x, (PackedTensor, CompressedExperts)),
        )
    jax.tree.map(add, top)
    return tot
