"""Bit-packing for ultra-low-bit weight storage (paper §3.3).

Storage layout
--------------
Quantized integer codes ``q ∈ [0, 2^b)`` are packed along the *K* (reduction)
axis so that a fused-dequant matmul kernel reads contiguous packed rows:

* 1-bit: 8 codes / uint8          (paper Eq. 8: ``B~ = (sign(W)+1)/2``)
* 2-bit: 4 codes / uint8
* 4-bit: 2 codes / uint8
* 3-bit: stored as a 2-bit plane + 1-bit plane, ``q = (hi << 1) | lo``.
  This is the TPU-native alternative to HQQ's padded 32-bit containers:
  exactly 3.0 bits/weight and both planes are power-of-two packed
  (DESIGN.md §5.3).

All functions are pure ``jnp`` and jittable; the packed axis must be a
multiple of the pack factor (pad with ``pad_to_multiple`` first).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "pack_bits",
    "unpack_bits",
    "packed_nbytes",
    "pad_to_multiple",
    "PackedTensor",
]


def pad_to_multiple(x: jnp.ndarray, multiple: int, axis: int) -> jnp.ndarray:
    """Zero-pad ``x`` along ``axis`` up to a multiple of ``multiple``."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _pack_pow2(q: jnp.ndarray, bits: int, axis: int) -> jnp.ndarray:
    """Pack codes with power-of-two ``bits`` (1, 2, 4) along ``axis``."""
    per = 8 // bits
    q = jnp.asarray(q, jnp.uint8)
    if q.shape[axis] % per != 0:
        raise ValueError(
            f"axis {axis} size {q.shape[axis]} not a multiple of {per} "
            f"for {bits}-bit packing; call pad_to_multiple first"
        )
    axis = axis % q.ndim
    new_shape = (
        q.shape[:axis] + (q.shape[axis] // per, per) + q.shape[axis + 1 :]
    )
    q = q.reshape(new_shape)
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits).reshape(
        (1,) * axis + (1, per) + (1,) * (q.ndim - axis - 2)
    )
    packed = jnp.sum(
        (q & ((1 << bits) - 1)).astype(jnp.uint8) << shifts,
        axis=axis + 1,
        dtype=jnp.uint8,
    )
    return packed


def _unpack_pow2(packed: jnp.ndarray, bits: int, axis: int) -> jnp.ndarray:
    per = 8 // bits
    axis = axis % packed.ndim
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits).reshape(
        (1,) * (axis + 1) + (per,) + (1,) * (packed.ndim - axis - 1)
    )
    vals = (jnp.expand_dims(packed, axis + 1) >> shifts) & ((1 << bits) - 1)
    new_shape = (
        packed.shape[:axis]
        + (packed.shape[axis] * per,)
        + packed.shape[axis + 1 :]
    )
    # move the unpacked sub-axis next to the packed axis then flatten
    vals = jnp.moveaxis(vals, axis + 1, axis + 1)  # already adjacent
    return vals.reshape(new_shape)


def pack_bits(q: jnp.ndarray, bits: int, axis: int = -1):
    """Pack integer codes into compact storage.

    Returns a single uint8 array for bits in {1,2,4,8} or a tuple
    ``(hi_plane, lo_plane)`` for bits == 3.
    """
    if bits == 8:
        return jnp.asarray(q, jnp.uint8)
    if bits in (1, 2, 4):
        return _pack_pow2(q, bits, axis)
    if bits == 3:
        q = jnp.asarray(q, jnp.uint8)
        hi = (q >> 1) & 0x3  # 2-bit plane
        lo = q & 0x1  # 1-bit plane
        return (_pack_pow2(hi, 2, axis), _pack_pow2(lo, 1, axis))
    raise ValueError(f"unsupported bit-width {bits}")


def unpack_bits(packed, bits: int, axis: int = -1) -> jnp.ndarray:
    """Inverse of :func:`pack_bits` (returns uint8 codes)."""
    if bits == 8:
        return jnp.asarray(packed, jnp.uint8)
    if bits in (1, 2, 4):
        return _unpack_pow2(packed, bits, axis)
    if bits == 3:
        hi_p, lo_p = packed
        hi = _unpack_pow2(hi_p, 2, axis)
        lo = _unpack_pow2(lo_p, 1, axis)
        return (hi << 1) | lo
    raise ValueError(f"unsupported bit-width {bits}")


def packed_nbytes(shape: Tuple[int, ...], bits: int, axis: int = -1) -> int:
    """Exact byte count of the packed representation of ``shape``."""
    n = int(np.prod(shape))
    k = shape[axis]
    per_row = n // k
    if bits in (1, 2, 4, 8):
        return per_row * ((k * bits + 7) // 8)
    if bits == 3:
        return per_row * (((k * 2 + 7) // 8) + ((k + 7) // 8))
    raise ValueError(f"unsupported bit-width {bits}")


@dataclasses.dataclass
class PackedTensor:
    """A bit-packed quantized tensor + its dequantization parameters.

    ``data`` is the packed uint8 array (or (hi, lo) planes for 3-bit).
    ``scale``/``zero`` are group-wise along the packed (K) axis with
    group size ``group``; shape ``(K // group, *other_dims)``-broadcastable.
    """

    data: object
    scale: jnp.ndarray
    zero: jnp.ndarray
    bits: int
    shape: Tuple[int, ...]  # logical (unpacked) shape
    group: int
    axis: int = 0  # packed/grouped axis in the logical shape

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        q = unpack_bits(self.data, self.bits, self.axis)
        # strip potential padding introduced by pack alignment
        take = [slice(None)] * len(self.shape)
        take[self.axis] = slice(0, self.shape[self.axis])
        q = q[tuple(take)].astype(dtype)
        k = self.shape[self.axis]
        g = self.group
        ngroups = (k + g - 1) // g
        # reshape K axis into (ngroups, g) to apply group params
        ax = self.axis % len(self.shape)
        new_shape = self.shape[:ax] + (ngroups, g) + self.shape[ax + 1 :]
        if k % g != 0:
            pad = [(0, 0)] * len(self.shape)
            pad[ax] = (0, ngroups * g - k)
            q = jnp.pad(q, pad)
        qg = q.reshape(new_shape)
        scale = jnp.expand_dims(self.scale, ax + 1)
        zero = jnp.expand_dims(self.zero, ax + 1)
        w = (qg - zero) * scale
        w = w.reshape(
            self.shape[:ax] + (ngroups * g,) + self.shape[ax + 1 :]
        )
        take = [slice(None)] * len(self.shape)
        take[ax] = slice(0, k)
        return w[tuple(take)].astype(dtype)

    @property
    def nbytes(self) -> int:
        base = packed_nbytes(self.shape, self.bits, self.axis)
        return base + self.scale.size * self.scale.dtype.itemsize + (
            self.zero.size * self.zero.dtype.itemsize
        )


def _pt_flatten(pt: PackedTensor):
    return (pt.data, pt.scale, pt.zero), (pt.bits, pt.shape, pt.group, pt.axis)


def _pt_unflatten(aux, children):
    data, scale, zero = children
    bits, shape, group, axis = aux
    return PackedTensor(
        data=data, scale=scale, zero=zero, bits=bits, shape=shape,
        group=group, axis=axis,
    )


jax.tree_util.register_pytree_node(PackedTensor, _pt_flatten, _pt_unflatten)
