"""OTP router training (paper §3.4.2, Eq. 14, Fig. 13).

End-to-end distillation of the per-layer DM routers on a *frozen,
PMQ-compressed* backbone: the student runs with Gumbel-sampled masks, the
teacher is the same compressed model without masks (paper: "non-masked
MoE models"). Only the DM routers (a few thousand params) receive
gradients — this is the paper's only training phase and the `train_4k`
mode for the 1T kimi config (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from . import otp as otp_mod
from .pipeline import compressed_logits

__all__ = ["OTPTrainConfig", "init_otp_params", "train_otp"]


@dataclasses.dataclass(frozen=True)
class OTPTrainConfig:
    steps: int = 100
    batch: int = 8
    lr: float = 2e-3
    lam: float = 1.0  # sparsity weight λ (Eq. 14)
    tau: float = 1.0  # Gumbel temperature
    seed: int = 0


def init_otp_params(rng, cfg) -> List[Dict]:
    ks = jax.random.split(rng, cfg.num_layers)
    return [
        otp_mod.init_otp_router(k, cfg.d_model, cfg.top_k) for k in ks
    ]


def train_otp(
    blocks_c, top, cfg, tokens: np.ndarray, tcfg: OTPTrainConfig
) -> Tuple[List[Dict], List[Dict]]:
    """Train DM routers. ``tokens [N, S]`` calibration samples.

    Returns ``(otp_params, history)`` with per-step kl/mask_ratio logs.
    """
    rng = jax.random.PRNGKey(tcfg.seed)
    rng, k0 = jax.random.split(rng)
    otp_params = init_otp_params(k0, cfg)
    ocfg = AdamWConfig(lr=tcfg.lr, weight_decay=0.0)
    opt_state = adamw_init(otp_params, ocfg)

    def loss_fn(op, batch_tokens, step_rng):
        rngs = jax.random.split(step_rng, cfg.num_layers)
        student, masks = compressed_logits(
            blocks_c, top, batch_tokens, cfg,
            otp_params=op, otp_rngs=list(rngs), otp_tau=tcfg.tau,
            collect_masks=True,
        )
        teacher, _ = compressed_logits(blocks_c, top, batch_tokens, cfg)
        teacher = jax.lax.stop_gradient(teacher)
        mask_cat = jnp.concatenate([m.reshape(-1) for m in masks])
        loss, aux = otp_mod.otp_losses(student, teacher, mask_cat, tcfg.lam)
        return loss, aux

    @jax.jit
    def step_fn(op, opt_state, batch_tokens, step_rng):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            op, batch_tokens, step_rng
        )
        op, opt_state = adamw_update(op, grads, opt_state, ocfg)
        return op, opt_state, loss, aux

    history = []
    n = tokens.shape[0]
    for step in range(tcfg.steps):
        rng, ks, kb = jax.random.split(rng, 3)
        sel = jax.random.randint(kb, (tcfg.batch,), 0, n)
        batch_tokens = jnp.asarray(tokens)[sel]
        otp_params, opt_state, loss, aux = step_fn(
            otp_params, opt_state, batch_tokens, ks
        )
        history.append(
            {
                "step": step,
                "loss": float(loss),
                "kl": float(aux["kl"]),
                "mask_ratio": float(aux["mask_ratio"]),
            }
        )
    return otp_params, history
