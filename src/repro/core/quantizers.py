"""Weight quantizers (paper §3.1 Eqs. 3/4 and §3.3 Eqs. 8/9).

Three families:

* :func:`quantize_affine` — group-wise asymmetric uniform quantization
  (the ``Q(·)`` of Eq. 3) used for 2/3/4/8-bit experts and the uniform 4-bit
  attention/gate/shared-expert weights.
* :func:`quantize_binary` — 1-bit sign quantization with per-column L1
  scales (Eqs. 4/8): ``B = sign(W)``, ``s = ||W||_1 / d`` per output channel,
  stored as the ``{0,1}`` transform ``B~ = (sign(W)+1)/2``.
* :func:`hqq_refine` — HQQ-style half-quadratic refinement of the zero point
  (the paper stores weights with the HQQ tool [50]); optional, improves RTN.

Conventions: weights are ``W ∈ R[K, N]`` (reduction axis first — i.e. the
layout consumed by ``y = x @ W``); quantization groups run along K.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .packing import PackedTensor, pack_bits, pad_to_multiple

__all__ = [
    "affine_params",
    "quantize_affine",
    "dequantize_affine",
    "quantize_binary",
    "hqq_refine",
    "quantize_to_packed",
    "rtn_codes",
    "kv_quant_params",
    "kv_quant_codes",
    "quantize_kv_rows",
    "dequantize_kv_rows",
]


def _group_reshape(w: jnp.ndarray, group: int) -> jnp.ndarray:
    """[K, N] -> [K//group, group, N] (pads K if needed)."""
    k = w.shape[0]
    ngroups = (k + group - 1) // group
    if k % group:
        w = jnp.pad(w, ((0, ngroups * group - k), (0, 0)))
    return w.reshape(ngroups, group, w.shape[-1])


def affine_params(
    w: jnp.ndarray, bits: int, group: int = 128
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(group, column) scale & zero per Eq. 3.

    Returns ``scale, zero`` of shape ``[K//group, N]`` (float32 scale, float
    zero kept unrounded for HQQ compatibility; rounding happens in
    :func:`rtn_codes`).
    """
    wg = _group_reshape(w, group)
    wmax = jnp.max(wg, axis=1)
    wmin = jnp.min(wg, axis=1)
    qmax = 2.0**bits - 1.0
    scale = (wmax - wmin) / qmax
    scale = jnp.maximum(scale, 1e-8)
    zero = -wmin / scale
    return scale.astype(jnp.float32), zero.astype(jnp.float32)


def rtn_codes(
    w: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    bits: int,
    group: int = 128,
) -> jnp.ndarray:
    """Round-to-nearest codes: ``clamp(round(w/s) + z, 0, 2^b-1)`` (Eq. 3)."""
    wg = _group_reshape(w, group)
    q = jnp.round(wg / scale[:, None, :] + zero[:, None, :])
    q = jnp.clip(q, 0.0, 2.0**bits - 1.0)
    q = q.reshape(-1, w.shape[-1])[: w.shape[0]]
    return q.astype(jnp.uint8)


def quantize_affine(
    w: jnp.ndarray, bits: int, group: int = 128, refine: bool = False
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full RTN affine quantization. Returns ``(codes, scale, zero)``."""
    scale, zero = affine_params(w, bits, group)
    if refine:
        scale, zero = hqq_refine(w, scale, zero, bits, group)
    codes = rtn_codes(w, scale, zero, bits, group)
    return codes, scale, zero


def dequantize_affine(
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    group: int = 128,
    dtype=jnp.float32,
) -> jnp.ndarray:
    k, n = codes.shape
    qg = _group_reshape(codes.astype(jnp.float32), group)
    w = (qg - zero[:, None, :]) * scale[:, None, :]
    return w.reshape(-1, n)[:k].astype(dtype)


# ----------------------------------------------------- KV-row quantization
# Serving-side KV-page compression (ROADMAP item 2): the paged pools store
# uint8 codes with one affine (scale, zero) pair per KV *row* — per (layer,
# page, page-offset, kv-head), i.e. per token per head — so a row written
# once at prefill or decode never needs requantizing, whole pages stay
# bit-exactly swappable/copyable, and shared prefix pages dequantize
# identically for every reader. The math is exactly Eq. 3 with the
# quantization group spanning the head_dim axis: the helpers reshape
# ``x[..., dh]`` to the ``[K, N]`` layout :func:`affine_params` /
# :func:`rtn_codes` consume (``K = dh`` rows, one column per KV row,
# ``group = dh``), so KV pages ride the same quantizer as the weights.

def kv_quant_params(x: jnp.ndarray, bits: int = 8):
    """Per-row scale & zero over the trailing ``head_dim`` axis.

    ``x [..., dh]`` → ``(scale, zero)`` of shape ``x.shape[:-1]`` (f32).
    """
    dh = x.shape[-1]
    w = x.reshape(-1, dh).T  # [dh, M]: one group per KV row
    scale, zero = affine_params(w, bits, group=dh)  # [1, M]
    lead = x.shape[:-1]
    return scale.reshape(lead), zero.reshape(lead)


def kv_quant_codes(
    x: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray, bits: int = 8
) -> jnp.ndarray:
    """RTN codes for KV rows: uint8, same shape as ``x``."""
    dh = x.shape[-1]
    w = x.reshape(-1, dh).T
    codes = rtn_codes(
        w, scale.reshape(1, -1), zero.reshape(1, -1), bits, group=dh
    )
    return codes.T.reshape(x.shape)


def quantize_kv_rows(x: jnp.ndarray, bits: int = 8):
    """Quantize KV rows in one shot. Returns ``(codes, scale, zero)``:
    ``codes`` uint8 shaped like ``x``, ``scale``/``zero`` f32 shaped
    ``x.shape[:-1]``. All-zero rows (unwritten pool pages) round-trip to
    exactly zero (``scale`` floors at 1e-8, ``zero = 0``, codes 0)."""
    scale, zero = kv_quant_params(x, bits)
    return kv_quant_codes(x, scale, zero, bits), scale, zero


def dequantize_kv_rows(
    codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Inverse of :func:`quantize_kv_rows`: ``(q - z) * s`` in f32 —
    the exact expression the paged-attention dequant epilogues apply
    (ref oracle and Pallas kernel), so every reader of a quantized page
    sees bit-identical floats."""
    x = (codes.astype(jnp.float32) - zero[..., None].astype(jnp.float32))
    return (x * scale[..., None].astype(jnp.float32)).astype(dtype)


@partial(jax.jit, static_argnames=("bits", "group", "iters"))
def _hqq_iter(w, scale, zero, bits, group, iters):
    """Half-quadratic zero-point refinement (HQQ [50], p=0.7 shrinkage)."""
    qmax = 2.0**bits - 1.0
    wg = _group_reshape(w, group)
    beta, kappa, p = 10.0, 1.01, 0.7

    def body(carry, _):
        zero, beta = carry
        q = jnp.clip(jnp.round(wg / scale[:, None, :] + zero[:, None, :]), 0.0, qmax)
        wq = (q - zero[:, None, :]) * scale[:, None, :]
        err = wg - wq
        # generalized soft-threshold toward |err|^p sparsity
        mag = jnp.abs(err)
        shrunk = jnp.sign(err) * jnp.maximum(
            mag - (mag ** (p - 1.0) + 1e-8) / beta, 0.0
        )
        we = wg - shrunk
        zero_new = jnp.mean(
            q - we / scale[:, None, :], axis=1
        )
        return (zero_new, beta * kappa), None

    (zero, _), _ = jax.lax.scan(body, (zero, beta), None, length=iters)
    return scale, zero


def hqq_refine(w, scale, zero, bits, group=128, iters=20):
    """Refine ``zero`` to minimize a robust (|.|^0.7) reconstruction loss."""
    return _hqq_iter(w, scale, zero, bits, group, iters)


def quantize_binary(
    w: jnp.ndarray, per_channel: bool = True
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """1-bit sign quantization (Eqs. 4/8).

    Returns ``(b01, scale)``: ``b01 ∈ {0,1}[K,N]`` (the ``B~`` storage
    transform) and ``scale``: per output channel ``||W[:,j]||_1 / K`` when
    ``per_channel`` (paper's channel-wise binarization scales [46]),
    else a scalar ``||W||_1 / (K·N)``.
    """
    b01 = (w >= 0).astype(jnp.uint8)
    if per_channel:
        scale = jnp.mean(jnp.abs(w), axis=0, keepdims=True)  # [1, N]
    else:
        scale = jnp.mean(jnp.abs(w)).reshape(1, 1)
    return b01, scale.astype(jnp.float32)


def dequantize_binary(b01: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    return ((b01.astype(jnp.float32) * 2.0 - 1.0) * scale).astype(dtype)


def quantize_to_packed(
    w: jnp.ndarray,
    bits: int,
    group: int = 128,
    refine: bool = True,
    codes: jnp.ndarray | None = None,
    scale: jnp.ndarray | None = None,
    zero: jnp.ndarray | None = None,
) -> PackedTensor:
    """Quantize ``W[K,N]`` to a :class:`PackedTensor` ready for the kernels.

    ``bits == 1`` uses sign binarization (zero encodes nothing; we store the
    per-channel scale in ``scale`` and ``zero = 0.5`` so the shared affine
    dequant path ``(q - z)*s`` yields ``±0.5·s_eff`` with ``s_eff = 2·s`` —
    i.e. 1-bit rides the same kernel with scale doubled and zero 0.5).

    Pre-computed ``codes/scale/zero`` (e.g. from GPTQ) are packed as-is.
    """
    k, n = w.shape
    if bits == 1 and codes is None:
        b01, s = quantize_binary(w)
        ngroups = (k + group - 1) // group
        scale_g = jnp.broadcast_to(2.0 * s, (ngroups, n)).astype(jnp.float32)
        zero_g = jnp.full((ngroups, n), 0.5, jnp.float32)
        codes = b01
        scale, zero = scale_g, zero_g
    elif codes is None:
        codes, scale, zero = quantize_affine(w, bits, group, refine=refine)
    per = {1: 8, 2: 4, 3: 8, 4: 2, 8: 1}[bits]
    codes = pad_to_multiple(codes, per, axis=0)
    data = pack_bits(codes, bits, axis=0)
    return PackedTensor(
        data=data,
        scale=jnp.asarray(scale, jnp.float32),
        zero=jnp.asarray(zero, jnp.float32),
        bits=bits,
        shape=(k, n),
        group=group,
        axis=0,
    )
