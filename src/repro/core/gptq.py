"""GPTQ post-training quantization (Frantar et al. [11]) — paper §3.1.

The paper uses GPTQ as the foundational PTQ tool for PMQ: Hessian-based
estimation ``H = 2·X·Xᵀ`` plus column-wise quantization-error compensation.
This is an offline (pre-loading) procedure, so it is implemented in numpy
(float64 Cholesky for stability) rather than inside a jit.

Layout convention: ``W ∈ R[K, N]`` with ``y = x @ W`` (K = input/reduction
dim). GPTQ walks the K axis in order, compensating not-yet-quantized rows.

Supports affine 2/3/4/8-bit group-wise quantization and 1-bit sign
binarization (per-channel L1 scale, Eq. 4) so that every PMQ bit-width
{1,2,3} flows through the same error-compensated pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["GPTQResult", "hessian_from_inputs", "gptq_quantize"]


@dataclasses.dataclass
class GPTQResult:
    codes: np.ndarray  # uint8 [K, N] integer codes (binary: {0,1})
    scale: np.ndarray  # float32 [K//group, N]
    zero: np.ndarray  # float32 [K//group, N]
    bits: int
    group: int
    quant_error: float  # sum of per-row compensated MSE (diagnostic)


def hessian_from_inputs(x: np.ndarray) -> np.ndarray:
    """``H = 2·XᵀX`` over calibration activations ``x [nsamples, K]``."""
    x = np.asarray(x, np.float64)
    return 2.0 * (x.T @ x)


def _affine_group_params(wg: np.ndarray, bits: int) -> Tuple[np.ndarray, np.ndarray]:
    """Min/max affine params for one K-group ``wg [g, N]`` (Eq. 3)."""
    wmax = wg.max(axis=0)
    wmin = wg.min(axis=0)
    qmax = 2.0**bits - 1.0
    scale = np.maximum((wmax - wmin) / qmax, 1e-8)
    zero = -wmin / scale
    return scale, zero


def gptq_quantize(
    w: np.ndarray,
    hessian: np.ndarray,
    bits: int,
    group: int = 128,
    percdamp: float = 0.01,
    blocksize: int = 128,
    binary_scale: Optional[np.ndarray] = None,
) -> GPTQResult:
    """Quantize ``w [K, N]`` with GPTQ error compensation.

    ``hessian`` is ``H = 2XᵀX`` of shape ``[K, K]``. For ``bits == 1`` the
    quantizer is ``sign`` with per-column scale (L1 mean of the *original*
    weights, or ``binary_scale`` if given); codes are the ``{0,1}``
    transform of Eq. 8 and ``(scale, zero) = (2α, 0.5)`` so the shared
    affine dequant ``(q - z)·s`` reproduces ``±α``.
    """
    w = np.array(w, np.float64, copy=True)
    k, n = w.shape
    h = np.array(hessian, np.float64, copy=True)
    assert h.shape == (k, k)

    # dead rows: never-activated inputs contribute nothing — freeze them
    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    w[dead, :] = 0.0

    # dampen + inverse via Cholesky, then upper Cholesky of the inverse
    damp = percdamp * float(np.mean(np.diag(h)))
    h[np.diag_indices(k)] += max(damp, 1e-10)
    l = np.linalg.cholesky(h)
    hinv = np.linalg.inv(l).T @ np.linalg.inv(l)  # H^-1 = L^-T L^-1
    l_inv = np.linalg.cholesky(hinv)
    hinv_u = l_inv.T  # upper-triangular U with UᵀU = H^-1

    qmax = 2.0**bits - 1.0
    codes = np.zeros((k, n), np.uint8)
    ngroups = (k + group - 1) // group
    scales = np.zeros((ngroups, n), np.float32)
    zeros = np.zeros((ngroups, n), np.float32)

    if bits == 1:
        alpha = (
            np.asarray(binary_scale, np.float64).reshape(1, n)
            if binary_scale is not None
            else np.mean(np.abs(w), axis=0, keepdims=True)
        )
        scales[:] = (2.0 * alpha).astype(np.float32)
        zeros[:] = 0.5

    total_err = 0.0
    for b0 in range(0, k, blocksize):
        b1 = min(b0 + blocksize, k)
        wb = w[b0:b1, :].copy()
        errb = np.zeros_like(wb)
        hu = hinv_u[b0:b1, b0:b1]
        for i in range(b1 - b0):
            kk = b0 + i
            d = hu[i, i]
            g = kk // group
            if bits == 1:
                q = (wb[i, :] >= 0).astype(np.float64)
                s = scales[g].astype(np.float64)
                z = zeros[g].astype(np.float64)
            else:
                if kk % group == 0:
                    # params from the error-compensated weights of this group
                    g1 = min(kk + group, k)
                    wg = np.concatenate(
                        [wb[i : min(i + group, b1 - b0), :], w[b1:g1, :]], axis=0
                    )
                    s_g, z_g = _affine_group_params(wg, bits)
                    scales[g] = s_g.astype(np.float32)
                    zeros[g] = z_g.astype(np.float32)
                s = scales[g].astype(np.float64)
                z = zeros[g].astype(np.float64)
                q = np.clip(np.round(wb[i, :] / s + z), 0.0, qmax)
            codes[kk, :] = q.astype(np.uint8)
            wq = (q - z) * s
            err = (wb[i, :] - wq) / d
            total_err += float(np.sum(((wb[i, :] - wq)) ** 2))
            # compensate the remaining rows of this block
            if i + 1 < b1 - b0:
                wb[i + 1 :, :] -= np.outer(hu[i, i + 1 :], err)
            errb[i, :] = err
        # lazy batch update of all rows after the block
        if b1 < k:
            w[b1:, :] -= hinv_u[b0:b1, b1:].T @ errb
    return GPTQResult(
        codes=codes,
        scale=scales,
        zero=zeros,
        bits=bits,
        group=group,
        quant_error=total_err,
    )
