"""AdamW in pure JAX, with optional 8-bit block-quantized moments.

Distributed-optimization features (DESIGN.md §7):

* bf16 params + f32 master copy (``master=True``) — the standard mixed-
  precision trick;
* 8-bit moments (``state_bits=8``): per-block (128) absmax-scaled int8
  m/v — 4× less optimizer-state HBM, the lever that lets large dense
  trainings fit the assigned mesh;
* the state pytree mirrors the param pytree, so FSDP-style sharding rules
  apply verbatim (see ``repro.parallel.sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]

_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    state_bits: int = 32  # 32 | 8
    master: bool = False  # keep f32 master copy of bf16 params


class _Q8(NamedTuple):
    q: jnp.ndarray  # int8 codes
    scale: jnp.ndarray  # f32 per block


def _q8_zeros(x):
    n = x.size
    nb = (n + _BLOCK - 1) // _BLOCK
    return _Q8(
        q=jnp.zeros((nb * _BLOCK,), jnp.int8), scale=jnp.zeros((nb,), jnp.float32)
    )


def _q8_encode(x):
    n = x.size
    nb = (n + _BLOCK - 1) // _BLOCK
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, nb * _BLOCK - n))
    xb = xf.reshape(nb, _BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return _Q8(q=q.reshape(-1), scale=scale)


def _q8_decode(s: _Q8, shape):
    xb = s.q.reshape(-1, _BLOCK).astype(jnp.float32) * s.scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return xb.reshape(-1)[:n].reshape(shape)


def adamw_init(params, cfg: AdamWConfig):
    def one(p):
        if cfg.state_bits == 8:
            m = _q8_zeros(p)
            v = _q8_zeros(p)
        else:
            m = jnp.zeros_like(p, jnp.float32)
            v = jnp.zeros_like(p, jnp.float32)
        st = {"m": m, "v": v}
        if cfg.master and p.dtype != jnp.float32:
            st["master"] = p.astype(jnp.float32)
        return st

    leaves_state = jax.tree.map(one, params)
    return {"step": jnp.zeros((), jnp.int32), "per_param": leaves_state}


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def one(p, g, st):
        g32 = g.astype(jnp.float32)
        if cfg.state_bits == 8:
            m = _q8_decode(st["m"], p.shape)
            v = _q8_decode(st["v"], p.shape)
        else:
            m, v = st["m"], st["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * (g32 * g32)
        mh = m / b1c
        vh = v / b2c
        base = st.get("master", p.astype(jnp.float32))
        upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * base
        new_master = base - cfg.lr * lr_scale * upd
        new_p = new_master.astype(p.dtype)
        new_st = {
            "m": _q8_encode(m) if cfg.state_bits == 8 else m,
            "v": _q8_encode(v) if cfg.state_bits == 8 else v,
        }
        if "master" in st:
            new_st["master"] = new_master
        return new_p, new_st

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["per_param"])
    out = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"step": step, "per_param": tdef.unflatten([o[1] for o in out])}
    return new_params, new_state
