"""Gradient compression with error feedback (cross-pod all-reduce diet).

Int8 block-quantized gradients before the data-parallel all-reduce: at
2×16×16 the pod axis crosses DCN, where 4× fewer bytes is the difference
between overlap-hidden and exposed. Error feedback (residual carried to
the next step) keeps convergence unbiased (1-bit Adam lineage).

``compressed_psum`` is the shard_map building block; ``EFState`` rides the
optimizer state pytree so it checkpoints/reshards like everything else.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_grad", "dequantize_grad", "ef_compress", "compressed_psum"]

_BLOCK = 256


def quantize_grad(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise absmax int8. Returns (codes int8 [n], scales f32 [nb])."""
    n = g.size
    nb = (n + _BLOCK - 1) // _BLOCK
    flat = jnp.pad(g.reshape(-1).astype(jnp.float32), (0, nb * _BLOCK - n))
    blocks = flat.reshape(nb, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_grad(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    blocks = q.reshape(-1, _BLOCK).astype(jnp.float32) * scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return blocks.reshape(-1)[:n].reshape(shape)


def ef_compress(g: jnp.ndarray, residual: jnp.ndarray):
    """Error-feedback compression: quantize (g + residual), carry error."""
    corrected = g.astype(jnp.float32) + residual
    q, s = quantize_grad(corrected)
    deq = dequantize_grad(q, s, g.shape)
    new_residual = corrected - deq
    return (q, s), deq, new_residual


def compressed_psum(g: jnp.ndarray, axis_name: str, residual: jnp.ndarray):
    """shard_map body: int8-quantize locally, psum the *dequantized* grads
    (wire bytes modeled at int8 by the collective-bytes analysis; XLA does
    the arithmetic in f32 after local dequant, matching 1-bit-Adam-style
    implementations where the AG/RS payload is the int8 codes).
    Returns (reduced_grad, new_residual)."""
    (q, s), deq, new_res = ef_compress(g, residual)
    return jax.lax.psum(deq, axis_name), new_res
