"""Mixture-of-Experts layer (paper §3.1 Eq. 1) with TPU-friendly dispatch.

Dispatch is sort-free GShard-style **cumsum + scatter** (DESIGN.md §5.4):
no [T, E, C] dispatch einsum (which is quadratic-ish in tokens) and no
global argsort — a [T·k, E] one-hot cumsum ranks tokens within their
expert, then a scatter builds the ``[E·capacity, D]`` layout whose leading
dim shards over the ``model`` axis (expert parallelism). Expert FFNs run
as a batched einsum over the expert dim (or the PMQ-quantized bucketed
path in :mod:`repro.core.compressed_moe`).

OTP hooks: ``gate_mask [T, k]`` multiplies gate weights *before* dispatch,
and masked (token, k)-slots are routed to the drop bucket so pruned
experts consume no capacity and no FLOPs (paper §3.4 / Fig. 8).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from .layers import init_linear, init_mlp, linear, mlp

__all__ = [
    "init_moe",
    "moe_layer",
    "route_topk",
    "capacity_dispatch",
    "dispatch_capacity",
    "slot_fill_counts",
    "MoEOut",
]


def dispatch_capacity(cfg, t: int, capacity_factor=None) -> int:
    """Per-expert capacity for ``t`` tokens: ``cf·t·k/E``, sublane-aligned
    (multiple of 8, floor 8). One formula shared by the pjit MoE layer,
    the compressed path, the shard_map EP bodies and the serving
    engine's capacity-utilization gauge — they must agree or dispatch
    layouts and their observability drift apart."""
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity_factor
    cap = int(cf * t * cfg.top_k / cfg.num_experts)
    return max(8, ((cap + 7) // 8) * 8)


class MoEOut(NamedTuple):
    y: jnp.ndarray
    aux_loss: jnp.ndarray
    router_probs: jnp.ndarray  # [T, E] (for stats/calibration)
    topk_idx: jnp.ndarray  # [T, k]
    topk_gates: jnp.ndarray  # [T, k]


def init_moe(rng, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.num_experts
    ks = jax.random.split(rng, 5)
    scale = 1.0 / (d**0.5)
    p = {
        "router": init_linear(ks[0], d, e, jnp.float32, scale),
        "experts": {
            "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * scale,
            "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * scale,
            "w_down": jax.random.normal(ks[3], (e, f, d), dtype) * (1.0 / f**0.5),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.num_shared_experts, dtype)
    return p


def route_topk(router_p, x2: jnp.ndarray, k: int):
    """Softmax router + top-k with renormalized gates.

    ``x2 [T, D]`` → probs [T, E], idx [T, k], gates [T, k].
    """
    logits = linear(router_p, x2.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return probs, idx, gates


def _rank_within_expert(eids: jnp.ndarray, e: int) -> jnp.ndarray:
    """Stable rank of each slot within its expert (GShard cumsum, no sort).

    Large problems (T·k·E elements > 2²⁶) run a chunked scan so the
    [chunk, E] one-hot never exceeds ~128 MiB.
    """
    n = eids.shape[0]
    if n * e <= 2**26:
        onehot = (eids[:, None] == jnp.arange(e)[None, :]).astype(jnp.int32)
        onehot = shard(onehot, "moe_tke")
        rank = jnp.cumsum(onehot, axis=0) - onehot
        return jnp.sum(rank * onehot, axis=1)
    chunk = max(1, 2**26 // e // 8 * 8)
    nchunks = (n + chunk - 1) // chunk
    pad = nchunks * chunk - n
    ep = jnp.pad(eids, (0, pad), constant_values=e)  # pads rank harmlessly
    chunks = ep.reshape(nchunks, chunk)

    def body(counts, ch):
        oh = (ch[:, None] == jnp.arange(e)[None, :]).astype(jnp.int32)
        r = counts[None, :] + jnp.cumsum(oh, axis=0) - oh
        rank_ch = jnp.sum(r * oh, axis=1)
        return counts + oh.sum(axis=0), rank_ch

    _, ranks = jax.lax.scan(body, jnp.zeros((e,), jnp.int32), chunks)
    return ranks.reshape(-1)[:n]


def capacity_dispatch(
    x2: jnp.ndarray,
    idx: jnp.ndarray,
    gates: jnp.ndarray,
    num_experts: int,
    capacity: int,
    gate_mask: Optional[jnp.ndarray] = None,
):
    """Build the expert-major layout.

    Returns ``(xp [E·cap, D], dest [T·k], valid [T·k], gates_flat [T·k])``.
    ``dest`` maps (token, choice) slots into rows of ``xp`` (E·cap = drop).

    The row movement is **gather-based**: a cheap int32 scatter builds the
    inverse permutation (xp row → source slot), then ``xp = x2[src]`` —
    GSPMD turns the gather into bounded-volume resharding instead of
    all-gathering the scattered rows (DESIGN.md §5.4).
    """
    t, k = idx.shape
    e = num_experts
    eids = idx.reshape(-1)
    gflat = gates.reshape(-1)
    if gate_mask is not None:
        mflat = gate_mask.reshape(-1)
        gflat = gflat * mflat
        eids = jnp.where(mflat > 0, eids, e)  # pruned → drop bucket
    rank = _rank_within_expert(eids, e)
    valid = (rank < capacity) & (eids < e)
    dest = jnp.where(valid, eids * capacity + rank, e * capacity)
    # inverse permutation: xp row -> source (token,choice) slot (+1; 0=empty)
    inv = jnp.zeros((e * capacity + 1,), jnp.int32)
    inv = inv.at[dest].set(jnp.arange(t * k, dtype=jnp.int32) + 1)[: e * capacity]
    src_token = jnp.where(inv > 0, (inv - 1) // k, t)  # t = zero row
    x2_pad = jnp.concatenate([x2, jnp.zeros((1, x2.shape[1]), x2.dtype)], axis=0)
    xp = x2_pad[src_token]
    return xp, dest, valid, gflat


def slot_fill_counts(
    dest: jnp.ndarray, valid: jnp.ndarray, num_units: int, capacity: int
) -> jnp.ndarray:
    """Occupied-row count per dispatch unit of a capacity layout.

    Inverts :func:`capacity_dispatch`'s encoding (``dest = unit·cap +
    rank`` for valid slots, drop bucket beyond): returns ``[num_units]``
    int32 counts ≤ capacity. Because ranks are assigned densely from 0,
    each unit's occupied rows are a *prefix* — the invariant the grouped
    expert-GEMM compaction (``grouped_bucket_ffn``) builds on.
    """
    occ = jnp.where(valid, dest // capacity, num_units)
    return jnp.zeros((num_units + 1,), jnp.int32).at[occ].add(1)[:-1]


def combine(yp: jnp.ndarray, dest, valid, gflat, t: int, k: int) -> jnp.ndarray:
    """Gather expert outputs back to token order and mix by gates."""
    d = yp.shape[-1]
    ypad = jnp.concatenate([yp, jnp.zeros((1, d), yp.dtype)], axis=0)
    rows = ypad[jnp.where(valid, dest, yp.shape[0])]
    y = (rows.reshape(t, k, d) * gflat.reshape(t, k, 1).astype(yp.dtype)).sum(axis=1)
    return y


def expert_ffn(experts_p, xp: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Batched SwiGLU over the expert dim: ``xp [E·cap, D] → [E·cap, D]``."""
    e = num_experts
    cap = xp.shape[0] // e
    x3 = xp.reshape(e, cap, -1)
    x3 = shard(x3, "moe_ecd")  # EP on experts + DP on capacity
    wg, wu, wd = (
        experts_p["w_gate"],
        experts_p["w_up"],
        experts_p["w_down"],
    )
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x3, wg.astype(x3.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", x3, wu.astype(x3.dtype))
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(x3.dtype))
    return y.reshape(e * cap, -1)


def load_balance_loss(probs: jnp.ndarray, idx: jnp.ndarray, num_experts: int):
    """Switch-style aux loss: ``E · Σ_e f_e · p̄_e``."""
    t, k = idx.shape
    onehot = jax.nn.one_hot(idx, num_experts, dtype=jnp.float32)  # [T,k,E]
    f = onehot.sum(axis=(0, 1)) / (t * k)
    p = probs.mean(axis=0)
    return num_experts * jnp.sum(f * p)


def ep_shardmap_ok(cfg, mesh, x, num_units: int) -> bool:
    """Divisibility guard for the shard_map EP region."""
    if mesh is None or "model" not in mesh.axis_names:
        return False
    from ..parallel.sharding import batch_axes
    import numpy as np

    model = mesh.shape["model"]
    bsz = int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))
    return num_units % model == 0 and x.shape[0] % bsz == 0


def moe_layer(
    p,
    x: jnp.ndarray,
    cfg,
    *,
    gate_mask_fn=None,
    expert_ffn_fn=None,
) -> MoEOut:
    """Full MoE block. ``x [B, S, D]``.

    ``gate_mask_fn(x2, idx, gates) -> mask [T, k]`` is the OTP hook.
    ``expert_ffn_fn(xp) -> yp`` overrides expert compute (compressed path).

    Inside a mesh context (when divisibility holds) the routed-expert
    region runs the zero-all-to-all shard_map EP path
    (:mod:`repro.parallel.ep_shardmap`); the pjit/GSPMD path below is the
    single-host / fallback implementation.
    """
    from ..parallel.sharding import current_mesh

    mesh = current_mesh()
    if (
        expert_ffn_fn is None
        and mesh is not None
        and ep_shardmap_ok(cfg, mesh, x, cfg.num_experts)
    ):
        from ..parallel.ep_shardmap import moe_region_sharded

        y, aux = moe_region_sharded(p, x, cfg, mesh, gate_mask_fn=gate_mask_fn)
        if "shared" in p:
            b, s, d = x.shape
            y = y + mlp(p["shared"], x.reshape(b * s, d)).reshape(b, s, d)
        return MoEOut(y, aux, None, None, None)
    b, s, d = x.shape
    t = b * s
    x2 = x.reshape(t, d)
    e, k = cfg.num_experts, cfg.top_k
    probs, idx, gates = route_topk(p["router"], x2, k)
    gate_mask = None
    if gate_mask_fn is not None:
        gate_mask = gate_mask_fn(x2, idx, gates)
    cap = dispatch_capacity(cfg, t)
    xp, dest, valid, gflat = capacity_dispatch(x2, idx, gates, e, cap, gate_mask)
    xp = shard(xp, "moe_ed")
    if expert_ffn_fn is not None:
        yp = expert_ffn_fn(xp)
    else:
        yp = expert_ffn(p["experts"], xp, e)
    y = combine(yp, dest, valid, gflat, t, k)
    if "shared" in p:
        y = y + mlp(p["shared"], x2)
    aux = load_balance_loss(probs, idx, e)
    return MoEOut(y.reshape(b, s, d), aux, probs, idx, gates)
