"""Decoder-only LM covering dense, MoE and VLM-backbone families.

One stacked-parameter ``lax.scan`` over layers (compile time independent of
depth — essential for 62-layer × 512-device dry-runs). Mixed local/global
attention (gemma3 5:1) rides the same scan via a traced per-layer window
(global layers get window = S+1). The MoE path plugs the capacity
dispatch from :mod:`repro.models.moe`; the PMQ/OTP compressed path swaps
``expert_ffn_fn`` / ``gate_mask_fn`` (see :mod:`repro.core.compressed_moe`).

Modes: ``train_loss`` (chunked xent), ``prefill`` (build KV cache, last
logits), ``decode_step`` (one token, donated cache).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.quantizers import dequantize_kv_rows, quantize_kv_rows
from ..kernels import ops
from ..parallel.sharding import shard
from . import layers as L
from .moe import init_moe, moe_layer

__all__ = [
    "init_lm",
    "train_loss",
    "prefill",
    "decode_step",
    "paged_decode_step",
    "paged_decode_horizon",
    "paged_prefill_chunk",
    "forward_hidden",
    "layer_windows",
]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def layer_windows_static(cfg, s: int):
    """Per-layer effective window as a host numpy array (python loops)."""
    import numpy as np

    idx = np.arange(cfg.num_layers)
    if cfg.local_global_ratio > 0 and cfg.local_window > 0:
        is_global = (idx % (cfg.local_global_ratio + 1)) == cfg.local_global_ratio
        return np.where(is_global, s + 1, cfg.local_window).astype(np.int32)
    if cfg.local_window > 0:
        return np.full((cfg.num_layers,), cfg.local_window, np.int32)
    return np.full((cfg.num_layers,), s + 1, np.int32)


def layer_windows(cfg, s: int) -> jnp.ndarray:
    """Per-layer effective window (traced into the scan)."""
    return jnp.asarray(layer_windows_static(cfg, s))


# ------------------------------------------------------------------- init
def init_lm(rng, cfg) -> Dict[str, Any]:
    dt = _dtype(cfg)
    k_emb, k_blocks, k_out = jax.random.split(rng, 3)

    def init_block(k):
        ka, km = jax.random.split(k)
        p = {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": L.init_attention(ka, cfg, dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
        }
        if cfg.is_moe:
            p["moe"] = init_moe(km, cfg, dt)
        else:
            p["mlp"] = L.init_mlp(km, cfg.d_model, cfg.d_ff, dt)
        return p

    blocks = jax.vmap(init_block)(jax.random.split(k_blocks, cfg.num_layers))
    params = {
        "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), dt) * 0.02,
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(k_out, (cfg.vocab_size, cfg.d_model), dt) * 0.02
        )
    return params


def _out_embedding(params):
    return params.get("unembed", params["embed"])


# ------------------------------------------------------------ block body
def _block(p, x, cfg, *, positions, window, moe_hooks=None):
    """One transformer block (full-sequence). Returns (x, aux, kv).

    Sequence-parallel discipline (Megatron-SP): the residual stream is
    seq-sharded ("act_btd"); attention/FFN regions run on the gathered
    layout ("act_full") — one AG entering, one RS leaving per region.
    """
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    h = shard(h, "act_full")
    attn_out, kv = L.attention(
        p["attn"], h, cfg, positions=positions, causal=True, window=window
    )
    attn_out = shard(attn_out, "act_btd")
    x = x + attn_out
    x = shard(x, "act_btd")
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    h = shard(h, "act_full")
    aux = jnp.float32(0)
    if cfg.is_moe:
        if "moe_ce" in p:  # PMQ-compressed experts (+ optional OTP)
            from ..core.compressed_moe import compressed_moe_layer

            hooks = moe_hooks or {}
            use_otp = hooks.get("use_otp", True)
            y, info = compressed_moe_layer(
                p["moe"], p["moe_ce"], h, cfg,
                otp_params=p.get("otp") if use_otp else None,
                otp_rng=hooks.get("otp_rng"),
                otp_tau=hooks.get("otp_tau", 1.0),
                ffn_backend=hooks.get("ffn_backend"),
            )
            # save the region output across remat: recomputing it would
            # re-all-gather the packed expert weights in the backward pass
            from jax.ad_checkpoint import checkpoint_name

            y = checkpoint_name(y, "moe_out")
            x = x + y
            if info.get("mask_l1") is not None:
                aux = info["mask_l1"]  # ℓ1 term channel (Eq. 14)
        else:
            hooks = moe_hooks or {}
            out = moe_layer(
                p["moe"], h, cfg,
                gate_mask_fn=hooks.get("gate_mask_fn"),
                expert_ffn_fn=hooks.get("expert_ffn_fn"),
            )
            x = x + out.y
            aux = out.aux_loss
    else:
        x = x + shard(L.mlp(p["mlp"], h), "act_btd")
    x = shard(x, "act_btd")
    return x, aux, kv


def _embed_inputs(params, cfg, tokens, patch_embeds=None):
    x = L.embed_tokens(params["embed"], tokens)
    if cfg.family == "vlm" and patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    return x


def forward_hidden(
    params,
    tokens: jnp.ndarray,
    cfg,
    *,
    patch_embeds: Optional[jnp.ndarray] = None,
    collect_cache: bool = False,
    moe_hooks=None,
):
    """Run all blocks; returns (hidden [B,S,D], aux_loss, cache|None)."""
    x = _embed_inputs(params, cfg, tokens, patch_embeds)
    b, s, d = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    windows = layer_windows(cfg, s)

    def body(carry, xs):
        xc, aux = carry
        p_l, win = xs
        xn, a, kv = _block(
            p_l, xc, cfg, positions=positions, window=win, moe_hooks=moe_hooks
        )
        ys = kv if collect_cache else None
        return (xn, aux + a), ys

    body_fn = body
    if cfg.remat == "block":
        body_fn = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.save_only_these_names("moe_out"),
        )
    (x, aux), kvs = jax.lax.scan(body_fn, (x, jnp.float32(0)), (params["blocks"], windows))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    cache = None
    if collect_cache:
        cache = {"k": kvs[0], "v": kvs[1]}  # [L, B, S, Hkv, dh]
    return x, aux, cache


# ------------------------------------------------------------------ train
def train_loss(params, batch, cfg, *, moe_hooks=None, aux_weight: float = 0.01):
    tokens = batch["tokens"]
    labels = batch["labels"]
    patch = batch.get("patch_embeds")
    hidden, aux, _ = forward_hidden(
        params, tokens, cfg, patch_embeds=patch, moe_hooks=moe_hooks
    )
    if patch is not None:  # loss only on text positions
        hidden = hidden[:, patch.shape[1] :]
    nll = L.chunked_xent(hidden, _out_embedding(params), labels, cfg.logits_chunk)
    loss = nll + aux_weight * aux / max(cfg.num_layers, 1)
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------- serving
def prefill(params, batch, cfg, *, moe_hooks=None, paged=None):
    """Build a KV cache of the prompt; return (cache, last-token logits).

    With ``paged={"cache": <paged layout>, "start": s, "valid_len": n}``
    the prompt chunk is written into the paged pool instead of building a
    dense cache (see :func:`paged_prefill_chunk`).
    """
    if paged is not None:
        new_cache, logits, _ = paged_prefill_chunk(
            params, paged["cache"], batch["tokens"],
            paged.get("start", 0),
            paged.get("valid_len", batch["tokens"].shape[1]),
            cfg, moe_hooks=moe_hooks,
        )
        return new_cache, logits
    tokens = batch["tokens"]
    patch = batch.get("patch_embeds")
    hidden, _, cache = forward_hidden(
        params, tokens, cfg, patch_embeds=patch, collect_cache=True,
        moe_hooks=moe_hooks,
    )
    last = hidden[:, -1:, :]
    logits = jnp.einsum(
        "btd,vd->btv", last.astype(jnp.float32),
        _out_embedding(params).astype(jnp.float32),
    )
    cache["pos"] = jnp.int32(tokens.shape[1] + (patch.shape[1] if patch is not None else 0))
    return cache, logits


def _ffn_delta(p, h, cfg, moe_hooks=None):
    """FFN half of a decode-style block.

    Returns ``(Δx, expert_activation [B, S], slot_counts [num_slots])``.

    ``expert_activation`` is the **per-token** executed fraction of top-k
    expert slots: the mean of the OTP decode mask (deterministic argmax,
    paper §3.4 τ→0 limit) when masks are active, else 1.0. It is kept
    per token so callers can exclude padding/inactive slots before
    reducing (the paged decode step masks with ``cache["active"]``).
    ``slot_counts`` is the PMQ layer's per-permuted-slot dispatch count
    (the offload prefetcher's router statistic; empty ``[0]`` outside the
    compressed path). ``moe_hooks["count_weight"]`` ([T] bool) marks
    which tokens are real traffic; ``moe_hooks["ffn_backend"]`` selects
    the compressed expert-FFN implementation (grouped GEMM vs legacy
    scan — a static trace-time choice, so the serving engine's jitted
    programs never retrace over it). Shared by the dense and paged
    decode paths so they stay numerically identical.
    """
    ones = jnp.ones(h.shape[:2], jnp.float32)
    no_counts = jnp.zeros((0,), jnp.int32)
    if not cfg.is_moe:
        return L.mlp(p["mlp"], h), ones, no_counts
    if "moe_ce" in p:
        from ..core.compressed_moe import compressed_moe_layer

        hooks = moe_hooks or {}
        use_otp = hooks.get("use_otp", True)
        y, info = compressed_moe_layer(
            p["moe"], p["moe_ce"], h, cfg,
            otp_params=p.get("otp") if use_otp else None,
            count_weight=hooks.get("count_weight"),
            ffn_backend=hooks.get("ffn_backend"),
        )
        act = ones
        if info.get("mask") is not None:
            act = info["mask"].mean(axis=-1).reshape(h.shape[:2])
        counts = info.get("slot_counts")
        return y, act, counts if counts is not None else no_counts
    out = moe_layer(p["moe"], h, cfg)
    return out.y, ones, no_counts


def _decode_block(p, x, cfg, *, k_cache, v_cache, pos, window, moe_hooks=None):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_out, (k_cache, v_cache) = L.decode_attention(
        p["attn"], h, cfg, k_cache=k_cache, v_cache=v_cache, pos=pos, window=window
    )
    x = x + attn_out
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    delta, _, _ = _ffn_delta(p, h, cfg, moe_hooks)
    x = x + delta
    return x, (k_cache, v_cache)


def decode_step(params, cache, token: jnp.ndarray, pos: jnp.ndarray, cfg,
                *, moe_hooks=None):
    """One decode step. ``token [B, 1]``, ``pos`` scalar int32 (next slot).

    Cache layout ``{"k": [L,B,S,Hkv,dh], "v": ..., "pos"}``; returns
    ``(new_cache, logits [B,1,V])``.

    The cache rides the scan **carry** (not xs/ys): XLA aliases while-loop
    carries in place, so a donated multi-GB cache is updated with a single
    [B,1,Hkv,dh] write per layer instead of double-buffering the whole
    tensor (−2× cache HBM at decode).

    A cache carrying ``"block_tables"`` is the *paged* layout
    (:mod:`repro.serving.kvcache`); it dispatches to
    :func:`paged_decode_step` with ``pos`` as per-slot positions ``[B]``.
    """
    if "block_tables" in cache:
        new_cache, logits, _ = paged_decode_step(
            params, cache, token, pos, cfg, moe_hooks=moe_hooks
        )
        return new_cache, logits
    x = L.embed_tokens(params["embed"], token)
    b = token.shape[0]
    s = cache["k"].shape[2]
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    windows = layer_windows(cfg, s)
    layer_ids = jnp.arange(cfg.num_layers, dtype=jnp.int32)

    def body(carry, xs):
        xc, kf, vf = carry
        p_l, win, l = xs
        k_l = jax.lax.dynamic_index_in_dim(kf, l, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(vf, l, 0, keepdims=False)
        xn, (k_l2, v_l2) = _decode_block(
            p_l, xc, cfg, k_cache=k_l, v_cache=v_l, pos=pos, window=win,
            moe_hooks=moe_hooks,
        )
        # persist only the new token's K/V into the carried buffers
        k_new = jax.lax.dynamic_slice(k_l2, (0, pos, 0, 0), (b, 1, hkv, dh))
        v_new = jax.lax.dynamic_slice(v_l2, (0, pos, 0, 0), (b, 1, hkv, dh))
        kf = jax.lax.dynamic_update_slice(kf, k_new[None], (l, 0, pos, 0, 0))
        vf = jax.lax.dynamic_update_slice(vf, v_new[None], (l, 0, pos, 0, 0))
        return (xn, kf, vf), None

    (x, kf, vf), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]), (params["blocks"], windows, layer_ids)
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "btd,vd->btv", x.astype(jnp.float32),
        _out_embedding(params).astype(jnp.float32),
    )
    new_cache = {"k": kf, "v": vf, "pos": pos + 1}
    return new_cache, logits


# ------------------------------------------------------- paged serving
def _paged_pool_dims(cache):
    l, nb, bs = cache["k"].shape[0], cache["k"].shape[1], cache["k"].shape[2]
    return l, nb, bs


#: Code width of quantized KV pools (int8 per-row affine — see
#: repro.core.quantizers.quantize_kv_rows and serving.kvcache).
KV_QUANT_BITS = 8

_KV_QUANT_KEYS = ("k_scale", "k_zero", "v_scale", "v_zero")


def _flatten_kv_quant(cache, nl, nb, bs, hkv):
    """``cache["kv_quant"]`` ({k,v}×{scale,zero} [L, NB, BS, Hkv]) →
    flat tuple ``(ks, kz, vs, vz)`` [L, NB·BS, Hkv], or ``()`` on fp
    pools — an empty tuple threads through scan carries untouched."""
    q = cache.get("kv_quant")
    if q is None:
        return ()
    return tuple(q[k].reshape(nl, nb * bs, hkv) for k in _KV_QUANT_KEYS)


def _unflatten_kv_quant(qs, nl, nb, bs, hkv):
    if not qs:
        return None
    return {
        k: a.reshape(nl, nb, bs, hkv)
        for k, a in zip(_KV_QUANT_KEYS, qs)
    }


def _paged_decode_core(params, kf, vf, qs, tables, token, positions, active,
                       cfg, nb, bs, *, moe_hooks=None):
    """One decode step over the *flattened* paged pools — the shared body
    of :func:`paged_decode_step` (single step) and
    :func:`paged_decode_horizon` (H fused steps): both run exactly this
    computation per step, so their logits are bit-identical step for
    step.

    ``kf``/``vf`` are ``[L, NB·BS, Hkv, dh]``; ``tables [B, MB]``;
    ``token [B, 1]``; ``positions [B]``; ``active [B]`` bool or ``None``
    (every slot then writes). ``qs`` is ``()`` for fp pools — that path
    is byte-for-byte the historical computation — or the flat per-row
    dequant tables ``(k_scale, k_zero, v_scale, v_zero)`` ``[L, NB·BS,
    Hkv]`` for int8 pools: the new token's K/V rows are quantized
    (per-row affine, deterministic in the row values alone — so
    identical tokens at identical positions produce identical codes
    regardless of batch composition) before the scatter, and attention
    reads through the kernel's dequant epilogue. Returns ``(kf, vf, qs,
    logits [B,1,V], per_slot_act [B], slot_counts [L, num_slots])`` —
    ``per_slot_act`` is the per-slot executed fraction of top-k expert
    slots (OTP decode masks), unreduced so callers can mask inactive
    slots.
    """
    x = L.embed_tokens(params["embed"], token)
    b = token.shape[0]
    nl = kf.shape[0]
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = hq // hkv
    s_log = tables.shape[1] * bs
    windows = layer_windows(cfg, s_log)
    layer_ids = jnp.arange(nl, dtype=jnp.int32)
    # flat destination of the new token's K/V; inactive slots land one
    # past the pool end and are dropped by the scatter
    page = jnp.take_along_axis(
        tables, (positions // bs)[:, None], axis=1
    )[:, 0]
    dest = page * bs + positions % bs
    if active is not None:
        dest = jnp.where(active, dest, nb * bs)
    lengths = positions + 1
    hooks = dict(moe_hooks or {})
    if active is not None:
        hooks["count_weight"] = active  # [B] = [T] at decode (S = 1)
    quantized = bool(qs)

    def body(carry, xs):
        xc, kf, vf, qs = carry
        p_l, win, l = xs
        h = L.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        q, k_new, v_new = L._qkv(p_l["attn"], h, cfg, positions[:, None])
        quant_l = None
        if quantized:
            ksf, kzf, vsf, vzf = qs
            kc, ks, kz = quantize_kv_rows(k_new[:, 0], KV_QUANT_BITS)
            vc, vs, vz = quantize_kv_rows(v_new[:, 0], KV_QUANT_BITS)
            kf = kf.at[l, dest].set(kc, mode="drop")
            vf = vf.at[l, dest].set(vc, mode="drop")
            ksf = ksf.at[l, dest].set(ks, mode="drop")
            kzf = kzf.at[l, dest].set(kz, mode="drop")
            vsf = vsf.at[l, dest].set(vs, mode="drop")
            vzf = vzf.at[l, dest].set(vz, mode="drop")
            qs = (ksf, kzf, vsf, vzf)
            quant_l = tuple(
                jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False)
                .reshape(nb, bs, hkv) for a in qs
            )
        else:
            kf = kf.at[l, dest].set(k_new[:, 0].astype(kf.dtype), mode="drop")
            vf = vf.at[l, dest].set(v_new[:, 0].astype(vf.dtype), mode="drop")
        k_l = jax.lax.dynamic_index_in_dim(kf, l, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(vf, l, 0, keepdims=False)
        attn = ops.paged_attention(
            q.reshape(b, hkv, g, dh),
            k_l.reshape(nb, bs, hkv, dh),
            v_l.reshape(nb, bs, hkv, dh),
            tables, lengths, window=win, quant=quant_l,
        )
        attn = attn.reshape(b, 1, hq * dh).astype(xc.dtype)
        xc = xc + L.linear(p_l["attn"]["wo"], attn)
        h2 = L.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        delta, act, counts = _ffn_delta(p_l, h2, cfg, hooks)
        xc = xc + delta
        return (xc, kf, vf, qs), (act, counts)

    (x, kf, vf, qs), (acts, slot_counts) = jax.lax.scan(
        body, (x, kf, vf, qs), (params["blocks"], windows, layer_ids)
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "btd,vd->btv", x.astype(jnp.float32),
        _out_embedding(params).astype(jnp.float32),
    )
    # acts [L, B, 1] per-token: keep per-slot so garbage tokens decoded
    # by empty slots cannot dilute the OTP activation metric
    per_slot = acts.mean(axis=(0, 2))  # [B]
    return kf, vf, qs, logits, per_slot, slot_counts


def _masked_activation(per_slot, active):
    if active is None:
        return per_slot.mean()
    w = active.astype(jnp.float32)
    return jnp.sum(per_slot * w) / jnp.maximum(w.sum(), 1.0)


def paged_decode_step(params, cache, token: jnp.ndarray, positions: jnp.ndarray,
                      cfg, *, moe_hooks=None):
    """One decode step over a paged KV pool (continuous batching).

    ``cache = {"k": [L,NB,BS,Hkv,dh], "v": ..., "block_tables": [B,MB],
    "active": [B] bool}``; ``token [B,1]``; ``positions [B]`` — per-slot
    write position (slots decode at *different* logical lengths, unlike
    the dense path's single scalar ``pos``). Inactive slots compute but
    never write (their scatter destination is out of bounds → dropped),
    so freed pages can be re-used by a newly admitted request in the same
    jitted program. ``"active"`` may be omitted — every slot then writes.

    The block tables are static-shape ``[B, MB]`` rows padded with 0
    beyond each slot's allocated pages: with dynamic page growth the
    serving engine appends entries between jitted steps, and the only
    invariant this step needs is that ``tables[slot, positions[slot]//BS]``
    is an allocated page for every *active* slot (the engine grows before
    decoding). Padding entries are never read — the attention gather is
    clamped to ``lengths = positions + 1``.

    Returns ``(new_cache, logits [B,1,V], info)`` where
    ``info["expert_activation"]`` is the mean executed fraction of top-k
    expert slots across layers (OTP §3.4 decode masks make it < 1),
    reduced over **active slots only** — inactive slots decode garbage
    tokens whose masks would otherwise dilute the metric — and
    ``info["slot_counts"]`` ([L, num_slots] int32, or [L, 0] outside the
    PMQ path) counts dispatched (token, choice) pairs per permuted expert
    slot per layer, again excluding inactive slots (the serving offload
    manager's prefetch/miss signal).
    """
    nl, nb, bs = _paged_pool_dims(cache)
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    active = cache.get("active")
    qs = _flatten_kv_quant(cache, nl, nb, bs, hkv)
    kf, vf, qs, logits, per_slot, slot_counts = _paged_decode_core(
        params,
        cache["k"].reshape(nl, nb * bs, hkv, dh),
        cache["v"].reshape(nl, nb * bs, hkv, dh),
        qs, cache["block_tables"], token, positions, active, cfg, nb, bs,
        moe_hooks=moe_hooks,
    )
    new_cache = dict(
        cache,
        k=kf.reshape(nl, nb, bs, hkv, dh),
        v=vf.reshape(nl, nb, bs, hkv, dh),
    )
    if qs:
        new_cache["kv_quant"] = _unflatten_kv_quant(qs, nl, nb, bs, hkv)
    info = {
        "expert_activation": _masked_activation(per_slot, active),
        "slot_counts": slot_counts,
    }
    return new_cache, logits, info


def paged_decode_horizon(params, cache, token: jnp.ndarray,
                         positions: jnp.ndarray, cfg, *, horizon: int,
                         budgets: jnp.ndarray, eos_ids: jnp.ndarray,
                         moe_hooks=None, temperature: float = 0.0,
                         rng_key=None):
    """Fused ``H``-step decode: one jitted program advances every slot up
    to ``horizon`` tokens with **on-device sampling** feeding each step's
    output token into the next step — the serving engine pays one
    dispatch and one host sync per *megastep* instead of per token.

    Each scan step runs exactly :func:`_paged_decode_core` (the same body
    :func:`paged_decode_step` wraps), so greedy outputs are bit-identical
    to ``H`` single steps. Per-slot stop logic lives inside the scan as
    the carried ``active`` mask:

    * ``budgets [B]`` int32 — tokens the slot may still emit
      (``max_new - len(out)``); a slot deactivates the step its budget
      hits zero, so a request whose budget ends mid-horizon emits no
      extra tokens,
    * ``eos_ids [B]`` int32 — per-slot stop token, ``-1`` disables;
      emitting it deactivates the slot from the next step on,
    * slots inactive at entry (``cache["active"]``) compute but never
      write KV nor emit, exactly as in the single-step program.

    ``temperature`` is **trace-time static**: ``0`` (default) compiles
    greedy argmax — the bit-identity path every invariant test runs —
    and ``> 0`` compiles categorical sampling from ``logits/T`` with one
    explicit subkey per horizon step split from ``rng_key`` (replays of
    the same megastep reuse the same key, so sampled runs are
    deterministic per trace and idempotent under offload replay).

    Returns ``(new_cache, tokens [H, B], emits [H, B], info)``: row ``s``
    holds the token each slot emitted at horizon step ``s`` (``-1`` where
    ``emits`` is False); ``info["expert_activation"]`` is the per-step
    active-masked activation ``[H]`` and ``info["slot_counts"]`` the
    per-step dispatch counts ``[H, L, num_slots]`` (step-major — the
    offload manager's horizon-union working set + replay order).
    """
    if horizon < 1:
        raise ValueError(f"horizon must be ≥ 1, got {horizon}")
    greedy = temperature <= 0.0
    if not greedy and rng_key is None:
        raise ValueError("temperature sampling needs an explicit rng_key")
    nl, nb, bs = _paged_pool_dims(cache)
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    tables = cache["block_tables"]
    active0 = cache.get("active")
    if active0 is None:
        active0 = jnp.ones((token.shape[0],), bool)

    def step(carry, key):
        kf, vf, qs, cur, pos, act, budget = carry
        kf, vf, qs, logits, per_slot, counts = _paged_decode_core(
            params, kf, vf, qs, tables, cur, pos, act, cfg, nb, bs,
            moe_hooks=moe_hooks,
        )
        lg = logits[:, -1, :]  # [B, V] f32
        if greedy:
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                key, lg / jnp.float32(temperature), axis=-1
            ).astype(jnp.int32)
        emit = act  # a slot active at step entry emits this step's token
        budget = budget - emit.astype(jnp.int32)
        stop = (budget <= 0) | ((eos_ids >= 0) & (nxt == eos_ids))
        ys = (
            jnp.where(emit, nxt, -1),
            emit,
            _masked_activation(per_slot, act),
            counts,
        )
        carry = (kf, vf, qs, nxt[:, None], pos + emit.astype(jnp.int32),
                 act & ~stop, budget)
        return carry, ys

    keys = (
        jnp.zeros((horizon,), jnp.int32) if greedy
        else jax.random.split(rng_key, horizon)
    )
    init = (
        cache["k"].reshape(nl, nb * bs, hkv, dh),
        cache["v"].reshape(nl, nb * bs, hkv, dh),
        _flatten_kv_quant(cache, nl, nb, bs, hkv),
        token, positions, active0, budgets,
    )
    # the horizon scan is fully unrolled: H is small and static, and a
    # rolled while-loop forbids XLA from aliasing the donated KV pools /
    # fusing across steps (measured ~1.8x per-step decode cost on CPU);
    # unrolling keeps per-step cost at the single-step program's while
    # still eliminating the per-token host round-trips
    (kf, vf, qs, *_), (toks, emits, acts, counts) = jax.lax.scan(
        step, init, keys, unroll=horizon
    )
    new_cache = dict(
        cache,
        k=kf.reshape(nl, nb, bs, hkv, dh),
        v=vf.reshape(nl, nb, bs, hkv, dh),
    )
    if qs:
        new_cache["kv_quant"] = _unflatten_kv_quant(qs, nl, nb, bs, hkv)
    info = {"expert_activation": acts, "slot_counts": counts}
    return new_cache, toks, emits, info


def paged_prefill_chunk(params, cache, tokens: jnp.ndarray, start: jnp.ndarray,
                        valid_len: jnp.ndarray, cfg, *, moe_hooks=None):
    """Chunked prefill of ONE request (``B = 1``) into its paged slot.

    ``tokens [1, C]`` is one fixed-size prompt chunk (the tail chunk is
    right-padded; padded positions never write K/V and never appear in
    the gathered kv, so valid rows are exact). ``start`` (scalar) counts
    tokens already written; ``valid_len`` (scalar ≤ C) is the chunk's
    real length. ``cache`` carries this slot's table as ``[1, MB]``.

    Long prompts stream through in O(C · S) attention per chunk via the
    online-softmax path in :func:`repro.models.layers.attention` — the
    engine never materializes a full [P, P] score matrix nor re-prefills
    earlier chunks (contrast the wave batcher's per-wave re-prefill).

    Returns ``(new_cache, logits [1,1,V], info)`` — logits of the last
    *valid* token (the request's first generated token once the final
    chunk is in); ``info["slot_counts"]`` ([L, num_slots], or [L, 0]
    outside the PMQ path) counts the chunk's per-slot expert dispatches,
    excluding right-padded positions (see :func:`paged_decode_step`).
    """
    x = L.embed_tokens(params["embed"], tokens)
    c = tokens.shape[1]
    nl, nb, bs = _paged_pool_dims(cache)
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    tables = cache["block_tables"]  # [1, MB]
    mb = tables.shape[1]
    s_log = mb * bs
    windows = layer_windows(cfg, s_log)
    layer_ids = jnp.arange(nl, dtype=jnp.int32)
    kf = cache["k"].reshape(nl, nb * bs, hkv, dh)
    vf = cache["v"].reshape(nl, nb * bs, hkv, dh)

    posf = start + jnp.arange(c, dtype=jnp.int32)  # absolute positions [C]
    pos2d = posf[None, :]
    page = tables[0, posf // bs]
    dest = jnp.where(jnp.arange(c) < valid_len, page * bs + posf % bs, nb * bs)
    length = start + valid_len
    # logical kv axis with the -1 padding sentinel beyond the filled part
    logical = jnp.arange(s_log, dtype=jnp.int32)
    kv_pos = jnp.where(logical < length, logical, -1)
    phys = tables[0, logical // bs] * bs + logical % bs  # [S_log]
    hooks = dict(moe_hooks or {})
    hooks["count_weight"] = jnp.arange(c) < valid_len  # [C] = [T] at B=1
    qs = _flatten_kv_quant(cache, nl, nb, bs, hkv)
    quantized = bool(qs)

    def body(carry, xs):
        xc, kf, vf, qs = carry
        p_l, win, l = xs
        h = L.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        k_new, v_new = L._kv_only(p_l["attn"], h, cfg, pos2d)
        if quantized:
            ksf, kzf, vsf, vzf = qs
            kc, ks, kz = quantize_kv_rows(k_new[0], KV_QUANT_BITS)
            vc, vs, vz = quantize_kv_rows(v_new[0], KV_QUANT_BITS)
            kf = kf.at[l, dest].set(kc, mode="drop")
            vf = vf.at[l, dest].set(vc, mode="drop")
            ksf = ksf.at[l, dest].set(ks, mode="drop")
            kzf = kzf.at[l, dest].set(kz, mode="drop")
            vsf = vsf.at[l, dest].set(vs, mode="drop")
            vzf = vzf.at[l, dest].set(vz, mode="drop")
            qs = (ksf, kzf, vsf, vzf)
            # dequantize the gathered rows with the SAME f32 expression as
            # the paged-attention kernels' epilogue — prefill attention
            # over shared-prefix pages sees bit-identical floats to every
            # later decode read of the same pages
            ksl, kzl, vsl, vzl = (
                jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False)[phys]
                for a in qs
            )
            kr = jax.lax.dynamic_index_in_dim(kf, l, 0, keepdims=False)[phys]
            vr = jax.lax.dynamic_index_in_dim(vf, l, 0, keepdims=False)[phys]
            k_log = dequantize_kv_rows(kr, ksl, kzl)[None]
            v_log = dequantize_kv_rows(vr, vsl, vzl)[None]
        else:
            kf = kf.at[l, dest].set(k_new[0].astype(kf.dtype), mode="drop")
            vf = vf.at[l, dest].set(v_new[0].astype(vf.dtype), mode="drop")
            k_log = jax.lax.dynamic_index_in_dim(kf, l, 0, keepdims=False)[phys][None]
            v_log = jax.lax.dynamic_index_in_dim(vf, l, 0, keepdims=False)[phys][None]
        attn_out, _ = L.attention(
            p_l["attn"], h, cfg, positions=pos2d, causal=True, window=win,
            kv_override=(k_log, v_log, kv_pos),
        )
        xc = xc + attn_out
        h2 = L.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        delta, _, counts = _ffn_delta(p_l, h2, cfg, hooks)
        xc = xc + delta
        return (xc, kf, vf, qs), counts

    (x, kf, vf, qs), slot_counts = jax.lax.scan(
        body, (x, kf, vf, qs), (params["blocks"], windows, layer_ids)
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
    logits = jnp.einsum(
        "btd,vd->btv", last.astype(jnp.float32),
        _out_embedding(params).astype(jnp.float32),
    )
    new_cache = dict(
        cache,
        k=kf.reshape(nl, nb, bs, hkv, dh),
        v=vf.reshape(nl, nb, bs, hkv, dh),
    )
    if qs:
        new_cache["kv_quant"] = _unflatten_kv_quant(qs, nl, nb, bs, hkv)
    return new_cache, logits, {"slot_counts": slot_counts}


# --------------------------------------------- python-loop (calibration)
def forward_layers_python(params, tokens, cfg, *, capture: str = "moe"):
    """Unscanned forward used by PMQ calibration / OTP training on small
    models: returns per-layer captured tensors (router stats or MoE inputs).

    Only usable when layer params are unstacked via :func:`unstack_blocks`.
    """
    raise NotImplementedError("use repro.core.calibrate helpers")


def unstack_blocks(params, cfg):
    """Split stacked block params into a list of per-layer pytrees."""
    blocks = params["blocks"]
    return [jax.tree.map(lambda a: a[i], blocks) for i in range(cfg.num_layers)]


def restack_blocks(block_list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *block_list)
