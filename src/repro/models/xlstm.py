"""xLSTM (sLSTM + mLSTM alternating blocks) — arXiv:2405.04517.

``mLSTM``: matrix memory ``C ∈ [B,H,dv,dk]`` with exponential-gate
stabilization, sequential ``lax.scan`` over time (state is O(1) in S —
this is why xlstm-350m runs the long_500k decode cell). ``sLSTM``: scalar
memory per channel with exp-gating + normalizer state, followed by a
gated FFN (proj factor 4/3). Both blocks carry a width-4 causal conv.
``d_ff = 0`` in the config: all capacity lives in the block projections.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import layers as L
from .recurrent import _causal_conv

__all__ = ["init_xlstm", "train_loss", "prefill", "decode_step"]


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ------------------------------------------------------------ mLSTM block
def _init_mlstm(rng, cfg, dt):
    d = cfg.d_model
    ip = 2 * d  # inner (up-projected) width
    h = cfg.num_heads
    ks = jax.random.split(rng, 8)
    return {
        "ln": jnp.zeros((d,), dt),
        "proj_in": L.init_linear(ks[0], d, 2 * ip, dt),  # u ‖ z-gate
        "conv": jax.random.normal(ks[1], (4, ip), dt) * 0.1,
        "wq": L.init_linear(ks[2], ip, ip, dt),
        "wk": L.init_linear(ks[3], ip, ip, dt),
        "wv": L.init_linear(ks[4], ip, ip, dt),
        "w_if": L.init_linear(ks[5], ip, 2 * h, dt),  # per-head ĩ, f̃
        "out_norm": jnp.zeros((ip,), dt),
        "proj_out": L.init_linear(ks[6], ip, d, dt),
    }


def _mlstm_scan(q, k, v, ig, fg, state=None):
    """Stabilized mLSTM recurrence.

    q/k/v ``[B,S,H,dh]``; ig/fg ``[B,S,H]``. Returns (h [B,S,H,dh], state).
    state = (C [B,H,dh,dh], n [B,H,dh], m [B,H]).
    """
    b, s, h, dh = q.shape
    q32, k32, v32 = (t.astype(jnp.float32) for t in (q, k, v))
    k32 = k32 / (dh**0.5)
    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, it, ft = inp  # [B,H,dh] / [B,H]
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c = f_p[..., None, None] * c + i_p[..., None, None] * (
            vt[..., :, None] * kt[..., None, :]
        )
        n = f_p[..., None] * n + i_p[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", c, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)
        return (c, n, m_new), num / den[..., None]

    xs = (
        jnp.moveaxis(q32, 1, 0),
        jnp.moveaxis(k32, 1, 0),
        jnp.moveaxis(v32, 1, 0),
        jnp.moveaxis(ig.astype(jnp.float32), 1, 0),
        jnp.moveaxis(fg.astype(jnp.float32), 1, 0),
    )
    if s <= 64:
        (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
        return jnp.moveaxis(hs, 0, 1).astype(q.dtype), (c, n, m)
    # chunked BPTT: storing C [B,H,dh,dh] per timestep is O(S·dh²) — for
    # train_4k that is ~67 GB/device. Checkpoint chunk boundaries only and
    # recompute the inner steps in the backward pass (chunkwise mLSTM).
    chunk = 64
    pad = (-s) % chunk
    if pad:
        xs = jax.tree.map(
            lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)), xs
        )
    nchunks = (s + pad) // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape(nchunks, chunk, *a.shape[1:]), xs
    )

    def chunk_body(carry, xc):
        return jax.lax.scan(step, carry, xc)

    (c, n, m), hs = jax.lax.scan(
        jax.checkpoint(chunk_body, prevent_cse=False), (c0, n0, m0), xs_c
    )
    hs = hs.reshape(nchunks * chunk, *hs.shape[2:])[:s]
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype), (c, n, m)


def _mlstm_block(p, x, cfg, state=None, conv_state=None):
    b, s, d = x.shape
    h = cfg.num_heads
    hnorm = L.rms_norm(x, p["ln"], cfg.norm_eps)
    uz = L.linear(p["proj_in"], hnorm)
    ip = uz.shape[-1] // 2
    u, z = uz[..., :ip], uz[..., ip:]
    cu, conv_state = _causal_conv(u, p["conv"], conv_state)
    cu = jax.nn.silu(cu)
    dh = ip // h
    q = L.linear(p["wq"], cu).reshape(b, s, h, dh)
    k = L.linear(p["wk"], cu).reshape(b, s, h, dh)
    v = L.linear(p["wv"], u).reshape(b, s, h, dh)
    gif = L.linear(p["w_if"], cu).astype(jnp.float32)
    ig, fg = gif[..., :h], jax.nn.log_sigmoid(gif[..., h:])
    hseq, state = _mlstm_scan(q, k, v, ig, fg, state)
    hseq = hseq.reshape(b, s, ip)
    hseq = L.rms_norm(hseq, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + L.linear(p["proj_out"], hseq), (state, conv_state)


# ------------------------------------------------------------ sLSTM block
def _init_slstm(rng, cfg, dt):
    d = cfg.d_model
    f = int(round(d * 4 / 3 / 64)) * 64  # gated FFN width
    ks = jax.random.split(rng, 6)
    return {
        "ln": jnp.zeros((d,), dt),
        "conv": jax.random.normal(ks[0], (4, d), dt) * 0.1,
        "w_z": L.init_linear(ks[1], d, d, dt),
        "w_o": L.init_linear(ks[2], d, d, dt),
        "w_if": L.init_linear(ks[3], d, 2 * d, dt),
        "ln2": jnp.zeros((d,), dt),
        "ffn": {
            "proj_in": L.init_linear(ks[4], d, 2 * f, dt),
            "proj_out": L.init_linear(ks[5], f, d, dt),
        },
    }


def _slstm_seq(z, o, ig, fg, state=None):
    """Scalar-memory recurrence: all [B, S, D] (f32 gates)."""
    b, s, d = z.shape
    if state is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.full((b, d), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def step(carry, inp):
        c, n, m = carry
        zt, ot, it, ft = inp
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c = f_p * c + i_p * zt
        n = f_p * n + i_p
        h = ot * c / jnp.maximum(n, 1.0)
        return (c, n, m_new), h

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (z, o, ig, fg))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1), (c, n, m)


def _slstm_block(p, x, cfg, state=None, conv_state=None):
    hnorm = L.rms_norm(x, p["ln"], cfg.norm_eps)
    cx, conv_state = _causal_conv(hnorm, p["conv"], conv_state)
    cx = jax.nn.silu(cx)
    z = jnp.tanh(L.linear(p["w_z"], hnorm))
    o = jax.nn.sigmoid(L.linear(p["w_o"], hnorm))
    gif = L.linear(p["w_if"], cx).astype(jnp.float32)
    d = x.shape[-1]
    ig, fg = gif[..., :d], jax.nn.log_sigmoid(gif[..., d:])
    hseq, state = _slstm_seq(z, o, ig, fg, state)
    x = x + hseq.astype(x.dtype)
    # gated FFN (proj factor 4/3)
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    uv = L.linear(p["ffn"]["proj_in"], h2)
    f = uv.shape[-1] // 2
    x = x + L.linear(p["ffn"]["proj_out"], jax.nn.silu(uv[..., :f]) * uv[..., f:])
    return x, (state, conv_state)


# ------------------------------------------------------------------ model
def init_xlstm(rng, cfg) -> Dict:
    dt = _dt(cfg)
    n_groups = cfg.num_layers // 2  # (mlstm, slstm) pairs
    ks = jax.random.split(rng, 3)

    def init_group(k):
        k1, k2 = jax.random.split(k)
        return {"m": _init_mlstm(k1, cfg, dt), "s": _init_slstm(k2, cfg, dt)}

    groups = jax.vmap(init_group)(jax.random.split(ks[0], n_groups))
    return {
        "embed": jax.random.normal(ks[1], (cfg.vocab_size, cfg.d_model), dt) * 0.02,
        "groups": groups,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }


def _forward(params, tokens, cfg, collect_cache=False):
    x = L.embed_tokens(params["embed"], tokens)

    def body(xc, p_g):
        xc, (mstate, mconv) = _mlstm_block(p_g["m"], xc, cfg)
        xc, (sstate, sconv) = _slstm_block(p_g["s"], xc, cfg)
        ys = (mstate, mconv, sstate, sconv) if collect_cache else None
        return xc, ys

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat == "block" else body
    x, ys = jax.lax.scan(body_fn, x, params["groups"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    cache = None
    if collect_cache:
        mstate, mconv, sstate, sconv = ys
        cache = {"m": mstate, "mconv": mconv, "s": sstate, "sconv": sconv}
    return x, cache


def train_loss(params, batch, cfg, **_):
    hidden, _ = _forward(params, batch["tokens"], cfg)
    nll = L.chunked_xent(hidden, params["embed"], batch["labels"], cfg.logits_chunk)
    return nll, {"nll": nll}


def prefill(params, batch, cfg, **_):
    hidden, cache = _forward(params, batch["tokens"], cfg, collect_cache=True)
    logits = jnp.einsum(
        "btd,vd->btv", hidden[:, -1:].astype(jnp.float32),
        params["embed"].astype(jnp.float32),
    )
    cache["pos"] = jnp.int32(batch["tokens"].shape[1])
    return cache, logits


def decode_step(params, cache, token, pos, cfg, **_):
    x = L.embed_tokens(params["embed"], token)

    def body(xc, xs):
        p_g, mc, mn, mm, mconv, sc, sn, sm, sconv = xs
        xc, ((mc, mn, mm), mconv) = _mlstm_block(
            p_g["m"], xc, cfg, state=(mc, mn, mm), conv_state=mconv
        )
        xc, ((sc, sn, sm), sconv) = _slstm_block(
            p_g["s"], xc, cfg, state=(sc, sn, sm), conv_state=sconv
        )
        return xc, (mc, mn, mm, mconv, sc, sn, sm, sconv)

    mc, mn, mm = cache["m"]
    sc, sn, sm = cache["s"]
    x, ys = jax.lax.scan(
        body, x,
        (params["groups"], mc, mn, mm, cache["mconv"], sc, sn, sm, cache["sconv"]),
    )
    mc, mn, mm, mconv, sc, sn, sm, sconv = ys
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "btd,vd->btv", x.astype(jnp.float32), params["embed"].astype(jnp.float32)
    )
    new_cache = {
        "m": (mc, mn, mm), "mconv": mconv, "s": (sc, sn, sm), "sconv": sconv,
        "pos": pos + 1,
    }
    return new_cache, logits
