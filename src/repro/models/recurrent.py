"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention.

Block pattern ``(rglru, rglru, attn)`` (1 attention per 2 recurrent,
per the assignment). The RG-LRU linear recurrence
``h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)`` is evaluated with
``jax.lax.associative_scan`` (O(log S) depth — the TPU-idiomatic choice;
DESIGN.md §5). Decode keeps O(1) state: RNN hidden + a width-4 causal
conv tail; attention blocks use the standard KV cache with a 2048 local
window.

Layer stacking: the 26 layers = 8 × (R,R,A) scanned groups + 2 trailing R
blocks unrolled (mixed param structures can't share one scan).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import layers as L

__all__ = ["init_recurrent", "train_loss", "prefill", "decode_step"]

CONV_W = 4
RGLRU_C = 8.0


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------- RG block
def _init_rg_block(rng, cfg, dt):
    d, w = cfg.d_model, cfg.rglru_width
    ks = jax.random.split(rng, 7)
    return {
        "ln1": jnp.zeros((d,), dt),
        "gate_in": L.init_linear(ks[0], d, w, dt),  # gelu branch
        "proj_in": L.init_linear(ks[1], d, w, dt),  # recurrence branch
        "conv": jax.random.normal(ks[2], (CONV_W, w), dt) * 0.1,
        "wa": L.init_linear(ks[3], w, w, dt),  # recurrence gate r_t
        "wx": L.init_linear(ks[4], w, w, dt),  # input gate i_t
        "lam": jnp.full((w,), 2.0, jnp.float32),  # Λ: a = sigmoid(Λ)
        "proj_out": L.init_linear(ks[5], w, d, dt),
        "ln2": jnp.zeros((d,), dt),
        "mlp": L.init_mlp(ks[6], d, cfg.d_ff, dt),
    }


def _causal_conv(x, kernel, state=None):
    """Depthwise causal conv, width 4. ``x [B,S,W]``, ``kernel [4,W]``.

    With ``state [B,3,W]`` (decode), prepends it instead of zero padding.
    Returns (y, new_state).
    """
    b, s, w = x.shape
    if state is None:
        pad = jnp.zeros((b, CONV_W - 1, w), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+3, W]
    y = sum(
        xp[:, i : i + s, :] * kernel[i][None, None, :] for i in range(CONV_W)
    )
    new_state = xp[:, -(CONV_W - 1) :, :]
    return y, new_state


def _rglru(p, u, h0=None):
    """RG-LRU over ``u [B,S,W]``; returns (y, h_last)."""
    r = jax.nn.sigmoid(L.linear(p["wa"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(L.linear(p["wx"], u).astype(jnp.float32))
    log_a_base = jax.nn.log_sigmoid(p["lam"])[None, None, :]  # [1,1,W]
    log_a = RGLRU_C * r * log_a_base  # per-step log decay (≤ 0)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * u.astype(jnp.float32))
    if h0 is not None:
        gated = gated.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(comb, (a, gated), axis=1)
    return h.astype(u.dtype), h[:, -1, :]


def _rg_block(p, x, cfg, conv_state=None, h0=None):
    """Returns (x, (conv_state, h_last))."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(L.linear(p["gate_in"], h))
    u = L.linear(p["proj_in"], h)
    u, conv_state = _causal_conv(u, p["conv"], conv_state)
    y, h_last = _rglru(p, u, h0)
    x = x + L.linear(p["proj_out"], y * gate)
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h2)
    return x, (conv_state, h_last)


# ------------------------------------------------------------- attn block
def _init_attn_block(rng, cfg, dt):
    ka, km = jax.random.split(rng)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "attn": L.init_attention(ka, cfg, dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, dt),
    }


def _attn_block(p, x, cfg, positions):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, kv = L.attention(
        p["attn"], h, cfg, positions=positions, causal=True,
        window=cfg.local_window,
    )
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + L.mlp(p["mlp"], h), kv


# ---------------------------------------------------------------- model
def _layout(cfg):
    """(num_groups, trailing_rg): 26 = 8×(R,R,A) + 2×R."""
    group = len(cfg.block_pattern)  # 3
    n_groups = cfg.num_layers // group
    trailing = cfg.num_layers - n_groups * group
    return n_groups, trailing


def init_recurrent(rng, cfg) -> Dict:
    dt = _dt(cfg)
    n_groups, trailing = _layout(cfg)
    ks = jax.random.split(rng, 4)

    def init_group(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "rg1": _init_rg_block(k1, cfg, dt),
            "rg2": _init_rg_block(k2, cfg, dt),
            "attn": _init_attn_block(k3, cfg, dt),
        }

    groups = jax.vmap(init_group)(jax.random.split(ks[0], n_groups))
    tail = [
        _init_rg_block(k, cfg, dt)
        for k in jax.random.split(ks[1], trailing)
    ]
    return {
        "embed": jax.random.normal(ks[2], (cfg.vocab_size, cfg.d_model), dt) * 0.02,
        "groups": groups,
        "tail": tail,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }


def _forward(params, tokens, cfg, collect_cache=False):
    x = L.embed_tokens(params["embed"], tokens)
    b, s, d = x.shape
    pos = jnp.arange(s, dtype=jnp.int32)

    def body(xc, p_g):
        xc, st1 = _rg_block(p_g["rg1"], xc, cfg)
        xc, st2 = _rg_block(p_g["rg2"], xc, cfg)
        xc, kv = _attn_block(p_g["attn"], xc, cfg, pos)
        ys = (st1, st2, kv) if collect_cache else None
        return xc, ys

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat == "block" else body
    x, ys = jax.lax.scan(body_fn, x, params["groups"])
    tail_states = []
    for p_rg in params["tail"]:
        x, st = _rg_block(p_rg, x, cfg)
        tail_states.append(st)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    cache = None
    if collect_cache:
        st1, st2, kv = ys
        w = cfg.rglru_width
        if tail_states:
            tail_conv = jnp.stack([t[0] for t in tail_states])
            tail_h = jnp.stack([t[1] for t in tail_states])
        else:
            tail_conv = jnp.zeros((0, b, CONV_W - 1, w), x.dtype)
            tail_h = jnp.zeros((0, b, w), jnp.float32)
        cache = {
            "conv1": st1[0], "h1": st1[1],  # [G, B, 3, W], [G, B, W]
            "conv2": st2[0], "h2": st2[1],
            "k": kv[0], "v": kv[1],  # [G, B, S, Hkv, dh]
            "tail_conv": tail_conv,
            "tail_h": tail_h,
        }
    return x, cache


def train_loss(params, batch, cfg, **_):
    hidden, _ = _forward(params, batch["tokens"], cfg)
    nll = L.chunked_xent(hidden, params["embed"], batch["labels"], cfg.logits_chunk)
    return nll, {"nll": nll}


def prefill(params, batch, cfg, **_):
    hidden, cache = _forward(params, batch["tokens"], cfg, collect_cache=True)
    logits = jnp.einsum(
        "btd,vd->btv", hidden[:, -1:].astype(jnp.float32),
        params["embed"].astype(jnp.float32),
    )
    cache["pos"] = jnp.int32(batch["tokens"].shape[1])
    return cache, logits


def _rg_decode(p, x, cfg, conv_state, h_prev):
    """Single-token recurrent step. ``x [B,1,D]``."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(L.linear(p["gate_in"], h))
    u = L.linear(p["proj_in"], h)
    u, conv_state = _causal_conv(u, p["conv"], conv_state)
    r = jax.nn.sigmoid(L.linear(p["wa"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(L.linear(p["wx"], u).astype(jnp.float32))
    log_a = RGLRU_C * r * jax.nn.log_sigmoid(p["lam"])[None, None, :]
    a = jnp.exp(log_a)[:, 0]
    gated = (jnp.sqrt(jnp.maximum(1 - a * a, 1e-9)))
    h_new = a * h_prev.astype(jnp.float32) + gated * (
        i[:, 0] * u[:, 0].astype(jnp.float32)
    )
    y = h_new[:, None, :].astype(x.dtype)
    x = x + L.linear(p["proj_out"], y * gate)
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h2)
    return x, (conv_state, h_new)


def decode_step(params, cache, token, pos, cfg, **_):
    x = L.embed_tokens(params["embed"], token)

    def body(xc, xs):
        p_g, c1, h1, c2, h2, k_l, v_l = xs
        xc, (c1, h1) = _rg_decode(p_g["rg1"], xc, cfg, c1, h1)
        xc, (c2, h2) = _rg_decode(p_g["rg2"], xc, cfg, c2, h2)
        h = L.rms_norm(xc, p_g["attn"]["ln1"], cfg.norm_eps)
        a, (k_l, v_l) = L.decode_attention(
            p_g["attn"]["attn"], h, cfg, k_cache=k_l, v_cache=v_l, pos=pos,
            window=cfg.local_window,
        )
        xc = xc + a
        h = L.rms_norm(xc, p_g["attn"]["ln2"], cfg.norm_eps)
        xc = xc + L.mlp(p_g["attn"]["mlp"], h)
        return xc, (c1, h1, c2, h2, k_l, v_l)

    x, ys = jax.lax.scan(
        body, x,
        (params["groups"], cache["conv1"], cache["h1"], cache["conv2"],
         cache["h2"], cache["k"], cache["v"]),
    )
    c1, h1, c2, h2, ks, vs = ys
    tail_conv, tail_h = [], []
    for i, p_rg in enumerate(params["tail"]):
        x, (c, hh) = _rg_decode(
            p_rg, x, cfg, cache["tail_conv"][i], cache["tail_h"][i]
        )
        tail_conv.append(c)
        tail_h.append(hh)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "btd,vd->btv", x.astype(jnp.float32), params["embed"].astype(jnp.float32)
    )
    new_cache = {
        "conv1": c1, "h1": h1, "conv2": c2, "h2": h2, "k": ks, "v": vs,
        "tail_conv": jnp.stack(tail_conv) if tail_conv else cache["tail_conv"],
        "tail_h": jnp.stack(tail_h) if tail_h else cache["tail_h"],
        "pos": pos + 1,
    }
    return new_cache, logits
