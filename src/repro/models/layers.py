"""Shared neural-net layers (pure-functional JAX; params are pytrees).

Conventions
-----------
* weight matrices are ``[K_in, N_out]`` (``y = x @ W``) so the PMQ packed
  kernels substitute 1:1 (a leaf may be a ``PackedTensor``);
* activations ``[B, S, D]``; attention heads ``[B, S, H, dh]``;
* long-context attention uses a q-chunk × kv-chunk online-softmax sweep
  (flash-style) so prefill_32k / long_500k never materialize [S, S];
* every init takes an explicit PRNG key; dtype from the config.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.packing import PackedTensor
from ..kernels import ops

__all__ = [
    "linear",
    "init_linear",
    "rms_norm",
    "apply_rope",
    "init_attention",
    "attention",
    "decode_attention",
    "init_mlp",
    "mlp",
    "chunked_xent",
    "sinusoidal_positions",
]

NEG_INF = -1e30


# ----------------------------------------------------------------- basics
def init_linear(rng, k: int, n: int, dtype=jnp.float32, scale: float = None):
    scale = scale if scale is not None else (1.0 / (k**0.5))
    return {"w": jax.random.normal(rng, (k, n), dtype) * scale}


def linear(p, x: jnp.ndarray) -> jnp.ndarray:
    w = p["w"]
    if isinstance(w, PackedTensor):
        return ops.quant_matmul(x, w)
    return x @ w.astype(x.dtype)


def embed_tokens(embed: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Vocab-sharded embedding lookup.

    GSPMD lowers ``jnp.take`` over a vocab-sharded table by all-gathering
    it (measured: 7.8 GiB/device f32 at command-r scale). Inside a mesh
    context this uses a shard_map masked-local-take + psum over ``model``
    instead (one [B,S,D] bf16 psum — the canonical Megatron embedding).
    """
    from ..parallel.sharding import batch_axes, current_mesh, manual_region

    mesh = current_mesh()
    if (
        mesh is None
        or "model" not in mesh.axis_names
        or embed.shape[0] % mesh.shape["model"] != 0
        or tokens.ndim != 2
    ):
        return jnp.take(embed, tokens, axis=0)
    ba = batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in ba])) if True else 1
    if tokens.shape[0] % bsz:
        return jnp.take(embed, tokens, axis=0)

    def body(emb_l, tok):
        with manual_region():
            vloc = emb_l.shape[0]
            lo = jax.lax.axis_index("model") * vloc
            rel = tok - lo
            ok = (rel >= 0) & (rel < vloc)
            x = jnp.take(emb_l, jnp.clip(rel, 0, vloc - 1), axis=0)
            x = x * ok[..., None].astype(emb_l.dtype)
            return jax.lax.psum(x, "model")

    from jax.sharding import PartitionSpec as P
    from ..parallel.sharding import shard_map

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P("model", None), P(ba, None)),
        out_specs=P(ba, None, None),
        check_vma=False,
    )(embed, tokens)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    # statistics in f32 (tiny, per-token); the normalize multiply stays in
    # the activation dtype so no [B,S,D]-sized f32 exists in fwd or bwd
    var = jnp.mean(
        x.astype(jnp.float32) * x.astype(jnp.float32), axis=-1, keepdims=True
    )
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + w.astype(x.dtype))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. ``x [B, S, H, dh]``, ``positions [S] or [B, S]``."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freq[None, :]  # [S, half]
        ang = ang[None, :, None, :]  # [1, S, 1, half]
    else:
        ang = positions[..., None].astype(jnp.float32) * freq  # [B, S, half]
        ang = ang[:, :, None, :]
    # angles in f32 (position-only, tiny); the rotation multiply stays in
    # the activation dtype — a full [B,S,H,dh] f32 copy here costs ~2 GiB
    # per layer in the backward pass at 35B scale
    sin = jnp.sin(ang).astype(x.dtype)
    cos = jnp.cos(ang).astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sinusoidal_positions(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Absolute sinusoidal embeddings (whisper)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -------------------------------------------------------------- attention
def init_attention(rng, cfg, dtype=jnp.float32):
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": init_linear(ks[0], d, hq * dh, dtype),
        "wk": init_linear(ks[1], d, hkv * dh, dtype),
        "wv": init_linear(ks[2], d, hkv * dh, dtype),
        "wo": init_linear(ks[3], hq * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), dtype)
        p["k_norm"] = jnp.zeros((dh,), dtype)
    return p


def _qkv(p, x, cfg, positions, rope: bool = True):
    b, s, _ = x.shape
    hq, dh = cfg.num_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(b, s, hq, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
    k, v = _kv_only(p, x, cfg, positions, rope=rope)
    return q, k, v


def _kv_only(p, x, cfg, positions, rope: bool = True):
    """K/V projection without the query — paged prefill writes K/V
    itself and lets :func:`attention`'s cross-attention path own q."""
    b, s, _ = x.shape
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    k = linear(p["wk"], x).reshape(b, s, hkv, dh)
    v = linear(p["wv"], x).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def _mask_chunk(q_pos, kv_pos, causal: bool, window):
    """[qc, kc] boolean validity from absolute positions.

    ``window`` may be a *traced* scalar (mixed local/global scans pass the
    per-layer effective window; full-attention layers pass S+1) or None.
    """
    qp = q_pos[:, None]
    kp = kv_pos[None, :]
    m = kp >= 0  # padding sentinel
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    return m


def _online_attn(q, k, v, q_pos, kv_pos, *, causal, window, kv_chunk):
    """One q-chunk, scan over kv chunks with online softmax.

    q [B, qc, Hkv, G, dh]; k/v [B, Skv, Hkv, dh]. Returns [B, qc, Hkv, G, dh].
    """
    b, qc, hkv, g, dh = q.shape
    skv = k.shape[1]
    nkv = skv // kv_chunk
    scale = dh**-0.5
    q32 = q.astype(jnp.float32) * scale

    kc3 = k.reshape(b, nkv, kv_chunk, hkv, dh)
    vc3 = v.reshape(b, nkv, kv_chunk, hkv, dh)
    kvp = kv_pos.reshape(nkv, kv_chunk)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, kp = inp
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", q32, kc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        mask = _mask_chunk(q_pos, kp, causal, window)  # [qc, kc]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, qc, dh), jnp.float32)
    # flash-style backward: never keep [qc, kc] score/probability tiles as
    # scan residuals — recompute them per kv-chunk in the backward pass
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        (m0, l0, a0),
        (jnp.moveaxis(kc3, 1, 0), jnp.moveaxis(vc3, 1, 0), kvp),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.einsum("bhgqd->bqhgd", out)


def attention(
    p,
    x: jnp.ndarray,
    cfg,
    *,
    positions: jnp.ndarray,
    causal: bool = True,
    window=None,
    rope: bool = True,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence attention (train / prefill / encoder / cross).

    Returns ``(out [B,S,D], (k, v))`` — k/v are returned for cache builds.
    ``kv_override = (k, v, kv_pos)`` turns this into cross-attention.
    """
    b, s, d = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = hq // hkv
    if kv_override is not None:
        # cross-attention: only the query comes from x
        q = linear(p["wq"], x).reshape(b, s, hq, dh)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if rope:
            q = apply_rope(q, positions, cfg.rope_theta)
        k, v, kv_pos = kv_override
    else:
        q, k, v = _qkv(p, x, cfg, positions, rope=rope)
        kv_pos = positions if positions.ndim == 1 else positions[0]
    qc = min(cfg.attn_q_chunk, s)
    kvc = min(cfg.attn_kv_chunk, k.shape[1])
    # pad q/kv to chunk multiples; padded kv positions get the -1 sentinel
    # (masked), padded query rows are sliced off below
    q_pos_1d = positions if positions.ndim == 1 else positions[0]
    s_pad = (-s) % qc
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        q_pos_1d_q = jnp.concatenate(
            [q_pos_1d, jnp.full((s_pad,), -1, q_pos_1d.dtype)]
        )
    else:
        q_pos_1d_q = q_pos_1d
    kv_pad = (-k.shape[1]) % kvc
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        kv_pos = jnp.concatenate([kv_pos, jnp.full((kv_pad,), -1, kv_pos.dtype)])
    sq = s + s_pad
    nq = sq // qc
    q5 = q.reshape(b, nq, qc, hkv, g, dh)
    qp = q_pos_1d_q.reshape(nq, qc)

    def one(args):
        qch, qpch = args
        o = _online_attn(
            qch, k, v, qpch, kv_pos, causal=causal, window=window, kv_chunk=kvc
        )
        return o.astype(x.dtype)  # never stack f32 [B,S,H,dh] across chunks

    one = jax.checkpoint(one, prevent_cse=False)
    out = jax.lax.map(one, (jnp.moveaxis(q5, 1, 0), qp))  # [nq, B, qc, hkv, g, dh]
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hq * dh)[:, :s]
    kv_s = k.shape[1] - kv_pad
    return linear(p["wo"], out.astype(x.dtype)), (k[:, :kv_s], v[:, :kv_s])


def decode_attention(
    p,
    x: jnp.ndarray,
    cfg,
    *,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,
    window=None,
    rope: bool = True,
    cross: bool = False,
    kv_len: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Single-step decode: ``x [B, 1, D]`` against cache ``[B, S, Hkv, dh]``.

    Self-attention writes the new k/v at ``pos`` (scalar int32) before
    attending. Cross-attention (``cross=True``) reads the cache only.
    Returns ``(out [B,1,D], (k_cache, v_cache))``.
    """
    b, _, d = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = hq // hkv
    s = k_cache.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(p, x, cfg, positions, rope=rope and not cross)
    if not cross:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), pos, axis=1
        )
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), pos, axis=1
        )
        limit = pos
    else:
        limit = (kv_len - 1) if kv_len is not None else s - 1
    q32 = q.reshape(b, hkv, g, dh).astype(jnp.float32) * dh**-0.5
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", q32, k_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    kv_pos = jnp.arange(s)
    valid = kv_pos <= limit
    if window is not None and not cross:
        valid &= kv_pos > pos - window
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", w, v_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out = out.reshape(b, 1, hq * dh).astype(x.dtype)
    return linear(p["wo"], out), (k_cache, v_cache)


# -------------------------------------------------------------------- MLP
def init_mlp(rng, d: int, f: int, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": init_linear(ks[0], d, f, dtype),
        "w_up": init_linear(ks[1], d, f, dtype),
        "w_down": init_linear(ks[2], f, d, dtype),
    }


def mlp(p, x: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU feed-forward."""
    return linear(p["w_down"], jax.nn.silu(linear(p["w_gate"], x)) * linear(p["w_up"], x))


# ------------------------------------------------------------------- loss
def _divisor_chunk(s: int, chunk: int) -> int:
    """Largest divisor of ``s`` that is ≤ chunk (streaming chunk size)."""
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    return chunk


def chunked_xent(
    hidden: jnp.ndarray,
    emb: jnp.ndarray,
    labels: jnp.ndarray,
    chunk: int = 512,
) -> jnp.ndarray:
    """Streaming softmax cross-entropy: never materializes [B, S, V].

    ``hidden [B, S, D]``, ``emb [V, D]`` (output projection = embᵀ),
    ``labels [B, S]`` int32 (−1 = ignore). Returns mean NLL over valid.
    """
    b, s, d = hidden.shape
    chunk = _divisor_chunk(s, chunk)
    n = s // chunk
    hc = jnp.moveaxis(hidden.reshape(b, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)
    w = emb  # keep vocab-sharded bf16; a global f32 cast all-gathers it

    def body(carry, inp):
        tot, cnt = carry
        h, y = inp
        logits = jnp.einsum(
            "bcd,vd->bcv", h.astype(w.dtype), w,
            preferred_element_type=jnp.float32,
        )
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(y, 0)[..., None], axis=-1
        )[..., 0]
        valid = (y >= 0).astype(jnp.float32)
        nll = (lse - picked) * valid
        return (tot + nll.sum(), cnt + valid.sum()), None

    # recompute the [B, c, V] logits chunk in the backward pass
    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        (jnp.float32(0), jnp.float32(0)),
        (hc, lc),
    )
    return tot / jnp.maximum(cnt, 1.0)


def chunked_kl(
    hidden_s: jnp.ndarray,
    hidden_t: jnp.ndarray,
    emb: jnp.ndarray,
    chunk: int = 512,
) -> jnp.ndarray:
    """Streaming KL(teacher ‖ student) over vocab, never materializing
    [B, S, V] (OTP distillation loss, Eq. 14 first term)."""
    b, s, d = hidden_s.shape
    chunk = _divisor_chunk(s, chunk)
    n = s // chunk
    hs = jnp.moveaxis(hidden_s.reshape(b, n, chunk, d), 1, 0)
    ht = jnp.moveaxis(hidden_t.reshape(b, n, chunk, d), 1, 0)
    w = emb  # keep vocab-sharded bf16 (see chunked_xent)

    def body(tot, inp):
        a, t = inp
        ls = jax.nn.log_softmax(
            jnp.einsum("bcd,vd->bcv", a.astype(w.dtype), w,
                       preferred_element_type=jnp.float32), axis=-1
        )
        lt = jax.nn.log_softmax(
            jnp.einsum("bcd,vd->bcv", t.astype(w.dtype), w,
                       preferred_element_type=jnp.float32), axis=-1
        )
        kl = jnp.sum(jnp.exp(lt) * (lt - ls), axis=-1)
        return tot + kl.sum(), None

    tot, _ = jax.lax.scan(body, jnp.float32(0), (hs, ht))
    return tot / (b * s)
