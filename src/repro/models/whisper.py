"""Whisper-style encoder–decoder backbone (audio frontend stubbed).

Per the assignment, ``input_specs()`` provides precomputed frame
embeddings ``[B, 1500, D]`` (the conv frontend is a stub). Encoder:
bidirectional attention + sinusoidal positions. Decoder blocks: causal
self-attention (cached) + cross-attention to the encoder output (cross
K/V cached at prefill) + SwiGLU MLP. Sinusoidal absolute positions keep
the synthetic 32k stress shapes well-defined (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import layers as L

__all__ = ["init_whisper", "train_loss", "prefill", "decode_step", "encode"]


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _init_enc_block(rng, cfg, dt):
    ka, km = jax.random.split(rng)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "attn": L.init_attention(ka, cfg, dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, dt),
    }


def _init_dec_block(rng, cfg, dt):
    ka, kc, km = jax.random.split(rng, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "attn": L.init_attention(ka, cfg, dt),
        "lnx": jnp.zeros((cfg.d_model,), dt),
        "cross": L.init_attention(kc, cfg, dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, dt),
    }


def init_whisper(rng, cfg) -> Dict:
    dt = _dt(cfg)
    ke, kd, kt = jax.random.split(rng, 3)
    enc = jax.vmap(lambda k: _init_enc_block(k, cfg, dt))(
        jax.random.split(ke, cfg.encoder_layers)
    )
    dec = jax.vmap(lambda k: _init_dec_block(k, cfg, dt))(
        jax.random.split(kd, cfg.num_layers)
    )
    return {
        "embed": jax.random.normal(kt, (cfg.vocab_size, cfg.d_model), dt) * 0.02,
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_norm": jnp.zeros((cfg.d_model,), dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }


def encode(params, frames: jnp.ndarray, cfg) -> jnp.ndarray:
    """``frames [B, S_enc, D]`` (stub embeddings) → encoder states."""
    b, s, d = frames.shape
    pos = jnp.arange(s, dtype=jnp.int32)
    x = frames + L.sinusoidal_positions(pos, d)[None].astype(frames.dtype)

    def body(xc, p_l):
        h = L.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        a, _ = L.attention(
            p_l["attn"], h, cfg, positions=pos, causal=False, rope=False
        )
        xc = xc + a
        h = L.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        return xc + L.mlp(p_l["mlp"], h), None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat == "block" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(p_cross, enc_out, cfg):
    """Precompute cross-attention K/V from encoder states."""
    b, s, _ = enc_out.shape
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    k = L.linear(p_cross["wk"], enc_out).reshape(b, s, hkv, dh)
    v = L.linear(p_cross["wv"], enc_out).reshape(b, s, hkv, dh)
    return k, v


def _dec_stack(params, x, cfg, enc_out, positions, collect_cache=False):
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(carry, p_l):
        xc = carry
        h = L.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        a, kv = L.attention(
            p_l["attn"], h, cfg, positions=positions, causal=True, rope=False
        )
        xc = xc + a
        h = L.rms_norm(xc, p_l["lnx"], cfg.norm_eps)
        ck, cv = _cross_kv(p_l["cross"], enc_out, cfg)
        q_only = dict(p_l["cross"])  # reuse wq/wo; kv overridden
        a, _ = L.attention(
            q_only, h, cfg, positions=positions, causal=False, rope=False,
            kv_override=(ck, cv, enc_pos),
        )
        xc = xc + a
        h = L.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        xc = xc + L.mlp(p_l["mlp"], h)
        ys = (kv[0], kv[1], ck, cv) if collect_cache else None
        return xc, ys

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat == "block" else body
    x, ys = jax.lax.scan(body_fn, x, params["dec_blocks"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), ys


def train_loss(params, batch, cfg, **_):
    frames, tokens, labels = batch["frames"], batch["tokens"], batch["labels"]
    enc_out = encode(params, frames, cfg)
    s = tokens.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)
    x = L.embed_tokens(params["embed"], tokens)
    x = x + L.sinusoidal_positions(pos, cfg.d_model)[None].astype(x.dtype)
    hidden, _ = _dec_stack(params, x, cfg, enc_out, pos)
    nll = L.chunked_xent(hidden, params["embed"], labels, cfg.logits_chunk)
    return nll, {"nll": nll}


def prefill(params, batch, cfg, **_):
    frames, tokens = batch["frames"], batch["tokens"]
    enc_out = encode(params, frames, cfg)
    s = tokens.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)
    x = L.embed_tokens(params["embed"], tokens)
    x = x + L.sinusoidal_positions(pos, cfg.d_model)[None].astype(x.dtype)
    hidden, ys = _dec_stack(params, x, cfg, enc_out, pos, collect_cache=True)
    k, v, ck, cv = ys
    logits = jnp.einsum(
        "btd,vd->btv", hidden[:, -1:].astype(jnp.float32),
        params["embed"].astype(jnp.float32),
    )
    cache = {"k": k, "v": v, "cross_k": ck, "cross_v": cv, "pos": jnp.int32(s)}
    return cache, logits


def decode_step(params, cache, token, pos, cfg, **_):
    x = L.embed_tokens(params["embed"], token)
    x = x + L.sinusoidal_positions(pos[None], cfg.d_model)[None].astype(x.dtype)

    def body(xc, xs):
        p_l, k_l, v_l, ck_l, cv_l = xs
        h = L.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        a, (k_l, v_l) = L.decode_attention(
            p_l["attn"], h, cfg, k_cache=k_l, v_cache=v_l, pos=pos, rope=False
        )
        xc = xc + a
        h = L.rms_norm(xc, p_l["lnx"], cfg.norm_eps)
        a, _ = L.decode_attention(
            p_l["cross"], h, cfg, k_cache=ck_l, v_cache=cv_l, pos=pos, cross=True,
            rope=False,
        )
        xc = xc + a
        h = L.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        xc = xc + L.mlp(p_l["mlp"], h)
        return xc, (k_l, v_l)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec_blocks"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "btd,vd->btv", x.astype(jnp.float32), params["embed"].astype(jnp.float32)
    )
    new_cache = dict(cache, k=ks, v=vs, pos=pos + 1)
    return new_cache, logits
