"""Uniform model API over all assigned architecture families.

``get_model(cfg)`` returns a :class:`ModelBundle` whose members close over
the config:

* ``init(rng) -> params``
* ``train_loss(params, batch) -> (loss, metrics)``
* ``prefill(params, batch) -> (cache, logits)``
* ``decode_step(params, cache, token, pos) -> (cache, logits)``
* ``input_specs(shape) -> (step_name, kwargs of ShapeDtypeStruct)`` — the
  dry-run stand-ins (no allocation), incl. cache specs via ``eval_shape``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import recurrent, transformer, whisper, xlstm

__all__ = ["ModelBundle", "get_model"]


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable

    def param_shapes(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ------------------------------------------------------------- specs
    def batch_specs(self, shape: ShapeConfig, kind: str) -> Dict[str, Any]:
        cfg = self.cfg
        b = shape.global_batch
        s = shape.seq_len
        act_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        tok = lambda bb, ss: jax.ShapeDtypeStruct((bb, ss), jnp.int32)
        batch: Dict[str, Any] = {}
        if cfg.family == "vlm":
            p = cfg.num_patch_tokens
            s_text = max(s - p, 1)
            batch["tokens"] = tok(b, s_text)
            batch["patch_embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model), act_dt)
            if kind == "train":
                batch["labels"] = tok(b, s_text)
        elif cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), act_dt
            )
            batch["tokens"] = tok(b, s)
            if kind == "train":
                batch["labels"] = tok(b, s)
        else:
            batch["tokens"] = tok(b, s)
            if kind == "train":
                batch["labels"] = tok(b, s)
        return batch

    def input_specs(self, shape: ShapeConfig) -> Tuple[str, Dict[str, Any]]:
        """(step_name, kwargs-of-specs) for the dry-run."""
        if shape.kind == "train":
            return "train", {"batch": self.batch_specs(shape, "train")}
        if shape.kind == "prefill":
            return "prefill", {"batch": self.batch_specs(shape, "prefill")}
        # decode: cache spec from eval_shape of prefill at seq_len
        params = self.param_shapes()
        batch = self.batch_specs(shape, "prefill")
        cache, _ = jax.eval_shape(self.prefill, params, batch)
        token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return "decode", {"cache": cache, "token": token, "pos": pos}


def get_model(cfg: ModelConfig) -> ModelBundle:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return ModelBundle(
            cfg=cfg,
            init=partial(transformer.init_lm, cfg=cfg),
            train_loss=partial(transformer.train_loss, cfg=cfg),
            prefill=partial(transformer.prefill, cfg=cfg),
            decode_step=partial(transformer.decode_step, cfg=cfg),
        )
    if fam == "encdec":
        return ModelBundle(
            cfg=cfg,
            init=partial(whisper.init_whisper, cfg=cfg),
            train_loss=partial(whisper.train_loss, cfg=cfg),
            prefill=partial(whisper.prefill, cfg=cfg),
            decode_step=partial(whisper.decode_step, cfg=cfg),
        )
    if fam == "hybrid":
        return ModelBundle(
            cfg=cfg,
            init=partial(recurrent.init_recurrent, cfg=cfg),
            train_loss=partial(recurrent.train_loss, cfg=cfg),
            prefill=partial(recurrent.prefill, cfg=cfg),
            decode_step=partial(recurrent.decode_step, cfg=cfg),
        )
    if fam == "ssm":
        return ModelBundle(
            cfg=cfg,
            init=partial(xlstm.init_xlstm, cfg=cfg),
            train_loss=partial(xlstm.train_loss, cfg=cfg),
            prefill=partial(xlstm.prefill, cfg=cfg),
            decode_step=partial(xlstm.decode_step, cfg=cfg),
        )
    raise ValueError(f"unknown family {fam}")
