"""Checkpointing: async, atomic, keep-last-k, reshard-on-restore.

Design (single-host numpy backend with the same interface a multi-host
tensorstore deployment would use):

* ``save`` snapshots the param/opt pytree to host memory synchronously
  (cheap), then a writer thread serializes to ``step_XXXX.tmp`` and
  atomically renames — training never blocks on disk.
* a ``manifest.json`` is written last; a checkpoint without a manifest is
  invisible to ``latest_step`` (crash-safe).
* ``restore`` rebuilds arrays and ``device_put``s them with *target*
  shardings — restoring onto a different mesh (elastic re-scale) is the
  same code path.
* ``keep`` bounds disk usage (keep-last-k).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._async = async_write
        self._errors: list = []
        if async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------- write
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        named, _ = _flatten_with_paths(tree)
        snapshot = {k: np.asarray(v) for k, v in named.items()}
        if self._async and not blocking:
            self._q.put((step, snapshot))
        else:
            self._write(step, snapshot)

    def wait(self) -> None:
        """Block until all queued writes land (and surface errors)."""
        if self._async:
            self._q.join()
        if self._errors:
            raise RuntimeError(f"checkpoint writer failed: {self._errors}")

    def _worker(self):
        while True:
            step, snapshot = self._q.get()
            try:
                self._write(step, snapshot)
            except Exception as e:  # pragma: no cover
                self._errors.append(repr(e))
            finally:
                self._q.task_done()

    def _write(self, step: int, snapshot: Dict[str, np.ndarray]):
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **snapshot)
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(snapshot.keys()),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -------------------------------------------------------------- read
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Rebuild the pytree of ``like`` (shape/dtype template).

        ``shardings`` (matching pytree of NamedSharding) re-places arrays —
        a *different* mesh than at save time is fine (elastic restore).
        """
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        data = np.load(path)
        named, treedef = _flatten_with_paths(like)
        if shardings is not None:
            shard_named, _ = _flatten_with_paths(shardings)
        leaves = []
        for key in named:
            arr = data[key]
            if shardings is not None and key in shard_named:
                leaves.append(jax.device_put(arr, shard_named[key]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
