"""Sharding rules: logical activation names + path-based param specs.

DP over (pod, data), TP/EP over model, SP for long-context KV
(DESIGN.md §7). Models call :func:`shard` with a logical name; inside a
:func:`sharding_rules` context this becomes ``with_sharding_constraint``
(skipped when a dim doesn't divide — GSPMD then decides), outside it is
identity so smoke tests/CPU runs are unaffected.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "shard",
    "shard_map",
    "sharding_rules",
    "batch_axes",
    "activation_rules",
    "param_spec_for_path",
    "make_param_shardings",
    "cache_pspec",
]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """Version shim: ``jax.shard_map`` (new API) or
    ``jax.experimental.shard_map.shard_map`` (jax ≤ 0.4.x, where the
    replication check is spelled ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )

_CTX: contextvars.ContextVar = contextvars.ContextVar("sharding_ctx", default=None)


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def activation_rules(mesh: Mesh, sequence_parallel: bool = True) -> Dict[str, P]:
    """Megatron-SP style: the residual stream between blocks is sharded
    over (batch → data, seq → model). The per-layer remat stash and the
    TP boundary collectives then scale 1/model (AG+RS instead of AR)."""
    ba = batch_axes(mesh)
    seq = "model" if sequence_parallel else None
    return {
        "act_btd": P(ba, seq, None),  # [B, S, D] residual stream (SP)
        "act_full": P(ba, None, None),  # gathered entry to attn/mlp regions
        "moe_ed": P("model", None),  # [E*cap, D] expert-major rows
        "moe_ecd": P("model", "data", None),  # [E, cap, D]: EP × cap-DP
        "moe_elcd": P("model", None, "data", None),  # [ep, local, cap, D]
        "moe_tke": P(ba, None),  # [T*k, E] routing one-hot/rank buffers
        "logits_btv": P(ba, None, "model"),
    }


@contextlib.contextmanager
def sharding_rules(mesh: Mesh, rules: Optional[Dict[str, P]] = None):
    token = _CTX.set({"mesh": mesh, "rules": rules or activation_rules(mesh)})
    try:
        yield
    finally:
        _CTX.reset(token)


@contextlib.contextmanager
def manual_region():
    """Inside shard_map bodies: all axes are manual → ``shard`` = identity
    (with_sharding_constraint on manual axes is an error)."""
    token = _CTX.set(None)
    try:
        yield
    finally:
        _CTX.reset(token)


def _divides(shape, spec: P, mesh: Mesh) -> bool:
    for dim, names in zip(shape, spec):
        if names is None:
            continue
        names = names if isinstance(names, tuple) else (names,)
        size = int(np.prod([mesh.shape[n] for n in names]))
        if dim % size != 0:
            return False
    return True


def model_axis_size() -> int:
    """Model-axis extent of the active sharding context (1 outside)."""
    ctx = _CTX.get()
    if ctx is None:
        return 1
    return int(ctx["mesh"].shape.get("model", 1))


def current_mesh() -> Optional[Mesh]:
    ctx = _CTX.get()
    return None if ctx is None else ctx["mesh"]


def shard(x, name: str):
    ctx = _CTX.get()
    if ctx is None:
        return x
    spec = ctx["rules"].get(name)
    if spec is None:
        return x
    mesh = ctx["mesh"]
    if len(spec) > x.ndim or not _divides(x.shape, spec, mesh):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ------------------------------------------------------------ param specs
# Path-pattern → PartitionSpec builder. Patterns match the "/"-joined tree
# path. PackedTensor leaves append child indices ("attn/wq/w/0" = packed
# data) — the `(?:/\d+)*$` tail matches them; packed rows inherit the
# logical weight's row/col parallelism (divisibility checked downstream).
_IDX = r"(?:/\d+)*$"
_PARAM_RULES = [
    # attention projections: column-parallel qkv, row-parallel o
    (r"attn/w[qkv]/w" + _IDX, P(None, "model")),
    (r"attn/wo/w" + _IDX, P("model", None)),
    (r"cross/w[qkv]/w" + _IDX, P(None, "model")),
    (r"cross/wo/w" + _IDX, P("model", None)),
    # dense / shared-expert SwiGLU: column-parallel in, row-parallel out
    (r"(mlp|shared|ffn)/w_(gate|up)/w" + _IDX, P(None, "model")),
    (r"(mlp|shared|ffn)/w_down/w" + _IDX, P("model", None)),
    # MoE experts (bf16 stacked): expert-parallel over model axis
    (r"experts/w_(gate|up|down)$", P("model", None, None)),
    # PMQ-compressed expert buckets [cnt, K?, N]: EP on the bucket dim
    (r"moe_ce/.*", P("model", None, None)),
    (r"router/w$", P(None, None)),
    # embeddings: vocab-parallel
    (r"^(embed|unembed)$", P("model", None)),
    # recurrent / xlstm / whisper-style block projections
    (r"(proj_in|gate_in|wq|wk|wv|wa|wx|w_z|w_o|w_if)/w" + _IDX, P(None, "model")),
    (r"(proj_out|out)/w" + _IDX, P("model", None)),
    (r"ffn/proj_in/w" + _IDX, P(None, "model")),
    (r"ffn/proj_out/w" + _IDX, P("model", None)),
    # everything else (norms, biases, small params): replicated
]


def param_spec_for_path(path: str, ndim: int, stacked: bool) -> P:
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            parts = list(spec)
            if stacked:
                parts = [None] + parts
            # pad/truncate to ndim
            while len(parts) < ndim:
                parts.append(None)
            return P(*parts[:ndim])
    return P(*([None] * ndim))


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


STACKED_PREFIXES = ("blocks", "groups", "enc_blocks", "dec_blocks")


def make_param_shardings(mesh: Mesh, params, stacked_prefixes=STACKED_PREFIXES):
    """Pytree of NamedShardings for a param tree (stacked layer dims aware).

    Falls back to replication when a spec doesn't divide the dim — this is
    what lets 40-head attention ride a 16-way model axis (output dim 5120
    divides even though head count doesn't; embeddings of odd vocab sizes
    replicate instead of crashing).
    """

    def one(path, leaf):
        ps = _path_str(path)
        stacked = any(ps.startswith(pref) for pref in stacked_prefixes)
        nd = getattr(leaf, "ndim", 0)
        spec = param_spec_for_path(ps, nd, stacked)
        if not _divides(leaf.shape, spec, mesh):
            spec = P(*([None] * nd))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def cache_pspec(mesh: Mesh, cache_shape, prefer: str = "batch") -> P:
    """KV cache [L, B, S, Hkv, dh] — 2-D sharded.

    Preference order: (batch→data, heads→model); heads that don't divide
    fall back to (batch→data, seq→model); long-context (batch=1):
    (seq→data, heads→model), else seq over both axes.
    """
    ba = batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in ba]))
    model = mesh.shape.get("model", 1)
    data = mesh.shape.get("data", 1)
    l, b, s, h = cache_shape[:4]
    if prefer == "batch" and b % bsz == 0:
        if h % model == 0:
            return P(None, ba, None, "model", None)
        if s % model == 0:
            return P(None, ba, "model", None, None)
        return P(None, ba, None, None, None)
    # long-context: sequence first
    if s % data == 0 and h % model == 0:
        return P(None, None, "data", "model", None)
    if s % (data * model) == 0:
        return P(None, None, ("data", "model"), None, None)
    if s % data == 0:
        return P(None, None, "data", None, None)
    return P(*([None] * len(cache_shape)))
