"""shard_map expert parallelism — the collective-minimal MoE region.

Key observation (DESIGN.md §7): in the sequence-parallel block layout the
MoE region's input is already *replicated over the model axis* within
each data shard (``act_full``). Expert parallelism therefore needs **no
all-to-all at all**: every (data d, model m) device

1. routes its data-shard's tokens (duplicated across m — routing is
   ~0.1 % of expert FLOPs),
2. keeps only the (token, k)-slots whose expert lives on model-shard m,
3. runs the *local* capacity dispatch + expert FFN (bf16 batched einsum
   or the PMQ bucket path — everything device-local),
4. contributes its partial combine; one ``psum`` over ``model`` per layer
   merges expert outputs — the same wire cost as a dense TP block.

This replaces the pjit/GSPMD global-dispatch path, which replicated the
[E·cap, D] buffer per device (measured: kimi-k2 prefill_32k collective
term 414 s/step → see EXPERIMENTS.md §Perf).

Gradients flow through ``shard_map``; OTP masks are computed
token-locally so they are identical on every model shard (the DM router
rides ``in_specs=P(None, None)`` so it is differentiable end-to-end).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import sharding as shd
from .sharding import batch_axes, manual_region

__all__ = ["moe_region_sharded", "compressed_moe_region_sharded"]


def moe_region_sharded(p: Dict, x: jnp.ndarray, cfg, mesh,
                       gate_mask_fn=None):
    """bf16 expert path. ``x [B, S, D]`` (batch on data, seq gathered)."""
    from ..models import moe as moe_mod

    ba = batch_axes(mesh)
    model = mesh.shape["model"]
    e, k = cfg.num_experts, cfg.top_k
    eploc = e // model

    def body(xl, wr, wg, wu, wd):
        with manual_region():
            return _body(xl, wr, wg, wu, wd)

    def _body(xl, wr, wg, wu, wd):
        b, s, d = xl.shape
        x2 = xl.reshape(b * s, d)
        t = x2.shape[0]
        midx = jax.lax.axis_index("model")
        probs, idx, gates = moe_mod.route_topk({"w": wr}, x2, k)
        mask = gate_mask_fn(x2, idx, gates) if gate_mask_fn else None
        lo = midx * eploc
        sel = ((idx >= lo) & (idx < lo + eploc)).astype(gates.dtype)
        if mask is not None:
            sel = sel * mask
        local_idx = jnp.clip(idx - lo, 0, eploc - 1)
        cap = moe_mod.dispatch_capacity(cfg, t)
        xp, dest, valid, gflat = moe_mod.capacity_dispatch(
            x2, local_idx, gates, eploc, cap, gate_mask=sel
        )
        x3 = xp.reshape(eploc, cap, d)
        h = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", x3, wg.astype(x3.dtype))
        ) * jnp.einsum("ecd,edf->ecf", x3, wu.astype(x3.dtype))
        yp = jnp.einsum("ecf,efd->ecd", h, wd.astype(x3.dtype)).reshape(
            eploc * cap, d
        )
        y_partial = moe_mod.combine(yp, dest, valid, gflat, t, k)
        y = jax.lax.psum(y_partial, "model")
        aux = jax.lax.pmean(moe_mod.load_balance_loss(probs, idx, e), ba)
        return y.reshape(b, s, d), aux

    fn = shd.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(ba, None, None),
            P(None, None),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(ba, None, None), P()),
        check_vma=False,
    )
    ex = p["experts"]
    return fn(x, p["router"]["w"], ex["w_gate"], ex["w_up"], ex["w_down"])


def _slot_tables(meta, model: int) -> Tuple[np.ndarray, np.ndarray]:
    """Static maps: global permuted slot → (model shard, local slot).

    Bucket rows shard contiguously *within each bucket* (P("model") on the
    bucket dim), so shard m's local layout is the concat of its share of
    every bucket, preserving bucket order.
    """
    num_slots = sum(m.count for m in meta)
    shard_of = np.zeros(num_slots, np.int32)
    local_of = np.zeros(num_slots, np.int32)
    for m in meta:
        cnt_loc = m.count // model
        off = np.arange(m.count)
        shard_of[m.start : m.start + m.count] = off // cnt_loc
        local_of[m.start : m.start + m.count] = m.start // model + off % cnt_loc
    return shard_of, local_of


def compressed_moe_region_sharded(
    p: Dict, ce, x: jnp.ndarray, cfg, mesh,
    otp_params: Optional[Dict] = None, otp_rng=None, otp_tau: float = 1.0,
    capacity_factor: Optional[float] = None,
    ffn_backend: Optional[str] = None,
):
    """PMQ-compressed expert path (bit-bucketed, device-local dequant).

    Bucket counts are multiples of the model extent (builder guarantee);
    each shard runs its local share of every bucket through the same
    grouped-GEMM primitive as the local path
    (:func:`repro.core.compressed_moe.grouped_bucket_ffn`): occupied rows
    compact into bm-aligned ragged groups, one fused gate/up + one down
    ``ops.moe_gmm`` call per bucket, dead capacity blocks skipped via
    ``num_active``. ``ffn_backend="scan"`` keeps the legacy one-expert-
    at-a-time scan (dequant-matmul through ``ops.quant_matmul_parts``,
    so TPU shards still get the Pallas dequant-GEMM).
    """
    from ..core import compressed_moe as cmoe
    from ..core import otp as otp_mod
    from ..kernels import ops
    from ..models import moe as moe_mod

    path, kb = cmoe._resolve_backend(ffn_backend)
    ba = batch_axes(mesh)
    model = mesh.shape["model"]
    data = mesh.shape.get("data", 1)
    e, k = cfg.num_experts, cfg.top_k
    eploc = ce.num_slots // model
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity_factor
    shard_of_np, local_of_np = _slot_tables(ce.meta, model)
    shard_of = jnp.asarray(shard_of_np)
    local_of = jnp.asarray(local_of_np)

    # 2-D expert sharding (EP over model × expert-TP over data): kimi-scale
    # packed experts (~322 GB at 2.25 b) must use *every* chip for storage.
    # gate/up go column-parallel on F, down row-parallel on F (+ one psum
    # over data per layer). Requires quant groups to align with F shards.
    f = ce.d_ff
    etp = (
        data > 1
        and f % data == 0
        and (f // data) % ce.group == 0
        and (f // ce.group) % data == 0
    )
    # ETP correctness requires the F-contraction partials of a token to be
    # summable across the data axis — valid only if tokens are REPLICATED
    # over data. Small T (decode): replicate tokens (per-device weight
    # reads stay at the 1/(model·data) storage share — the decode-roofline
    # optimum). Large T (prefill/train): keep tokens data-sharded and
    # all-gather each layer's F-shards instead (ZeRO-3-style; transient =
    # one layer's model-share).
    import os

    t_global = x.shape[0] * x.shape[1]
    etp_mode = None
    if etp:
        thresh = int(os.environ.get("REPRO_ETP_REPLICATE_MAX", 32768))
        etp_mode = "replicate_tokens" if t_global <= thresh else "gather_weights"

    def _wspec(wname: str, ndim: int) -> P:
        if not etp:
            return P("model", *([None] * (ndim - 1)))
        if wname in ("w_gate", "w_up"):
            # [cnt, D(/per|/group), F]: F column-parallel over data
            return P("model", *([None] * (ndim - 2)), "data")
        # w_down [cnt, F(/per|/group), D]: F row-parallel over data
        return P("model", "data", *([None] * (ndim - 2)))

    # flatten CE arrays (+ optional OTP params) into positional args
    bucket_names = [f"b{i}" for i in range(len(ce.meta))]
    arr_list, spec_list = [], []
    for bn in bucket_names:
        for wname in ("w_gate", "w_up", "w_down"):
            entry = ce.arrays[bn][wname]
            for key in ("data", "hi", "lo", "scale", "zero"):
                if key in entry:
                    a = entry[key]
                    arr_list.append(a)
                    spec_list.append(_wspec(wname, a.ndim))
    has_otp = otp_params is not None
    otp_args, otp_specs = (), ()
    if has_otp:
        otp_args = (otp_params["fc1"], otp_params["fc2"])
        otp_specs = (P(None, None), P(None, None))

    slot_map = ce.slot_of_expert
    if slot_map.ndim > 1:
        slot_map = slot_map[0]

    def rebuild(local_arrays):
        it = iter(local_arrays)
        out = {}
        for bn in bucket_names:
            out[bn] = {}
            for wname in ("w_gate", "w_up", "w_down"):
                entry = ce.arrays[bn][wname]
                out[bn][wname] = {
                    key: next(it)
                    for key in ("data", "hi", "lo", "scale", "zero")
                    if key in entry
                }
        return out

    def body(xl, wr, *rest):
        with manual_region():
            return _body(xl, wr, *rest)

    def _body(xl, wr, *rest):
        if has_otp:
            fc1, fc2 = rest[:2]
            local_arrays = rest[2:]
        else:
            fc1 = fc2 = None
            local_arrays = rest
        local = rebuild(local_arrays)
        if etp_mode == "gather_weights":
            # rebuild full-F weights from the data-axis shards
            def _gather(wname, key, a):
                if wname in ("w_gate", "w_up"):
                    return jax.lax.all_gather(a, "data", axis=a.ndim - 1, tiled=True)
                return jax.lax.all_gather(a, "data", axis=1, tiled=True)

            local = {
                bn: {
                    wname: {
                        key: _gather(wname, key, arr)
                        for key, arr in entry.items()
                    }
                    for wname, entry in bucket.items()
                }
                for bn, bucket in local.items()
            }
        b, s, d = xl.shape
        x2 = xl.reshape(b * s, d)
        t = x2.shape[0]
        midx = jax.lax.axis_index("model")
        probs, idx, gates = moe_mod.route_topk({"w": wr}, x2, k)
        mask = None
        if has_otp:
            mask = otp_mod.otp_mask(
                {"fc1": fc1, "fc2": fc2}, x2, idx, gates,
                rng=otp_rng, tau=otp_tau,
            )
        sidx = slot_map[idx]  # original expert id → permuted slot
        sel = (shard_of[sidx] == midx).astype(gates.dtype)
        if mask is not None:
            sel = sel * mask
        local_idx = local_of[sidx]
        cap = moe_mod.dispatch_capacity(cfg, t, cf)
        xp, dest, valid, gflat = moe_mod.capacity_dispatch(
            x2, local_idx, gates, eploc, cap, gate_mask=sel
        )
        # occupied-row counts per local slot (prefix occupancy — see
        # grouped_bucket_ffn): the ragged frontier of the grouped GEMMs
        local_fill = moe_mod.slot_fill_counts(dest, valid, eploc, cap)

        ys = []
        for i, m in enumerate(ce.meta):
            cnt_loc = m.count // model
            st_loc = m.start // model
            xb = jax.lax.slice_in_dim(xp, st_loc * cap, (st_loc + cnt_loc) * cap)
            wdict = local[f"b{i}"]

            if path == "scan":
                x3 = xb.reshape(cnt_loc, cap, d)

                def step(_, inp, bits=m.bits):
                    x2_, wg, wu, wd_ = inp

                    def mm(xx, wd2):
                        pk = (wd2["hi"], wd2["lo"]) if bits == 3 else wd2["data"]
                        return ops.quant_matmul_parts(
                            xx, pk, wd2["scale"], wd2["zero"],
                            bits=bits, group=ce.group, backend=kb,
                        )

                    h = jax.nn.silu(mm(x2_, wg)) * mm(x2_, wu)
                    return None, mm(h, wd_)

                _, y = jax.lax.scan(
                    step, None,
                    (x3, wdict["w_gate"], wdict["w_up"], wdict["w_down"]),
                )
                ys.append(y.reshape(cnt_loc * cap, d))
                continue

            fill = jax.lax.slice_in_dim(local_fill, st_loc, st_loc + cnt_loc)
            y = cmoe.grouped_bucket_ffn(
                xb, wdict, bits=m.bits, group=ce.group, count=cnt_loc,
                cap=cap, kernel_backend=kb, fill=fill,
            )
            ys.append(y)
        yp = jnp.concatenate(ys, axis=0)
        if etp_mode == "replicate_tokens":
            # tokens replicated over data: F-partials sum across data, and
            # expert partials across model — one fused psum
            yp = jax.lax.psum(yp, "data")
        y_partial = moe_mod.combine(yp, dest, valid, gflat, t, k)
        y = jax.lax.psum(y_partial, "model")
        m_l1 = mask.mean() if mask is not None else jnp.float32(0)
        return y.reshape(b, s, d), m_l1

    x_spec = (
        P(None, None, None) if etp_mode == "replicate_tokens" else P(ba, None, None)
    )
    fn = shd.shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), *otp_specs, *spec_list),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    y, m_l1 = fn(x, p["router"]["w"], *otp_args, *arr_list)
    return y, m_l1
