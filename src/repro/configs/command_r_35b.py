"""command-r-35b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    attn_bias=False,
    rope_theta=8e6,
    tie_embeddings=True,
    # 64 q-heads: keep the [B,H,qc,kc] backward tile ≈ 1 GiB/device
    attn_q_chunk=256,
)
