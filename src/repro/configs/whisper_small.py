"""whisper-small [audio] — enc-dec, conv frontend stubbed (frame embeds)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,  # decoder layers
    encoder_layers=12,
    encoder_seq=1500,  # mel frames after conv stride (stubbed)
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    frontend="frame_stub",
    rope_theta=1e4,  # sinusoidal absolute used in-model; rope unused
)
