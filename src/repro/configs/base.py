"""Model + shape configuration system.

Every assigned architecture is a :class:`ModelConfig` in
``repro/configs/<id>.py``; the four assigned input shapes are
:class:`ShapeConfig`. ``reduced()`` produces the CPU smoke-test config of
the same family (small dims, few experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "QuantConfig"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """PMQ compression settings attached to a model."""

    enabled: bool = False
    target_avg_bits: float = 2.25
    bit_choices: Tuple[int, ...] = (1, 2, 3)
    group: int = 128
    attn_bits: int = 4  # uniform width for non-expert weights (paper §3.2.3)
    alpha: float = 1.0
    beta: float = 0.5
    gamma: float = 1.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_capacity_factor: float = 1.25
    # --- attention pattern ---
    local_window: int = 0  # sliding window for local layers
    local_global_ratio: int = 0  # N local layers per 1 global (gemma3: 5)
    qk_norm: bool = False
    attn_bias: bool = False
    # --- hybrid / ssm ---
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rglru","rglru","attn")
    rglru_width: int = 0  # RNN width (recurrentgemma: d_model*1.0 rounded)
    # --- encoder-decoder ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed source length (whisper: 1500 frames)
    # --- frontend stubs ---
    frontend: str = ""  # "" | "patch_stub" | "frame_stub"
    num_patch_tokens: int = 0  # llava anyres tiles -> tokens
    # --- misc ---
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    quant: QuantConfig = QuantConfig()
    # remat policy: "none" | "block" (checkpoint each layer)
    remat: str = "block"
    # loss chunking (tokens per logits chunk; bounds logits memory)
    logits_chunk: int = 512
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md: sliding-window/recurrent)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.local_global_ratio > 0 and self.local_window > 0

    def reduced(self) -> "ModelConfig":
        """Smoke-test config: same family/topology, tiny dims."""
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 2 if not self.block_pattern else len(self.block_pattern)),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32,
            d_ff=256,
            d_ff_expert=128 if self.d_ff_expert else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 8) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            num_patch_tokens=min(self.num_patch_tokens, 8) if self.num_patch_tokens else 0,
            local_window=min(self.local_window, 16) if self.local_window else 0,
            rglru_width=128 if self.rglru_width else 0,
            logits_chunk=64,
            attn_q_chunk=32,
            attn_kv_chunk=32,
            dtype="float32",
            remat="none",
        )

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, l = self.d_model, self.num_layers
        attn = l * (
            self.num_heads * self.head_dim * d * 2  # q, o
            + self.num_kv_heads * self.head_dim * d * 2  # k, v
        )
        if self.family == "encdec":
            attn += self.encoder_layers * (
                self.num_heads * self.head_dim * d * 4
            ) + l * (self.num_heads * self.head_dim * d * 2 + self.num_kv_heads * self.head_dim * d * 2)
        ffn = 0
        if self.is_moe:
            ffn = l * self.num_experts * 3 * d * self.d_ff_expert
            ffn += l * self.num_shared_experts * 3 * d * self.d_ff_expert
            ffn += l * d * self.num_experts  # router
        elif self.d_ff:
            nl = l + (self.encoder_layers if self.family == "encdec" else 0)
            ffn = nl * 3 * d * self.d_ff
        if self.family == "ssm":  # xlstm block projections (approx)
            ffn = l * (8 * d * d)
        if self.family == "hybrid":
            n_rec = sum(1 for b in self.block_pattern for _ in [b] if b == "rglru")
            # per recurrent block: in/out proj + gates
            ffn += 0  # counted via d_ff MLPs; rglru params small
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return attn + ffn + emb

    def active_param_count(self) -> int:
        """Per-token activated parameters (MoE: top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        d, l = self.d_model, self.num_layers
        total = self.param_count()
        all_experts = l * self.num_experts * 3 * d * self.d_ff_expert
        active = l * self.top_k * 3 * d * self.d_ff_expert
        return total - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def supported_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Shape cells for this arch (skips per DESIGN.md §4)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    if cfg.family == "encdec" and cfg.name == "whisper-small":
        # decoder context is synthetic-stress beyond 448; keep decode_32k,
        # skip long_500k (full attention anyway)
        pass
    return tuple(names)
