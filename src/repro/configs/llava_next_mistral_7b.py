"""llava-next-mistral-7b [vlm] — mistral backbone, anyres patch stub."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    frontend="patch_stub",
    num_patch_tokens=2880,  # anyres tiling: base 576 + 4 tiles x 576
    rope_theta=1e6,
)
