"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384e top-8 (paper-table)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    d_ff_expert=2048,
    vocab_size=163840,
    num_experts=384,
    top_k=8,
    num_shared_experts=1,
    rope_theta=5e4,
)
