"""xlstm-350m [ssm] — alternating mLSTM + sLSTM blocks [arXiv:2405.04517]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,  # xLSTM blocks carry their own projections
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
)
