"""moonshot-v1-16b-a3b [moe] — kimi/moonlight 64e top-6 + shared experts."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    d_ff_expert=1408,
    vocab_size=163840,
    num_experts=64,
    top_k=6,
    num_shared_experts=2,
    rope_theta=5e4,
)
