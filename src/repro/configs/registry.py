"""Config registry: ``get_config("<arch-id>")`` for all assigned archs."""
from __future__ import annotations

from .base import ModelConfig, SHAPES, ShapeConfig, supported_shapes

from .qwen3_14b import CONFIG as _qwen3
from .gemma3_27b import CONFIG as _gemma3
from .command_r_35b import CONFIG as _commandr
from .tinyllama_1_1b import CONFIG as _tinyllama
from .whisper_small import CONFIG as _whisper
from .moonshot_v1_16b_a3b import CONFIG as _moonshot
from .kimi_k2_1t_a32b import CONFIG as _kimi
from .recurrentgemma_2b import CONFIG as _rgemma
from .llava_next_mistral_7b import CONFIG as _llava
from .xlstm_350m import CONFIG as _xlstm

CONFIGS = {
    c.name: c
    for c in [
        _qwen3,
        _gemma3,
        _commandr,
        _tinyllama,
        _whisper,
        _moonshot,
        _kimi,
        _rgemma,
        _llava,
        _xlstm,
    ]
}

ARCH_IDS = tuple(sorted(CONFIGS))


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return CONFIGS[name]


def all_cells():
    """Every (arch, shape) dry-run cell, with skips applied (DESIGN §4)."""
    for name in ARCH_IDS:
        cfg = CONFIGS[name]
        for shape_name in supported_shapes(cfg):
            yield name, shape_name
