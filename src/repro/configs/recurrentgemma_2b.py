"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, pattern R,R,A."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn"),  # 1 attn : 2 recurrent
    rglru_width=2560,
    local_window=2048,  # attention blocks are local-only (griffin)
    rope_theta=1e4,
    tie_embeddings=True,
)
