"""gemma3-27b [dense] — 5:1 local:global sliding window, 128k context."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    local_window=1024,
    local_global_ratio=5,  # 5 local : 1 global
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
)
