"""Dry-run machinery test: a full lower+compile on a small forced mesh in
a subprocess (fast), exercising train / decode / quantized-serve step
builders, shardings and the HLO analyzer end-to-end."""
import json
import os
import subprocess
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# portable child env (CI checkouts are not /root/repo): keep the host's
# PATH/HOME, and never probe for accelerators in the child — a stripped
# env otherwise stalls minutes in TPU discovery
_CHILD_ENV = {
    "PYTHONPATH": "src",
    "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
    "HOME": os.environ.get("HOME", "/root"),
    "JAX_PLATFORMS": "cpu",
}

_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax
from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_step
from repro.launch.hlo_analysis import analyze_hlo
from repro.parallel.sharding import sharding_rules, activation_rules

mesh = make_test_mesh(data=2, model=4)
out = {}
cells = [
    ("tinyllama-1.1b", "train_4k", {}),
    ("moonshot-v1-16b-a3b", "decode_32k", {}),          # quantized MoE decode
    ("kimi-k2-1t-a32b", "train_4k", {}),                # OTP distill mode
    ("xlstm-350m", "long_500k", {}),
]
for arch, shape_name, kw in cells:
    cfg = get_config(arch).reduced()
    # widen reduced config heads so the tiny mesh shards something
    shape = dataclasses.replace(SHAPES[shape_name], seq_len=64, global_batch=4)
    art = build_step(cfg, shape, mesh, **kw)
    with mesh, sharding_rules(mesh, activation_rules(mesh)):
        compiled = jax.jit(
            art.fn, in_shardings=art.in_shardings,
            donate_argnums=art.donate_argnums,
        ).lower(*art.arg_specs).compile()
    s = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    out[f"{arch}/{shape_name}"] = {
        "step": art.name,
        "flops": s.flops,
        "colls": sum(s.collective_bytes.values()),
        "temp": mem.temp_size_in_bytes,
    }
print("RESULT " + json.dumps(out))
"""


def test_dryrun_reduced_cells_compile():
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET],
        capture_output=True, text=True, timeout=900,
        env=_CHILD_ENV,
        cwd=_REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert out["tinyllama-1.1b/train_4k"]["step"] == "train_step"
    assert out["kimi-k2-1t-a32b/train_4k"]["step"] == "otp_train_step"
    assert out["moonshot-v1-16b-a3b/decode_32k"]["step"] == "decode_step"
    for k, v in out.items():
        assert v["flops"] > 0, k
