"""OTP tests: candidate masks (Eq. 10), Gumbel sampling (Eq. 13),
temperature limit, λ monotonicity (Fig. 13), learnability (Tab. 6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import otp


def test_candidate_masks_eq10():
    c = np.asarray(otp.candidate_masks(6))
    expect = np.array(
        [
            [1, 1, 1, 1, 1, 1],
            [1, 1, 1, 1, 1, 0],
            [1, 1, 1, 1, 0, 0],
            [1, 1, 1, 0, 0, 0],
            [1, 1, 0, 0, 0, 0],
            [1, 0, 0, 0, 0, 0],
        ],
        np.float32,
    )
    np.testing.assert_array_equal(c, expect)


def test_gumbel_tau_limit_approaches_onehot():
    rng = jax.random.PRNGKey(0)
    logits = jnp.array([[2.0, 0.5, -1.0, 0.0]])
    y_hi, _ = otp.sample_mask_gumbel(rng, logits, 4, tau=5.0)
    y_lo, _ = otp.sample_mask_gumbel(rng, logits, 4, tau=0.01)
    # straight-through forward is always hard one-hot
    for y in (y_hi, y_lo):
        assert np.allclose(np.sort(np.asarray(y))[..., -1], 1.0, atol=1e-5)
    # soft component sharpness: low tau → soft ~ hard (grad path converges)
    u = jax.random.uniform(rng, logits.shape, minval=1e-6, maxval=1 - 1e-6)
    g = -jnp.log(-jnp.log(u))
    soft_hi = jax.nn.softmax((logits + g) / 5.0)
    soft_lo = jax.nn.softmax((logits + g) / 0.01)
    assert float(soft_lo.max()) > float(soft_hi.max())
    assert float(soft_lo.max()) > 0.999


def test_mask_sampling_distribution_follows_logits():
    rng = jax.random.PRNGKey(1)
    logits = jnp.tile(jnp.array([[3.0, 0.0, 0.0, -3.0]]), (4096, 1))
    _, mask = otp.sample_mask_gumbel(rng, logits, 4, tau=1.0)
    # candidate 0 (keep all) dominates → mean mask high
    assert float(mask.mean()) > 0.7


def test_otp_mask_unsorts_back_to_slot_order():
    # gates deliberately unsorted: slot 1 is strongest
    p = otp.init_otp_router(jax.random.PRNGKey(0), 8, 3)
    x2 = jnp.zeros((1, 8))
    gates = jnp.array([[0.2, 0.5, 0.3]])
    idx = jnp.array([[4, 2, 7]])
    # force argmax choice = keep only strongest (candidate k-1)
    p = jax.tree.map(jnp.zeros_like, p)
    p["fc2"] = p["fc2"].at[:, -1].set(100.0)  # bias towards last candidate
    # fc2 input: concat(silu(fc1 x)=0, gates) → logits = gates @ fc2[3:,:]
    mask = otp.otp_mask(p, x2, idx, gates)
    np.testing.assert_array_equal(np.asarray(mask), [[0.0, 1.0, 0.0]])


def test_otp_losses_lambda_monotone():
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)
    t = jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)
    m = jnp.asarray(rng.uniform(size=(100,)), jnp.float32)
    l1, _ = otp.otp_losses(s, t, m, lam=1.0)
    l2, _ = otp.otp_losses(s, t, m, lam=2.0)
    assert float(l2) > float(l1)
    l_same, aux = otp.otp_losses(s, s, m, lam=0.0)
    assert float(l_same) < 1e-5  # KL(s, s) == 0


def test_learnable_router_prefers_pruning_under_sparsity_pressure():
    """Gradient descent on Eq. 14 with dominant λ should raise mask ratio."""
    rng = jax.random.PRNGKey(3)
    k = 4
    p = otp.init_otp_router(rng, 16, k)
    x2 = jax.random.normal(rng, (64, 16))
    gates = jax.nn.softmax(jax.random.normal(rng, (64, k)))
    idx = jnp.tile(jnp.arange(k)[None], (64, 1))

    def loss_fn(params, key):
        order = jnp.argsort(-gates, axis=-1)
        gs = jnp.take_along_axis(gates, order, axis=-1)
        logits = otp.dm_logits(params, x2, gs)
        _, mask = otp.sample_mask_gumbel(key, logits, k, tau=1.0)
        return jnp.abs(mask).mean()  # pure sparsity objective

    lr = 0.5
    r0 = None
    for i in range(60):
        key = jax.random.fold_in(rng, i)
        val, g = jax.value_and_grad(loss_fn)(p, key)
        if r0 is None:
            r0 = float(val)
        p = jax.tree.map(lambda a, b: a - lr * b, p, g)
    r1 = float(loss_fn(p, jax.random.fold_in(rng, 999)))
    assert r1 < r0 - 0.1, f"mask mean did not drop: {r0} -> {r1}"
