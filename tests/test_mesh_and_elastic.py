"""Mesh construction, elastic re-mesh, sharding rules (forced devices in
a subprocess so the main test process keeps 1 device)."""
import os
import subprocess
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# portable child env (CI checkouts are not /root/repo): keep the host's
# PATH/HOME, and never probe for accelerators in the child — a stripped
# env otherwise stalls minutes in TPU discovery
_CHILD_ENV = {
    "PYTHONPATH": "src",
    "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
    "HOME": os.environ.get("HOME", "/root"),
    "JAX_PLATFORMS": "cpu",
}

import numpy as np

from repro.parallel.sharding import param_spec_for_path
from repro.runtime.elastic import accum_for

_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_test_mesh
from repro.runtime.elastic import shrink_mesh, reshard_tree
from repro.parallel.sharding import make_param_shardings
from repro.checkpoint.checkpointer import Checkpointer
import tempfile

mesh = make_test_mesh(data=4, model=4)
assert mesh.devices.shape == (4, 4)
params = {
    "embed": jnp.arange(32.0).reshape(8, 4),
    "blocks": {"attn": {"wq": {"w": jnp.ones((2, 4, 8))}}},
}
sh = make_param_shardings(mesh, params)
# embed vocab-sharded on model; wq col-parallel
assert sh["embed"].spec == P("model", None), sh["embed"].spec
assert sh["blocks"]["attn"]["wq"]["w"].spec == P(None, None, "model")
placed = reshard_tree(params, sh)

# checkpoint on the 4x4 mesh, restore onto a shrunken 2x4 mesh
with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(d, async_write=False)
    ck.save(0, placed)
    small = shrink_mesh(mesh, 2)
    assert small.devices.shape == (2, 4)
    sh2 = make_param_shardings(small, params)
    restored = ck.restore(0, params, shardings=sh2)
    np.testing.assert_array_equal(
        np.asarray(restored["embed"]), np.asarray(params["embed"])
    )
    assert restored["embed"].sharding.mesh.shape["data"] == 2
print("OK")
"""


def test_mesh_shard_ckpt_elastic_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET],
        capture_output=True, text=True, timeout=300,
        env=_CHILD_ENV,
        cwd=_REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_accum_for_preserves_global_batch():
    assert accum_for(256, 64) == 4
    try:
        accum_for(256, 60)
        raise AssertionError("expected failure")
    except AssertionError as e:
        if "expected failure" in str(e):
            raise
    except Exception:
        pass


def test_param_rules_cover_families():
    cases = [
        ("blocks/attn/wq/w", 3, True, (None, None, "model")),
        ("blocks/attn/wq/w/0", 3, True, (None, None, "model")),  # packed
        ("blocks/attn/wo/w", 3, True, (None, "model", None)),
        ("blocks/mlp/w_gate/w", 3, True, (None, None, "model")),
        ("blocks/moe/experts/w_gate", 4, True, (None, "model", None, None)),
        ("embed", 2, False, ("model", None)),
        ("blocks/ln1", 2, True, (None, None)),
        ("groups/m/wq/w", 3, True, (None, None, "model")),
        ("groups/rg1/proj_out/w", 3, True, (None, "model", None)),
    ]
    for path, nd, stacked, want in cases:
        spec = param_spec_for_path(path, nd, stacked)
        assert tuple(spec) == want, (path, tuple(spec), want)
