"""Unit + property tests for packing / quantizers / GPTQ (paper §3.1/3.3)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import packing, quantizers
from repro.core.gptq import gptq_quantize, hessian_from_inputs


# ---------------------------------------------------------------- packing
@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("shape,axis", [((32, 5), 0), ((4, 64), 1), ((2, 16, 3), 1)])
def test_pack_roundtrip(bits, shape, axis):
    rng = np.random.default_rng(0)
    q = rng.integers(0, 2**bits, size=shape).astype(np.uint8)
    packed = packing.pack_bits(jnp.asarray(q), bits, axis=axis)
    out = packing.unpack_bits(packed, bits, axis=axis)
    np.testing.assert_array_equal(np.asarray(out), q)


@given(
    bits=st.sampled_from([1, 2, 3, 4]),
    k=st.integers(1, 9),
    n=st.integers(1, 7),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_pack_roundtrip_property(bits, k, n, seed):
    rng = np.random.default_rng(seed)
    per = {1: 8, 2: 4, 3: 8, 4: 2}[bits]
    q = rng.integers(0, 2**bits, size=(k * per, n)).astype(np.uint8)
    packed = packing.pack_bits(jnp.asarray(q), bits, axis=0)
    out = packing.unpack_bits(packed, bits, axis=0)
    np.testing.assert_array_equal(np.asarray(out), q)


def test_packed_nbytes_exact():
    # 3-bit must cost exactly 3 bits/val: 2-bit plane + 1-bit plane
    assert packing.packed_nbytes((8, 4), 3, axis=0) == 4 * (2 + 1)
    assert packing.packed_nbytes((8, 4), 1, axis=0) == 4 * 1
    assert packing.packed_nbytes((8, 4), 2, axis=0) == 4 * 2
    assert packing.packed_nbytes((8, 4), 4, axis=0) == 4 * 4


# ------------------------------------------------------------- quantizers
@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_affine_roundtrip_error_bounded(bits):
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(256, 32)), jnp.float32)
    codes, scale, zero = quantizers.quantize_affine(w, bits, group=128)
    wq = quantizers.dequantize_affine(codes, scale, zero, group=128)
    # max error within one quantization step
    step = np.repeat(np.asarray(scale), 128, axis=0)[:256]
    assert np.all(np.abs(np.asarray(w - wq)) <= step * 0.5 + 1e-6)


def test_binary_quantize_matches_eq4():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    b01, scale = quantizers.quantize_binary(w)
    assert set(np.unique(np.asarray(b01))) <= {0, 1}
    np.testing.assert_allclose(
        np.asarray(scale), np.mean(np.abs(np.asarray(w)), axis=0, keepdims=True),
        rtol=1e-6,
    )
    wq = quantizers.dequantize_binary(b01, scale)
    np.testing.assert_allclose(
        np.asarray(jnp.sign(w) * scale + (w == 0) * scale), np.asarray(wq), rtol=1e-5
    )


@pytest.mark.parametrize("bits", [1, 2, 3, 4])
def test_quantize_to_packed_dequant_consistent(bits):
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(256, 24)), jnp.float32)
    pt = quantizers.quantize_to_packed(w, bits, group=128, refine=False)
    wq = pt.dequantize()
    assert wq.shape == w.shape
    if bits == 1:
        ref = quantizers.dequantize_binary(*quantizers.quantize_binary(w))
        np.testing.assert_allclose(np.asarray(wq), np.asarray(ref), rtol=1e-5)
    else:
        codes, scale, zero = quantizers.quantize_affine(w, bits, 128, refine=False)
        ref = quantizers.dequantize_affine(codes, scale, zero, 128)
        np.testing.assert_allclose(np.asarray(wq), np.asarray(ref), rtol=1e-5)
    # storage really is `bits` per weight (plus params)
    assert pt.nbytes < w.size * bits / 8 + pt.scale.nbytes + pt.zero.nbytes + 16


def test_hqq_refine_improves_rtn():
    rng = np.random.default_rng(4)
    # heavy-tailed weights: where zero-point refinement helps
    w = jnp.asarray(rng.standard_t(df=3, size=(256, 64)), jnp.float32)
    base_err, ref_err = [], []
    for refine in (False, True):
        codes, scale, zero = quantizers.quantize_affine(w, 2, 64, refine=refine)
        wq = quantizers.dequantize_affine(codes, scale, zero, 64)
        err = float(jnp.mean((w - wq) ** 2))
        (ref_err if refine else base_err).append(err)
    assert ref_err[0] <= base_err[0] * 1.02  # never meaningfully worse


# ------------------------------------------------------------------ gptq
def _rand_problem(k=128, n=32, nsamp=256, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k, n)).astype(np.float64)
    x = rng.normal(size=(nsamp, k)).astype(np.float64)
    # correlated inputs make compensation matter
    mix = rng.normal(size=(k, k)) * 0.3 + np.eye(k)
    x = x @ mix
    return w, x


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_gptq_beats_rtn(bits):
    w, x = _rand_problem(seed=5)
    h = hessian_from_inputs(x)
    res = gptq_quantize(w, h, bits=bits, group=64)
    # reconstruct
    k, n = w.shape
    qg = res.codes.astype(np.float64).reshape(-1, 64, n)
    wq = ((qg - res.zero[:, None, :]) * res.scale[:, None, :]).reshape(k, n)
    gptq_err = np.linalg.norm(x @ w - x @ wq) ** 2
    # RTN baseline
    codes, scale, zero = quantizers.quantize_affine(
        jnp.asarray(w, jnp.float32), bits, 64, refine=False
    )
    wr = np.asarray(quantizers.dequantize_affine(codes, scale, zero, 64), np.float64)
    rtn_err = np.linalg.norm(x @ w - x @ wr) ** 2
    assert gptq_err < rtn_err, f"GPTQ {gptq_err:.3f} !< RTN {rtn_err:.3f}"


def test_gptq_binary_beats_plain_sign():
    w, x = _rand_problem(k=96, n=24, seed=6)
    h = hessian_from_inputs(x)
    res = gptq_quantize(w, h, bits=1, group=32)
    k, n = w.shape
    qg = res.codes.astype(np.float64).reshape(-1, 32, n)
    wq = ((qg - res.zero[:, None, :]) * res.scale[:, None, :]).reshape(k, n)
    gptq_err = np.linalg.norm(x @ w - x @ wq) ** 2
    alpha = np.mean(np.abs(w), axis=0, keepdims=True)
    ws = np.where(w >= 0, alpha, -alpha)
    sign_err = np.linalg.norm(x @ w - x @ ws) ** 2
    assert gptq_err < sign_err


def test_gptq_identity_hessian_equals_rtn():
    # with H = I there is nothing to compensate into later rows *from the
    # final row*, but earlier rows still match plain RTN exactly
    w, _ = _rand_problem(k=64, n=8, seed=7)
    h = np.eye(64)
    res = gptq_quantize(w, h, bits=4, group=64, percdamp=0.0)
    codes, scale, zero = quantizers.quantize_affine(
        jnp.asarray(w, jnp.float32), 4, 64, refine=False
    )
    np.testing.assert_allclose(res.codes, np.asarray(codes))
