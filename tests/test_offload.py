"""Host-offloaded PMQ expert buckets (repro.serving.offload).

The contract under test: **residency is invisible to correctness**.
Greedy outputs of the offloaded engine are bit-identical to the
all-resident engine for any expert budget that holds the per-step
working set — fuzzed over random traces and budgets the same way
tests/test_serving_sim.py fuzzes KV pool pressure — including runs that
force prefetch misses (the step replays after a synchronous upload) and
runs whose budget is smaller than a step's working set (the manager
grows the resident buffer rather than serving wrong tokens).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.compressed_moe import (
    CompressedExperts,
    build_compressed_experts,
    compressed_expert_ffn,
)
from repro.models import transformer as tf
from repro.models.registry import get_model
from repro.serving import (
    EngineConfig,
    ExpertOffloadManager,
    PagedServingEngine,
    Request,
)

TINY_MOE = ModelConfig(
    name="tiny-offload-moe",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    d_ff_expert=64,
    vocab_size=128,
    num_experts=4,
    top_k=2,
    num_shared_experts=1,
    dtype="float32",
    remat="none",
    logits_chunk=32,
    attn_q_chunk=16,
    attn_kv_chunk=16,
)

BITS = [1, 2, 2, 3]  # buckets (count 1, 2, 1) -> num_slots = 4

# decode_horizon=1 pins the per-token baseline these budget/preemption
# traces were shaped around; the fused-megastep miss path (horizon-union
# working set, whole-megastep replay) has its own tests below
ECFG = EngineConfig(
    max_slots=2, block_size=4, num_blocks=16, max_blocks_per_slot=6,
    prefill_chunk=4, decode_horizon=1,
)


def compress_for_serving(cfg, params, bits=BITS):
    """Layer-uniform PMQ buckets in the stacked serving layout (no GPTQ,
    fp attention/router — the expert buckets are what offload manages)."""
    blocks = tf.unstack_blocks(params, cfg)
    blocks_c = []
    for p_l in blocks:
        experts = {
            k: np.asarray(p_l["moe"]["experts"][k])
            for k in ("w_gate", "w_up", "w_down")
        }
        ce = build_compressed_experts(experts, bits, group=32, ep=1,
                                      refine=False)
        blocks_c.append({
            "ln1": p_l["ln1"], "attn": p_l["attn"], "ln2": p_l["ln2"],
            "moe": {"router": p_l["moe"]["router"],
                    "shared": p_l["moe"]["shared"]},
            "moe_ce": ce,
        })
    return {
        "embed": params["embed"],
        "final_norm": params["final_norm"],
        "blocks": tf.restack_blocks(blocks_c),
    }


@pytest.fixture(scope="module")
def compressed_model():
    bundle = get_model(TINY_MOE)
    params = bundle.init(jax.random.PRNGKey(0))
    return TINY_MOE, compress_for_serving(TINY_MOE, params)


def make_requests(cfg, n, seed, max_new=5, plen=6):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new=max_new,
        )
        for i in range(n)
    ]


# ------------------------------------------------------- gather bit-exact
def test_resident_gather_bitwise_identical():
    """compressed_expert_ffn through a resident partition whose rows hold
    the true weights is bit-identical to the all-resident path — the
    gather moves bytes, never values."""
    rng = np.random.default_rng(0)
    e, d, f = 4, 32, 48
    experts = {
        "w_gate": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_up": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_down": rng.normal(size=(e, f, d)).astype(np.float32),
    }
    ce = build_compressed_experts(experts, BITS, group=16, ep=1, refine=False)
    cap = 8
    xp = jnp.asarray(rng.normal(size=(ce.num_slots * cap, d)), jnp.float32)
    y_full = np.asarray(compressed_expert_ffn(ce, xp, cap))
    # identity maps (all resident, rows == slots)
    rmap = {
        f"b{i}": jnp.arange(m.count, dtype=jnp.int32)
        for i, m in enumerate(ce.meta)
    }
    ce_id = dataclasses.replace(
        ce, resident_map=rmap, resident_rows=tuple(m.count for m in ce.meta)
    )
    np.testing.assert_array_equal(
        np.asarray(compressed_expert_ffn(ce_id, xp, cap)), y_full
    )
    # permuted rows: bucket b1 (count 2) stored reversed in its buffer
    arrays = dict(ce.arrays)
    arrays["b1"] = jax.tree.map(lambda a: a[::-1], ce.arrays["b1"])
    rmap2 = dict(rmap, b1=jnp.asarray([1, 0], jnp.int32))
    ce_perm = dataclasses.replace(
        ce, arrays=arrays, resident_map=rmap2,
        resident_rows=tuple(m.count for m in ce.meta),
    )
    np.testing.assert_array_equal(
        np.asarray(compressed_expert_ffn(ce_perm, xp, cap)), y_full
    )


def test_residency_changes_keep_pytree_stable():
    """Uploads change leaf *values* only: the flattened treedef — what
    decides whether jit retraces — is identical across residency states
    of the same budget, and differs once the budget (shapes) changes."""
    bundle = get_model(TINY_MOE)
    params = bundle.init(jax.random.PRNGKey(0))
    ce = compress_for_serving(TINY_MOE, params)["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=3)
    before = jax.tree_util.tree_structure(mgr.ce)
    # residency-only movement: bucket b1 (slots 1..2, budget 1) swaps its
    # resident slot — values move, treedef (what decides retraces) doesn't
    counts = np.zeros((2, mgr.num_slots), np.int64)
    counts[:, 2] = 1  # only the cold slot of b1 is used
    mgr.begin_step()
    ups, _ = mgr.ensure_resident(counts)
    assert ups >= 1 and mgr.grows == 0
    assert jax.tree_util.tree_structure(mgr.ce) == before
    assert mgr.resident_slots_of(0)["b1"] == {1}
    # working-set overflow (both b1 slots in one step) forces growth —
    # a legitimate shape/structure change that re-specializes the jit
    counts[:, 1] = 1
    mgr.begin_step()
    mgr.ensure_resident(counts)
    assert mgr.grows == 1
    assert jax.tree_util.tree_structure(mgr.ce) != before


# -------------------------------------------------- engine equivalence
@pytest.mark.parametrize("seed", [0, 1])
def test_offload_equivalence_budget_sweep(compressed_model, seed):
    """Greedy outputs are bit-identical to the all-resident engine at
    every budget from fully resident down to the per-bucket floor, over
    random traces with mid-flight admissions (3 requests, 2 slots)."""
    cfg, params = compressed_model
    baseline = PagedServingEngine(cfg, params, ECFG)
    out0 = baseline.serve(make_requests(cfg, 3, seed))
    assert baseline.offload is None
    num_slots = params["blocks"]["moe_ce"].num_slots
    for budget in range(num_slots, 2, -1):
        eng = PagedServingEngine(
            cfg, params, dataclasses.replace(ECFG, resident_experts=budget)
        )
        out = eng.serve(make_requests(cfg, 3, seed))
        assert out == out0, f"budget {budget} diverged from all-resident"
        m = eng.metrics.summary()
        if budget >= num_slots:
            # fully resident: every program run must hit
            assert m["expert_prefetch_misses"] == 0
            assert m["expert_hit_rate"] == 1.0


def test_forced_prefetch_miss_replays_bit_identical(compressed_model):
    """A budget below the slot count starts with cold experts resident
    nowhere — the first programs that route to them MUST miss, upload
    synchronously, replay, and still emit bit-identical tokens."""
    cfg, params = compressed_model
    baseline = PagedServingEngine(cfg, params, ECFG)
    out0 = baseline.serve(make_requests(cfg, 3, 0))
    eng = PagedServingEngine(
        cfg, params, dataclasses.replace(ECFG, resident_experts=3)
    )
    # before any traffic the device holds only the budgeted slice
    assert eng.offload.resident_bytes < eng.offload.host_bytes
    out = eng.serve(make_requests(cfg, 3, 0))
    m = eng.metrics.summary()
    assert m["expert_prefetch_misses"] >= 1, "trace must force a miss"
    assert m["expert_miss_uploads"] >= 1
    assert m["expert_upload_bytes"] > 0
    assert out == out0
    # resident gauge tracks the (possibly grown) device footprint
    assert eng.offload.resident_bytes <= eng.offload.host_bytes
    assert m["expert_resident_bytes_last"] == eng.offload.resident_bytes


def test_offload_composes_with_preemption(compressed_model):
    """Expert offload and KV preemption squeeze different memories; both
    at once must still reproduce the roomy all-resident run."""
    cfg, params = compressed_model
    roomy = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=3, num_blocks=16,
                            max_blocks_per_slot=4),
    )
    out0 = roomy.serve(make_requests(cfg, 3, 2, max_new=8, plen=3))
    tight = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=3, num_blocks=6,
                            max_blocks_per_slot=4, preempt_mode="swap",
                            resident_experts=3),
    )
    out = tight.serve(make_requests(cfg, 3, 2, max_new=8, plen=3))
    m = tight.metrics.summary()
    assert m["preemptions"] >= 1, "tight pool must preempt"
    assert out == out0


def test_offload_deterministic_replay(compressed_model):
    """Same trace, same budget ⇒ identical outputs AND identical
    wall-clock-free counters (prefetch decisions, miss uploads, upload
    bytes are all deterministic functions of the trace)."""
    cfg, params = compressed_model
    runs = []
    for _ in range(2):
        eng = PagedServingEngine(
            cfg, params, dataclasses.replace(ECFG, resident_experts=3)
        )
        out = eng.serve(make_requests(cfg, 3, 1))
        runs.append((out, eng.metrics.counters()))
    (out_a, ctr_a), (out_b, ctr_b) = runs
    assert out_a == out_b
    assert ctr_a == ctr_b


def test_budget_below_working_set_grows_not_corrupts(compressed_model):
    """The per-bucket floor (1 slot each) is below the decode working
    set here; the manager must grow the buffer (counted) and keep the
    outputs bit-identical — never silently compute with wrong rows."""
    cfg, params = compressed_model
    baseline = PagedServingEngine(cfg, params, ECFG)
    out0 = baseline.serve(make_requests(cfg, 2, 3))
    eng = PagedServingEngine(
        cfg, params, dataclasses.replace(ECFG, resident_experts=1)
    )
    out = eng.serve(make_requests(cfg, 2, 3))
    assert out == out0
    assert eng.offload.grows >= 1
    # grown buffers never exceed the bucket counts
    ce = params["blocks"]["moe_ce"]
    assert all(
        r <= m.count for r, m in zip(eng.offload.budgets, ce.meta)
    )


# -------------------------------------------- fused decode-horizon megasteps
@pytest.mark.parametrize("horizon", [2, 4, 8])
@pytest.mark.parametrize("seed", [0, 1])
def test_offload_equivalence_across_horizons(compressed_model, horizon, seed):
    """Acceptance (horizon × offload): for H ∈ {2, 4, 8} and every
    budget down to near the floor, the fused megastep's miss path —
    horizon-union working set, whole-megastep replay — emits tokens
    bit-identical to the all-resident H=1 engine."""
    cfg, params = compressed_model
    baseline = PagedServingEngine(cfg, params, ECFG)
    out0 = baseline.serve(make_requests(cfg, 3, seed, max_new=7))
    num_slots = params["blocks"]["moe_ce"].num_slots
    for budget in range(num_slots, 2, -1):
        eng = PagedServingEngine(
            cfg, params,
            dataclasses.replace(ECFG, resident_experts=budget,
                                decode_horizon=horizon),
        )
        out = eng.serve(make_requests(cfg, 3, seed, max_new=7))
        assert out == out0, (
            f"H={horizon} budget={budget} diverged from all-resident H=1"
        )


def test_offload_megastep_replay_counts(compressed_model):
    """A decode megastep whose working set was force-evicted after
    prefill must miss, replay the whole megastep (decode_replays ≥ 1),
    accept within the H·L induction bound — with bit-identical outputs
    and the replay time split out of the decode-compute timer."""
    cfg, params = compressed_model
    baseline = PagedServingEngine(
        cfg, params, dataclasses.replace(ECFG, decode_horizon=4)
    )
    reqs0 = make_requests(cfg, 2, 0, max_new=6)
    out0 = baseline.serve(reqs0)
    eng = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, resident_experts=3, decode_horizon=4),
    )
    reqs = make_requests(cfg, 2, 0, max_new=6)
    for r in reqs:
        eng.submit(r)
    eng._converge()  # admission plan: prefill uploads the prompt working set
    # force-evict bucket b1 entirely (its budget row goes free): any b1
    # traffic in the coming megasteps must miss inside the fused program;
    # the controller's residency convergence is disabled so prefetch
    # cannot quietly undo the eviction
    mgr = eng.offload
    mgr.slot_row["b1"][:, :] = -1
    mgr.row_slot["b1"][:, :] = -1
    eng.controller.offload = None
    eng.run()
    assert {r.rid: eng.results[r.rid] for r in reqs} == out0
    c = eng.metrics.counters()
    assert c["expert_prefetch_misses"] >= 1
    assert c["decode_replays"] >= 1  # ≥ 1 whole-megastep replay happened
    # dispatch accounting: every decode dispatch is a megastep or replay
    assert c["decode_dispatches"] == c["megasteps"] + c["decode_replays"]
    # induction bound: every megastep accepted within H·L extra runs
    assert c["decode_dispatches"] <= c["megasteps"] * (1 + 4 * cfg.num_layers)
    s = eng.metrics.summary()
    # satellite: replay/upload time is split out of the decode timer
    assert s["decode_offload_mean_s"] > 0.0
    assert s["decode_compute_mean_s"] > 0.0


def test_offload_horizon_composes_with_preemption(compressed_model):
    """Horizon × offload × preemption: all three memory squeezes at once
    still reproduce the roomy all-resident run (tight pool sized so the
    horizon-ahead reservations genuinely collide)."""
    cfg, params = compressed_model
    roomy = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=3, num_blocks=24,
                            max_blocks_per_slot=6),
    )
    out0 = roomy.serve(make_requests(cfg, 3, 2, max_new=16, plen=3))
    tight = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=3, num_blocks=7,
                            max_blocks_per_slot=6, preempt_mode="swap",
                            resident_experts=3, decode_horizon=4),
    )
    out = tight.serve(make_requests(cfg, 3, 2, max_new=16, plen=3))
    m = tight.metrics.summary()
    assert m["preemptions"] >= 1, "tight pool must preempt"
    assert out == out0


# ------------------------------------------------------- manager units
def test_prefetch_follows_router_stats(compressed_model):
    """The EMA prefetcher uploads the hottest slot of an under-budget
    bucket ahead of need and evicts the cold one."""
    cfg, params = compressed_model
    ce = params["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=3, ema_decay=0.5)
    # bucket b1 spans slots 1..2 with budget 1: slot 1 (local 0) seeded
    assert mgr.resident_slots_of(0)["b1"] == {0}
    counts = np.zeros((2, ce.num_slots), np.int64)
    counts[:, 2] = 5  # traffic hammers slot 2 (bucket-local 1)
    mgr.update_stats(counts)
    ups, nbytes = mgr.prefetch()
    assert ups >= 1 and nbytes > 0
    assert mgr.resident_slots_of(0)["b1"] == {1}
    # stats now favor the resident slot: prefetch is idempotent
    assert mgr.prefetch() == (0, 0)


def test_manager_rejects_bad_inputs(compressed_model):
    cfg, params = compressed_model
    ce = params["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=2)
    with pytest.raises(ValueError):
        ExpertOffloadManager(mgr.ce, resident_slots=2)  # already offloaded
    # unstacked (single-layer) buckets are not a serving layout
    rng = np.random.default_rng(0)
    e, d, f = 4, 32, 32
    experts = {
        "w_gate": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_up": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_down": rng.normal(size=(e, f, d)).astype(np.float32),
    }
    flat = build_compressed_experts(experts, BITS, group=16, ep=1,
                                    refine=False)
    with pytest.raises(ValueError):
        ExpertOffloadManager(flat, resident_slots=2)


def test_engine_requires_compressed_params_for_offload():
    bundle = get_model(TINY_MOE)
    params = bundle.init(jax.random.PRNGKey(0))  # fp experts, no moe_ce
    with pytest.raises(ValueError):
        PagedServingEngine(
            TINY_MOE, params,
            dataclasses.replace(ECFG, resident_experts=2),
        )
