"""Host-offloaded PMQ expert buckets (repro.serving.offload).

The contract under test: **residency is invisible to correctness**.
Greedy outputs of the offloaded engine are bit-identical to the
all-resident engine for any expert budget that holds the per-step
working set — fuzzed over random traces and budgets the same way
tests/test_serving_sim.py fuzzes KV pool pressure — including runs that
force prefetch misses (the step replays after a synchronous upload) and
runs whose budget is smaller than a step's working set (the manager
grows the resident buffer rather than serving wrong tokens).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.compressed_moe import (
    CompressedExperts,
    build_compressed_experts,
    compressed_expert_ffn,
)
from repro.models import transformer as tf
from repro.models.registry import get_model
from repro.serving import (
    EngineConfig,
    ExpertOffloadManager,
    PagedServingEngine,
    Request,
)

TINY_MOE = ModelConfig(
    name="tiny-offload-moe",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    d_ff_expert=64,
    vocab_size=128,
    num_experts=4,
    top_k=2,
    num_shared_experts=1,
    dtype="float32",
    remat="none",
    logits_chunk=32,
    attn_q_chunk=16,
    attn_kv_chunk=16,
)

BITS = [1, 2, 2, 3]  # buckets (count 1, 2, 1) -> num_slots = 4

# decode_horizon=1 pins the per-token baseline these budget/preemption
# traces were shaped around; the fused-megastep miss path (horizon-union
# working set, whole-megastep replay) has its own tests below
ECFG = EngineConfig(
    max_slots=2, block_size=4, num_blocks=16, max_blocks_per_slot=6,
    prefill_chunk=4, decode_horizon=1,
)


def compress_for_serving(cfg, params, bits=BITS):
    """Layer-uniform PMQ buckets in the stacked serving layout (no GPTQ,
    fp attention/router — the expert buckets are what offload manages)."""
    blocks = tf.unstack_blocks(params, cfg)
    blocks_c = []
    for p_l in blocks:
        experts = {
            k: np.asarray(p_l["moe"]["experts"][k])
            for k in ("w_gate", "w_up", "w_down")
        }
        ce = build_compressed_experts(experts, bits, group=32, ep=1,
                                      refine=False)
        blocks_c.append({
            "ln1": p_l["ln1"], "attn": p_l["attn"], "ln2": p_l["ln2"],
            "moe": {"router": p_l["moe"]["router"],
                    "shared": p_l["moe"]["shared"]},
            "moe_ce": ce,
        })
    return {
        "embed": params["embed"],
        "final_norm": params["final_norm"],
        "blocks": tf.restack_blocks(blocks_c),
    }


@pytest.fixture(scope="module")
def compressed_model():
    bundle = get_model(TINY_MOE)
    params = bundle.init(jax.random.PRNGKey(0))
    return TINY_MOE, compress_for_serving(TINY_MOE, params)


def make_requests(cfg, n, seed, max_new=5, plen=6):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new=max_new,
        )
        for i in range(n)
    ]


# ------------------------------------------------------- gather bit-exact
def test_resident_gather_bitwise_identical():
    """compressed_expert_ffn through a resident partition whose rows hold
    the true weights is bit-identical to the all-resident path — the
    gather moves bytes, never values."""
    rng = np.random.default_rng(0)
    e, d, f = 4, 32, 48
    experts = {
        "w_gate": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_up": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_down": rng.normal(size=(e, f, d)).astype(np.float32),
    }
    ce = build_compressed_experts(experts, BITS, group=16, ep=1, refine=False)
    cap = 8
    xp = jnp.asarray(rng.normal(size=(ce.num_slots * cap, d)), jnp.float32)
    y_full = np.asarray(compressed_expert_ffn(ce, xp, cap))
    # identity maps (all resident, rows == slots)
    rmap = {
        f"b{i}": jnp.arange(m.count, dtype=jnp.int32)
        for i, m in enumerate(ce.meta)
    }
    ce_id = dataclasses.replace(
        ce, resident_map=rmap, resident_rows=tuple(m.count for m in ce.meta)
    )
    np.testing.assert_array_equal(
        np.asarray(compressed_expert_ffn(ce_id, xp, cap)), y_full
    )
    # permuted rows: bucket b1 (count 2) stored reversed in its buffer
    arrays = dict(ce.arrays)
    arrays["b1"] = jax.tree.map(lambda a: a[::-1], ce.arrays["b1"])
    rmap2 = dict(rmap, b1=jnp.asarray([1, 0], jnp.int32))
    ce_perm = dataclasses.replace(
        ce, arrays=arrays, resident_map=rmap2,
        resident_rows=tuple(m.count for m in ce.meta),
    )
    np.testing.assert_array_equal(
        np.asarray(compressed_expert_ffn(ce_perm, xp, cap)), y_full
    )


def test_residency_changes_keep_pytree_stable():
    """Uploads change leaf *values* only: the flattened treedef — what
    decides whether jit retraces — is identical across residency states
    of the same budget, and differs once the budget (shapes) changes."""
    bundle = get_model(TINY_MOE)
    params = bundle.init(jax.random.PRNGKey(0))
    ce = compress_for_serving(TINY_MOE, params)["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=3)
    before = jax.tree_util.tree_structure(mgr.ce)
    # residency-only movement: bucket b1 (slots 1..2, budget 1) swaps its
    # resident slot — values move, treedef (what decides retraces) doesn't
    counts = np.zeros((2, mgr.num_slots), np.int64)
    counts[:, 2] = 1  # only the cold slot of b1 is used
    mgr.begin_step()
    ups, _ = mgr.ensure_resident(counts)
    assert ups >= 1 and mgr.grows == 0
    assert jax.tree_util.tree_structure(mgr.ce) == before
    assert mgr.resident_slots_of(0)["b1"] == {1}
    # working-set overflow (both b1 slots in one step) forces growth —
    # a legitimate shape/structure change that re-specializes the jit
    counts[:, 1] = 1
    mgr.begin_step()
    mgr.ensure_resident(counts)
    assert mgr.grows == 1
    assert jax.tree_util.tree_structure(mgr.ce) != before


# -------------------------------------------------- engine equivalence
@pytest.mark.parametrize("seed", [0, 1])
def test_offload_equivalence_budget_sweep(compressed_model, seed):
    """Greedy outputs are bit-identical to the all-resident engine at
    every budget from fully resident down to the per-bucket floor, over
    random traces with mid-flight admissions (3 requests, 2 slots)."""
    cfg, params = compressed_model
    baseline = PagedServingEngine(cfg, params, ECFG)
    out0 = baseline.serve(make_requests(cfg, 3, seed))
    assert baseline.offload is None
    num_slots = params["blocks"]["moe_ce"].num_slots
    for budget in range(num_slots, 2, -1):
        eng = PagedServingEngine(
            cfg, params, dataclasses.replace(ECFG, resident_experts=budget)
        )
        out = eng.serve(make_requests(cfg, 3, seed))
        assert out == out0, f"budget {budget} diverged from all-resident"
        m = eng.metrics.summary()
        if budget >= num_slots:
            # fully resident: every program run must hit
            assert m["expert_prefetch_misses"] == 0
            assert m["expert_hit_rate"] == 1.0


def test_forced_prefetch_miss_replays_bit_identical(compressed_model):
    """A budget below the slot count starts with cold experts resident
    nowhere — the first programs that route to them MUST miss, upload
    synchronously, replay, and still emit bit-identical tokens."""
    cfg, params = compressed_model
    baseline = PagedServingEngine(cfg, params, ECFG)
    out0 = baseline.serve(make_requests(cfg, 3, 0))
    eng = PagedServingEngine(
        cfg, params, dataclasses.replace(ECFG, resident_experts=3)
    )
    # before any traffic the device holds only the budgeted slice
    assert eng.offload.resident_bytes < eng.offload.host_bytes
    out = eng.serve(make_requests(cfg, 3, 0))
    m = eng.metrics.summary()
    assert m["expert_prefetch_misses"] >= 1, "trace must force a miss"
    assert m["expert_miss_uploads"] >= 1
    assert m["expert_upload_bytes"] > 0
    assert out == out0
    # resident gauge tracks the (possibly grown) device footprint
    assert eng.offload.resident_bytes <= eng.offload.host_bytes
    assert m["expert_resident_bytes_last"] == eng.offload.resident_bytes


def test_offload_composes_with_preemption(compressed_model):
    """Expert offload and KV preemption squeeze different memories; both
    at once must still reproduce the roomy all-resident run."""
    cfg, params = compressed_model
    roomy = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=3, num_blocks=16,
                            max_blocks_per_slot=4),
    )
    out0 = roomy.serve(make_requests(cfg, 3, 2, max_new=8, plen=3))
    tight = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=3, num_blocks=6,
                            max_blocks_per_slot=4, preempt_mode="swap",
                            resident_experts=3),
    )
    out = tight.serve(make_requests(cfg, 3, 2, max_new=8, plen=3))
    m = tight.metrics.summary()
    assert m["preemptions"] >= 1, "tight pool must preempt"
    assert out == out0


def test_offload_deterministic_replay(compressed_model):
    """Same trace, same budget ⇒ identical outputs AND identical
    wall-clock-free counters (prefetch decisions, miss uploads, upload
    bytes are all deterministic functions of the trace)."""
    cfg, params = compressed_model
    runs = []
    for _ in range(2):
        eng = PagedServingEngine(
            cfg, params, dataclasses.replace(ECFG, resident_experts=3)
        )
        out = eng.serve(make_requests(cfg, 3, 1))
        runs.append((out, eng.metrics.counters()))
    (out_a, ctr_a), (out_b, ctr_b) = runs
    assert out_a == out_b
    assert ctr_a == ctr_b


def test_budget_below_working_set_grows_not_corrupts(compressed_model):
    """The per-bucket floor (1 slot each) is below the decode working
    set here; the manager must grow the buffer (counted) and keep the
    outputs bit-identical — never silently compute with wrong rows."""
    cfg, params = compressed_model
    baseline = PagedServingEngine(cfg, params, ECFG)
    out0 = baseline.serve(make_requests(cfg, 2, 3))
    eng = PagedServingEngine(
        cfg, params, dataclasses.replace(ECFG, resident_experts=1)
    )
    out = eng.serve(make_requests(cfg, 2, 3))
    assert out == out0
    assert eng.offload.grows >= 1
    # grown buffers never exceed the bucket counts
    ce = params["blocks"]["moe_ce"]
    assert all(
        r <= m.count for r, m in zip(eng.offload.budgets, ce.meta)
    )


# -------------------------------------------- fused decode-horizon megasteps
@pytest.mark.parametrize("horizon", [2, 4, 8])
@pytest.mark.parametrize("seed", [0, 1])
def test_offload_equivalence_across_horizons(compressed_model, horizon, seed):
    """Acceptance (horizon × offload): for H ∈ {2, 4, 8} and every
    budget down to near the floor, the fused megastep's miss path —
    horizon-union working set, whole-megastep replay — emits tokens
    bit-identical to the all-resident H=1 engine."""
    cfg, params = compressed_model
    baseline = PagedServingEngine(cfg, params, ECFG)
    out0 = baseline.serve(make_requests(cfg, 3, seed, max_new=7))
    num_slots = params["blocks"]["moe_ce"].num_slots
    for budget in range(num_slots, 2, -1):
        eng = PagedServingEngine(
            cfg, params,
            dataclasses.replace(ECFG, resident_experts=budget,
                                decode_horizon=horizon),
        )
        out = eng.serve(make_requests(cfg, 3, seed, max_new=7))
        assert out == out0, (
            f"H={horizon} budget={budget} diverged from all-resident H=1"
        )


def test_offload_megastep_replay_counts(compressed_model):
    """A decode megastep whose working set was force-evicted after
    prefill must miss, replay the whole megastep (decode_replays ≥ 1),
    accept within the H·L induction bound — with bit-identical outputs
    and the replay time split out of the decode-compute timer."""
    cfg, params = compressed_model
    baseline = PagedServingEngine(
        cfg, params, dataclasses.replace(ECFG, decode_horizon=4)
    )
    reqs0 = make_requests(cfg, 2, 0, max_new=6)
    out0 = baseline.serve(reqs0)
    eng = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, resident_experts=3, decode_horizon=4),
    )
    reqs = make_requests(cfg, 2, 0, max_new=6)
    for r in reqs:
        eng.submit(r)
    eng._converge()  # admission plan: prefill uploads the prompt working set
    # force-evict bucket b1 entirely (its budget row goes free): any b1
    # traffic in the coming megasteps must miss inside the fused program;
    # the controller's residency convergence is disabled so prefetch
    # cannot quietly undo the eviction
    mgr = eng.offload
    mgr.slot_row["b1"][:, :] = -1
    mgr.row_slot["b1"][:, :] = -1
    eng.controller.offload = None
    eng.run()
    assert {r.rid: eng.results[r.rid] for r in reqs} == out0
    c = eng.metrics.counters()
    assert c["expert_prefetch_misses"] >= 1
    assert c["decode_replays"] >= 1  # ≥ 1 whole-megastep replay happened
    # dispatch accounting: every decode dispatch is a megastep or replay
    assert c["decode_dispatches"] == c["megasteps"] + c["decode_replays"]
    # induction bound: every megastep accepted within H·L extra runs
    assert c["decode_dispatches"] <= c["megasteps"] * (1 + 4 * cfg.num_layers)
    s = eng.metrics.summary()
    # satellite: replay/upload time is split out of the decode timer
    assert s["decode_offload_mean_s"] > 0.0
    assert s["decode_compute_mean_s"] > 0.0


def test_offload_horizon_composes_with_preemption(compressed_model):
    """Horizon × offload × preemption: all three memory squeezes at once
    still reproduce the roomy all-resident run (tight pool sized so the
    horizon-ahead reservations genuinely collide)."""
    cfg, params = compressed_model
    roomy = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=3, num_blocks=24,
                            max_blocks_per_slot=6),
    )
    out0 = roomy.serve(make_requests(cfg, 3, 2, max_new=16, plen=3))
    tight = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=3, num_blocks=7,
                            max_blocks_per_slot=6, preempt_mode="swap",
                            resident_experts=3, decode_horizon=4),
    )
    out = tight.serve(make_requests(cfg, 3, 2, max_new=16, plen=3))
    m = tight.metrics.summary()
    assert m["preemptions"] >= 1, "tight pool must preempt"
    assert out == out0


# ------------------------------------------------------- manager units
def test_prefetch_follows_router_stats(compressed_model):
    """The EMA prefetcher uploads the hottest slot of an under-budget
    bucket ahead of need and evicts the cold one."""
    cfg, params = compressed_model
    ce = params["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=3, ema_decay=0.5)
    # bucket b1 spans slots 1..2 with budget 1: slot 1 (local 0) seeded
    assert mgr.resident_slots_of(0)["b1"] == {0}
    counts = np.zeros((2, ce.num_slots), np.int64)
    counts[:, 2] = 5  # traffic hammers slot 2 (bucket-local 1)
    mgr.update_stats(counts)
    ups, nbytes = mgr.prefetch()
    assert ups >= 1 and nbytes > 0
    assert mgr.resident_slots_of(0)["b1"] == {1}
    # stats now favor the resident slot: prefetch is idempotent
    assert mgr.prefetch() == (0, 0)


def test_manager_rejects_bad_inputs(compressed_model):
    cfg, params = compressed_model
    ce = params["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=2)
    with pytest.raises(ValueError):
        ExpertOffloadManager(mgr.ce, resident_slots=2)  # already offloaded
    # unstacked (single-layer) buckets are not a serving layout
    rng = np.random.default_rng(0)
    e, d, f = 4, 32, 32
    experts = {
        "w_gate": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_up": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_down": rng.normal(size=(e, f, d)).astype(np.float32),
    }
    flat = build_compressed_experts(experts, BITS, group=16, ep=1,
                                    refine=False)
    with pytest.raises(ValueError):
        ExpertOffloadManager(flat, resident_slots=2)


def test_engine_requires_compressed_params_for_offload():
    bundle = get_model(TINY_MOE)
    params = bundle.init(jax.random.PRNGKey(0))  # fp experts, no moe_ce
    with pytest.raises(ValueError):
        PagedServingEngine(
            TINY_MOE, params,
            dataclasses.replace(ECFG, resident_experts=2),
        )


# ------------------------------------------------ async double-buffering
def test_async_issue_commit_flips_residency(compressed_model):
    """issue_async stages the planner's uploads without touching the
    live tables; commit_async flips buffers, tables, and device maps in
    one boundary step — after which prefetch is idempotent again."""
    cfg, params = compressed_model
    ce = params["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=3, ema_decay=0.5)
    assert mgr.resident_slots_of(0)["b1"] == {0}
    counts = np.zeros((2, ce.num_slots), np.int64)
    counts[:, 2] = 5  # bucket b1 local slot 1 turns hot
    mgr.update_stats(counts)
    targets = mgr.residency_targets()
    assert targets, "under-budget bucket must want the hot slot"
    ups, nbytes = mgr.issue_async(targets)
    assert ups >= 1 and nbytes > 0
    # staged, not live: the serving tables still show the cold slot
    assert mgr.resident_slots_of(0)["b1"] == {0}
    assert mgr.issue_async(targets) == (0, 0)  # one batch in flight max
    committed, dropped, cbytes, wait_s = mgr.commit_async()
    assert (committed, dropped) == (ups, 0) and cbytes == nbytes
    assert wait_s >= 0.0
    assert mgr.resident_slots_of(0)["b1"] == {1}
    assert mgr.residency_targets() == ()  # converged
    assert mgr.commit_async() == (0, 0, 0, 0.0)  # nothing in flight


def test_async_commit_drops_stale_batch(compressed_model):
    """A sync upload landing between issue and commit (a miss replay, a
    grow) bumps the bucket version; the staged batch must be dropped
    whole — never flipped over fresher buffers."""
    cfg, params = compressed_model
    ce = params["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=3, ema_decay=0.5)
    counts = np.zeros((2, ce.num_slots), np.int64)
    counts[:, 2] = 5
    mgr.update_stats(counts)
    ups, _ = mgr.issue_async(mgr.residency_targets())
    assert ups >= 1
    # a miss replay beats the staged batch to the same rows: the
    # synchronous backstop uploads immediately and bumps the version
    miss = np.zeros((2 * 2, ce.num_slots), np.int64)
    miss[0, 2] = 1
    m_ups, m_bytes = mgr.ensure_resident(miss)
    assert m_ups >= 1
    committed, dropped, cbytes, wait_s = mgr.commit_async()
    assert committed == 0 and dropped == ups
    assert cbytes == 0 and wait_s == 0.0
    # the miss upload's placement is live and correct
    assert 1 in mgr.resident_slots_of(0)["b1"]


def test_async_engine_outputs_bit_identical(compressed_model):
    """Engine-level: async_offload=True serves bit-identical tokens to
    the synchronous engine across budgets (placement independence makes
    the one-boundary-stale plan invisible to outputs)."""
    cfg, params = compressed_model
    num_slots = params["blocks"]["moe_ce"].num_slots
    for budget in (num_slots - 1, 3):
        sync = PagedServingEngine(
            cfg, params,
            dataclasses.replace(ECFG, decode_horizon=4,
                                resident_experts=budget),
        )
        out0 = sync.serve(make_requests(cfg, 4, 7, max_new=8))
        eng = PagedServingEngine(
            cfg, params,
            dataclasses.replace(ECFG, decode_horizon=4,
                                resident_experts=budget,
                                async_offload=True),
        )
        out = eng.serve(make_requests(cfg, 4, 7, max_new=8))
        assert out == out0, f"async diverged at budget {budget}"


def test_async_requires_offload_config():
    """async_offload / offload_dir without a residency budget is a
    config error, not a silent no-op."""
    bundle = get_model(TINY_MOE)
    params = bundle.init(jax.random.PRNGKey(0))
    for kw in ({"async_offload": True}, {"offload_dir": "/tmp/nope"}):
        with pytest.raises(ValueError):
            PagedServingEngine(
                TINY_MOE, params, dataclasses.replace(ECFG, **kw)
            )


# ------------------------------------------------ three-tier expert store
def test_tierstore_roundtrip_bitwise(compressed_model, tmp_path):
    """Spill to mmap'd packed buckets, reopen cold, and read every row
    back bitwise-equal with the CRC the manifest recorded."""
    from repro.serving.tierstore import TieredExpertStore

    cfg, params = compressed_model
    ce = params["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=3)  # in-memory host
    host = mgr.host
    store = TieredExpertStore(host, offload_dir=str(tmp_path / "tier"))
    reopened = TieredExpertStore.reopen(str(tmp_path / "tier"))
    for bk, tree in host.items():
        layers = jax.tree.leaves(tree)[0].shape[0]
        slots = jax.tree.leaves(tree)[0].shape[1]
        for l in range(layers):
            for s in range(slots):
                want = jax.tree.map(lambda a: np.asarray(a[l, s]), tree)
                for st in (store, reopened):
                    got = st.row(bk, l, s)
                    for wl, gl in zip(jax.tree.leaves(want),
                                     jax.tree.leaves(got)):
                        assert wl.dtype == gl.dtype
                        assert np.array_equal(wl, gl)
                assert store.crc(bk, l, s) == reopened.crc(bk, l, s)


def test_tierstore_detects_corruption(compressed_model, tmp_path):
    """Flipping bytes in a spilled leaf file fails closed on fetch —
    CRC mismatch raises ExpertUploadFailed, never serves wrong rows."""
    from repro.serving.faults import ExpertUploadFailed
    from repro.serving.tierstore import TieredExpertStore

    cfg, params = compressed_model
    ce = params["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=3)
    d = tmp_path / "tier"
    TieredExpertStore(mgr.host, offload_dir=str(d))
    victim = sorted(p for p in d.iterdir() if p.suffix == ".npy")[0]
    raw = bytearray(victim.read_bytes())
    raw[-64:] = bytes(64)  # stomp the tail of the array payload
    victim.write_bytes(bytes(raw))
    reopened = TieredExpertStore.reopen(str(d))
    bk = victim.name.split("__")[0]
    with pytest.raises(ExpertUploadFailed):
        for l in range(2):
            for s in range(8):
                try:
                    reopened.row(bk, l, s)
                except IndexError:
                    break


def test_tiered_engine_serves_from_disk(compressed_model, tmp_path):
    """End-to-end: an engine whose device budget is below total expert
    bytes and whose backing store lives on disk serves bit-identical
    tokens, with every cold fetch CRC-verified and counted."""
    cfg, params = compressed_model
    sync = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, decode_horizon=4, resident_experts=3),
    )
    out0 = sync.serve(make_requests(cfg, 3, 5, max_new=8))
    eng = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, decode_horizon=4, resident_experts=3,
                            offload_dir=str(tmp_path / "tier"),
                            host_expert_bytes=8192),
    )
    assert eng.offload.host is None  # numpy host store replaced by tiers
    # the configured device budget starts below the disk store's total
    # (grows may later close the gap — correctness beats the budget)
    assert eng.offload.resident_bytes < eng.offload.host_bytes
    out = eng.serve(make_requests(cfg, 3, 5, max_new=8))
    assert out == out0
    c = eng.metrics.counters()
    assert c["tier_disk_hits"] >= 1
    assert c["tier_disk_bytes"] > 0
    # the bounded host row cache stayed under its byte budget
    assert eng.offload.store.host_cached_bytes <= 8192


# ------------------------------------------------ backoff boundedness
def test_prefetch_backoff_map_stays_bounded(compressed_model):
    """The deferred-retry map prunes at plan boundaries: entries whose
    row degraded (terminal) or became resident (satisfied) can never be
    consumed and must not accumulate over a long serve."""
    from repro.serving import FaultPlan, FaultSpec

    cfg, params = compressed_model
    plan = FaultPlan([FaultSpec(site="upload", mode="fail", count=2)])
    eng = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, decode_horizon=4, resident_experts=3),
        faults=plan,
    )
    out = eng.serve(make_requests(cfg, 4, 11, max_new=8))
    assert out  # transient faults recovered (miss path retries inline)
    mgr = eng.offload
    live = len(mgr._retry_after)
    assert live <= mgr.num_layers * mgr.num_slots, (
        f"retry map leaked: {live} entries"
    )
    pruned = mgr.prune_backoff()
    # after an explicit prune every surviving entry is still consumable:
    # non-degraded and non-resident
    for bk, layer, slot in mgr._retry_after:
        assert (bk, layer, slot) not in mgr._degraded_rows
        assert mgr.slot_row[bk][layer, slot] < 0
    assert pruned >= 0


def test_prune_backoff_removes_dead_entries(compressed_model):
    """Unit: entries for degraded rows and for rows that became resident
    are exactly the ones pruned; a pending consumable entry survives."""
    cfg, params = compressed_model
    ce = params["blocks"]["moe_ce"]
    mgr = ExpertOffloadManager(ce, resident_slots=3)
    resident_key = ("b1", 0, 0)   # seeded resident (local slot 0)
    pending_key = ("b1", 1, 1)    # non-resident in layer 1
    assert mgr.slot_row["b1"][0, 0] >= 0
    assert mgr.slot_row["b1"][1, 1] < 0
    mgr._retry_after[resident_key] = 10
    mgr._retry_after[pending_key] = 10
    mgr._degraded_rows[("b2", 0, 0)] = {"dead": True}
    mgr._retry_after[("b2", 0, 0)] = 99
    mgr._attempts[("b2", 0, 0)] = 7
    assert mgr.prune_backoff() == 2
    assert set(mgr._retry_after) == {pending_key}
    assert ("b2", 0, 0) not in mgr._attempts
    del mgr._degraded_rows[("b2", 0, 0)]
