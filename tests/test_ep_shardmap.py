"""shard_map EP vs single-device reference: identical math.

Runs in a subprocess with 8 forced host devices; the same weights and
tokens go through (a) the pjit/no-mesh MoE layer and (b) the shard_map
EP region on a 2×4 mesh — outputs must match to float tolerance. Also
covers the PMQ-compressed region (incl. slot remapping + OTP mask).
"""
import os
import subprocess
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# portable child env (CI checkouts are not /root/repo): keep the host's
# PATH/HOME, and never probe for accelerators in the child — a stripped
# env otherwise stalls minutes in TPU discovery
_CHILD_ENV = {
    "PYTHONPATH": "src",
    "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
    "HOME": os.environ.get("HOME", "/root"),
    "JAX_PLATFORMS": "cpu",
}

_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_test_mesh
from repro.models.moe import init_moe, moe_layer
from repro.models.registry import get_model
from repro.parallel.sharding import sharding_rules, activation_rules
from repro.core.compressed_moe import build_compressed_experts, compressed_moe_layer
from repro.core.otp import init_otp_router

CFG = ModelConfig(
    name="eptest", family="moe", num_layers=1, d_model=64, num_heads=2,
    num_kv_heads=2, head_dim=32, d_ff=128, d_ff_expert=128, vocab_size=128,
    num_experts=8, top_k=2, num_shared_experts=1, dtype="float32",
    remat="none", moe_capacity_factor=4.0, logits_chunk=32,
    attn_q_chunk=32, attn_kv_chunk=32,
)
rng = jax.random.PRNGKey(0)
p = init_moe(rng, CFG)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, CFG.d_model))

# reference (no mesh context)
ref = moe_layer(p, x, CFG)
mesh = make_test_mesh(data=2, model=4)
with mesh, sharding_rules(mesh, activation_rules(mesh)):
    out = jax.jit(lambda p, x: moe_layer(p, x, CFG).y)(p, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref.y), rtol=2e-4, atol=2e-4)
print("bf16-path OK")

# compressed path (+ OTP deterministic mask)
experts = {k: np.asarray(p["experts"][k]) for k in ("w_gate", "w_up", "w_down")}
bits = np.array([1, 2, 2, 2, 2, 3, 3, 2])
ce4 = build_compressed_experts(experts, bits, group=64, ep=4, refine=False)
ce1 = build_compressed_experts(experts, bits, group=64, ep=1, refine=False)
otp = init_otp_router(jax.random.PRNGKey(3), CFG.d_model, CFG.top_k)
y_ref, info_ref = compressed_moe_layer(p, ce1, x, CFG, otp_params=otp)
with mesh, sharding_rules(mesh, activation_rules(mesh)):
    y_sm, info_sm = jax.jit(
        lambda p, ce, x, otp: compressed_moe_layer(p, ce, x, CFG, otp_params=otp)
    )(p, ce4, x, otp)
np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref), rtol=5e-4, atol=5e-4)
ml_ref = float(info_ref["mask_l1"])
ml_sm = float(info_sm["mask_l1"])
assert abs(ml_ref - ml_sm) < 1e-5, (ml_ref, ml_sm)
print("compressed-path OK", ml_ref)

# ETP gather_weights mode (large-T path): force via env threshold
os.environ["REPRO_ETP_REPLICATE_MAX"] = "1"
with mesh, sharding_rules(mesh, activation_rules(mesh)):
    y_gw, _ = jax.jit(
        lambda p, ce, x: compressed_moe_layer(p, ce, x, CFG)
    )(p, ce4, x)
y_ref_nootp, _ = compressed_moe_layer(p, ce1, x, CFG)
np.testing.assert_allclose(
    np.asarray(y_gw), np.asarray(y_ref_nootp), rtol=5e-4, atol=5e-4
)
print("gather-weights OK")
"""


def test_ep_shardmap_matches_reference():
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET],
        capture_output=True, text=True, timeout=900,
        env=_CHILD_ENV,
        cwd=_REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "bf16-path OK" in r.stdout
    assert "compressed-path OK" in r.stdout
