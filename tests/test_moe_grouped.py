"""Grouped expert-GEMM dispatch (the compressed-MoE hot path).

Contract: the grouped path — ragged compaction + ``ops.moe_gmm`` /
``ops.moe_gmm_swiglu`` with ``num_active`` block skipping — computes the
same thing as the legacy per-expert scan for every routing pattern:
bit-bucket mixes, OTP masks, capacity clipping, empty experts, resident
partitions, and expert-parallel reshapes. Plus: the Pallas kernels match
their jnp oracles in interpret mode, and the serving engine's greedy
outputs are unchanged under the default (grouped) backend.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import compressed_moe as cm
from repro.core.quantizers import quantize_to_packed
from repro.kernels import ops, ref
from repro.models.moe import capacity_dispatch, slot_fill_counts


def _experts(e, d, f, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w_gate": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_up": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_down": rng.normal(size=(e, f, d)).astype(np.float32),
    }


def _routed(ce, t, k, cap, seed, mask_p=0.0):
    """Random routing → (xp, slot_fill, dest, valid)."""
    rng = np.random.default_rng(seed)
    x2 = jnp.asarray(rng.normal(size=(t, ce.d_model)), jnp.float32)
    slots = jnp.asarray(rng.integers(0, ce.num_slots, size=(t, k)), jnp.int32)
    gates = jax.nn.softmax(jnp.asarray(rng.normal(size=(t, k)), jnp.float32))
    mask = None
    if mask_p > 0:
        mask = jnp.asarray(
            (rng.random((t, k)) > mask_p).astype(np.float32)
        )
    xp, dest, valid, _ = capacity_dispatch(
        x2, slots, gates, ce.num_slots, cap, mask
    )
    fill = slot_fill_counts(dest, valid, ce.num_slots, cap)
    return xp, fill, dest, valid


# ------------------------------------------------- grouped == scan (fuzzed)
@given(
    bits_seed=st.integers(0, 1000),
    t=st.integers(6, 28),
    k=st.integers(1, 3),
    cap=st.sampled_from([8, 16, 24]),
    mask_p=st.sampled_from([0.0, 0.4]),
)
@settings(max_examples=10, deadline=None)
def test_grouped_matches_scan_fuzzed(bits_seed, t, k, cap, mask_p):
    rng = np.random.default_rng(bits_seed)
    e = int(rng.integers(3, 7))
    bits = [int(b) for b in rng.choice([1, 2, 3, 4], size=e)]
    ce = cm.build_compressed_experts(
        _experts(e, 32, 48, seed=bits_seed), bits, group=16, ep=1,
        refine=False,
    )
    xp, fill, dest, valid = _routed(ce, t, k, cap, bits_seed, mask_p)
    y_scan = np.asarray(cm.compressed_expert_ffn(ce, xp, cap, backend="scan"))
    y_ref = np.asarray(
        cm.compressed_expert_ffn(ce, xp, cap, backend="ref", slot_fill=fill)
    )
    y_int = np.asarray(
        cm.compressed_expert_ffn(
            ce, xp, cap, backend="interpret", slot_fill=fill
        )
    )
    np.testing.assert_allclose(y_ref, y_scan, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(y_int, y_ref, rtol=2e-4, atol=2e-4)
    # uncompacted grouped layout (no slot_fill) agrees too
    y_nofill = np.asarray(
        cm.compressed_expert_ffn(ce, xp, cap, backend="ref")
    )
    np.testing.assert_allclose(y_nofill, y_ref, rtol=2e-4, atol=2e-4)


def test_empty_expert_contributes_nothing():
    """An expert with zero routed rows must produce exactly-zero output
    rows and zero grouped blocks — the ragged frontier skips it."""
    e = 4
    ce = cm.build_compressed_experts(
        _experts(e, 32, 32, seed=1), [2, 2, 4, 4], group=16, ep=1,
        refine=False,
    )
    cap = 16
    t, k = 10, 2
    rng = np.random.default_rng(2)
    x2 = jnp.asarray(rng.normal(size=(t, 32)), jnp.float32)
    # route everything to slot 1: slots 0, 2, 3 stay empty
    slots = jnp.ones((t, k), jnp.int32)
    gates = jnp.full((t, k), 0.5, jnp.float32)
    xp, dest, valid, _ = capacity_dispatch(x2, slots, gates, ce.num_slots, cap)
    fill = slot_fill_counts(dest, valid, ce.num_slots, cap)
    assert list(np.asarray(fill)) == [0, 16, 0, 0]  # cap-clipped to 16
    y = np.asarray(
        cm.compressed_expert_ffn(ce, xp, cap, backend="ref", slot_fill=fill)
    )
    y_scan = np.asarray(cm.compressed_expert_ffn(ce, xp, cap, backend="scan"))
    np.testing.assert_allclose(y, y_scan, rtol=2e-4, atol=2e-4)
    for s in (0, 2, 3):
        assert np.all(y[s * cap : (s + 1) * cap] == 0.0)


def test_grouped_resident_map_bitwise_identical():
    """Resident indirection rides the scalar block_expert table: same
    bits in, same floats out as the all-resident grouped path."""
    ce = cm.build_compressed_experts(
        _experts(4, 32, 48, seed=3), [1, 2, 2, 3], group=16, ep=1,
        refine=False,
    )
    cap = 8
    xp, fill, _, _ = _routed(ce, 12, 2, cap, seed=4)
    y_full = np.asarray(
        cm.compressed_expert_ffn(ce, xp, cap, backend="ref", slot_fill=fill)
    )
    # permuted resident rows: bucket b1 (count 2) stored reversed
    arrays = dict(ce.arrays)
    arrays["b1"] = jax.tree.map(lambda a: a[::-1], ce.arrays["b1"])
    rmap = {
        f"b{i}": jnp.arange(m.count, dtype=jnp.int32)
        for i, m in enumerate(ce.meta)
    }
    rmap["b1"] = jnp.asarray([1, 0], jnp.int32)
    ce_perm = dataclasses.replace(
        ce, arrays=arrays, resident_map=rmap,
        resident_rows=tuple(m.count for m in ce.meta),
    )
    y_res = np.asarray(
        cm.compressed_expert_ffn(
            ce_perm, xp, cap, backend="ref", slot_fill=fill
        )
    )
    np.testing.assert_array_equal(y_res, y_full)


def test_grouped_ep_reshape_equivalent(monkeypatch):
    """ep > 1 splits each bucket across the model axis; the vmapped
    grouped path must agree with the ep=1 result (same math, reshaped)."""
    ce = cm.build_compressed_experts(
        _experts(4, 32, 32, seed=5), [2, 2, 2, 2], group=16, ep=2,
        refine=False,
    )
    cap = 16
    xp, fill, _, _ = _routed(ce, 14, 2, cap, seed=6)
    y1 = np.asarray(
        cm.compressed_expert_ffn(ce, xp, cap, backend="ref", slot_fill=fill)
    )
    monkeypatch.setattr(cm, "model_axis_size", lambda: 2)
    y2 = np.asarray(
        cm.compressed_expert_ffn(ce, xp, cap, backend="ref", slot_fill=fill)
    )
    np.testing.assert_allclose(y2, y1, rtol=2e-5, atol=2e-5)


def test_bad_backend_rejected():
    ce = cm.build_compressed_experts(
        _experts(2, 32, 32, seed=7), [2, 2], group=16, ep=1, refine=False
    )
    xp = jnp.zeros((ce.num_slots * 8, 32), jnp.float32)
    with pytest.raises(ValueError, match="not in"):
        cm.compressed_expert_ffn(ce, xp, 8, backend="nope")


def test_gmm_block_rows_divides_cap():
    for cap in (8, 16, 24, 32, 64, 128, 256, 1000 * 8):
        bm = cm.gmm_block_rows(cap)
        assert cap % bm == 0 and bm % 8 == 0


# ------------------------------------------------------ kernel-level ragged
def _packed_bucket(e, k, n, bits, group, seed):
    rng = np.random.default_rng(seed)
    ws = [jnp.asarray(rng.normal(size=(k, n)), jnp.float32) for _ in range(e)]
    pts = [quantize_to_packed(w, bits, group=group, refine=False) for w in ws]
    if bits == 3:
        packed = (
            jnp.stack([p.data[0] for p in pts]),
            jnp.stack([p.data[1] for p in pts]),
        )
    else:
        packed = jnp.stack([p.data for p in pts])
    scale = jnp.stack([p.scale for p in pts])
    zero = jnp.stack([p.zero for p in pts])
    return packed, scale, zero


@pytest.mark.parametrize("bits", [1, 2, 3, 4])
def test_moe_gmm_num_active_skips_blocks(bits):
    e, k, n, bm = 3, 128, 128, 8
    packed, scale, zero = _packed_bucket(e, k, n, bits, 128, seed=bits)
    rng = np.random.default_rng(bits + 1)
    m = 6 * bm
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    be = jnp.asarray([0, 0, 1, 2, 2, 2], jnp.int32)
    na = jnp.asarray([4], jnp.int32)
    y_ref = ref.moe_gmm_ref(
        x, packed, scale, zero, be, na, bits=bits, group=128, bm=bm
    )
    y = ops.moe_gmm(
        x, packed, scale, zero, be, na,
        bits=bits, group=128, backend="interpret", bm=bm,
    )
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5
    )
    # blocks past the frontier are exactly zero; blocks before it match
    # the unmasked GEMM
    y_all = ref.moe_gmm_ref(
        x, packed, scale, zero, be, bits=bits, group=128, bm=bm
    )
    np.testing.assert_array_equal(np.asarray(y)[4 * bm :], 0.0)
    np.testing.assert_allclose(
        np.asarray(y)[: 4 * bm], np.asarray(y_all)[: 4 * bm],
        rtol=2e-5, atol=2e-5,
    )


@pytest.mark.parametrize("bits", [2, 3])
def test_moe_gmm_swiglu_matches_oracle(bits):
    e, k, n, bm = 3, 128, 128, 8
    gp, gs, gz = _packed_bucket(e, k, n, bits, 128, seed=10 + bits)
    up, us, uz = _packed_bucket(e, k, n, bits, 128, seed=20 + bits)
    rng = np.random.default_rng(30 + bits)
    m = 4 * bm
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    be = jnp.asarray([0, 1, 1, 2], jnp.int32)
    na = jnp.asarray([3], jnp.int32)
    y_ref = ref.moe_gmm_swiglu_ref(
        x, gp, up, gs, gz, us, uz, be, na, bits=bits, group=128, bm=bm
    )
    # oracle == composition of the two plain grouped GEMMs
    comp = jax.nn.silu(
        ref.moe_gmm_ref(x, gp, gs, gz, be, na, bits=bits, group=128, bm=bm)
    ) * ref.moe_gmm_ref(x, up, us, uz, be, na, bits=bits, group=128, bm=bm)
    np.testing.assert_allclose(
        np.asarray(y_ref), np.asarray(comp), rtol=2e-5, atol=2e-5
    )
    y = ops.moe_gmm_swiglu(
        x, gp, up, gs, gz, us, uz, be, na,
        bits=bits, group=128, backend="interpret", bm=bm,
    )
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5
    )
    np.testing.assert_array_equal(np.asarray(y)[3 * bm :], 0.0)


# ------------------------------------------------- serving greedy unchanged
def test_engine_greedy_outputs_unchanged_by_backend():
    """The default (grouped) engine serves the exact same greedy tokens
    as a scan-backend engine over the same trace — the kernel-path
    swap is invisible to served traffic."""
    from test_offload import TINY_MOE, compress_for_serving, make_requests
    from repro.models.registry import get_model
    from repro.serving import EngineConfig, PagedServingEngine, Request

    bundle = get_model(TINY_MOE)
    params = bundle.init(jax.random.PRNGKey(0))
    params_c = compress_for_serving(TINY_MOE, params)
    ecfg = EngineConfig(
        max_slots=2, block_size=4, num_blocks=16, max_blocks_per_slot=6,
        prefill_chunk=4,
    )
    outs = {}
    for backend in (None, "scan"):
        engine = PagedServingEngine(
            TINY_MOE, params_c,
            dataclasses.replace(ecfg, ffn_backend=backend),
        )
        reqs = make_requests(TINY_MOE, 3, seed=11, max_new=4)
        outs[backend] = engine.serve(reqs)
        if backend is None:
            # PMQ engines must report the capacity-padding gauge
            util = engine.metrics.capacity_utilization
            assert util and all(0.0 < u <= 1.0 for u in util)
    assert outs[None] == outs["scan"]
