"""Serving subsystem tests: paged-KV allocator invariants, paged-vs-dense
decode equivalence, and continuous-batching scheduler behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention_pallas
from repro.models import transformer as tf
from repro.models.registry import get_model
from repro.serving import (
    BlockAllocator,
    EngineConfig,
    PagedKVCache,
    PagedServingEngine,
    PoolExhausted,
    Request,
)
from repro.serving.engine import dense_greedy_reference

TINY_MOE = ModelConfig(
    name="tiny-serving-moe",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    d_ff_expert=64,
    vocab_size=128,
    num_experts=4,
    top_k=2,
    num_shared_experts=1,
    dtype="float32",
    remat="none",
    logits_chunk=32,
    attn_q_chunk=16,
    attn_kv_chunk=16,
)

ECFG = EngineConfig(
    max_slots=2, block_size=4, num_blocks=16, max_blocks_per_slot=6,
    prefill_chunk=4,
)


@pytest.fixture(scope="module")
def model():
    bundle = get_model(TINY_MOE)
    params = bundle.init(jax.random.PRNGKey(0))
    return TINY_MOE, params


# ------------------------------------------------------- block allocator
def test_allocator_alloc_free_roundtrip():
    a = BlockAllocator(8)
    blocks = a.alloc(5)
    assert len(set(blocks)) == 5 and a.num_free == 3
    a.free(blocks)
    assert a.num_free == 8


def test_allocator_exhaustion_raises_and_leaves_state():
    a = BlockAllocator(4)
    a.alloc(3)
    with pytest.raises(PoolExhausted):
        a.alloc(2)
    assert a.num_free == 1  # failed alloc took nothing


def test_allocator_double_free_raises():
    a = BlockAllocator(4)
    blocks = a.alloc(2)
    a.free(blocks)
    with pytest.raises(ValueError):
        a.free([blocks[0]])
    with pytest.raises(ValueError):
        a.free([99])  # never allocated


def test_allocator_recycles_blocks():
    a = BlockAllocator(4)
    first = a.alloc(4)
    a.free(first)
    second = a.alloc(4)
    assert sorted(second) == sorted(first)  # same physical pages reused


def test_kvcache_slot_lifecycle():
    cache = PagedKVCache.create(
        TINY_MOE, num_blocks=8, block_size=4, max_slots=2,
        max_blocks_per_slot=4,
    )
    slot = cache.acquire_slot(10)  # 3 blocks
    assert cache.allocator.num_free == 5
    assert (cache.block_tables[slot, :3] >= 0).all()
    with pytest.raises(PoolExhausted):
        cache.acquire_slot(17)  # 5 blocks > max_blocks_per_slot
    cache.release_slot(slot)
    assert cache.allocator.num_free == 8
    assert slot in cache.free_slots


# ------------------------------------------------- paged attention kernel
@pytest.mark.parametrize("window", [None, 7])
def test_paged_attention_pallas_matches_ref(window):
    rng = np.random.default_rng(0)
    b, hkv, g, dh, nb, bs, mb = 3, 2, 2, 32, 16, 8, 4
    q = jnp.asarray(rng.normal(size=(b, hkv, g, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, hkv, dh)), jnp.float32)
    bt = jnp.asarray(rng.permutation(nb)[: b * mb].reshape(b, mb), jnp.int32)
    lengths = jnp.asarray([5, 17, 32], jnp.int32)
    o_ref = ref.paged_attention_ref(q, kp, vp, bt, lengths, window=window)
    win = jnp.asarray([window if window else mb * bs + 1], jnp.int32)
    o_pal = paged_attention_pallas(q, kp, vp, bt, lengths, win, interpret=True)
    np.testing.assert_allclose(
        np.asarray(o_ref), np.asarray(o_pal), rtol=1e-5, atol=1e-5
    )


# ------------------------------------------------ paged == dense decoding
def test_paged_matches_dense_logits(model):
    """Chunked paged prefill + paged decode reproduce the dense path's
    logits step for step (the engine runs at drop-free expert capacity,
    so the reference does too)."""
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)
    mcfg = eng.model_cfg
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=9).astype(np.int32)
    max_new = 4
    ref_toks, ref_logits = dense_greedy_reference(mcfg, params, prompt, max_new)

    # drive the jitted steps directly to observe per-step logits
    cache = eng.cache
    slot = cache.acquire_slot(len(prompt) + max_new)
    table_row = jnp.asarray(cache.block_tables[slot : slot + 1])
    c = ECFG.prefill_chunk
    for off in range(0, len(prompt), c):
        n = min(c, len(prompt) - off)
        chunk = np.zeros((1, c), np.int32)
        chunk[0, :n] = prompt[off : off + n]
        cache.k, cache.v, logits = eng._prefill(
            params, cache.k, cache.v, jnp.asarray(chunk),
            jnp.int32(off), jnp.int32(n), table_row,
        )
    np.testing.assert_allclose(
        np.asarray(logits)[0, -1], ref_logits[0], rtol=1e-4, atol=1e-4
    )
    toks = [int(np.argmax(np.asarray(logits)[0, -1]))]
    pos = len(prompt)
    b = ECFG.max_slots
    for step in range(max_new - 1):
        token = np.zeros((b, 1), np.int32)
        token[slot] = toks[-1]
        positions = np.zeros((b,), np.int32)
        positions[slot] = pos
        active = np.zeros((b,), bool)
        active[slot] = True
        cache.k, cache.v, logits, _ = eng._decode(
            params, cache.k, cache.v, jnp.asarray(token),
            jnp.asarray(positions), cache.tables_device(), jnp.asarray(active),
        )
        np.testing.assert_allclose(
            np.asarray(logits)[slot, -1], ref_logits[step + 1],
            rtol=1e-4, atol=1e-4,
        )
        toks.append(int(np.argmax(np.asarray(logits)[slot, -1])))
        pos += 1
    assert toks == ref_toks
    cache.release_slot(slot)


def test_engine_serve_matches_dense_greedy_reference(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=7).astype(np.int32)
    ref_toks, _ = dense_greedy_reference(eng.model_cfg, params, prompt, 5)
    out = eng.serve([Request(rid=0, prompt=prompt, max_new=5)])
    assert out[0] == ref_toks


# -------------------------------------------------- continuous batching
def test_scheduler_mid_flight_admission(model):
    """With 2 slots and 3 requests, the third must join once a short
    request finishes — no wave barrier, pages recycled."""
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)
    rng = np.random.default_rng(3)
    reqs = [
        Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32), max_new=2),
        Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32), max_new=8),
        Request(rid=2, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32), max_new=3),
    ]
    out = eng.serve(reqs)
    for r in reqs:
        assert len(out[r.rid]) == r.max_new  # independent completion
    m = eng.metrics.summary()
    assert m["mid_flight_admissions"] >= 1
    assert m["slot_releases"] == 3
    # request 2 was admitted strictly after decoding started
    admit_steps = {a["rid"]: a["step"] for a in eng.metrics.admissions}
    assert admit_steps[2] > 0
    # all pages returned to the pool
    assert eng.cache.allocator.num_free == ECFG.num_blocks
    assert len(eng.cache.free_slots) == ECFG.max_slots


def test_model_api_paged_dispatch(model):
    """The bundle-level API accepts the paged cache layout: decode_step
    dispatches on ``"block_tables" in cache`` and prefill on ``paged=``,
    both matching the direct paged functions."""
    cfg, params = model
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    cache = PagedKVCache.create(
        cfg, num_blocks=8, block_size=4, max_slots=2, max_blocks_per_slot=4
    )
    slot = cache.acquire_slot(len(prompt) + 2)
    table_row = jnp.asarray(cache.block_tables[slot : slot + 1])
    pc = {"k": cache.k, "v": cache.v, "block_tables": table_row}
    # prefill via the dispatch kwarg == direct paged_prefill_chunk
    pc1, logits1 = tf.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, cfg,
        paged={"cache": pc},
    )
    pc2, logits2 = tf.paged_prefill_chunk(
        params, pc, jnp.asarray(prompt[None]), jnp.int32(0),
        jnp.int32(len(prompt)), cfg,
    )
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2))
    # decode via decode_step dispatch == direct paged_decode_step
    tables = jnp.zeros((2, 4), jnp.int32).at[0].set(table_row[0])
    dcache = {
        "k": pc1["k"], "v": pc1["v"], "block_tables": tables,
        "active": jnp.asarray([True, False]),
    }
    token = jnp.asarray([[int(np.argmax(np.asarray(logits1)[0, -1]))], [0]],
                        jnp.int32)
    positions = jnp.asarray([len(prompt), 0], jnp.int32)
    out1, lg1 = tf.decode_step(params, dcache, token, positions, cfg)
    out2, lg2, info = tf.paged_decode_step(params, dcache, token, positions, cfg)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2))
    assert float(info["expert_activation"]) == 1.0  # no OTP params here
    assert "block_tables" in out1


def test_empty_prompt_rejected(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(0, np.int32), max_new=4))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=1, prompt=np.zeros(4, np.int32), max_new=0))


def test_oversized_request_rejected(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)
    prompt = np.zeros(ECFG.max_blocks_per_slot * ECFG.block_size, np.int32)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=prompt, max_new=4))


def test_pool_too_small_raises(model):
    cfg, params = model
    ecfg = dataclasses.replace(ECFG, num_blocks=2, max_blocks_per_slot=6)
    eng = PagedServingEngine(cfg, params, ecfg)
    prompt = np.zeros(12, np.int32)  # needs 4 blocks, pool has 2
    with pytest.raises(PoolExhausted):
        eng.serve([Request(rid=0, prompt=prompt, max_new=4)])
