"""Serving subsystem tests: paged-KV allocator invariants, paged-vs-dense
decode equivalence, and continuous-batching scheduler behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention_pallas
from repro.models import transformer as tf
from repro.models.registry import get_model
from repro.serving import (
    BlockAllocator,
    EngineConfig,
    PagedKVCache,
    PagedServingEngine,
    PoolExhausted,
    PrefixCache,
    Request,
)
from repro.serving.engine import dense_greedy_reference

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

TINY_MOE = ModelConfig(
    name="tiny-serving-moe",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    d_ff_expert=64,
    vocab_size=128,
    num_experts=4,
    top_k=2,
    num_shared_experts=1,
    dtype="float32",
    remat="none",
    logits_chunk=32,
    attn_q_chunk=16,
    attn_kv_chunk=16,
)

# decode_horizon=1 pins the historical per-token program — the baseline
# whose pressure dynamics (growth/preemption counts, admission steps)
# these tests assert exactly; fused-horizon behavior is covered by the
# dedicated horizon tests below and the randomized harness
ECFG = EngineConfig(
    max_slots=2, block_size=4, num_blocks=16, max_blocks_per_slot=6,
    prefill_chunk=4, decode_horizon=1,
)


@pytest.fixture(scope="module")
def model():
    bundle = get_model(TINY_MOE)
    params = bundle.init(jax.random.PRNGKey(0))
    return TINY_MOE, params


# ------------------------------------------------------- block allocator
def test_allocator_alloc_free_roundtrip():
    a = BlockAllocator(8)
    blocks = a.alloc(5)
    assert len(set(blocks)) == 5 and a.num_free == 3
    a.free(blocks)
    assert a.num_free == 8


def test_allocator_alloc_zero_is_stateless():
    a = BlockAllocator(4)
    held = a.alloc(2)
    assert a.alloc(0) == []
    assert a.num_free == 2
    assert a.allocated == frozenset(held)


def test_allocator_exhaustion_raises_and_leaves_state():
    a = BlockAllocator(4)
    a.alloc(3)
    with pytest.raises(PoolExhausted):
        a.alloc(2)
    assert a.num_free == 1  # failed alloc took nothing


def test_allocator_double_free_raises():
    a = BlockAllocator(4)
    blocks = a.alloc(2)
    a.free(blocks)
    with pytest.raises(ValueError):
        a.free([blocks[0]])
    with pytest.raises(ValueError):
        a.free([99])  # never allocated


def test_allocator_free_is_atomic():
    """A free list containing one bad block must leave the allocator
    untouched (documented invariant) — not half-free the good prefix."""
    a = BlockAllocator(8)
    good = a.alloc(3)
    free_before = a.num_free
    with pytest.raises(ValueError):
        a.free([good[0], good[1], 99])  # 99 was never allocated
    assert a.num_free == free_before
    assert a.allocated == frozenset(good)  # nothing partially freed
    with pytest.raises(ValueError):
        a.free([good[0], good[0]])  # duplicate within one call
    assert a.num_free == free_before
    assert a.allocated == frozenset(good)
    a.free(good)  # the valid list still frees in full
    assert a.num_free == 8 and a.allocated == frozenset()


def test_allocator_recycles_blocks():
    a = BlockAllocator(4)
    first = a.alloc(4)
    a.free(first)
    second = a.alloc(4)
    assert sorted(second) == sorted(first)  # same physical pages reused


def test_kvcache_slot_lifecycle():
    cache = PagedKVCache.create(
        TINY_MOE, num_blocks=8, block_size=4, max_slots=2,
        max_blocks_per_slot=4,
    )
    slot = cache.acquire_slot(10)  # 3 blocks
    assert cache.allocator.num_free == 5
    assert (cache.block_tables[slot, :3] >= 0).all()
    with pytest.raises(PoolExhausted):
        cache.acquire_slot(17)  # 5 blocks > max_blocks_per_slot
    cache.release_slot(slot)
    assert cache.allocator.num_free == 8
    assert slot in cache.free_slots


# ----------------------------------------------- COW refcount invariants
def test_allocator_incref_shares_and_defers_free():
    a = BlockAllocator(4)
    blocks = a.alloc(2)
    a.incref(blocks)  # a second holder (e.g. a prefix-cache entry)
    assert all(a.refcount(b) == 2 for b in blocks)
    a.free(blocks)  # first holder releases: pages stay allocated
    assert a.num_free == 2 and a.allocated == frozenset(blocks)
    a.free(blocks)  # last holder releases: pages recycle
    assert a.num_free == 4 and a.allocated == frozenset()
    with pytest.raises(ValueError):
        a.free([blocks[0]])  # refcount-0 page: double free
    with pytest.raises(ValueError):
        a.incref([blocks[0]])  # cannot share a free page


def test_allocator_incref_is_atomic():
    a = BlockAllocator(4)
    good = a.alloc(2)
    with pytest.raises(ValueError):
        a.incref([good[0], 99])  # one bad page: nothing increments
    assert all(a.refcount(b) == 1 for b in good)


if HAS_HYPOTHESIS:
    def _op_seqs():
        return st.lists(
            st.tuples(
                st.sampled_from(["alloc", "incref", "free"]),
                st.integers(0, 2**16),
            ),
            max_size=40,
        )
else:  # decoration-time stand-in; the test collects as skipped
    def _op_seqs():
        return None


@given(ops=_op_seqs())
@settings()
def test_property_cow_refcounts_never_corrupt(ops):
    """Hypothesis: under ANY interleaving of alloc / incref / free the
    allocator never double-frees, never frees a page whose refcount is
    still positive, mirrors an exact shadow refcount map, and conserves
    ``free + allocated == num_blocks`` — then drains back to fully
    free."""
    a = BlockAllocator(12)
    shadow: dict = {}
    for kind, seed in ops:
        rng = np.random.default_rng(seed)
        live = sorted(shadow)
        if kind == "alloc":
            n = int(rng.integers(0, 5))
            if n > a.num_free:
                with pytest.raises(PoolExhausted):
                    a.alloc(n)
            else:
                got = a.alloc(n)
                assert len(set(got)) == n
                assert not set(got) & set(shadow), "handed out a live page"
                for b in got:
                    shadow[b] = 1
        elif kind == "incref" and live:
            picks = [b for b in live if rng.integers(0, 2)]
            a.incref(picks)
            for b in picks:
                shadow[b] += 1
        elif kind == "free" and live:
            picks = [b for b in live if rng.integers(0, 2)]
            a.free(picks)
            for b in picks:
                shadow[b] -= 1
                if shadow[b] == 0:
                    del shadow[b]
        assert a.num_free + len(a.allocated) == a.num_blocks
        assert a.allocated == frozenset(shadow)
        assert len(set(a.free_pages)) == len(a.free_pages)
        assert not set(a.free_pages) & set(shadow), "page free AND held"
        for b, rc in shadow.items():
            assert a.refcount(b) == rc
    while shadow:  # drain every remaining hold, one per page per call
        live = sorted(shadow)
        a.free(live)
        for b in live:
            shadow[b] -= 1
            if shadow[b] == 0:
                del shadow[b]
    assert a.num_free == a.num_blocks


# ----------------------------------------------------- prefix page cache
def test_prefix_cache_register_lookup_roundtrip():
    a = BlockAllocator(16)
    pc = PrefixCache(a, 4)
    prompt = np.arange(10, dtype=np.int32)  # 2 full pages + 2-token tail
    blocks = a.alloc(3)
    assert pc.register(prompt, blocks, last_logits=np.ones(7)) == 3
    ent = pc.lookup(prompt)  # full-prompt entry carries the logits
    assert ent.n_tokens == 10 and ent.last_logits is not None
    longer = np.concatenate([prompt[:8], np.asarray([99, 98], np.int32)])
    ent2 = pc.lookup(longer)  # diverging suffix → longest boundary entry
    assert ent2.n_tokens == 8 and ent2.last_logits is None
    assert pc.lookup(np.asarray([50] * 6, np.int32)) is None
    # page 0 is held by the slot + all three entries; re-registration
    # must not leak holds
    assert a.refcount(blocks[0]) == 4
    assert pc.register(prompt, blocks) == 0
    assert a.refcount(blocks[0]) == 4
    pc.check_consistency()


def test_prefix_cache_lru_eviction_and_protect():
    a = BlockAllocator(6)
    pc = PrefixCache(a, 4)
    b1 = a.alloc(1)
    pc.register(np.arange(4, dtype=np.int32), b1, last_logits=np.zeros(2))
    b2 = a.alloc(1)
    pc.register(np.arange(10, 14, dtype=np.int32), b2,
                last_logits=np.zeros(2))
    a.free(b1)
    a.free(b2)  # slots done: pages are cache-held only
    assert a.num_free == 4
    pc.lookup(np.arange(4, dtype=np.int32))  # refresh entry 1 → 2 is LRU
    pc.evict_for(5)
    assert a.num_free == 5
    assert pc.lookup(np.arange(10, 14, dtype=np.int32)) is None
    assert pc.lookup(np.arange(4, dtype=np.int32)) is not None
    # the survivor's pages are protected: eviction must leave it alone
    pc.evict_for(6, protect=frozenset(b1))
    assert a.num_free == 5 and pc.n_entries == 1  # boundary == full entry
    pc.check_consistency()


def test_prefix_cache_reclaimable_is_exact():
    a = BlockAllocator(8)
    pc = PrefixCache(a, 4)
    b = a.alloc(2)
    pc.register(np.arange(8, dtype=np.int32), b, last_logits=np.zeros(2))
    # the owning slot is still live: eviction would drop holds but free
    # no page — reclaimable must say 0, not 2
    assert pc.reclaimable() == 0
    a.free(b)
    assert pc.reclaimable() == 2
    # protecting the entry's pages removes them from the count entirely
    assert pc.reclaimable(frozenset({b[0]})) == 0


def test_acquire_slot_shared_prefix_cow():
    cache = PagedKVCache.create(
        TINY_MOE, num_blocks=8, block_size=4, max_slots=2,
        max_blocks_per_slot=8, prefix_cache=True,
    )
    prompt = np.arange(6, dtype=np.int32)  # 1 full page + 2-token tail
    slot = cache.acquire_slot(6)
    blocks0 = list(cache.slot_blocks[slot])
    cache.register_prefix(prompt, slot, last_logits=np.zeros(3))
    ent = cache.prefix_lookup(prompt)
    assert ent is not None and ent.n_tokens == 6
    slot2 = cache.acquire_slot(8, prefix_entry=ent, rid=7)
    blocks2 = cache.slot_blocks[slot2]
    assert blocks2[0] == blocks0[0], "aligned page must be shared"
    assert blocks2[1] != blocks0[1], "tail page must be a private copy"
    # page 0: slot1 + slot2 + two cache entries (boundary at 4, full at 6)
    assert cache.allocator.refcount(blocks0[0]) == 4
    cache.check_consistency()
    cache.release_slot(slot)
    cache.release_slot(slot2)
    cache.check_consistency()  # cache holds keep the pages alive
    assert cache.allocator.num_free < 8
    cache.clear_prefix_cache()
    assert cache.allocator.num_free == 8


def test_kvcache_kv_bits_validation_and_quant_swap_guard():
    with pytest.raises(ValueError):
        PagedKVCache.create(
            TINY_MOE, num_blocks=4, block_size=4, max_slots=1,
            max_blocks_per_slot=4, kv_bits=4,
        )
    cache = PagedKVCache.create(
        TINY_MOE, num_blocks=4, block_size=4, max_slots=2,
        max_blocks_per_slot=2, kv_bits=8,
    )
    assert cache.k.dtype == jnp.uint8
    assert set(cache.quant) == {"k_scale", "k_zero", "v_scale", "v_zero"}
    slot = cache.acquire_slot(4)
    sw = cache.swap_out(slot, 4)
    assert sw.quant is not None  # scales travel with the codes
    slot2 = cache.acquire_slot(4)
    with pytest.raises(ValueError):
        cache.swap_in(slot2, dataclasses.replace(sw, quant=None))
    cache.swap_in(slot2, sw)  # the genuine payload restores fine
    cache.release_slot(slot2)


# ------------------------------------------------- paged attention kernel
@pytest.mark.parametrize("window", [None, 7])
def test_paged_attention_pallas_matches_ref(window):
    rng = np.random.default_rng(0)
    b, hkv, g, dh, nb, bs, mb = 3, 2, 2, 32, 16, 8, 4
    q = jnp.asarray(rng.normal(size=(b, hkv, g, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, hkv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, hkv, dh)), jnp.float32)
    bt = jnp.asarray(rng.permutation(nb)[: b * mb].reshape(b, mb), jnp.int32)
    lengths = jnp.asarray([5, 17, 32], jnp.int32)
    o_ref = ref.paged_attention_ref(q, kp, vp, bt, lengths, window=window)
    win = jnp.asarray([window if window else mb * bs + 1], jnp.int32)
    o_pal = paged_attention_pallas(q, kp, vp, bt, lengths, win, interpret=True)
    np.testing.assert_allclose(
        np.asarray(o_ref), np.asarray(o_pal), rtol=1e-5, atol=1e-5
    )


# ------------------------------------------------ paged == dense decoding
def test_paged_matches_dense_logits(model):
    """Chunked paged prefill + paged decode reproduce the dense path's
    logits step for step (the engine runs at drop-free expert capacity,
    so the reference does too)."""
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)
    mcfg = eng.model_cfg
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=9).astype(np.int32)
    max_new = 4
    ref_toks, ref_logits = dense_greedy_reference(mcfg, params, prompt, max_new)

    # drive the jitted steps directly to observe per-step logits
    cache = eng.cache
    slot = cache.acquire_slot(len(prompt) + max_new)
    table_row = jnp.asarray(cache.block_tables[slot : slot + 1])
    c = ECFG.prefill_chunk
    for off in range(0, len(prompt), c):
        n = min(c, len(prompt) - off)
        chunk = np.zeros((1, c), np.int32)
        chunk[0, :n] = prompt[off : off + n]
        cache.k, cache.v, _, logits, _ = eng._prefill(
            params, cache.k, cache.v, cache.quant, jnp.asarray(chunk),
            jnp.int32(off), jnp.int32(n), table_row,
        )
    np.testing.assert_allclose(
        np.asarray(logits)[0, -1], ref_logits[0], rtol=1e-4, atol=1e-4
    )
    toks = [int(np.argmax(np.asarray(logits)[0, -1]))]
    pos = len(prompt)
    b = ECFG.max_slots

    @jax.jit
    def decode_fn(k, v, token, positions, active):
        pc = {"k": k, "v": v, "block_tables": cache.tables_device(),
              "active": active}
        nc, logits, _ = tf.paged_decode_step(params, pc, token, positions, mcfg)
        return nc["k"], nc["v"], logits

    for step in range(max_new - 1):
        token = np.zeros((b, 1), np.int32)
        token[slot] = toks[-1]
        positions = np.zeros((b,), np.int32)
        positions[slot] = pos
        active = np.zeros((b,), bool)
        active[slot] = True
        cache.k, cache.v, logits = decode_fn(
            cache.k, cache.v, jnp.asarray(token),
            jnp.asarray(positions), jnp.asarray(active),
        )
        np.testing.assert_allclose(
            np.asarray(logits)[slot, -1], ref_logits[step + 1],
            rtol=1e-4, atol=1e-4,
        )
        toks.append(int(np.argmax(np.asarray(logits)[slot, -1])))
        pos += 1
    assert toks == ref_toks
    cache.release_slot(slot)


def test_engine_serve_matches_dense_greedy_reference(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=7).astype(np.int32)
    ref_toks, _ = dense_greedy_reference(eng.model_cfg, params, prompt, 5)
    out = eng.serve([Request(rid=0, prompt=prompt, max_new=5)])
    assert out[0] == ref_toks


# -------------------------------------------------- continuous batching
def test_scheduler_mid_flight_admission(model):
    """With 2 slots and 3 requests, the third must join once a short
    request finishes — no wave barrier, pages recycled."""
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)
    rng = np.random.default_rng(3)
    reqs = [
        Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32), max_new=2),
        Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32), max_new=8),
        Request(rid=2, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32), max_new=3),
    ]
    out = eng.serve(reqs)
    for r in reqs:
        assert len(out[r.rid]) == r.max_new  # independent completion
    m = eng.metrics.summary()
    assert m["mid_flight_admissions"] >= 1
    assert m["slot_releases"] == 3
    # request 2 was admitted strictly after decoding started
    admit_steps = {a["rid"]: a["step"] for a in eng.metrics.admissions}
    assert admit_steps[2] > 0
    # all pages returned to the pool
    assert eng.cache.allocator.num_free == ECFG.num_blocks
    assert len(eng.cache.free_slots) == ECFG.max_slots


def test_admission_depth_counts_admitted_request(model):
    """record_admission logs the queue depth the admission decision saw —
    including the request being admitted (regression: the engine used to
    read queue_depth after try_admit popped the head, off by one)."""
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)  # 2 slots
    rng = np.random.default_rng(11)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=5).astype(np.int32),
                max_new=3)
        for i in range(3)
    ]
    eng.serve(reqs)
    depths = [a["queue_depth"] for a in eng.metrics.admissions]
    # step 0: three waiting, two slots -> depths 3 then 2; the third is
    # admitted alone once a slot frees -> depth 1 (itself)
    assert depths == [3, 2, 1]
    assert all(d >= 1 for d in depths)  # an admitted request counts itself


def test_expert_activation_ignores_inactive_slots(model):
    """Regression for OTP activation dilution: the per-step activation
    metric must be computed over *active* slots only — the garbage token
    an empty slot decodes must not move it (paged_decode_step used to
    average the mask over all slots)."""
    cfg, _ = model
    from test_offload import compress_for_serving

    from repro.core.otp import init_otp_router

    bundle = get_model(cfg)
    params_c = compress_for_serving(cfg, bundle.init(jax.random.PRNGKey(0)))
    otps = [
        init_otp_router(jax.random.PRNGKey(100 + l), cfg.d_model, cfg.top_k)
        for l in range(cfg.num_layers)
    ]
    params_c["blocks"]["otp"] = jax.tree.map(lambda *xs: jnp.stack(xs), *otps)
    cache = PagedKVCache.create(
        cfg, num_blocks=8, block_size=4, max_slots=2, max_blocks_per_slot=2
    )
    cache.acquire_slot(2)
    cache.acquire_slot(2)
    tables = jnp.asarray(cache.block_tables)
    positions = jnp.zeros((2,), jnp.int32)

    @jax.jit
    def act_of(tokens, active):
        pc = {"k": cache.k, "v": cache.v, "block_tables": tables,
              "active": active}
        _, _, info = tf.paged_decode_step(params_c, pc, tokens, positions, cfg)
        return info["expert_activation"]

    masked, unmasked = [], []
    for garbage in range(10):
        tokens = jnp.asarray([[7], [garbage]], jnp.int32)
        masked.append(float(act_of(tokens, jnp.asarray([True, False]))))
        unmasked.append(float(act_of(tokens, jnp.asarray([True, True]))))
    # sanity: slot 1's token genuinely moves the metric when it counts
    assert len({round(u, 6) for u in unmasked}) > 1
    # regression: with slot 1 inactive its token must not move the metric
    assert len({round(m, 6) for m in masked}) == 1


def test_model_api_paged_dispatch(model):
    """The bundle-level API accepts the paged cache layout: decode_step
    dispatches on ``"block_tables" in cache`` and prefill on ``paged=``,
    both matching the direct paged functions."""
    cfg, params = model
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    cache = PagedKVCache.create(
        cfg, num_blocks=8, block_size=4, max_slots=2, max_blocks_per_slot=4
    )
    slot = cache.acquire_slot(len(prompt) + 2)
    table_row = jnp.asarray(cache.block_tables[slot : slot + 1])
    pc = {"k": cache.k, "v": cache.v, "block_tables": table_row}
    # prefill via the dispatch kwarg == direct paged_prefill_chunk
    pc1, logits1 = tf.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, cfg,
        paged={"cache": pc},
    )
    pc2, logits2, _ = tf.paged_prefill_chunk(
        params, pc, jnp.asarray(prompt[None]), jnp.int32(0),
        jnp.int32(len(prompt)), cfg,
    )
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2))
    # decode via decode_step dispatch == direct paged_decode_step
    tables = jnp.zeros((2, 4), jnp.int32).at[0].set(table_row[0])
    dcache = {
        "k": pc1["k"], "v": pc1["v"], "block_tables": tables,
        "active": jnp.asarray([True, False]),
    }
    token = jnp.asarray([[int(np.argmax(np.asarray(logits1)[0, -1]))], [0]],
                        jnp.int32)
    positions = jnp.asarray([len(prompt), 0], jnp.int32)
    out1, lg1 = tf.decode_step(params, dcache, token, positions, cfg)
    out2, lg2, info = tf.paged_decode_step(params, dcache, token, positions, cfg)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2))
    assert float(info["expert_activation"]) == 1.0  # no OTP params here
    assert "block_tables" in out1


def test_empty_prompt_rejected(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(0, np.int32), max_new=4))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=1, prompt=np.zeros(4, np.int32), max_new=0))


def test_oversized_request_rejected(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ECFG)
    prompt = np.zeros(ECFG.max_blocks_per_slot * ECFG.block_size, np.int32)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=prompt, max_new=4))


def test_pool_too_small_raises(model):
    cfg, params = model
    ecfg = dataclasses.replace(ECFG, num_blocks=2, max_blocks_per_slot=6)
    eng = PagedServingEngine(cfg, params, ecfg)
    prompt = np.zeros(12, np.int32)  # needs 4 blocks, pool has 2
    with pytest.raises(PoolExhausted):
        eng.serve([Request(rid=0, prompt=prompt, max_new=4)])


# ------------------------------------------- dynamic growth + preemption
def test_kvcache_grow_extends_table():
    cache = PagedKVCache.create(
        TINY_MOE, num_blocks=8, block_size=4, max_slots=2,
        max_blocks_per_slot=4,
    )
    slot = cache.acquire_slot(5)  # 2 blocks
    assert cache.grow(slot, 0) == []
    new = cache.grow(slot, 1)
    assert len(new) == 1 and cache.allocator.num_free == 5
    assert list(cache.block_tables[slot, :3]) == cache.slot_blocks[slot]
    with pytest.raises(PoolExhausted):
        cache.grow(slot, 2)  # 3 + 2 > max_blocks_per_slot
    assert cache.allocator.num_free == 5  # failed grow took nothing
    cache.check_consistency()
    cache.release_slot(slot)


def test_swap_roundtrip_preserves_kv_bits():
    """swap_out → pages recycled by another tenant → swap_in restores the
    preempted slot's KV bit-for-bit into fresh pages."""
    rng = np.random.default_rng(0)
    cache = PagedKVCache.create(
        TINY_MOE, num_blocks=6, block_size=4, max_slots=2,
        max_blocks_per_slot=3,
    )
    slot = cache.acquire_slot(10)  # 3 blocks
    blocks = list(cache.slot_blocks[slot])
    fill = rng.normal(size=(TINY_MOE.num_layers, 3, 4, 2, 16)).astype(np.float32)
    cache.k = cache.k.at[:, np.asarray(blocks)].set(jnp.asarray(fill))
    cache.v = cache.v.at[:, np.asarray(blocks)].set(jnp.asarray(2 * fill))
    swapped = cache.swap_out(slot, 10)
    assert swapped.n_pages == 3 and swapped.n_tokens == 10
    assert cache.allocator.num_free == 6  # device pages freed immediately
    # another tenant scribbles over the recycled pages
    other = cache.acquire_slot(12)
    cache.k = cache.k.at[:, np.asarray(cache.slot_blocks[other])].set(-1.0)
    cache.release_slot(other)
    slot2 = cache.acquire_slot(10)
    nbytes = cache.swap_in(slot2, swapped)
    assert nbytes == swapped.nbytes
    got = np.asarray(cache.k[:, np.asarray(cache.slot_blocks[slot2])])
    np.testing.assert_array_equal(got, fill)
    got_v = np.asarray(cache.v[:, np.asarray(cache.slot_blocks[slot2])])
    np.testing.assert_array_equal(got_v, 2 * fill)


def test_grow_on_exhaustion_preempts_instead_of_raising(model):
    """A pool far below Σ(prompt+max_new) — which PR-1 admission would
    have rejected mid-run — now finishes every request by preempting on
    page exhaustion instead of raising PoolExhausted."""
    cfg, params = model
    reqs = [
        Request(rid=i, prompt=np.full(3, 5 + i, np.int32), max_new=12)
        for i in range(3)
    ]
    demand = sum(-(-(3 + 12) // ECFG.block_size) for _ in reqs)  # 12 blocks
    ecfg = dataclasses.replace(
        ECFG, max_slots=3, num_blocks=demand // 2, max_blocks_per_slot=4,
        preempt_mode="recompute",
    )
    eng = PagedServingEngine(cfg, params, ecfg)
    out = eng.serve(reqs)
    m = eng.metrics.summary()
    assert m["preemptions"] >= 1
    assert all(len(out[r.rid]) == r.max_new for r in reqs)
    assert eng.cache.allocator.num_free == ecfg.num_blocks


@pytest.mark.parametrize("preempt_mode", ["swap", "recompute"])
def test_preempted_resume_matches_never_preempted(model, preempt_mode):
    """A preempted-then-resumed request re-reads KV identical to a run
    that was never preempted: greedy tokens agree request by request."""
    cfg, params = model
    def mk():
        return [
            Request(rid=i, prompt=np.asarray([7 + i, 3, 11 + i], np.int32),
                    max_new=10)
            for i in range(3)
        ]
    roomy = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=3, num_blocks=16,
                            max_blocks_per_slot=4),
    )
    baseline = roomy.serve(mk())
    assert roomy.metrics.summary()["preemptions"] == 0
    tight = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=3, num_blocks=6,
                            max_blocks_per_slot=4, preempt_mode=preempt_mode),
    )
    pressured = tight.serve(mk())
    assert tight.metrics.summary()["preemptions"] >= 1
    assert pressured == baseline


def test_reserve_full_never_preempts(model):
    """The PR-1 baseline policy: full up-front reservation serializes
    under a tight pool but never grows, swaps, or preempts."""
    cfg, params = model
    reqs = [
        Request(rid=i, prompt=np.full(4, 2 + i, np.int32), max_new=8)
        for i in range(3)
    ]
    ecfg = dataclasses.replace(
        ECFG, max_slots=3, num_blocks=6, max_blocks_per_slot=4,
        reserve_full=True,
    )
    eng = PagedServingEngine(cfg, params, ecfg)
    out = eng.serve(reqs)
    m = eng.metrics.summary()
    assert m["preemptions"] == 0 and m["swap_bytes"] == 0
    assert all(len(out[r.rid]) == r.max_new for r in reqs)


# ------------------------------------------------- fused decode horizon
def _prefilled_slot(mcfg, params, prompt, max_new):
    """Fresh paged cache with one prefilled slot; returns
    (cache, slot, first_token)."""
    cache = PagedKVCache.create(
        mcfg, num_blocks=16, block_size=4, max_slots=2, max_blocks_per_slot=6
    )
    slot = cache.acquire_slot(len(prompt) + max_new)
    row = jnp.asarray(cache.block_tables[slot : slot + 1])
    pc = {"k": cache.k, "v": cache.v, "block_tables": row}
    pc, logits, _ = tf.paged_prefill_chunk(
        params, pc, jnp.asarray(prompt[None]), jnp.int32(0),
        jnp.int32(len(prompt)), mcfg,
    )
    cache.k, cache.v = pc["k"], pc["v"]
    return cache, slot, int(np.argmax(np.asarray(logits)[0, -1]))


@pytest.mark.parametrize("horizon", [2, 4, 6])
def test_horizon_program_matches_manual_steps(model, horizon):
    """paged_decode_horizon emits exactly the tokens of ``budget`` manual
    paged_decode_step calls with host-side argmax — including a horizon
    larger than the remaining budget (trailing scan steps emit nothing)
    — and leaves a bit-identical KV pool behind."""
    cfg, params = model
    mcfg = dataclasses.replace(
        cfg, moe_capacity_factor=float(cfg.num_experts)
    )
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    budget = 4
    cache, slot, tok0 = _prefilled_slot(mcfg, params, prompt, budget + 1)
    tables = cache.tables_device()
    b = 2
    # ---- manual single-step loop (the H = 1 reference semantics)
    k, v = cache.k, cache.v
    toks_ref, cur, pos = [], tok0, len(prompt)
    for _ in range(budget):
        token = np.zeros((b, 1), np.int32)
        token[slot] = cur
        positions = np.zeros((b,), np.int32)
        positions[slot] = pos
        active = np.zeros((b,), bool)
        active[slot] = True
        pc = {"k": k, "v": v, "block_tables": tables,
              "active": jnp.asarray(active)}
        pc, logits, _ = tf.paged_decode_step(
            params, pc, jnp.asarray(token), jnp.asarray(positions), mcfg
        )
        k, v = pc["k"], pc["v"]
        cur = int(np.argmax(np.asarray(logits)[slot, -1]))
        toks_ref.append(cur)
        pos += 1
    # ---- one fused horizon program from the same starting state
    token = np.zeros((b, 1), np.int32)
    token[slot] = tok0
    positions = np.zeros((b,), np.int32)
    positions[slot] = len(prompt)
    active = np.zeros((b,), bool)
    active[slot] = True
    budgets = np.zeros((b,), np.int32)
    budgets[slot] = budget
    hc = {"k": cache.k, "v": cache.v, "block_tables": tables,
          "active": jnp.asarray(active)}
    hc, toks, emits, info = tf.paged_decode_horizon(
        params, hc, jnp.asarray(token), jnp.asarray(positions), mcfg,
        horizon=horizon, budgets=jnp.asarray(budgets),
        eos_ids=jnp.full((b,), -1, np.int32),
    )
    toks, emits = np.asarray(toks), np.asarray(emits)
    n_emit = min(horizon, budget)
    assert list(emits[:, slot]) == [True] * n_emit + [False] * (horizon - n_emit)
    assert not emits[:, 1 - slot].any()  # inactive slot never emits
    assert list(toks[:n_emit, slot]) == toks_ref[:n_emit]
    assert (toks[n_emit:, slot] == -1).all()
    assert np.asarray(info["slot_counts"]).shape[0] == horizon
    if horizon >= budget:  # same writes happened ⇒ same pool bits
        np.testing.assert_array_equal(np.asarray(hc["k"]), np.asarray(k))
        np.testing.assert_array_equal(np.asarray(hc["v"]), np.asarray(v))


def test_horizon_eos_stops_mid_horizon(model):
    """A slot that emits its per-request EOS mid-horizon keeps the EOS
    token and emits nothing after it."""
    cfg, params = model
    mcfg = dataclasses.replace(
        cfg, moe_capacity_factor=float(cfg.num_experts)
    )
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    ref_toks, _ = dense_greedy_reference(mcfg, params, prompt, 6)
    eos = ref_toks[2]  # greedy emits this at decode step 2 of the horizon
    cache, slot, tok0 = _prefilled_slot(mcfg, params, prompt, 7)
    assert tok0 == ref_toks[0]
    b = 2
    token = np.zeros((b, 1), np.int32)
    token[slot] = tok0
    positions = np.zeros((b,), np.int32)
    positions[slot] = len(prompt)
    active = np.zeros((b,), bool)
    active[slot] = True
    budgets = np.zeros((b,), np.int32)
    budgets[slot] = 5
    eos_ids = np.full((b,), -1, np.int32)
    eos_ids[slot] = eos
    hc = {"k": cache.k, "v": cache.v, "block_tables": cache.tables_device(),
          "active": jnp.asarray(active)}
    _, toks, emits, _ = tf.paged_decode_horizon(
        params, hc, jnp.asarray(token), jnp.asarray(positions), mcfg,
        horizon=5, budgets=jnp.asarray(budgets),
        eos_ids=jnp.asarray(eos_ids),
    )
    toks, emits = np.asarray(toks), np.asarray(emits)
    emitted = [int(t) for t in toks[emits[:, slot], slot]]
    assert emitted == ref_toks[1:3]  # ... up to and including the EOS
    assert emitted[-1] == eos
    assert not emits[2:, slot].any()  # nothing after the stop


def test_engine_eos_request_matches_truncated_reference(model):
    """Engine-level EOS: the request finishes the step it emits its stop
    token, its output is the dense reference truncated at the EOS, and
    its slot frees at the right logical step."""
    cfg, params = model
    eng = PagedServingEngine(
        cfg, params, dataclasses.replace(ECFG, decode_horizon=4)
    )
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    ref_toks, _ = dense_greedy_reference(eng.model_cfg, params, prompt, 8)
    eos = ref_toks[3]
    assert eos not in ref_toks[:3]  # the cut lands where we think it does
    out = eng.serve([Request(rid=0, prompt=prompt, max_new=8, eos_id=eos)])
    assert out[0] == ref_toks[:4]  # truncated at (and including) the EOS
    # released at logical step 2: tokens 1..3 decode at steps 0..2
    assert eng.metrics.slot_releases[0]["step"] == 2
    assert eng.cache.allocator.num_free == ECFG.num_blocks


def test_engine_temperature_sampling_deterministic(model):
    """Sampled runs replay bit-identically under the same seed, and the
    knob leaves greedy untouched at temperature 0."""
    cfg, params = model
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
               for _ in range(3)]

    def serve(temp, seed, horizon=4):
        eng = PagedServingEngine(
            cfg, params,
            dataclasses.replace(ECFG, decode_horizon=horizon,
                                temperature=temp, sample_seed=seed),
        )
        return eng.serve(
            [Request(rid=i, prompt=prompts[i], max_new=6) for i in range(3)]
        )

    a = serve(2.0, seed=0)
    b = serve(2.0, seed=0)
    assert a == b  # explicit per-megastep keys ⇒ deterministic replay
    assert all(
        0 <= t < cfg.vocab_size for toks in a.values() for t in toks
    )
    # the TTFT token is sampled too (per-rid keys): identical prompts
    # under high temperature must not all open with the greedy argmax
    eng = PagedServingEngine(
        cfg, params,
        dataclasses.replace(ECFG, max_slots=2, decode_horizon=2,
                            temperature=5.0, sample_seed=3),
    )
    same = eng.serve([
        Request(rid=i, prompt=prompts[0], max_new=2) for i in range(6)
    ])
    assert len({toks[0] for toks in same.values()}) > 1
    greedy = serve(0.0, seed=0)
    ref = {
        i: dense_greedy_reference(
            PagedServingEngine(cfg, params, ECFG).model_cfg,
            params, prompts[i], 6,
        )[0]
        for i in range(3)
    }
    assert greedy == ref  # temperature 0 is exactly the greedy path


def test_decode_horizon_env_default(monkeypatch):
    """REPRO_DECODE_HORIZON sets the config default; explicit values and
    validation still win."""
    monkeypatch.setenv("REPRO_DECODE_HORIZON", "3")
    assert EngineConfig().decode_horizon == 3
    assert EngineConfig(decode_horizon=2).decode_horizon == 2
    monkeypatch.delenv("REPRO_DECODE_HORIZON")
    assert EngineConfig().decode_horizon == 8
    with pytest.raises(ValueError):
        PagedServingEngine(
            TINY_MOE, {}, dataclasses.replace(ECFG, decode_horizon=0)
        )
    with pytest.raises(ValueError):
        PagedServingEngine(
            TINY_MOE, {}, dataclasses.replace(ECFG, temperature=-1.0)
        )


# ---------------------------------------------------------- metrics unit
def test_metrics_megastep_split_and_dispatch_rates():
    from repro.serving import ServingMetrics

    m = ServingMetrics()
    # two megasteps of 4 logical steps each, 2 active slots throughout;
    # the second needed one offload replay
    for steps, runs, offload_s in ((4, 1, 0.0), (4, 2, 0.03)):
        m.record_megastep(steps, 0.01, offload_s, runs, runs)
        for _ in range(steps):
            m.record_decode_step(0.0025, 2, 1.0, 0, page_utilization=0.5)
    m.record_prefill_runs(1)
    s = m.summary()
    assert s["megasteps"] == 2
    assert s["decode_dispatches"] == 3 and s["decode_replays"] == 1
    assert s["decode_host_syncs"] == 3
    assert s["prefill_dispatches"] == 1 and s["prefill_replays"] == 0
    # compute vs offload split: replays no longer inflate compute time
    assert s["decode_compute_mean_s"] == pytest.approx(0.01)
    assert s["decode_offload_mean_s"] == pytest.approx(0.015)
    assert s["decode_offload_frac"] == pytest.approx(0.03 / 0.05)
    # 3 dispatches over 8 logical steps / 16 batch tokens
    assert s["dispatches_per_step"] == pytest.approx(3 / 8)
    assert s["dispatches_per_token"] == pytest.approx(3 / 16)
    assert s["syncs_per_token"] == pytest.approx(3 / 16)
    c = m.counters()
    assert c["megasteps"] == 2
    assert c["megastep_logical_steps"] == [4, 4]
    assert c["decode_dispatches"] == 3 and c["decode_replays"] == 1
    # the deterministic counters slice holds counts only — never seconds
    assert not any("_s" == k[-2:] for k in c)


def test_metrics_new_counters_and_json_roundtrip():
    import json

    from repro.serving import ServingMetrics

    m = ServingMetrics()
    m.record_admission(0, 0, 0, 0, 0)
    # a resumed re-admission mid-decode is a pressure artifact, not a
    # continuous-batching admission
    m.record_admission(1, 1, 3, 1, 0, resumed=True)
    assert m.mid_flight_admissions == 0
    m.record_ttft(0.5, 0.4)
    m.record_decode_step(0.01, 2, 1.0, 1, page_utilization=0.5)
    m.record_decode_step(0.01, 1, 1.0, 0, page_utilization=1.0)
    m.record_preemption(0, 0, 1, "swap", swap_bytes=1024)
    m.record_swap_in(1024)
    m.record_release(0, 0, 2)
    s = m.summary()
    assert s["preemptions"] == 1
    assert s["swap_out_bytes"] == 1024 and s["swap_in_bytes"] == 1024
    assert s["swap_bytes"] == 2048
    assert s["page_util_mean"] == pytest.approx(0.75)
    assert s["page_util_p95"] == pytest.approx(np.percentile([0.5, 1.0], 95))
    assert json.loads(m.to_json()) == s  # round-trip: every value JSON-safe
    # recompute-mode preemptions move no bytes
    m2 = ServingMetrics()
    m2.record_preemption(1, 1, 0, "recompute", swap_bytes=0)
    assert m2.summary()["swap_bytes"] == 0
    assert m2.counters()["preemptions"][0]["mode"] == "recompute"


def test_metrics_decode_step_without_page_utilization():
    from repro.serving import ServingMetrics

    m = ServingMetrics()
    # callers with no pool attached omit the gauge entirely — no sample
    # recorded, not a fake 0.0 dragging the mean down
    m.record_decode_step(0.01, 2, 1.0, 0)
    m.record_decode_step(0.01, 2, 1.0, 0, page_utilization=None)
    assert m.page_utilization == []
    m.record_decode_step(0.01, 2, 1.0, 0, page_utilization=0.5)
    assert m.page_utilization == [0.5]
    assert m.summary()["page_util_mean"] == pytest.approx(0.5)


def test_metrics_empty_summary_ratios_are_none():
    from repro.serving import ServingMetrics

    s = ServingMetrics().summary()
    # no generated tokens / no megasteps → undefined ratios stay None
    # instead of dividing by zero or reporting a misleading 0.0
    assert s["tokens_per_s"] is None
    assert s["dispatches_per_token"] is None
    assert s["syncs_per_token"] is None
    assert s["dispatches_per_step"] is None
    assert s["requests"] == 0  # plain counts still report zeros


def test_metrics_to_json_include_counters():
    import json

    from repro.serving import ServingMetrics

    m = ServingMetrics()
    m.record_admission(0, 0, 0, 0, 1)
    m.record_decode_step(0.01, 1, 1.0, 0, page_utilization=0.25)
    m.record_release(0, 0, 3)
    # default shape is unchanged: the flat summary dict
    assert json.loads(m.to_json()) == json.loads(json.dumps(m.summary()))
    doc = json.loads(m.to_json(include_counters=True))
    assert set(doc) == {"summary", "counters"}
    assert doc["summary"] == json.loads(json.dumps(m.summary()))
    assert doc["counters"] == json.loads(json.dumps(m.counters()))
    assert doc["counters"]["slot_releases"] == [{"rid": 0, "slot": 0, "step": 3}]
