"""Per-architecture smoke tests (assignment requirement).

Each assigned arch instantiates its REDUCED config (same family/topology,
small dims) and runs: one train step (fwd+bwd), a prefill, and a decode
step — asserting output shapes and no NaNs, on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.registry import get_model

jax.config.update("jax_enable_x64", False)

SMOKE_B, SMOKE_S = 2, 32


def _smoke_batch(bundle, kind: str):
    cfg = bundle.cfg
    rng = np.random.default_rng(0)
    batch = {}
    if cfg.family == "vlm":
        p = cfg.num_patch_tokens
        s_text = SMOKE_S
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (SMOKE_B, s_text)), jnp.int32
        )
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(SMOKE_B, p, cfg.d_model)), jnp.float32
        )
        if kind == "train":
            batch["labels"] = batch["tokens"]
    elif cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(SMOKE_B, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (SMOKE_B, SMOKE_S)), jnp.int32
        )
        if kind == "train":
            batch["labels"] = batch["tokens"]
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (SMOKE_B, SMOKE_S)), jnp.int32
        )
        if kind == "train":
            batch["labels"] = batch["tokens"]
    return batch


@pytest.fixture(scope="module")
def bundles():
    cache = {}
    for name in ARCH_IDS:
        cfg = get_config(name).reduced()
        bundle = get_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        cache[name] = (bundle, params)
    return cache


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(bundles, arch):
    bundle, params = bundles[arch]
    batch = _smoke_batch(bundle, "train")

    def loss_fn(p):
        loss, metrics = bundle.train_loss(p, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # loss near log(vocab) for random init
    assert 1.0 < float(loss) < 2.5 * np.log(bundle.cfg.vocab_size)
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: float(jnp.sum(jnp.abs(g.astype(jnp.float32)))), grads),
    )
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: grad sum {gnorm}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(bundles, arch):
    bundle, params = bundles[arch]
    cfg = bundle.cfg
    batch = _smoke_batch(bundle, "prefill")
    cache, logits = bundle.prefill(params, batch)
    assert logits.shape == (SMOKE_B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos = jnp.int32(SMOKE_S - 1)  # overwrite last slot (static cache size)
    new_cache, logits2 = bundle.decode_step(params, cache, token, pos)
    assert logits2.shape == (SMOKE_B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_supported_shapes(arch):
    from repro.configs.base import SHAPES, supported_shapes

    cfg = get_config(arch)
    bundle = get_model(cfg.reduced())
    for shape_name in supported_shapes(cfg):
        shape = SHAPES[shape_name]
        # reduced-size spec sanity (full specs exercised by the dry-run)
        import dataclasses

        small = dataclasses.replace(shape, seq_len=32, global_batch=2)
        step, kwargs = bundle.input_specs(small)
        assert step in ("train", "prefill", "decode")
        leaves = jax.tree.leaves(kwargs)
        assert all(hasattr(l, "shape") for l in leaves)


def test_decode_matches_prefill_increment():
    """Decoding token t with a cache of t-1 tokens == prefill of t tokens."""
    cfg = get_config("tinyllama-1.1b").reduced()
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    # full prefill of 8 tokens
    _, logits_full = bundle.prefill(params, {"tokens": toks})
    # prefill 7 then decode the 8th — pad cache to 8 slots via prefill(8)
    cache7, _ = bundle.prefill(params, {"tokens": toks})
    # rebuild a cache where only first 7 positions matter, decode pos=7
    new_cache, logits_inc = bundle.decode_step(
        params, cache7, toks[:, 7:8], jnp.int32(7)
    )
    np.testing.assert_allclose(
        np.asarray(logits_inc, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=2e-3, atol=2e-3,
    )
