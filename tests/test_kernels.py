"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes/bit-widths per the deliverable: every kernel is
asserted allclose against its ref.py oracle, plus hypothesis property
tests on the packing-dequant-matmul pipeline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.packing import PackedTensor
from repro.core.quantizers import (
    dequantize_kv_rows,
    quantize_kv_rows,
    quantize_to_packed,
)
from repro.kernels import ops, ref
from repro.kernels.binary_matmul import binary_matmul_pallas
from repro.kernels.moe_gmm import pad_groups, sort_by_expert
from repro.kernels.paged_attention import (
    paged_attention_pallas,
    paged_attention_quant_pallas,
)
from repro.kernels.quant_matmul import quant_matmul_pallas


def _mk_packed(k, n, bits, group, seed=0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    return w, quantize_to_packed(w, bits, group=group, refine=False)


# ------------------------------------------------------------ quant_matmul
@pytest.mark.parametrize("bits", [1, 2, 3, 4])
@pytest.mark.parametrize(
    "m,k,n,group,bm,bn,bk",
    [
        (8, 128, 128, 128, 8, 128, 128),
        (16, 256, 128, 128, 8, 128, 256),  # bk = 2 groups
        (32, 512, 256, 128, 16, 128, 128),
        (8, 128, 128, 64, 8, 128, 128),  # group < bk
    ],
)
def test_quant_matmul_matches_ref(bits, m, k, n, group, bm, bn, bk):
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    _, pt = _mk_packed(k, n, bits, group, seed=bits)
    y_ref = ref.quant_matmul_ref(
        x, pt.data, pt.scale, pt.zero, bits=bits, group=group
    )
    y = quant_matmul_pallas(
        x, pt.data, pt.scale, pt.zero,
        bits=bits, group=group, bm=bm, bn=bn, bk=bk, interpret=True,
    )
    # the kernel accumulates over K-chunks, the ref in one dot — f32
    # summation order alone moves results by ~5e-5 at k=512
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_dtypes(dtype):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(16, 128)), dtype)
    _, pt = _mk_packed(128, 128, 2, 128, seed=7)
    y_ref = ref.quant_matmul_ref(x, pt.data, pt.scale, pt.zero, bits=2, group=128)
    y = quant_matmul_pallas(
        x, pt.data, pt.scale, pt.zero, bits=2, group=128,
        bm=16, bn=128, bk=128, interpret=True,
    )
    assert y.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), rtol=2e-2, atol=2e-2
    )


def test_quant_matmul_wrapper_pads_m():
    # wrapper handles M not multiple of block via padding
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(3, 5, 128)), jnp.float32)  # leading dims
    w, pt = _mk_packed(128, 128, 4, 128, seed=8)
    y = ops.quant_matmul(x, pt, backend="interpret", bm=8, bn=128, bk=128)
    y_ref = jnp.einsum("abk,kn->abn", x, pt.dequantize())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5)


def test_quant_matmul_vs_exact_dequant():
    # end-to-end: kernel == x @ PackedTensor.dequantize()
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
    w, pt = _mk_packed(256, 128, 3, 128, seed=9)
    y = quant_matmul_pallas(
        x, pt.data, pt.scale, pt.zero, bits=3, group=128,
        bm=8, bn=128, bk=256, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ pt.dequantize()), rtol=2e-5, atol=2e-5
    )


@given(
    bits=st.sampled_from([1, 2, 3, 4]),
    mi=st.integers(1, 3),
    ki=st.integers(1, 3),
    ni=st.integers(1, 2),
    seed=st.integers(0, 1000),
)
@settings(max_examples=12, deadline=None)
def test_quant_matmul_property(bits, mi, ki, ni, seed):
    m, k, n = 8 * mi, 128 * ki, 128 * ni
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    _, pt = _mk_packed(k, n, bits, 128, seed=seed)
    y_ref = ref.quant_matmul_ref(x, pt.data, pt.scale, pt.zero, bits=bits, group=128)
    y = quant_matmul_pallas(
        x, pt.data, pt.scale, pt.zero, bits=bits, group=128,
        bm=8, bn=128, bk=128, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=3e-5, atol=3e-5)


# ----------------------------------------------------------- binary_matmul
@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (16, 256, 256), (32, 512, 128)])
def test_binary_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    from repro.core.quantizers import quantize_binary
    from repro.core.packing import pack_bits

    b01, alpha = quantize_binary(w)
    bp = pack_bits(b01, 1, axis=0)
    y_ref = ref.binary_matmul_ref(x, bp, alpha)
    y = binary_matmul_pallas(x, bp, alpha, bm=8, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5)
    # also against the plain sign-matmul semantics of Eq. 9
    y_math = x @ (jnp.sign(w) + (w == 0)) * alpha
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_math), rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------- moe_gmm
@pytest.mark.parametrize("bits", [1, 2, 3, 4])
def test_moe_gmm_matches_ref(bits):
    rng = np.random.default_rng(bits + 10)
    e, k, n, bm, cap = 4, 128, 128, 8, 16
    ws = [jnp.asarray(rng.normal(size=(k, n)), jnp.float32) for _ in range(e)]
    pts = [quantize_to_packed(w, bits, group=128, refine=False) for w in ws]
    if bits == 3:
        w_packed = (
            jnp.stack([pt.data[0] for pt in pts]),
            jnp.stack([pt.data[1] for pt in pts]),
        )
    else:
        w_packed = jnp.stack([pt.data for pt in pts])
    scale = jnp.stack([pt.scale for pt in pts])
    zero = jnp.stack([pt.zero for pt in pts])
    t = 40
    tokens = jnp.asarray(rng.normal(size=(t, k)), jnp.float32)
    eids = jnp.asarray(rng.integers(0, e, size=(t,)), jnp.int32)
    st_tok, order, gs = sort_by_expert(tokens, eids, e)
    xp, block_expert, row_map = pad_groups(st_tok, gs, bm=bm, capacity=cap)
    y_ref = ref.moe_gmm_ref(
        xp, w_packed, scale, zero, block_expert, bits=bits, group=128, bm=bm
    )
    from repro.kernels.moe_gmm import moe_gmm_pallas

    y = moe_gmm_pallas(
        xp, w_packed, scale, zero, block_expert,
        bits=bits, group=128, bm=bm, bn=128, bk=128, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5)
    # semantic check: each routed token got its own expert's matmul
    ws_deq = jnp.stack([pt.dequantize() for pt in pts])
    valid = np.asarray(row_map) >= 0
    got = np.asarray(y)[np.asarray(row_map)[valid]]
    want = np.asarray(
        jnp.einsum("tk,tkn->tn", st_tok[valid], ws_deq[np.asarray(eids)[np.asarray(order)][valid]])
    )
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_pad_groups_capacity_drop():
    # tokens beyond capacity are dropped, never mis-routed
    tokens = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    tokens = jnp.tile(tokens, (1, 64))  # k=128
    gs = jnp.array([5, 1], jnp.int32)
    xp, be, row_map = pad_groups(tokens, gs, bm=8, capacity=8)
    assert xp.shape == (16, 128)
    assert list(np.asarray(be)) == [0, 1]
    rm = np.asarray(row_map)
    assert (rm[:5] == np.arange(5)).all() and rm[5] == 8


# ------------------------------------------- paged attention, int8 KV pools
def _mk_paged(seed, b=3, hkv=2, g=2, dh=16, nb=16, bs=4, mb=4,
              ragged=True):
    """Random decode-attention problem over disjoint physical pages (the
    allocator never double-books a page across live sequences)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, hkv, g, dh)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(nb, bs, hkv, dh)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(nb, bs, hkv, dh)), jnp.float32)
    tables = jnp.asarray(
        rng.permutation(nb)[: b * mb].reshape(b, mb), jnp.int32
    )
    if ragged:  # each sequence a different logical length (partial pages)
        lengths = jnp.asarray(rng.integers(1, mb * bs + 1, size=b), jnp.int32)
    else:
        lengths = jnp.full((b,), mb * bs, jnp.int32)
    return q, kf, vf, tables, lengths


def _quantize_pools(kf, vf):
    kc, ks, kz = quantize_kv_rows(kf, 8)
    vc, vs, vz = quantize_kv_rows(vf, 8)
    return kc, vc, (ks, kz, vs, vz)


@pytest.mark.parametrize("ragged", [False, True])
def test_paged_attention_quant_kernel_matches_ref(ragged):
    q, kf, vf, tables, lengths = _mk_paged(3, ragged=ragged)
    kc, vc, quant = _quantize_pools(kf, vf)
    y_ref = ref.paged_attention_ref(q, kc, vc, tables, lengths, quant=quant)
    win = jnp.full((1,), 10**6, jnp.int32)
    y = paged_attention_quant_pallas(
        q, kc, vc, *quant, tables, lengths, win, interpret=True
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_quant_ref_bitwise_vs_dequantized_fp_ref():
    """The quant oracle must equal "dequantize the pools, then run the fp
    oracle" **bitwise**: both apply the same ``(codes − zero) × scale``
    f32 expression per row, and gathering commutes with a per-row map —
    this is the invariant that lets every reader (ref, kernel epilogue,
    prefill dequant-gather) see identical floats."""
    q, kf, vf, tables, lengths = _mk_paged(11)
    kc, vc, quant = _quantize_pools(kf, vf)
    ks, kz, vs, vz = quant
    y_q = ref.paged_attention_ref(q, kc, vc, tables, lengths, quant=quant)
    y_fp = ref.paged_attention_ref(
        q, dequantize_kv_rows(kc, ks, kz), dequantize_kv_rows(vc, vs, vz),
        tables, lengths,
    )
    assert np.array_equal(np.asarray(y_q), np.asarray(y_fp))


def test_paged_attention_quant_window_matches_ref():
    q, kf, vf, tables, lengths = _mk_paged(17)
    kc, vc, quant = _quantize_pools(kf, vf)
    y_ref = ref.paged_attention_ref(
        q, kc, vc, tables, lengths, window=5, quant=quant
    )
    y = paged_attention_quant_pallas(
        q, kc, vc, *quant, tables, lengths,
        jnp.full((1,), 5, jnp.int32), interpret=True,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_quant_roundtrip_accuracy():
    # int8 per-row codes should track the fp attention output closely —
    # a sanity bound on quantization noise, not a bit-identity claim
    q, kf, vf, tables, lengths = _mk_paged(23)
    kc, vc, quant = _quantize_pools(kf, vf)
    y_q = ref.paged_attention_ref(q, kc, vc, tables, lengths, quant=quant)
    y_fp = ref.paged_attention_ref(q, kf, vf, tables, lengths)
    np.testing.assert_allclose(np.asarray(y_q), np.asarray(y_fp),
                               rtol=0.15, atol=0.05)


def test_paged_attention_ops_dispatch_quant():
    """ops.paged_attention routes quant pools to the quant kernel and the
    quant oracle; the fp path stays byte-for-byte the historical one."""
    q, kf, vf, tables, lengths = _mk_paged(29)
    kc, vc, quant = _quantize_pools(kf, vf)
    y_ref = ops.paged_attention(q, kc, vc, tables, lengths,
                                backend="ref", quant=quant)
    y_int = ops.paged_attention(q, kc, vc, tables, lengths,
                                backend="interpret", quant=quant)
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    # fp dispatch is unchanged by the quant plumbing
    y_fp_ref = ops.paged_attention(q, kf, vf, tables, lengths, backend="ref")
    y_fp_int = ops.paged_attention(q, kf, vf, tables, lengths,
                                   backend="interpret")
    np.testing.assert_allclose(np.asarray(y_fp_int), np.asarray(y_fp_ref),
                               rtol=2e-5, atol=2e-5)


def test_quantize_kv_rows_zero_rows_roundtrip_exact():
    # unwritten pool pages are all-zero; they must dequantize to exact
    # zeros or page-granular admission would perturb masked-out lanes
    z = jnp.zeros((4, 4, 2, 16), jnp.float32)
    codes, scale, zero = quantize_kv_rows(z, 8)
    assert np.array_equal(np.asarray(codes), np.zeros_like(codes))
    out = dequantize_kv_rows(codes, scale, zero)
    assert np.array_equal(np.asarray(out), np.zeros_like(z))
