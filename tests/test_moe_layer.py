"""MoE layer invariants: dispatch correctness, capacity semantics,
gate-mask (OTP hook) behavior, chunked-rank equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.moe import (
    _rank_within_expert,
    capacity_dispatch,
    combine,
    expert_ffn,
    load_balance_loss,
    route_topk,
)


def test_rank_within_expert_matches_naive():
    rng = np.random.default_rng(0)
    eids = jnp.asarray(rng.integers(0, 5, size=(64,)), jnp.int32)
    rank = np.asarray(_rank_within_expert(eids, 5))
    seen = {}
    for i, e in enumerate(np.asarray(eids)):
        assert rank[i] == seen.get(int(e), 0)
        seen[int(e)] = seen.get(int(e), 0) + 1


def test_rank_chunked_path_equivalent():
    rng = np.random.default_rng(1)
    e = 64
    n = 2**26 // e + 640  # force the chunked path
    eids = jnp.asarray(rng.integers(0, e, size=(n,)), jnp.int32)
    chunked = _rank_within_expert(eids, e)
    # naive path on a prefix
    m = 4096
    small = _rank_within_expert(eids[:m], e)
    np.testing.assert_array_equal(np.asarray(chunked[:m]), np.asarray(small))


@given(
    t=st.integers(4, 24),
    k=st.integers(1, 3),
    e=st.integers(4, 8),
    seed=st.integers(0, 100),
)
@settings(max_examples=15, deadline=None)
def test_dispatch_combine_roundtrip(t, k, e, seed):
    """With ample capacity, dispatch+identity+combine == gate-weighted sum
    of the token itself repeated over its k slots."""
    rng = np.random.default_rng(seed)
    d = 8
    x2 = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)), jnp.int32)
    gates = jax.nn.softmax(jnp.asarray(rng.normal(size=(t, k)), jnp.float32))
    cap = t * k  # ample
    xp, dest, valid, gflat = capacity_dispatch(x2, idx, gates, e, cap)
    assert bool(valid.all())
    y = combine(xp, dest, valid, gflat, t, k)  # identity expert fn
    want = x2 * gates.sum(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_capacity_drop_loses_latest_tokens_only():
    d, e, k = 4, 2, 1
    x2 = jnp.arange(12.0).reshape(3, 4)
    idx = jnp.zeros((3, 1), jnp.int32)  # all to expert 0
    gates = jnp.ones((3, 1))
    xp, dest, valid, gflat = capacity_dispatch(x2, idx, gates, e, capacity=2)
    assert list(np.asarray(valid)) == [True, True, False]
    np.testing.assert_array_equal(np.asarray(xp[0]), np.asarray(x2[0]))
    np.testing.assert_array_equal(np.asarray(xp[1]), np.asarray(x2[1]))


def test_gate_mask_prunes_capacity_and_output():
    rng = np.random.default_rng(2)
    t, k, e, d = 6, 2, 4, 8
    x2 = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)), jnp.int32)
    gates = jax.nn.softmax(jnp.asarray(rng.normal(size=(t, k)), jnp.float32))
    mask = jnp.ones((t, k)).at[:, 1].set(0.0)  # prune the 2nd slot
    xp, dest, valid, gflat = capacity_dispatch(x2, idx, gates, e, 16, mask)
    v = np.asarray(valid).reshape(t, k)
    assert v[:, 1].sum() == 0  # pruned slots occupy no capacity
    y = combine(xp, dest, valid, gflat, t, k)
    want = x2 * np.asarray(gates)[:, :1]
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_route_topk_renormalizes():
    rng = np.random.default_rng(3)
    p = {"w": jnp.asarray(rng.normal(size=(8, 6)), jnp.float32)}
    x2 = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    probs, idx, gates = route_topk(p, x2, 2)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert probs.shape == (5, 6)


def test_load_balance_loss_uniform_is_one():
    t, e, k = 1024, 8, 2
    rng = np.random.default_rng(4)
    probs = jnp.full((t, e), 1.0 / e)
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)), jnp.int32)
    loss = load_balance_loss(probs, idx, e)
    np.testing.assert_allclose(float(loss), 1.0, atol=0.08)


def test_compressed_ep_fallback_warns_and_strict_raises(monkeypatch):
    """A bucket built for a different expert-parallel extent than the
    runtime mesh silently dropped EP (ep=1 fallback); it must warn by
    default and raise under REPRO_STRICT_EP=1 (regression for the silent
    fallback in compressed_expert_ffn)."""
    from repro.core import compressed_moe as cm

    rng = np.random.default_rng(5)
    e, d, f = 3, 16, 16
    experts = {
        "w_gate": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_up": rng.normal(size=(e, d, f)).astype(np.float32),
        "w_down": rng.normal(size=(e, f, d)).astype(np.float32),
    }
    # one 2-bit bucket of 3 experts, built for ep=1
    ce = cm.build_compressed_experts(experts, [2, 2, 2], group=8, ep=1,
                                     refine=False)
    cap = 8
    xp = jnp.asarray(rng.normal(size=(ce.num_slots * cap, d)), jnp.float32)
    y_ok = np.asarray(cm.compressed_expert_ffn(ce, xp, cap))  # ep=1: silent
    # pretend the mesh has a model axis of 2: 3 % 2 != 0 -> fallback
    monkeypatch.setattr(cm, "model_axis_size", lambda: 2)
    monkeypatch.delenv("REPRO_STRICT_EP", raising=False)
    with pytest.warns(RuntimeWarning, match="falling back to ep=1"):
        y_warn = cm.compressed_expert_ffn(ce, xp, cap)
    np.testing.assert_array_equal(np.asarray(y_warn), y_ok)  # math unchanged
    monkeypatch.setenv("REPRO_STRICT_EP", "1")
    with pytest.raises(AssertionError, match="not divisible"):
        cm.compressed_expert_ffn(ce, xp, cap)
    # a cleanly divisible bucket never trips the guard
    ce4 = cm.build_compressed_experts(
        experts, [2, 2, 2], group=8, ep=2, refine=False,
    )  # count padded 3 -> 4: divisible by the fake model axis
    cm.compressed_expert_ffn(
        ce4, jnp.zeros((ce4.num_slots * cap, d), jnp.float32), cap
    )
