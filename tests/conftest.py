"""Pytest bootstrap: make tests/ importable regardless of import mode
(``_hypothesis_compat`` is shared by the property-test modules)."""
import pathlib
import sys

_HERE = str(pathlib.Path(__file__).resolve().parent)
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)
