"""Pytest bootstrap: make tests/ importable regardless of import mode
(``_hypothesis_compat`` is shared by the property-test modules), and
register hypothesis profiles sized for CPU runners.

Profiles (selected via ``HYPOTHESIS_PROFILE``, default ``dev``):

* ``dev`` — a handful of examples; keeps the local tier-1 loop fast.
* ``ci`` — the Actions job's budget: more examples, no deadline (CPU
  runners jit-compile on the first example, which would trip any
  per-example deadline).
"""
import os
import pathlib
import sys

_HERE = str(pathlib.Path(__file__).resolve().parent)
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)

import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Drop jit/pjit executable caches after each test module.

    The suite compiles hundreds of distinct XLA:CPU programs (per-shape
    engines, Pallas interpret traces, dense references); keeping every
    executable alive for the whole session eventually segfaults the
    XLA CPU compiler on small runners. Per-module clearing bounds the
    live-executable set without recompiling within a module.
    """
    yield
    import jax

    jax.clear_caches()


try:
    from hypothesis import HealthCheck, settings

    _COMMON = dict(
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    # dev: fixed examples for a fast, reproducible local loop; ci: fresh
    # draws every run — replaying one frozen example set forever would
    # make the "CI fuzzes the state machine" claim hollow (failures print
    # a @reproduce_failure blob for replay)
    settings.register_profile("dev", max_examples=5, derandomize=True,
                              **_COMMON)
    settings.register_profile("ci", max_examples=25, print_blob=True,
                              **_COMMON)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ModuleNotFoundError:  # property tests skip via _hypothesis_compat
    pass
